"""The paper end-to-end: a MapReduce workflow over the XDT substrate,
declarative workflow DAGs with per-edge transfer routing, per-backend
latency + cost, producer-death recovery, and concurrent workflow requests
under virtual time.

Run:  PYTHONPATH=src python examples/xdt_workflow.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import LoadGenerator, ScalingPolicy, WorkflowEngine
from repro.core.dag import Edge, SizeRoute, Stage, WorkflowDAG
from repro.core.workloads import run_mr, run_set, run_vid


def functional_mapreduce():
    """A real (small) MapReduce on the workflow engine: the shuffle edges
    are XDT put/get, the driver orchestrates, at-most-once is asserted."""
    print("== functional MapReduce over XDT ==")
    M = R = 4
    data = np.arange(64.0)

    wf = WorkflowEngine()

    def mapper(ctx, shard):
        # emit R slices keyed by reducer: each is put() once, pulled once
        parts = np.array_split(np.asarray(shard) * 2.0, R)
        return [ctx.put(jnp.asarray(p), n_retrievals=1) for p in parts]

    def reducer(ctx, refs):
        return float(sum(ctx.get(r).sum() for r in refs))

    def driver(ctx, data):
        shards = np.array_split(data, M)
        ref_matrix = ctx.scatter("mapper", shards)       # M x R refs
        totals = []
        for j in range(R):
            totals.append(ctx.invoke("reducer", [row[j] for row in ref_matrix]))
        return sum(totals)

    wf.register("mapper", mapper)
    wf.register("reducer", reducer)
    wf.register("driver", driver)
    out = wf.run("driver", data)
    expect = float((data * 2).sum())
    assert abs(out - expect) < 1e-6, (out, expect)
    wf.assert_at_most_once()
    print(f"   result {out} == expected {expect}; "
          f"{wf.executed_count('mapper')} mappers, "
          f"{wf.executed_count('reducer')} reducers, all at-most-once")
    st = wf.transfer.registry.stats()
    print(f"   registry: {st.puts} puts, {st.gets} gets, "
          f"{st.bytes_in_use}B leaked (must be 0)")


def producer_death_recovery():
    print("\n== producer-death recovery (paper §4.2.2) ==")
    wf = WorkflowEngine(max_retries=2)
    attempts = []

    def flaky_producer(ctx, x):
        ref = ctx.put(jnp.full((8,), x))
        attempts.append(len(attempts))
        if len(attempts) == 1:           # first instance dies before the pull
            wf.transfer.kill_producer()
        return ctx.invoke("consumer", ref)

    wf.register("flaky_producer", flaky_producer)
    wf.register("consumer", lambda ctx, ref: float(ctx.get(ref).sum()))
    out = wf.run("flaky_producer", 3.0)
    print(f"   survived producer death: result={out}, attempts={len(attempts)} "
          "(orchestrator re-invoked with the original args)")


def declarative_dag_routing():
    """The DAG API end-to-end: declare stages + edges, give each edge its
    own transfer policy (one pinned through S3), execute on the calibrated
    cluster, and read the per-edge cost split — then compile the SAME graph
    onto the event-driven engine and price the run per medium."""
    print("\n== declarative DAG, per-edge transfer routing ==")
    dag = WorkflowDAG(
        "demo",
        stages=[
            Stage("driver", compute_s=0.02, gather_compute_s=0.01),
            Stage("worker", fan=4, compute_s=0.05, blocking=False),
        ],
        edges=[
            # bulk work units: routed per object at send time by SizeRoute
            Edge("driver", "worker", 4 << 20, label="work",
                 handoff="staged", fanout="broadcast", n_objects=2),
            # results must outlive the workers -> pinned through durable S3
            Edge("worker", "driver", 256 << 10, label="result",
                 handoff="staged", route="s3"),
        ],
    )
    run = dag.compile(target="cluster", backend=SizeRoute()).run(
        seed=0, deterministic=True)
    cost = run.cost()
    print(f"   cluster run: {run.latency_s*1e3:.1f}ms, "
          f"compute {cost.compute*1e6:.1f}u$, storage {cost.storage*1e6:.2f}u$")
    for label, row in run.edge_cost_rows().items():
        print(f"     edge {label:>7} -> {run.edge_media[label]:<7} "
              f"{row['bytes']>>10:6d}KB in {row['n_puts']}+{row['n_gets']} ops, "
              f"storage {row['storage_uUSD']:.2f}u$")
    # same declaration, lowered onto the engine (submit/drain, autoscaling)
    eng = WorkflowEngine(backend="xdt")
    binding = dag.compile(target="engine", engine=eng, backend=SizeRoute(),
                          bytes_scale=1e-2)
    eng.run(binding.entry, 1.0)
    eng.assert_at_most_once()
    ecost = binding.cost()
    media = {m: f"{o.n_puts}+{o.n_gets}"
             for m, o in binding.media_storage_ops().items()}
    print(f"   engine run: storage ops per medium {media}, "
          f"storage {ecost.storage*1e6:.2f}u$ (S3 edge priced, XDT free)")


def modeled_workloads():
    print("\n== modeled paper workloads (Fig 7 / Table 2, + hybrid routing) ==")
    for name, fn in [("VID", run_vid), ("SET", run_set), ("MR", run_mr)]:
        rows = {b: fn(b, seed=0) for b in ("s3", "elasticache", "xdt", "hybrid")}
        x = rows["xdt"]
        print(f"   {name}: XDT {x.latency_s:.3f}s | "
              f"speedup vs S3 {rows['s3'].latency_s/x.latency_s:.2f}x, "
              f"vs EC {rows['elasticache'].latency_s/x.latency_s:.2f}x | "
              f"cost {x.cost.total*1e6:.0f}u$ vs S3 "
              f"{rows['s3'].cost.total*1e6:.0f}u$, EC "
              f"{rows['elasticache'].cost.total*1e6:.0f}u$")
        h = rows["hybrid"]
        media = ", ".join(f"{e}:{m}" for e, m in h.edge_media.items())
        print(f"        hybrid: {h.latency_s:.3f}s, {h.cost.total*1e6:.0f}u$ "
              f"[{media}]")


def concurrent_requests_under_load():
    """Event-driven engine: overlapping requests, autoscaling, p50/p99 under
    a closed-loop load sweep — all in virtual time."""
    print("\n== concurrent workflows under virtual time ==")
    for backend in ("xdt", "s3"):
        eng = WorkflowEngine(backend=backend)
        eng.register(
            "worker", lambda ctx, ref: float(ctx.get(ref).sum()),
            policy=ScalingPolicy(max_instances=32, target_concurrency=1),
            service_time=0.02,
        )

        def entry(ctx, i):
            refs = [ctx.put(jnp.full((512,), float(i)), n_retrievals=1)
                    for _ in range(4)]
            outs = yield ctx.scatter_async("worker", refs)  # overlapping fan-out
            return sum(outs)

        eng.register("entry", entry,
                     policy=ScalingPolicy(max_instances=32), service_time=0.01)
        rep = LoadGenerator(eng, "entry").run_closed(
            n_clients=8, requests_per_client=4, think_time_s=0.01
        )
        dep = eng.control.deployments["worker"]
        print(f"   {backend:>4}: {rep.n_ok} req, p50 {rep.p50_s*1e3:.1f}ms, "
              f"p99 {rep.p99_s*1e3:.1f}ms, {rep.achieved_rps:.1f} rps, "
              f"${rep.usd_per_1k_requests:.4f}/1k req, "
              f"{dep.stats['cold_starts']} cold starts")


if __name__ == "__main__":
    functional_mapreduce()
    producer_death_recovery()
    declarative_dag_routing()
    concurrent_requests_under_load()
    modeled_workloads()
    print("\nxdt_workflow OK")
