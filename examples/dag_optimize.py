"""The graph optimizer end-to-end: declare a workflow DAG, optimize it
(fusion / co-placement / predictive spill), and run the optimized graph on
both lowerings — the calibrated cluster simulator and the event-driven
workflow engine.

Run:  PYTHONPATH=src python examples/dag_optimize.py
"""
from repro.core import WorkflowEngine
from repro.core.dag import SizeRoute
from repro.core.telemetry import TelemetryHub
from repro.core.workloads import DAGS


def optimize_and_compare():
    """dag.optimize() before compile: fused chains delete their transfer
    outright, co-placed consumers pull through shared memory."""
    print("== optimize() -> compile(target='cluster') ==")
    for name in ("vid", "set", "mr"):
        dag = DAGS[name]
        opt_dag, plan = dag.optimize()          # fuse + coplace (+ spill)
        print(f"   {name}: {plan.describe()}")
        for backend in ("s3", "xdt"):
            base = dag.compile(target="cluster", backend=backend).run(
                seed=0, deterministic=True)
            run = opt_dag.compile(
                target="cluster", backend=backend, plan=plan
            ).run(seed=0, deterministic=True)
            n_local = sum(u.n_local for u in run.edge_usage.values())
            print(f"      {backend:4s} {base.latency_s*1e3:7.1f}ms -> "
                  f"{run.latency_s*1e3:7.1f}ms, "
                  f"{base.cost().total*1e6:7.1f} -> "
                  f"{run.cost().total*1e6:7.1f}uUSD"
                  f"{f', {n_local} local pulls' if n_local else ''}")


def optimize_and_bind():
    """The same plan on the engine lowering: steering honors the affinity
    hints, honored pulls are modeled at shared-memory speed."""
    print("\n== optimize() -> compile(target='engine') ==")
    opt_dag, plan = DAGS["vid"].optimize()
    eng = WorkflowEngine(backend="xdt")
    binding = opt_dag.compile(target="engine", engine=eng,
                              backend=SizeRoute(), bytes_scale=1e-4,
                              plan=plan)
    for _ in range(4):                          # warm fleets between requests
        eng.run(binding.entry, 1.0)
    eng.assert_at_most_once()
    dep = eng.control.deployments["vid.recognition"]
    print(f"   4 requests: {eng.transfer.stats.local_pulls} shared-memory "
          f"pulls, {dep.stats['affine_hits']} affine steers, "
          f"{binding.edge_usage['frames'].n_local} local frames fetches")


def predictive_spill():
    """Feed the optimizer a telemetry hub whose reap window says the
    producer fleet will not outlive its consumers' pulls: the staged edge
    is rewritten durable, and a producer death no longer costs a retry."""
    print("\n== predictive spill from the reap window ==")
    t = [0.0]
    hub = TelemetryHub(lambda: t[0])
    for i in range(20):                         # observed history
        t[0] = i * 0.05
        hub.deployment("driver").record_reap(t[0])
        hub.deployment("trainer").record_arrival(t[0], 0)
        hub.deployment("trainer").record_cold_start(t[0])
    opt_dag, plan = DAGS["set"].optimize(telemetry=hub)
    print(f"   set: {plan.describe()}")
    for note in plan.notes:
        if note.startswith("spill:"):
            print(f"     {note}")


if __name__ == "__main__":
    optimize_and_compare()
    optimize_and_bind()
    predictive_spill()
    print("\ndag_optimize OK")
