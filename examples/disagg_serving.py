"""Disaggregated prefill/decode serving with the XDT cache handoff —
the paper's architecture applied to LLM serving, end to end.

A prefill pod computes each request's KV cache (the ephemeral object),
``put``s it, and the control plane steers the request to a decode pod that
``get``s (pulls) the cache directly.  The same run is repeated with the
through-storage ("staged") handoff; generations must be identical, and the
report shows the modeled latency/cost gap.

Run:  PYTHONPATH=src python examples/disagg_serving.py [--arch smollm_360m]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.cost import elasticache_storage_cost, s3_storage_cost
from repro.models import init_params
from repro.serving import DisaggregatedServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--decode-pods", type=int, default=2)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=rng.integers(4, 10))
               for _ in range(args.requests)]

    runs = {}
    for backend in ("xdt", "staged"):
        srv = DisaggregatedServer(cfg, params, n_decode_pods=args.decode_pods,
                                  max_batch=4, max_len=48, backend=backend)
        t0 = time.time()
        rids = [srv.submit(p, max_new_tokens=args.new_tokens) for p in prompts]
        done = srv.run_until_drained()
        wall = time.time() - t0
        runs[backend] = {"gen": {r: done[r].generated for r in rids},
                         "report": srv.handoff_report(), "wall": wall}
        print(f"[{backend:6s}] served {len(done)} requests in {wall:.1f}s "
              f"across {args.decode_pods} decode pods")

    assert runs["xdt"]["gen"] == runs["staged"]["gen"], "generations diverged!"
    print("\ngenerations identical across backends (API-preserving) ✓")

    rep = runs["xdt"]["report"]
    cache_b = rep["avg_cache_bytes"]
    print(f"\nper-handoff ephemeral object (KV/state cache): {cache_b/1024:.1f} KB")
    print(f"modeled handoff latency:  xdt={rep['modeled_latency_s_if_xdt']*1e3:7.2f}ms"
          f"  ec={rep['modeled_latency_s_if_elasticache']*1e3:7.2f}ms"
          f"  s3={rep['modeled_latency_s_if_s3']*1e3:7.2f}ms")
    n = rep["handoffs"]
    s3_fee = s3_storage_cost(int(n), int(n)) * 1e6
    ec_fee = elasticache_storage_cost(cache_b * n / 1e9) * 1e6
    print(f"storage bill for {n:.0f} handoffs: xdt=0.0u$  s3={s3_fee:.2f}u$  "
          f"ec={ec_fee:.2f}u$ (provisioned GB-hour)")
    print("\nAt production KV sizes (10s of MB-GBs per request) these gaps "
          "are the paper's 1.3-3.4x / 2-772x headline numbers.")


if __name__ == "__main__":
    main()
