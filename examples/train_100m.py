"""End-to-end training driver: a ~100M-parameter smollm-family model with
the full production stack — sharded deterministic loader, XDT-mediated
prefetch, fused AdamW step, async atomic checkpoints, crash-resume.

Quick CPU demo (a ~7M reduced model, 60 steps, <2 min):

    PYTHONPATH=src python examples/train_100m.py

The real thing (~100M params, 300 steps — sized for a single accelerator
host; on CPU budget about an hour):

    PYTHONPATH=src python examples/train_100m.py --full --steps 300
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config, smoke_config
from repro.data import ShardedLoader
from repro.data.prefetch import PrefetchingFeed
from repro.models import init_params
from repro.optim import OptConfig
from repro.train import Trainer, TrainerConfig


def model_config(full: bool):
    if full:
        # ~100M-parameter member of the smollm family (paper-exact shapes
        # scaled in depth/width; vocab kept small so params go to the body)
        cfg = dataclasses.replace(
            get_config("smollm_360m"),
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=2048, vocab=8192, head_dim=64, attn_chunk=128,
            loss_chunk=128,
        )
    else:
        cfg = dataclasses.replace(
            smoke_config("smollm_360m"),
            n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
            vocab=512, head_dim=32,
        )
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--workdir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    cfg = model_config(args.full)
    steps = args.steps or (300 if args.full else 60)
    batch = args.batch or (8 if args.full else 8)
    seq = args.seq or (512 if args.full else 64)

    n_params = cfg.n_params()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"{steps} steps x {batch}x{seq} tokens")

    params = init_params(cfg, jax.random.PRNGKey(0))
    loader = ShardedLoader(cfg, global_batch=batch, seq_len=seq)
    feed = PrefetchingFeed(loader.batch_at, depth=2)   # XDT-mediated prefetch

    lr = 1e-3 if args.full else 3e-3
    trainer = Trainer(
        cfg, params, mesh=None,
        opt_cfg=OptConfig(peak_lr=lr, warmup_steps=max(5, steps // 20),
                          total_steps=steps),
        tcfg=TrainerConfig(steps=steps, checkpoint_every=max(10, steps // 6),
                           log_every=max(1, steps // 12), remat="none"),
        workdir=args.workdir,
        batch_at=feed.get_batch,
    )
    t0 = time.time()
    try:
        out = trainer.run()
    finally:
        feed.close()
    dt = time.time() - t0
    tok_s = steps * batch * seq / dt
    print(f"\ndone: step {out['final_step']}  final loss {out['final_loss']:.4f}  "
          f"({dt:.0f}s, {tok_s:.0f} tok/s)")
    first = out["log"][0]["loss"]
    print(f"loss {first:.3f} -> {out['final_loss']:.3f} "
          f"({'improved' if out['final_loss'] < first else 'NOT improved'})")
    print(f"checkpoints in {args.workdir} (resume by re-running)")


if __name__ == "__main__":
    main()
