"""Streaming edges end-to-end: overlap transfer with compute, data-triggered
consumers, per-chunk routing, and mid-stream spill when the producer's reap
window closes in.

A streaming edge (``Edge(streaming=True, chunk_bytes=...)``) turns a
store-then-fetch handoff into a pipeline: the producer publishes fixed-size
chunks *while still computing*, the consumer fires on the first chunk and
pulls the rest as they land, and only the tail that outlives the producer's
compute is ever waited on.  Route policies resolve per chunk, so one
logical object may legitimately split across media.

Run:  PYTHONPATH=src python examples/streaming_pipeline.py
"""
import dataclasses

from repro.core import TelemetryHub, WorkflowEngine
from repro.core.dag import (
    Edge,
    FixedRoute,
    Stage,
    WorkflowDAG,
    critical_path_lower_bound,
)
from repro.core.dagopt import OnlineSpill
from repro.core.workloads import DAGS

MB = 1 << 20


def streamed(dag, labels, chunk_bytes=1 * MB):
    """``dag`` with the named edges switched to streaming."""
    edges = [
        dataclasses.replace(e, streaming=True, chunk_bytes=chunk_bytes)
        if e.label in labels else e
        for e in dag.edges
    ]
    return WorkflowDAG(dag.name, dag.stages, edges)


def overlap_on_the_cluster():
    """The paper workloads with streaming intermediates: makespan closes
    most of the gap between store-then-fetch and the critical-path bound
    (perfect overlap — data must still be produced AND moved)."""
    print("== streaming vs store-then-fetch vs the bound (cluster) ==")
    for name, labels in (("vid", ("fragment", "frames")),
                         ("mr", ("shuffle",))):
        dag = DAGS[name]
        for backend in ("s3", "xdt"):
            base = dag.compile(target="cluster", backend=backend).run(
                seed=0, deterministic=True)
            run = streamed(dag, labels).compile(
                target="cluster", backend=backend,
            ).run(seed=0, deterministic=True)
            bound = critical_path_lower_bound(dag, backend=backend)
            print(f"   {name}/{backend:>3}: {base.latency_s:6.3f}s -> "
                  f"{run.latency_s:6.3f}s  (bound {bound:6.3f}s, "
                  f"ratio {run.latency_s / bound:5.3f}x)")


def data_triggered_on_the_engine():
    """The same declaration on the event-driven engine: real chunk events
    on the virtual clock.  The consumer is spawned when the first chunk
    lands — no orchestration round-trip after the producer finishes — and
    the per-chunk requests still bill as ONE put + ONE ranged get."""
    print("\n== data-triggered activation (event-driven engine) ==")
    dag = WorkflowDAG(
        "pipe",
        [Stage("produce", compute_s=0.8), Stage("consume", compute_s=0.05)],
        [Edge("produce", "consume", 8 * MB, label="feed", handoff="sync")],
    )
    for variant, d in (("store-then-fetch", dag),
                       ("streaming 1MB", streamed(dag, ("feed",)))):
        eng = WorkflowEngine(backend="xdt")
        binding = d.compile(target="engine", engine=eng,
                            backend=FixedRoute("xdt"))
        eng.run(binding.entry, 1.0)
        (req,) = eng.requests
        u = binding.edge_usage["feed"]
        print(f"   {variant:>16}: {req.latency_s:6.3f}s, "
              f"{u.n_puts} put + {u.n_gets} get, media {dict(u.media)}")


def spill_mid_stream():
    """Online spill: the producer's predicted reap window closes between
    chunks, so the REMAINING chunks of the live stream divert to durable
    S3 while the already-published ones stay on the fast path — one
    object, two media, zero retries."""
    print("\n== OnlineSpill: reap window closes mid-stream ==")
    hub = TelemetryHub(lambda: 0.0)

    class Feed:                       # a producer deployment predicted to
        def expected_instance_lifetime_s(self, now):   # live ~1s more
            return 1.0

    hub.deployments["produce"] = Feed()
    dag = streamed(WorkflowDAG(
        "pipe",
        [Stage("produce", compute_s=1.0), Stage("consume", compute_s=0.05)],
        [Edge("produce", "consume", 8 * MB, label="feed", handoff="sync")],
    ), ("feed",))
    sp = OnlineSpill(hub, durable="s3")
    run = dag.compile(target="cluster", backend="xdt",
                      online_spill=sp).run(seed=0, deterministic=True)
    media = run.edge_usage["feed"].media
    print(f"   {len(sp.spills)} of {len(dag.edges[0].chunk_sizes())} chunks "
          f"spilled durable; the object now spans {sorted(media)} "
          f"({run.latency_s*1e3:.0f}ms)")
    for label, from_medium, at_s, eta_s in sp.spills[:3]:
        print(f"     chunk of {label!r} at t={at_s:.3f}s: predicted pull "
              f"eta {eta_s:.3f}s outlives the producer -> s3")


def backpressured_stream():
    """Credit-based backpressure: ``Edge(max_inflight_chunks=w)`` caps the
    producer at ``w`` published-but-undrained instance-resident chunks.
    A zero-compute producer would otherwise burst the whole object into
    memory before the consumer pulls once; with credits the peak resident
    footprint is provably ``<= w x chunk_bytes``.  Add
    ``OnlineSpill(pressure_patience=k)`` and a persistently empty window
    diverts the REMAINING stream durable instead of stalling forever."""
    print("\n== credit backpressure: bounded sender memory ==")
    dag = WorkflowDAG(
        "pipe",
        [Stage("produce", compute_s=0.0), Stage("consume", compute_s=0.05)],
        [Edge("produce", "consume", 8 * MB, label="feed", handoff="sync")],
    )

    def cell(label, variant, spill=None):
        eng = WorkflowEngine(backend="xdt")
        binding = variant.compile(target="engine", engine=eng,
                                  backend=FixedRoute("xdt"),
                                  online_spill=spill)
        eng.run(binding.entry, 1.0)
        peak = eng.transfer.stats.peak_inflight_chunk_bytes
        media = dict(binding.edge_usage["feed"].media)
        print(f"   {label:>22}: peak inflight {peak / MB:4.1f} MB, "
              f"media {media}")

    cell("unbounded", streamed(dag, ("feed",)))
    window = dataclasses.replace(
        streamed(dag, ("feed",)).edges[0], max_inflight_chunks=2)
    cell("window=2", WorkflowDAG(dag.name, dag.stages, [window]))
    sp = OnlineSpill(TelemetryHub(lambda: 0.0), durable="s3",
                     pressure_patience=2)
    cell("window=2 + pressure", WorkflowDAG(dag.name, dag.stages, [window]),
         spill=sp)
    print(f"     pressure spill fired {len(sp.pressure_spills)}x: a "
          "persistently empty window sends the tail durable")


def auto_tuned_chunks():
    """Telemetry-tuned chunk size: ``chunk_bytes=\"auto\"`` scores the
    candidate sizes per (edge, medium) with the analytic streamed-pull
    recurrence as prior — and the TelemetryHub's observed latency-vs-size
    model once it has enough samples — then re-scores the remaining bytes
    whenever a mid-stream route decision lands on a new medium."""
    print("\n== chunk_bytes=\"auto\": telemetry-tuned sizing ==")
    dag = WorkflowDAG(
        "pipe",
        [Stage("produce", compute_s=0.8), Stage("consume", compute_s=0.05)],
        [Edge("produce", "consume", 8 * MB, label="feed", handoff="sync")],
    )
    for backend in ("s3", "xdt"):
        rows = []
        for label, variant in (
            ("1MB", streamed(dag, ("feed",), chunk_bytes=1 * MB)),
            ("4MB", streamed(dag, ("feed",), chunk_bytes=4 * MB)),
            ("auto", streamed(dag, ("feed",), chunk_bytes="auto")),
        ):
            run = variant.compile(target="cluster", backend=backend).run(
                seed=0, deterministic=True)
            rows.append(f"{label} {run.latency_s * 1e3:6.1f}ms")
        print(f"   {backend:>3}: " + "  ".join(rows)
              + "   (auto ties or beats the best fixed size)")


if __name__ == "__main__":
    overlap_on_the_cluster()
    data_triggered_on_the_engine()
    spill_mid_stream()
    backpressured_stream()
    auto_tuned_chunks()
    print("\nstreaming_pipeline OK")
