"""Streaming edges end-to-end: overlap transfer with compute, data-triggered
consumers, per-chunk routing, and mid-stream spill when the producer's reap
window closes in.

A streaming edge (``Edge(streaming=True, chunk_bytes=...)``) turns a
store-then-fetch handoff into a pipeline: the producer publishes fixed-size
chunks *while still computing*, the consumer fires on the first chunk and
pulls the rest as they land, and only the tail that outlives the producer's
compute is ever waited on.  Route policies resolve per chunk, so one
logical object may legitimately split across media.

Run:  PYTHONPATH=src python examples/streaming_pipeline.py
"""
import dataclasses

from repro.core import TelemetryHub, WorkflowEngine
from repro.core.dag import (
    Edge,
    FixedRoute,
    Stage,
    WorkflowDAG,
    critical_path_lower_bound,
    execute_on_cluster,
)
from repro.core.dagopt import OnlineSpill
from repro.core.workloads import DAGS

MB = 1 << 20


def streamed(dag, labels, chunk_bytes=1 * MB):
    """``dag`` with the named edges switched to streaming."""
    edges = [
        dataclasses.replace(e, streaming=True, chunk_bytes=chunk_bytes)
        if e.label in labels else e
        for e in dag.edges
    ]
    return WorkflowDAG(dag.name, dag.stages, edges)


def overlap_on_the_cluster():
    """The paper workloads with streaming intermediates: makespan closes
    most of the gap between store-then-fetch and the critical-path bound
    (perfect overlap — data must still be produced AND moved)."""
    print("== streaming vs store-then-fetch vs the bound (cluster) ==")
    for name, labels in (("vid", ("fragment", "frames")),
                         ("mr", ("shuffle",))):
        dag = DAGS[name]
        for backend in ("s3", "xdt"):
            base = execute_on_cluster(dag, backend, seed=0,
                                      deterministic=True)
            run = execute_on_cluster(streamed(dag, labels), backend,
                                     seed=0, deterministic=True)
            bound = critical_path_lower_bound(dag, backend=backend)
            print(f"   {name}/{backend:>3}: {base.latency_s:6.3f}s -> "
                  f"{run.latency_s:6.3f}s  (bound {bound:6.3f}s, "
                  f"ratio {run.latency_s / bound:5.3f}x)")


def data_triggered_on_the_engine():
    """The same declaration on the event-driven engine: real chunk events
    on the virtual clock.  The consumer is spawned when the first chunk
    lands — no orchestration round-trip after the producer finishes — and
    the per-chunk requests still bill as ONE put + ONE ranged get."""
    print("\n== data-triggered activation (event-driven engine) ==")
    dag = WorkflowDAG(
        "pipe",
        [Stage("produce", compute_s=0.8), Stage("consume", compute_s=0.05)],
        [Edge("produce", "consume", 8 * MB, label="feed", handoff="sync")],
    )
    for variant, d in (("store-then-fetch", dag),
                       ("streaming 1MB", streamed(dag, ("feed",)))):
        eng = WorkflowEngine(backend="xdt")
        binding = d.bind(eng, default_route=FixedRoute("xdt"))
        eng.run(binding.entry, 1.0)
        (req,) = eng.requests
        u = binding.edge_usage["feed"]
        print(f"   {variant:>16}: {req.latency_s:6.3f}s, "
              f"{u.n_puts} put + {u.n_gets} get, media {dict(u.media)}")


def spill_mid_stream():
    """Online spill: the producer's predicted reap window closes between
    chunks, so the REMAINING chunks of the live stream divert to durable
    S3 while the already-published ones stay on the fast path — one
    object, two media, zero retries."""
    print("\n== OnlineSpill: reap window closes mid-stream ==")
    hub = TelemetryHub(lambda: 0.0)

    class Feed:                       # a producer deployment predicted to
        def expected_instance_lifetime_s(self, now):   # live ~1s more
            return 1.0

    hub.deployments["produce"] = Feed()
    dag = streamed(WorkflowDAG(
        "pipe",
        [Stage("produce", compute_s=1.0), Stage("consume", compute_s=0.05)],
        [Edge("produce", "consume", 8 * MB, label="feed", handoff="sync")],
    ), ("feed",))
    sp = OnlineSpill(hub, durable="s3")
    run = execute_on_cluster(dag, "xdt", seed=0, deterministic=True,
                             online_spill=sp)
    media = run.edge_usage["feed"].media
    print(f"   {len(sp.spills)} of {len(dag.edges[0].chunk_sizes())} chunks "
          f"spilled durable; the object now spans {sorted(media)} "
          f"({run.latency_s*1e3:.0f}ms)")
    for label, from_medium, at_s, eta_s in sp.spills[:3]:
        print(f"     chunk of {label!r} at t={at_s:.3f}s: predicted pull "
              f"eta {eta_s:.3f}s outlives the producer -> s3")


if __name__ == "__main__":
    overlap_on_the_cluster()
    data_triggered_on_the_engine()
    spill_mid_stream()
    print("\nstreaming_pipeline OK")
