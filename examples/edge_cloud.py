"""The edge-cloud continuum end-to-end: declare a topology, compare flat
vs tier-aware placement on the cluster lowering, then run the same DAG on
the event-driven engine where zone crossings cost real (virtual) time and
egress dollars.

One API drives everything: ``dag.compile(target="cluster"|"engine",
topology=...)``.  A run without a topology — or with a single-zone one —
is bit-identical to the flat paper cluster.

Run:  PYTHONPATH=src python examples/edge_cloud.py
"""
from repro.core import WorkflowEngine
from repro.core.topology import Coord, Topology, Zone
from repro.core.workloads import (
    EDGE_CLOUD_TOPOLOGY,
    EDGE_DAG,
    TOPO_DAGS,
    TOPO_WORKLOADS,
    TOPOLOGIES,
)


def declare_a_topology():
    """node -> zone -> region (-> edge-site); workload pins name the zones
    a stage's instances must spread across."""
    print("== the hierarchy ==")
    t = Topology(
        zones=(
            Zone("edge-a", region="site-a", site="edge"),
            Zone("us-1", region="us"),
            Zone("us-2", region="us"),
            Zone("eu-1", region="eu"),
        ),
        pin={"camera": ("edge-a",)},
    )
    pairs = (("us-1", "us-1"), ("us-1", "us-2"), ("us-1", "eu-1"),
             ("us-1", "edge-a"))
    for a, b in pairs:
        lv = t.crossing(t.zone_index[a], t.zone_index[b])
        print(f"   {a:>6} -> {b:<6} crossing level {lv} "
              f"({'free' if lv <= 1 else 'billed + tier link'})")


def flat_vs_tier_aware():
    """EDGE: four ingest sites pinned at the edge, trainer pinned to the
    cloud.  Naive round-robin drops the unpinned collector on edge-0;
    dag.optimize(topology=..., backend=...) homes it in the cloud."""
    print("\n== flat vs tier-aware placement (cluster lowering) ==")
    for name, fn in TOPO_WORKLOADS.items():
        topo = TOPOLOGIES[name]
        for backend in ("s3", "xdt"):
            _, plan = TOPO_DAGS[name].optimize(topology=topo, backend=backend)
            flat = fn(backend, seed=0, deterministic=True)
            aware = fn(backend, seed=0, deterministic=True, plan=plan)
            zones = ", ".join(f"{s}->{z}" for s, z in plan.zones.items())
            print(f"   {name}/{backend:>3}: {flat.latency_s:6.3f}s -> "
                  f"{aware.latency_s:6.3f}s  egress "
                  f"{flat.cost.egress*1e6:6.1f} -> "
                  f"{aware.cost.egress*1e6:6.1f}uUSD  [{zones}]")


def continuum_on_the_engine():
    """The same topology on the event-driven engine: the placer embeds the
    zone in every instance's coords, cross-zone pulls sleep the tier link
    and accrue egress on the binding, and steering falls back to any
    same-zone instance when the exact preferred node is busy."""
    print("\n== the engine lowering: placement debt on the virtual clock ==")
    for topology, label in ((None, "flat"),
                            (EDGE_CLOUD_TOPOLOGY, "edge-cloud")):
        eng = WorkflowEngine(backend="xdt")
        binding = EDGE_DAG.compile(
            target="engine", engine=eng, topology=topology, bytes_scale=1e-2,
        )
        eng.run(binding.entry, 1.0)
        eng.assert_at_most_once()
        (req,) = eng.requests
        zones = sorted({
            inst.coords.zone
            for dep in eng.control.deployments.values()
            for inst in dep.instances.values()
            if getattr(inst.coords, "zone", None) is not None
        })
        print(f"   {label:>10}: {req.latency_s:6.3f}s, egress "
              f"{binding.egress_usd*1e6:6.1f}uUSD, zones {zones or ['-']}")


def typed_coords_everywhere():
    """Coord IS its tuple — hash/equality unchanged — so the control-plane
    surfaces take either spelling; a Coord carrying a zone unlocks the
    same-zone steering fallback."""
    print("\n== Coord at the control surfaces ==")
    c = EDGE_CLOUD_TOPOLOGY.coord((4, 0), 4)      # zone index 4 = "cloud"
    print(f"   coord {tuple(c)} == plain tuple: {c == (4, 0)}; "
          f"path {c.path}")
    print(f"   Coord((1,)) and (1,) hash alike: "
          f"{hash(Coord((1,))) == hash((1,))}")


if __name__ == "__main__":
    declare_a_topology()
    flat_vs_tier_aware()
    continuum_on_the_engine()
    typed_coords_everywhere()
    print("\nedge_cloud OK")
