"""Quickstart: the XDT substrate + a model in under a minute (CPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import TransferEngine, WorkflowEngine, modeled_transfer_seconds
from repro.data import ShardedLoader
from repro.models import init_params
from repro.optim import OptConfig, adamw_init
from repro.serving import ServingEngine
from repro.train import make_train_step


def demo_xdt_api():
    """The paper's Table 1 API: invoke / put / get over real jax.Arrays."""
    print("== 1. XDT API ==")
    eng = TransferEngine("xdt")
    obj = jnp.arange(1 << 20, dtype=jnp.float32)        # 4 MB ephemeral object

    ref = eng.put(obj, n_retrievals=2)                   # buffer + mint ref
    print(f"   put 4MB -> opaque ref: {ref!r}")
    pulled = eng.get(ref)                                # consumer pulls
    assert bool((pulled == obj).all())
    print(f"   get -> {pulled.nbytes} bytes, modeled latency "
          f"{modeled_transfer_seconds('xdt', obj.nbytes)*1e3:.2f}ms "
          f"(S3 would be {modeled_transfer_seconds('s3', obj.nbytes)*1e3:.2f}ms)")

    out = eng.invoke(lambda x: x.sum(), obj)             # blocking 1-1 call
    print(f"   invoke(sum) = {float(out):.3e}")


def demo_workflow():
    """A two-function workflow with producer-death recovery."""
    print("\n== 2. Workflow engine ==")
    wf = WorkflowEngine()
    wf.register("square", lambda ctx, x: x * x)
    wf.register("entry", lambda ctx, x: ctx.invoke("square", x + 1))
    print(f"   run(entry, 6) = {wf.run('entry', 6)}")
    wf.assert_at_most_once()


def demo_training():
    print("\n== 3. Train a (reduced) smollm-360m for 20 steps ==")
    cfg = smoke_config("smollm_360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    loader = ShardedLoader(cfg, global_batch=8, seq_len=32)
    step = make_train_step(cfg, None,
                           OptConfig(peak_lr=3e-3, warmup_steps=2, total_steps=20),
                           remat="none", donate=False)
    opt = adamw_init(params)
    for i in range(20):
        params, opt, m = step(params, opt, loader.batch_at(i))
        if i % 5 == 0 or i == 19:
            print(f"   step {i:3d}  loss={float(m['loss']):.4f}")
    return params, cfg


def demo_serving(cfg, params):
    print("\n== 4. Serve it (continuous batching) ==")
    eng = ServingEngine(cfg, params, max_batch=2, max_len=48)
    rids = [eng.submit(np.arange(1, 6) + i, max_new_tokens=8) for i in range(3)]
    done = eng.run_until_drained()
    for rid in rids:
        print(f"   request {rid}: generated {done[rid].generated}")


if __name__ == "__main__":
    demo_xdt_api()
    demo_workflow()
    params, cfg = demo_training()
    demo_serving(cfg, params)
    print("\nquickstart OK")
