"""Chaos harness: fault plans, injectors, SLO guardrails, both lowerings."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AdaptiveRoute,
    DegradedBackend,
    Edge,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    FixedRoute,
    LoadGenerator,
    MediumUnavailable,
    RetriesExhausted,
    SLOGuard,
    SLOViolation,
    SizeRoute,
    Stage,
    WorkflowDAG,
    WorkflowEngine,
)
from repro.core.cost import (
    WorkflowCostInputs,
    combine_cost_inputs,
    tenant_bills,
    workflow_cost,
)
from repro.core.dag import execute_on_cluster
from repro.core.workloads import DAGS

BYTES_SCALE = 1e-2


def _dag() -> WorkflowDAG:
    """The fig12 probe shape: expensive producers, tiny staged objects."""
    return WorkflowDAG(
        "res",
        [
            Stage("driver", compute_s=0.01),
            Stage("producer", fan=2, compute_s=0.5, blocking=False),
            Stage("consumer", fan=2, compute_s=0.02, blocking=False),
        ],
        [
            Edge("driver", "producer", 16 << 10, label="task",
                 handoff="staged", fanout="broadcast",
                 latency_budget_s=0.06),
            Edge("producer", "consumer", 64 << 10, label="data",
                 handoff="staged", fanout="partition",
                 latency_budget_s=0.06),
        ],
    )


def _run_staggered(eng, binding, n, gap_s):
    for i in range(n):
        eng.sim.schedule_abs(i * gap_s, lambda: eng.submit(binding.entry, 1.0))
    eng.drain()


# --------------------------------------------------------------- plan shape


def test_fault_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent("meteor", at_s=0.0)
    with pytest.raises(ValueError, match=">= 0"):
        FaultEvent("evict", at_s=-1.0)
    with pytest.raises(ValueError, match="medium"):
        FaultEvent("degrade", at_s=0.0, duration_s=1.0)
    with pytest.raises(ValueError, match="medium"):
        FaultEvent("degrade", at_s=0.0, duration_s=1.0, medium="floppy")
    with pytest.raises(ValueError, match="error_rate"):
        FaultEvent("degrade", at_s=0.0, duration_s=1.0, medium="s3",
                   error_rate=1.5)
    with pytest.raises(ValueError, match="slowdown"):
        FaultEvent("degrade", at_s=0.0, duration_s=1.0, medium="s3",
                   slowdown=0.5)
    with pytest.raises(ValueError, match="duration_s"):
        FaultEvent("degrade", at_s=0.0, medium="s3")
    with pytest.raises(ValueError, match="cold_start_multiplier"):
        FaultEvent("storm", at_s=0.0, duration_s=1.0,
                   cold_start_multiplier=0.1)


def test_fault_plan_sorts_queries_and_is_falsy_when_empty():
    assert not FaultPlan()
    assert len(FaultPlan()) == 0
    plan = FaultPlan(
        [
            FaultEvent("degrade", at_s=2.0, duration_s=1.0, medium="s3",
                       slowdown=4.0, error_rate=0.25),
            FaultEvent("evict", at_s=0.5),
        ],
        seed=3,
    )
    assert plan and len(plan) == 2
    assert [e.kind for e in plan] == ["evict", "degrade"]  # sorted by at_s
    assert plan.has_evictions()
    assert plan.slowdown_at("s3", 2.5) == 4.0
    assert plan.slowdown_at("s3", 3.0) == 1.0        # window is half-open
    assert plan.slowdown_at("xdt", 2.5) == 1.0       # other media untouched
    assert plan.error_rate_at("s3", 2.5) == 0.25
    assert plan.error_rate_at("s3", 1.0) == 0.0
    # replays draw from a fresh seeded RNG every time
    assert plan.rng().random() == plan.rng().random()


def test_scenario_builders_cover_the_fig12_axis():
    storm = FaultPlan.eviction_storm(at_s=1.0, n_evictions=3, spacing_s=0.5)
    assert [e.at_s for e in storm] == [1.0, 1.5, 2.0]
    assert all(e.kind == "evict" for e in storm)
    throttle = FaultPlan.medium_throttle(medium="s3", slowdown=4.0,
                                         error_rate=0.3)
    assert throttle.events[0].error_rate == 0.3
    blackout = FaultPlan.medium_blackout(medium="elasticache")
    assert blackout.events[0].error_rate == 1.0
    cold = FaultPlan.cold_start_storm(multiplier=8.0, max_instances_cap=2)
    assert cold.events[0].cold_start_multiplier == 8.0


# --------------------------------------------------- zero-cost when unused


def test_empty_plan_installs_nothing_and_is_bit_identical():
    def run(with_harness: bool):
        eng = WorkflowEngine(backend="xdt", max_retries=2)
        binding = _dag().bind(eng, default_route=SizeRoute(),
                              bytes_scale=BYTES_SCALE)
        if with_harness:
            inj = FaultInjector(eng, FaultPlan()).install()
            assert not inj.installed
            assert eng.transfer._fault_penalty is None
            assert eng.transfer._fast_single_owner  # fused paths untouched
        _run_staggered(eng, binding, 3, 0.5)
        return (
            sum(lat for _, lat in eng.latency_records()),
            binding.cost().total,
        )

    assert run(False) == run(True)      # exact equality, no tolerance


def test_empty_plan_cluster_lowering_bit_identical():
    bare = execute_on_cluster(DAGS["mr"], "xdt", seed=0, deterministic=True)
    planned = execute_on_cluster(
        DAGS["mr"], "xdt", seed=0, deterministic=True, fault_plan=FaultPlan()
    )
    assert planned.latency_s == bare.latency_s
    assert planned.cost().total == bare.cost().total
    assert planned.faults is None       # no adapter even constructed


def test_install_uninstall_restores_the_engine_exactly():
    eng = WorkflowEngine(backend="xdt", max_retries=2)
    eng.register("f", lambda ctx, x: x)
    eng.run("f", 0)                     # materialize a deployment
    orig_strategy = eng.transfer._strategy("s3")  # materialize the lazy slot
    pol = eng.control.deployments["f"].policy
    cold0, cap0 = pol.cold_start_s, pol.max_instances
    plan = FaultPlan(
        [
            FaultEvent("degrade", at_s=0.0, duration_s=100.0, medium="s3",
                       slowdown=4.0, error_rate=0.5),
            FaultEvent("storm", at_s=0.0, duration_s=100.0,
                       cold_start_multiplier=8.0, max_instances_cap=1),
        ],
        seed=1,
    )
    inj = FaultInjector(eng, plan).install()
    assert inj.installed
    assert eng.transfer._fault_penalty is not None
    assert not eng.transfer._fast_single_owner   # dispatch sees every get
    inj._open_window(plan.events[0])
    inj._open_storm(plan.events[1])
    assert isinstance(eng.transfer._strategies["s3"], DegradedBackend)
    assert eng.transfer._degraded == {"s3": 4.0}
    assert pol.cold_start_s == cold0 * 8.0 and pol.max_instances == 1
    inj.uninstall()
    assert eng.transfer._strategies["s3"] is orig_strategy
    assert eng.transfer._degraded == {}
    assert eng.transfer._fault_penalty is None
    assert eng.transfer._fast_single_owner
    assert pol.cold_start_s == cold0 and pol.max_instances == cap0


# ----------------------------------------------- engine-lowering injection


def test_blackout_fails_terminally_with_recorded_statuses():
    """A full blackout on the only route exhausts the retry budget: every
    request lands in the log as terminal ``failed`` (never a crash), the
    wrapper names the injected cause, and retries stay bounded."""
    eng = WorkflowEngine(backend="xdt", max_retries=2)
    binding = _dag().bind(eng, default_route=FixedRoute("s3"),
                          bytes_scale=BYTES_SCALE)
    plan = FaultPlan.medium_blackout(medium="s3", at_s=0.0, duration_s=1e4)
    FaultInjector(eng, plan).install()
    _run_staggered(eng, binding, 4, 0.5)
    assert [r.status for r in eng.requests] == ["failed"] * 4
    assert all(isinstance(r.error, RetriesExhausted) for r in eng.requests)
    assert all(
        isinstance(r.error.cause, MediumUnavailable) for r in eng.requests
    )
    assert eng.failed_requests == 4
    assert eng.failed_codes == {"Fault.MediumUnavailable": 4}
    assert eng.retry_max <= eng.max_retries
    assert eng._inflight_requests == 0
    report = SLOGuard(availability_min=0.0).check(eng, "blackout")
    assert report.ok and report.n_failed == 4 and report.availability == 0.0


def test_eviction_storm_recovers_within_retry_budget():
    """Correlated node kills mid-flight: in-flight staged pulls die, the
    orchestrator retries, and every request still completes."""
    eng = WorkflowEngine(backend="xdt", max_retries=2)
    binding = _dag().bind(eng, default_route=FixedRoute("xdt"),
                          bytes_scale=BYTES_SCALE)
    plan = FaultPlan.eviction_storm(
        at_s=1.0, n_evictions=4, spacing_s=2.0, seed=7
    )
    inj = FaultInjector(eng, plan).install()
    _run_staggered(eng, binding, 12, 0.75)
    assert inj.n_evicted_instances > 0
    assert inj.n_evicted_buffers > 0
    assert eng.retry_total > 0                  # the storm actually hit
    assert eng.retry_max <= eng.max_retries
    assert all(r.status == "ok" for r in eng.requests)
    SLOGuard(availability_min=1.0).assert_ok(eng, "evictions")


def test_kill_racing_degraded_window_reroutes_durable_engine():
    """Satellite: an eviction *inside* an xdt degradation window.  The
    staged edge dies mid-throttle; the adaptive retry must land on a
    durable medium (penalty samples push xdt out of budget) and the retry
    count stays bounded."""
    eng = WorkflowEngine(backend="xdt", max_retries=2)
    binding = _dag().bind(
        eng,
        default_route=AdaptiveRoute(static=FixedRoute("xdt"),
                                    explore_every=0),
        bytes_scale=BYTES_SCALE,
    )
    plan = FaultPlan(
        [
            FaultEvent("degrade", at_s=0.5, duration_s=8.0, medium="xdt",
                       slowdown=10.0, error_rate=0.3),
            FaultEvent("evict", at_s=1.0),
        ],
        seed=5,
    )
    FaultInjector(eng, plan).install()
    _run_staggered(eng, binding, 8, 0.5)
    assert eng._inflight_requests == 0          # every request terminal
    assert eng.retry_max <= eng.max_retries     # bounded, not a retry loop
    assert all(r.status in ("ok", "failed") for r in eng.requests)
    data_media = set(binding.edge_usage["data"].media)
    assert data_media & {"s3", "elasticache"}   # rerouted durable
    # the fault timeline recorded the race (hub exists: adaptive route)
    kinds = {k for _, k, _ in eng.transfer.telemetry.faults}
    assert {"degrade_open", "evict", "degrade_close"} <= kinds


def test_kill_racing_degraded_window_cluster_lowering():
    """Same race on the discrete-event lowering: the staged edge's producer
    node is evicted inside a throttle window; fetches re-route durable with
    bounded refusal draws and the run still completes."""
    plan = FaultPlan(
        [
            FaultEvent("degrade", at_s=0.0, duration_s=5.0, medium="xdt",
                       slowdown=5.0, error_rate=0.5),
            FaultEvent("evict", at_s=0.05),
        ],
        seed=3,
    )
    clean = execute_on_cluster(DAGS["mr"], "xdt", seed=0, deterministic=True)
    run = execute_on_cluster(
        DAGS["mr"], "xdt", seed=0, deterministic=True, fault_plan=plan
    )
    s = run.faults.summary()
    assert s["retries"] > 0 and s["rerouted"] > 0
    assert s["evicted_nodes"]
    # refusal draws are bounded per fetch (then the durable escape hatch),
    # so total retries stay under (max_attempts + 1 eviction re-run) per
    # completed pull — bounded, not a retry loop
    n_pulls = sum(
        sum(u.media.values()) for u in run.edge_usage.values()
    )
    assert s["retries"] <= (run.faults.max_attempts + 1) * n_pulls
    # the adversity costs time; it never deadlocks or crashes the run
    assert run.latency_s > clean.latency_s
    assert run.cost().total > 0


def test_fault_aware_spill_beats_raw_dag_under_eviction_storm():
    """PredictiveSpill given the plan spills staged edges durable up front:
    strictly fewer eviction retries than the raw DAG under the same plan."""
    plan = FaultPlan.eviction_storm(
        at_s=0.05, n_evictions=2, spacing_s=0.1, seed=3
    )
    base = execute_on_cluster(
        DAGS["mr"], "xdt", seed=0, deterministic=True, fault_plan=plan
    )
    opt_dag, pplan = DAGS["mr"].optimize(fault_plan=plan)
    assert pplan.spilled                        # the storm forced a spill
    opt = execute_on_cluster(
        opt_dag, "xdt", seed=0, deterministic=True, plan=pplan,
        fault_plan=plan,
    )
    assert opt.faults.retries < base.faults.retries


def test_load_generator_survives_blackout():
    """Satellite: exhausted-retry requests land in the load report as
    terminal failures — the sweep completes instead of crashing."""
    import jax.numpy as jnp

    eng = WorkflowEngine(backend="s3", max_retries=1)
    eng.register("worker", lambda ctx, ref: float(ctx.get(ref).sum()))

    def entry(ctx, i):
        ref = ctx.put(jnp.full((64,), float(i), jnp.float32), n_retrievals=1)
        return ctx.invoke("worker", ref)

    eng.register("entry", entry)
    plan = FaultPlan.medium_blackout(medium="s3", at_s=0.0, duration_s=1e4)
    FaultInjector(eng, plan).install()
    rep = LoadGenerator(eng, "entry").run_closed(
        n_clients=2, requests_per_client=2
    )
    assert rep.n_requests == 4 and rep.n_ok == 0
    assert eng.failed_requests == 4
    assert eng.retry_max <= eng.max_retries


# ------------------------------------------------------------- SLO guard


def _tiny_engine(n_ok=3):
    eng = WorkflowEngine()
    eng.register("f", lambda ctx, x: x, service_time=0.1)
    for i in range(n_ok):
        eng.run("f", i)
    return eng


def test_slo_guard_clean_run_passes():
    eng = _tiny_engine()
    report = SLOGuard(availability_min=1.0).assert_ok(eng, "clean")
    assert report.ok and report.n_ok == report.n_requests == 3
    assert report.availability == 1.0
    assert report.retry_total == 0


def test_slo_guard_p99_budget_violation():
    eng = _tiny_engine()
    with pytest.raises(SLOViolation, match="p99"):
        SLOGuard(p99_budget_s=1e-6).assert_ok(eng, "tight")
    report = SLOGuard(p99_budget_s=1e-6).check(eng, "tight")
    assert not report.ok and any("p99" in v for v in report.violations)


def test_slo_guard_availability_violation():
    eng = WorkflowEngine(backend="xdt", max_retries=0)
    binding = _dag().bind(eng, default_route=FixedRoute("elasticache"),
                          bytes_scale=BYTES_SCALE)
    FaultInjector(
        eng, FaultPlan.medium_blackout(
            medium="elasticache", at_s=0.0, duration_s=1e4
        )
    ).install()
    _run_staggered(eng, binding, 2, 0.5)
    with pytest.raises(SLOViolation, match="availability"):
        SLOGuard(availability_min=1.0).assert_ok(eng, "blackout")


def test_require_dominates():
    SLOGuard.require_dominates(
        {"cost_usd": 1.0, "p99_s": 2.0}, {"cost_usd": 1.0, "p99_s": 2.5}
    )  # equal-or-better passes
    with pytest.raises(SLOViolation, match="must never lose"):
        SLOGuard.require_dominates(
            {"cost_usd": 1.1, "p99_s": 2.0}, {"cost_usd": 1.0, "p99_s": 2.5}
        )


# ------------------------------------- attribution exactness under faults


@given(
    seed=st.integers(0, 10_000),
    error_rate=st.floats(0.0, 1.0, allow_nan=False),
    at_s=st.floats(0.0, 1.0, allow_nan=False),
    n_tenants=st.integers(2, 3),
)
@settings(max_examples=8, deadline=None)
def test_tenant_bills_exact_under_injected_faults(
    seed, error_rate, at_s, n_tenants
):
    """Satellite: failed and retried requests must not break the linear-fee
    decomposition — per-tenant bills sum exactly to the combined bill no
    matter what the fault plan did to each tenant's accounting."""
    parts = {}
    for tid in range(n_tenants):
        eng = WorkflowEngine(backend="xdt", max_retries=1)
        binding = _dag().bind(eng, default_route=FixedRoute("s3"),
                              bytes_scale=BYTES_SCALE)
        plan = FaultPlan(
            [
                FaultEvent("degrade", at_s=at_s, duration_s=2.0,
                           medium="s3", slowdown=3.0,
                           error_rate=error_rate),
                FaultEvent("evict", at_s=at_s + 0.5),
            ],
            seed=seed + tid,
        )
        FaultInjector(eng, plan).install()
        _run_staggered(eng, binding, 2, 0.4)
        assert eng._inflight_requests == 0      # terminal either way
        ops = binding.media_storage_ops()
        parts[f"t{tid}"] = WorkflowCostInputs(
            n_function_invocations=len(eng.records),
            billed_duration_s=eng.billed_virtual_seconds(),
            n_storage_puts=sum(o.n_puts for o in ops.values()),
            n_storage_gets=sum(o.n_gets for o in ops.values()),
            storage_gb_seconds=sum(o.gb_seconds for o in ops.values()),
            peak_resident_gb=sum(o.peak_resident_gb for o in ops.values()),
        )
    combined = workflow_cost(combine_cost_inputs(parts.values()), "s3")
    bills = tenant_bills(parts, "s3")
    gap = abs(sum(b.total for b in bills.values()) - combined.total)
    assert gap <= 1e-9
