"""Property test: per-chunk billing coalesces exactly to the whole-object
storage bill.

A streamed object is k chunks, each individually routed — but the billing
contract is multipart-upload semantics: exactly ONE storage PUT and ONE
(ranged multi-) GET per (object, medium), regardless of chunk count, chunk
size, or where in the stream the route switches media.  Under random chunk
geometries and random mid-stream media splits, on every chunk-legal backend
and on both lowerings, the op counts must equal what the same object would
bill if it had been shipped whole (per medium) — never one op per chunk.

Runs under real ``hypothesis`` when installed, or the deterministic
``tests/_hypothesis_stub.py`` fallback registered by ``conftest.py``.
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Edge, Stage, WorkflowDAG, WorkflowEngine
from repro.core.dag import RoutePolicy, execute_on_cluster

BACKENDS = ("s3", "elasticache", "xdt")
# single-medium streams plus every ordered mid-stream switch between
# distinct media — the four service/instance backends' chunk-legal subset
# ("inline" chunks are refused at declaration time, pinned below)
MEDIA_SPLITS = [(m, m) for m in BACKENDS] + [
    (a, b) for a in BACKENDS for b in BACKENDS if a != b
]


class SplitRoute(RoutePolicy):
    """Scripted mid-stream switch: the first ``split`` resolutions go to
    ``m1``, the rest to ``m2`` — a deterministic stand-in for a stateful
    policy splitting one logical object across media."""

    def __init__(self, m1, m2, split):
        self.m1, self.m2, self.split = m1, m2, split
        self.calls = 0

    def resolve(self, edge, nbytes, evictable):
        self.calls += 1
        return self.m1 if self.calls <= self.split else self.m2


def _dag(nbytes, chunk_bytes):
    # compute-paced producer: every chunk publishes at a distinct offset,
    # so the route policy is consulted once per chunk (the adversarial
    # case for billing coalescing)
    return WorkflowDAG(
        "pipe",
        [Stage("p", compute_s=0.5), Stage("c", compute_s=0.01)],
        [Edge("p", "c", nbytes, label="feed", handoff="sync",
              streaming=True, chunk_bytes=chunk_bytes)],
    )


def _geometry(cb_kb, k, r_kb):
    """A random chunk geometry: k chunks of cb bytes with a ragged tail."""
    cb = cb_kb << 10
    r = min(r_kb, cb_kb) << 10
    nbytes = cb * (k - 1) + r
    return nbytes, cb, k


@settings(max_examples=25, deadline=None)
@given(
    cb_kb=st.integers(min_value=64, max_value=4096),
    k=st.integers(min_value=1, max_value=12),
    r_kb=st.integers(min_value=1, max_value=4096),
    split=st.integers(min_value=0, max_value=12),
    pair=st.integers(min_value=0, max_value=len(MEDIA_SPLITS) - 1),
)
def test_engine_chunk_billing_coalesces_to_whole_object(
    cb_kb, k, r_kb, split, pair
):
    nbytes, cb, k = _geometry(cb_kb, k, r_kb)
    m1, m2 = MEDIA_SPLITS[pair]
    route = SplitRoute(m1, m2, split)
    eng = WorkflowEngine(backend="xdt")
    binding = _dag(nbytes, cb).bind(eng, default_route=route)
    eng.submit(binding.entry, 1.0)
    eng.drain()
    (req,) = eng.requests
    assert req.status == "ok"
    u = binding.edge_usage["feed"]
    expect = {m1 if i < split else m2 for i in range(k)}
    assert set(u.media) == expect
    assert sum(u.media.values()) == k              # every chunk accounted
    assert sum(u.media_bytes.values()) == nbytes   # bytes conserved
    # THE contract: one PUT + one GET per (object, medium), never per chunk
    assert u.n_puts == len(expect)
    assert u.n_gets == len(expect)


@settings(max_examples=10, deadline=None)
@given(
    cb_kb=st.integers(min_value=64, max_value=4096),
    k=st.integers(min_value=1, max_value=8),
    r_kb=st.integers(min_value=1, max_value=4096),
    backend=st.integers(min_value=0, max_value=len(BACKENDS) - 1),
)
def test_cluster_chunk_billing_matches_whole_object_run(
    cb_kb, k, r_kb, backend
):
    nbytes, cb, k = _geometry(cb_kb, k, r_kb)
    m = BACKENDS[backend]
    plain = WorkflowDAG(
        "pipe",
        [Stage("p", compute_s=0.5), Stage("c", compute_s=0.01)],
        [Edge("p", "c", nbytes, label="feed", handoff="sync")],
    )
    base = execute_on_cluster(plain, m, seed=0, deterministic=True)
    run = execute_on_cluster(_dag(nbytes, cb), m, seed=0, deterministic=True)
    bu = base.edge_usage["feed"]
    u = run.edge_usage["feed"]
    assert (u.n_puts, u.n_gets) == (bu.n_puts, bu.n_gets)
    assert u.media == bu.media or sum(u.media.values()) == k
    assert sum(u.media_bytes.values()) == nbytes
    # k-way chunking never bills more dollars than the whole object
    assert run.cost().total <= base.cost().total * (1 + 1e-9)


def test_inline_chunks_stay_refused():
    # the fourth transport is not chunk-legal: chunks outlive the sync
    # message, so declaration-time validation must keep rejecting it
    with pytest.raises(ValueError, match="inline"):
        Edge("p", "c", 1 << 20, route="inline", streaming=True,
             chunk_bytes=4096)
