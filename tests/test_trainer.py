"""Trainer: fault-tolerant loop — checkpoint/restart, fault injection."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import ShardedLoader
from repro.models import init_params
from repro.optim import OptConfig
from repro.train import Trainer, TrainerConfig
from repro.train.trainer import SimulatedFailure


def _mk_trainer(tmp_path, steps=12, fault_hook=None, seed=0):
    cfg = smoke_config("smollm_360m")
    params = init_params(cfg, jax.random.PRNGKey(seed))
    loader = ShardedLoader(cfg, global_batch=4, seq_len=8)
    return Trainer(
        cfg, params, mesh=None,
        opt_cfg=OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=steps),
        tcfg=TrainerConfig(steps=steps, checkpoint_every=4, log_every=2,
                           remat="none"),
        workdir=str(tmp_path),
        batch_at=loader.batch_at,
        fault_hook=fault_hook,
    )


def _params_of(t):
    return [np.asarray(x, np.float32) for x in jax.tree.leaves(t.params)]


def test_loss_decreases(tmp_path):
    t = _mk_trainer(tmp_path / "a", steps=12)
    out = t.run()
    assert out["final_step"] == 12
    losses = [m["loss"] for m in out["log"]]
    assert losses[-1] < losses[0]


def test_crash_restart_bit_identical(tmp_path):
    """Kill at step 6, restart from the step-4 checkpoint, finish — final
    params must be bit-identical to an uninterrupted run."""
    ref = _mk_trainer(tmp_path / "ref", steps=10)
    ref.run()
    golden = _params_of(ref)

    def bomb(step):
        if step == 6 and not getattr(bomb, "fired", False):
            bomb.fired = True
            raise SimulatedFailure("node lost")

    crashy = _mk_trainer(tmp_path / "crash", steps=10, fault_hook=bomb)
    with pytest.raises(SimulatedFailure):
        crashy.run()

    resumed = _mk_trainer(tmp_path / "crash", steps=10)
    out = resumed.run()
    assert out["final_step"] == 10
    assert resumed.start_step == 4          # resumed from the last commit
    for a, b in zip(golden, _params_of(resumed)):
        np.testing.assert_array_equal(a, b)


def test_resume_skips_completed_run(tmp_path):
    t1 = _mk_trainer(tmp_path / "done", steps=8)
    t1.run()
    t2 = _mk_trainer(tmp_path / "done", steps=8)
    out = t2.run()
    assert t2.start_step == 8 and out["final_step"] == 8


def test_straggler_accounting(tmp_path):
    t = _mk_trainer(tmp_path / "s", steps=4)
    t.tcfg.straggler_deadline_s = 0.0       # every step blows the deadline
    out = t.run()
    assert out["stragglers"] == 4
