"""TransferEngine: the four backends' functional + modeled behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DEFAULT_NET,
    InlineTooLarge,
    TransferEngine,
    XDTObjectExhausted,
    XDTProducerGone,
    XDTRefInvalid,
    modeled_transfer_seconds,
)
from repro.core.refs import XDTRef


@pytest.mark.parametrize("backend", TransferEngine.BACKENDS)
def test_roundtrip_preserves_values(backend):
    eng = TransferEngine(backend)
    x = jnp.arange(128, dtype=jnp.float32).reshape(8, 16)
    ref = eng.put(x)
    out = eng.get(ref)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


@pytest.mark.parametrize("backend", TransferEngine.BACKENDS)
def test_pytree_roundtrip(backend):
    eng = TransferEngine(backend)
    tree = {"k": jnp.ones((4, 4)), "v": jnp.zeros((2,), jnp.int32)}
    out = eng.get(eng.put(tree))
    assert set(out) == {"k", "v"}
    np.testing.assert_array_equal(np.asarray(out["k"]), np.ones((4, 4)))


def test_inline_cap_enforced():
    eng = TransferEngine("inline", inline_limit=1024)
    with pytest.raises(InlineTooLarge):
        eng.put(jnp.zeros((1024,), jnp.float32))      # 4 KiB > 1 KiB cap
    eng.put(jnp.zeros((128,), jnp.float32))           # 512 B fits


def test_n_retrievals_exhaustion():
    eng = TransferEngine("xdt")
    ref = eng.put(jnp.ones(4), n_retrievals=2)
    eng.get(ref)
    eng.get(ref)
    with pytest.raises(XDTObjectExhausted):
        eng.get(ref)


def test_storage_backend_exhaustion():
    eng = TransferEngine("s3")
    ref = eng.put(jnp.ones(4), n_retrievals=1)
    eng.get(ref)
    with pytest.raises(XDTObjectExhausted):
        eng.get(ref)


def test_producer_death_surfaces_to_get():
    eng = TransferEngine("xdt")
    ref = eng.put(jnp.ones(4))
    eng.kill_producer()
    with pytest.raises(XDTProducerGone):
        eng.get(ref)


def test_forged_ref_rejected():
    eng = TransferEngine("xdt")
    eng.put(jnp.ones(4))
    with pytest.raises(XDTRefInvalid):
        eng.get(XDTRef(b"\x00" * 48))


def test_cross_engine_ref_rejected():
    """Refs are bound to the minter's trust domain."""
    a, b = TransferEngine("xdt"), TransferEngine("xdt")
    ref = a.put(jnp.ones(4))
    with pytest.raises(XDTRefInvalid):
        b.get(ref)


def test_invoke_blocking_semantics():
    eng = TransferEngine("xdt")
    out = eng.invoke(lambda x: x * 2, jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones(4))
    assert eng.stats.transfers == 1


def test_modeled_latency_ordering_large_objects():
    """Paper Fig. 2/5: for large transfers XDT < ElastiCache < S3."""
    for nbytes in (10 << 20, 100 << 20):
        t_xdt = modeled_transfer_seconds("xdt", nbytes)
        t_ec = modeled_transfer_seconds("elasticache", nbytes)
        t_s3 = modeled_transfer_seconds("s3", nbytes)
        assert t_xdt < t_ec < t_s3, (nbytes, t_xdt, t_ec, t_s3)


def test_modeled_latency_small_objects():
    """At 10 KB the paper measures XDT ~12% under EC and ~89% under S3."""
    n = 10 << 10
    t_xdt = modeled_transfer_seconds("xdt", n)
    t_ec = modeled_transfer_seconds("elasticache", n)
    t_s3 = modeled_transfer_seconds("s3", n)
    assert t_xdt < t_ec
    assert t_ec < 0.25 * t_s3          # ~89% lower in the paper


def test_storage_accounting():
    eng = TransferEngine("elasticache")
    ref = eng.put(jnp.zeros((1024,), jnp.float32), n_retrievals=2)
    eng.get(ref)
    eng.get(ref)
    assert eng.acct.n_storage_puts == 1
    assert eng.acct.n_storage_gets == 2
    assert eng.acct.peak_resident_gb > 0


def test_xdt_zero_storage_accounting():
    eng = TransferEngine("xdt")
    ref = eng.put(jnp.zeros((1024,), jnp.float32))
    eng.get(ref)
    assert eng.acct.n_storage_puts == 0
    assert eng.acct.n_storage_gets == 0


def test_stats_bytes_moved():
    eng = TransferEngine("xdt")
    x = jnp.zeros((256,), jnp.float32)
    eng.get(eng.put(x))
    assert eng.stats.bytes_moved == x.nbytes
