"""TransferEngine: the four backends' functional + modeled behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    InlineTooLarge,
    TransferEngine,
    XDTObjectExhausted,
    XDTProducerGone,
    XDTRefInvalid,
    modeled_transfer_seconds,
)
from repro.core.refs import XDTRef


@pytest.mark.parametrize("backend", TransferEngine.BACKENDS)
def test_roundtrip_preserves_values(backend):
    eng = TransferEngine(backend)
    x = jnp.arange(128, dtype=jnp.float32).reshape(8, 16)
    ref = eng.put(x)
    out = eng.get(ref)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


@pytest.mark.parametrize("backend", TransferEngine.BACKENDS)
def test_pytree_roundtrip(backend):
    eng = TransferEngine(backend)
    tree = {"k": jnp.ones((4, 4)), "v": jnp.zeros((2,), jnp.int32)}
    out = eng.get(eng.put(tree))
    assert set(out) == {"k", "v"}
    np.testing.assert_array_equal(np.asarray(out["k"]), np.ones((4, 4)))


def test_inline_cap_enforced():
    eng = TransferEngine("inline", inline_limit=1024)
    with pytest.raises(InlineTooLarge):
        eng.put(jnp.zeros((1024,), jnp.float32))      # 4 KiB > 1 KiB cap
    eng.put(jnp.zeros((128,), jnp.float32))           # 512 B fits


def test_n_retrievals_exhaustion():
    eng = TransferEngine("xdt")
    ref = eng.put(jnp.ones(4), n_retrievals=2)
    eng.get(ref)
    eng.get(ref)
    with pytest.raises(XDTObjectExhausted):
        eng.get(ref)


def test_storage_backend_exhaustion():
    eng = TransferEngine("s3")
    ref = eng.put(jnp.ones(4), n_retrievals=1)
    eng.get(ref)
    with pytest.raises(XDTObjectExhausted):
        eng.get(ref)


def test_producer_death_surfaces_to_get():
    eng = TransferEngine("xdt")
    ref = eng.put(jnp.ones(4))
    eng.kill_producer()
    with pytest.raises(XDTProducerGone):
        eng.get(ref)


def test_forged_ref_rejected():
    eng = TransferEngine("xdt")
    eng.put(jnp.ones(4))
    with pytest.raises(XDTRefInvalid):
        eng.get(XDTRef(b"\x00" * 48))


def test_cross_engine_ref_rejected():
    """Refs are bound to the minter's trust domain."""
    a, b = TransferEngine("xdt"), TransferEngine("xdt")
    ref = a.put(jnp.ones(4))
    with pytest.raises(XDTRefInvalid):
        b.get(ref)


def test_invoke_blocking_semantics():
    eng = TransferEngine("xdt")
    out = eng.invoke(lambda x: x * 2, jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones(4))
    assert eng.stats.transfers == 1


def test_modeled_latency_ordering_large_objects():
    """Paper Fig. 2/5: for large transfers XDT < ElastiCache < S3."""
    for nbytes in (10 << 20, 100 << 20):
        t_xdt = modeled_transfer_seconds("xdt", nbytes)
        t_ec = modeled_transfer_seconds("elasticache", nbytes)
        t_s3 = modeled_transfer_seconds("s3", nbytes)
        assert t_xdt < t_ec < t_s3, (nbytes, t_xdt, t_ec, t_s3)


def test_modeled_latency_small_objects():
    """At 10 KB the paper measures XDT ~12% under EC and ~89% under S3."""
    n = 10 << 10
    t_xdt = modeled_transfer_seconds("xdt", n)
    t_ec = modeled_transfer_seconds("elasticache", n)
    t_s3 = modeled_transfer_seconds("s3", n)
    assert t_xdt < t_ec
    assert t_ec < 0.25 * t_s3          # ~89% lower in the paper


def test_storage_accounting():
    eng = TransferEngine("elasticache")
    ref = eng.put(jnp.zeros((1024,), jnp.float32), n_retrievals=2)
    eng.get(ref)
    eng.get(ref)
    assert eng.acct.n_storage_puts == 1
    assert eng.acct.n_storage_gets == 2
    assert eng.acct.peak_resident_gb > 0


def test_xdt_zero_storage_accounting():
    eng = TransferEngine("xdt")
    ref = eng.put(jnp.zeros((1024,), jnp.float32))
    eng.get(ref)
    assert eng.acct.n_storage_puts == 0
    assert eng.acct.n_storage_gets == 0


def test_stats_bytes_moved():
    eng = TransferEngine("xdt")
    x = jnp.zeros((256,), jnp.float32)
    eng.get(eng.put(x))
    assert eng.stats.bytes_moved == x.nbytes


# ---------------------------------------------------------------------------
# Backend strategy classes: durability, exception safety, extensibility
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["s3", "elasticache", "hybrid"])
def test_service_objects_survive_producer_death(backend):
    """Through-storage durability: only XDT/inline buffers die with the
    producer instance; service-resident objects must remain retrievable."""
    eng = TransferEngine(backend)
    x = jnp.arange(64, dtype=jnp.float32)
    ref = eng.put(x, n_retrievals=1)
    eng.kill_producer()
    out = eng.get(ref)                       # regression: used to raise
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_service_refcount_not_burned_by_failed_copy():
    """s3/elasticache get(): the host->device copy happens before the
    retrieval is consumed, so a failed copy does not leak one of the N."""
    eng = TransferEngine("s3")
    ref = eng.put(jnp.ones(8), n_retrievals=1)
    key = next(iter(eng.service._objects))

    class Unarrayable:
        def __array__(self, *a, **k):
            raise RuntimeError("corrupt host object")

    good = eng.service._objects[key]
    eng.service._objects[key] = Unarrayable()
    with pytest.raises(RuntimeError):
        eng.get(ref)
    eng.service._objects[key] = good         # service heals; retrieval intact
    out = eng.get(ref)
    np.testing.assert_array_equal(np.asarray(out), np.ones(8))
    with pytest.raises(XDTObjectExhausted):
        eng.get(ref)                         # now genuinely exhausted


def test_service_consume_missing_key_raises_exhausted():
    from repro.core.transfer import ServiceStore

    store = ServiceStore()
    with pytest.raises(XDTObjectExhausted):
        store.consume(999)
    with pytest.raises(XDTObjectExhausted):
        store.fetch(999)


def test_shared_service_store_across_engines():
    """One ServiceStore per cluster: a consumer-side engine resolves keys
    minted by the producer-side engine (and survives the producer dying)."""
    from repro.core.refs import RefMinter
    from repro.core.transfer import ServiceStore

    store, minter = ServiceStore(), RefMinter()
    producer = TransferEngine("s3", service=store, minter=minter)
    consumer = TransferEngine("s3", service=store, minter=minter,
                              producer_coords=(1,))
    ref = producer.put(jnp.full((16,), 3.0), n_retrievals=1)
    producer.kill_producer()
    out = consumer.get(ref)
    np.testing.assert_array_equal(np.asarray(out), 3.0 * np.ones(16))
    assert consumer.stats.transfers == 1
    # the store's own accounting is the authoritative cluster-level view
    # (per-engine accts only see their side of a cross-engine transfer)
    assert store.acct.n_storage_puts == 1
    assert store.acct.n_storage_gets == 1
    assert store.acct.peak_resident_gb > 0
    assert len(store) == 0                   # freed after the last retrieval


def test_hybrid_backend_roundtrip_and_tiering():
    eng = TransferEngine("hybrid")
    x = jnp.arange(32, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(eng.get(eng.put(x))), np.asarray(x))
    # modeled latency: cache tier below the cutoff, S3 tier above it
    small, large = 10 << 10, 10 << 20
    assert modeled_transfer_seconds("hybrid", small) == modeled_transfer_seconds(
        "elasticache", small
    )
    assert modeled_transfer_seconds("hybrid", large) == modeled_transfer_seconds(
        "s3", large
    )


def test_register_custom_backend():
    from repro.core.transfer import (
        XDTBackend,
        available_backends,
        register_backend,
    )

    class LoopbackBackend(XDTBackend):
        name = "loopback"

        @classmethod
        def modeled_seconds(cls, nbytes, net):
            return 0.0

    register_backend(LoopbackBackend)
    assert "loopback" in available_backends()
    eng = TransferEngine("loopback")
    out = eng.get(eng.put(jnp.ones(4)))
    np.testing.assert_array_equal(np.asarray(out), np.ones(4))
    assert modeled_transfer_seconds("loopback", 1 << 20) == 0.0


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        TransferEngine("dynamo")
    with pytest.raises(ValueError):
        modeled_transfer_seconds("dynamo", 1024)
