"""Checkpoint store: atomicity, async, GC, restore fidelity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore, latest_step


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
                   "b": jnp.ones(3, jnp.float32)},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32)
        )
        assert x.dtype == y.dtype


def test_save_restore_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save(3, t)
    out = store.restore(3, t)
    _assert_tree_equal(t, out)


def test_bfloat16_survives(tmp_path):
    """Custom dtypes round-trip bit-exactly through the raw-bytes encoding."""
    store = CheckpointStore(str(tmp_path))
    t = {"w": (jnp.arange(7, dtype=jnp.bfloat16) * 0.1)}
    store.save(1, t)
    out = store.restore(1, t)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.asarray(t["w"], np.float32))


def test_latest_step_ignores_uncommitted(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(5, _tree())
    store.save(9, _tree())
    os.remove(tmp_path / "step_000000009" / "COMMIT")   # simulate crash
    assert latest_step(str(tmp_path)) == 5


def test_async_save_then_wait(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save_async(2, t)
    store.wait()
    assert latest_step(str(tmp_path)) == 2
    _assert_tree_equal(t, store.restore(2, t))


def test_gc_keeps_latest_k(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree())
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_000000003", "step_000000004"]


def test_restore_missing_raises(tmp_path):
    store = CheckpointStore(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        store.restore(42, _tree())


def test_overwrite_same_step(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, {"w": jnp.zeros(3)})
    store.save(1, {"w": jnp.ones(3)})
    out = store.restore(1, {"w": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(3))
