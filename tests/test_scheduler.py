"""Control plane: autoscaling, load balancing, keep-alive, fault injection."""
import pytest

from repro.core import WorkflowEngine
from repro.core.clock import MonotonicClock, VirtualClock
from repro.core.cluster import Simulator
from repro.core.scheduler import ControlPlane, Deployment, ScalingPolicy


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _dep(policy, clock=None):
    return Deployment("f", policy, clock=clock or FakeClock())


def test_min_instances_prewarmed():
    d = _dep(ScalingPolicy(min_instances=3))
    assert d.n_instances == 3
    assert d.stats["cold_starts"] == 0


def test_scale_up_on_demand_with_cold_start():
    clock = FakeClock()
    d = _dep(ScalingPolicy(min_instances=0, cold_start_s=0.5), clock)
    inst, wait = d.steer()
    assert d.stats["cold_starts"] == 1
    assert wait == 0.5                      # activator buffers across the boot


def test_least_loaded_steering():
    clock = FakeClock()
    d = _dep(ScalingPolicy(min_instances=2, target_concurrency=4), clock)
    a, _ = d.steer()
    b, _ = d.steer()
    assert a.instance_id != b.instance_id   # balanced, not piled on one


def test_concurrency_triggers_scale_up():
    clock = FakeClock()
    d = _dep(ScalingPolicy(min_instances=1, target_concurrency=1, max_instances=4), clock)
    a, _ = d.steer()                        # occupies the only instance
    b, _ = d.steer()                        # forces a scale-up
    assert d.n_instances == 2
    assert a.instance_id != b.instance_id


def test_max_instances_cap_queues_instead():
    clock = FakeClock()
    d = _dep(ScalingPolicy(min_instances=1, target_concurrency=1, max_instances=1), clock)
    a, _ = d.steer()
    b, _ = d.steer()                        # cap reached: queue on least-loaded
    assert d.n_instances == 1
    assert b.instance_id == a.instance_id


def test_keep_alive_reaping():
    clock = FakeClock()
    d = _dep(ScalingPolicy(min_instances=1, keep_alive_s=60.0, max_instances=8), clock)
    inst, _ = d.steer()
    d.release(inst.instance_id)
    clock.advance(61.0)
    d.steer()                               # triggers the idle sweep
    # min_instances floor is respected
    assert d.n_instances >= 1


def test_idle_scale_down_above_minimum():
    clock = FakeClock()
    d = _dep(ScalingPolicy(min_instances=1, target_concurrency=1,
                           keep_alive_s=10.0, max_instances=8), clock)
    insts = [d.steer()[0] for _ in range(4)]
    for i in insts:
        d.release(i.instance_id)
    assert d.n_instances == 4
    clock.advance(11.0)
    d.steer()
    assert d.n_instances <= 2               # reaped down toward the floor
    assert d.stats["scale_downs"] >= 2


def test_kill_removes_instance():
    d = _dep(ScalingPolicy(min_instances=2))
    iid = next(iter(d.instances))
    assert d.kill(iid)
    assert iid not in d.instances
    assert not d.kill(iid)


def test_control_plane_registry():
    cp = ControlPlane(clock=FakeClock())
    cp.register("decode", ScalingPolicy(min_instances=2))
    inst, _ = cp.steer("decode")
    assert inst.in_flight == 1
    cp.release("decode", inst.instance_id)
    assert inst.in_flight == 0


def test_placement_first_coords_available_before_data_moves():
    """XDT compatibility: the steering decision yields concrete placement
    coordinates (the consumer slice) before any payload is involved."""
    cp = ControlPlane(clock=FakeClock())
    cp.register("decode", ScalingPolicy(min_instances=3),
                placer=lambda i: (1 + i, 0))
    seen = set()
    for _ in range(3):
        inst, _ = cp.steer("decode")
        seen.add(inst.coords)
    assert seen == {(1, 0), (2, 0), (3, 0)}


# ---------------------------------------------------------------------------
# Autoscaler dynamics under virtual time (the injected Clock abstraction)
# ---------------------------------------------------------------------------


def _advance(sim, dt):
    """Advance virtual time by dt (a no-op event at now+dt)."""
    sim.schedule(dt, lambda: None)
    sim.run()


def test_virtual_clock_reads_simulator_time():
    sim = Simulator()
    clock = VirtualClock(sim)
    assert clock() == 0.0
    _advance(sim, 2.5)
    assert clock() == 2.5
    assert isinstance(MonotonicClock()(), float)


def test_scale_up_on_concurrency_pressure_virtual():
    sim = Simulator()
    d = Deployment("f", ScalingPolicy(min_instances=1, target_concurrency=2,
                                      max_instances=8, cold_start_s=0.4),
                   clock=VirtualClock(sim))
    # 2 in-flight fit the single instance; the 3rd forces a cold scale-up
    waits = [d.steer()[1] for _ in range(3)]
    assert d.n_instances == 2
    assert waits[:2] == [0.0, 0.0]
    assert waits[2] == pytest.approx(0.4)      # gated on the cold start, exactly
    assert d.stats["cold_starts"] == 1


def test_cold_start_gate_expires_with_virtual_time():
    sim = Simulator()
    d = Deployment("f", ScalingPolicy(min_instances=0, target_concurrency=1,
                                      max_instances=8, cold_start_s=0.4),
                   clock=VirtualClock(sim))
    inst, wait = d.steer()
    assert wait == pytest.approx(0.4)
    d.release(inst.instance_id)
    _advance(sim, 0.4)                         # the instance finished booting
    inst2, wait2 = d.steer()
    assert wait2 == 0.0 and inst2.instance_id == inst.instance_id


def test_keep_alive_expiry_scales_down_exactly():
    sim = Simulator()
    d = Deployment("f", ScalingPolicy(min_instances=1, target_concurrency=1,
                                      keep_alive_s=10.0, max_instances=8),
                   clock=VirtualClock(sim))
    insts = [d.steer()[0] for _ in range(4)]
    for i in insts:
        d.release(i.instance_id)
    assert d.n_instances == 4
    _advance(sim, 9.9)
    d.steer()                                  # within keep-alive: no reaping
    assert d.stats["scale_downs"] == 0
    _advance(sim, 0.2)                         # now 10.1s idle: expired
    d.steer()
    assert d.stats["scale_downs"] >= 2
    assert d.n_instances >= 1                  # min_instances floor holds


def test_workflow_burst_scales_up_then_idles_down():
    """End-to-end: a burst of concurrent requests grows the fleet; after the
    keep-alive window the next request finds it scaled back down."""
    eng = WorkflowEngine()
    eng.register("f", lambda ctx, x: x,
                 policy=ScalingPolicy(min_instances=1, target_concurrency=1,
                                      keep_alive_s=30.0, max_instances=16),
                 service_time=0.2)
    for i in range(6):
        eng.submit("f", i)
    eng.drain()
    dep = eng.control.deployments["f"]
    assert dep.n_instances == 6                # burst pressure scaled up
    assert dep.stats["cold_starts"] == 5
    _advance(eng.sim, 31.0)                    # idle past keep-alive
    eng.run("f", 99)
    assert dep.stats["scale_downs"] >= 4       # reaped down toward the floor
    assert dep.n_instances <= 2
