"""Deterministic stand-in for the optional ``hypothesis`` dependency.

The property tests in this suite use a small slice of the hypothesis API:
``@settings(...)``, ``@given(...)``, and the ``integers`` / ``floats`` /
``binary`` / ``lists`` / ``tuples`` strategies.  When the real package is
installed (see ``requirements-dev.txt``) it is used and this module is inert.
When it is missing, ``conftest.py`` registers this module under the
``hypothesis`` name so the suite still runs: each ``@given`` test executes a
fixed number of examples drawn from a seeded PRNG (deterministic across runs),
always including a minimum-size example.  That trades hypothesis's shrinking
and coverage for zero dependencies — full property coverage requires the real
package.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
from typing import Any, Callable

N_EXAMPLES = 12  # per @given test when running on the stub


class _Strategy:
    """A deterministic value source: ``draw(rng)`` and a minimal example."""

    def __init__(self, draw: Callable[[random.Random], Any], minimal: Any):
        self.draw = draw
        self.minimal = minimal


def integers(min_value: int = 0, max_value: int = 2**31 - 1) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value), min_value)


def floats(
    min_value: float = 0.0,
    max_value: float = 1.0,
    allow_nan: bool = True,
    allow_infinity: bool = True,
) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value), min_value)


def binary(min_size: int = 0, max_size: int = 64) -> _Strategy:
    def draw(rng: random.Random) -> bytes:
        n = rng.randint(min_size, max_size)
        return bytes(rng.randrange(256) for _ in range(n))

    return _Strategy(draw, b"\x00" * min_size)


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng: random.Random) -> list:
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw, [elements.minimal] * min_size)


def tuples(*elems: _Strategy) -> _Strategy:
    return _Strategy(
        lambda rng: tuple(e.draw(rng) for e in elems),
        tuple(e.minimal for e in elems),
    )


def settings(*_args, **_kwargs):
    """No-op decorator factory (max_examples/deadline are stub-fixed)."""

    def deco(fn):
        return fn

    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    """Run the test over N_EXAMPLES deterministic draws + the minimal example."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*outer_args, **outer_kwargs):
            rng = random.Random(fn.__qualname__)  # deterministic per test
            examples = [
                (
                    tuple(s.minimal for s in arg_strategies),
                    {k: s.minimal for k, s in kw_strategies.items()},
                )
            ]
            for _ in range(N_EXAMPLES):
                examples.append(
                    (
                        tuple(s.draw(rng) for s in arg_strategies),
                        {k: s.draw(rng) for k, s in kw_strategies.items()},
                    )
                )
            for args, kwargs in examples:
                fn(*outer_args, *args, **outer_kwargs, **kwargs)

        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution (it follows __wrapped__ otherwise).
        del wrapper.__wrapped__
        supplied = set(kw_strategies)
        params = [
            p
            for i, p in enumerate(inspect.signature(fn).parameters.values())
            if p.name not in supplied and i >= len(arg_strategies)
        ]
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper

    return deco


# `from hypothesis import strategies as st` needs a module-like attribute.
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.binary = binary
strategies.lists = lists
strategies.tuples = tuples
