"""Pluggable autoscaler policies: legacy default, rps, predictive, registry."""
import pytest

from repro.core import LoadGenerator, WorkflowEngine
from repro.core.scheduler import (
    AutoscalerPolicy,
    ConcurrencyPolicy,
    Deployment,
    PredictivePolicy,
    RpsPolicy,
    ScalingPolicy,
    available_autoscalers,
    make_autoscaler,
    register_autoscaler,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# Registry + defaults
# ---------------------------------------------------------------------------


def test_default_policy_is_legacy_concurrency():
    d = Deployment("f", ScalingPolicy(), clock=FakeClock())
    assert isinstance(d.autoscaler, ConcurrencyPolicy)
    assert d.telemetry is None          # legacy steer path stays bare


def test_registry_resolves_names_and_instances():
    assert set(available_autoscalers()) >= {"concurrency", "rps", "predictive"}
    assert isinstance(make_autoscaler("rps"), RpsPolicy)
    pol = PredictivePolicy(headroom=2.0)
    assert make_autoscaler(pol) is pol
    with pytest.raises(ValueError, match="autoscaler must be one of"):
        make_autoscaler("nope")


def test_register_custom_autoscaler():
    class AlwaysTwo(AutoscalerPolicy):
        name = "always-two"
        needs_telemetry = True
        reactive = False

        def desired_instances(self, dep, now):
            return 2

    register_autoscaler(AlwaysTwo)
    clock = FakeClock()
    d = Deployment("f", ScalingPolicy(autoscaler="always-two",
                                      cold_start_s=0.0), clock=clock)
    d.steer()
    assert d.n_instances == 2
    assert d.stats["prewarmed"] == 2


# ---------------------------------------------------------------------------
# RpsPolicy: fleet sized from the arrival-rate window
# ---------------------------------------------------------------------------


def _drive(dep, clock, rate, seconds, hold_train=None):
    """Steer at a fixed rate, releasing immediately (holding ~0)."""
    dt = 1.0 / rate
    n = int(seconds * rate)
    for _ in range(n):
        inst, _ = dep.steer()
        dep.release(inst.instance_id)
        clock.advance(dt)


def test_rps_policy_sizes_fleet_from_rate_not_misses():
    clock = FakeClock()
    d = Deployment(
        "f",
        ScalingPolicy(autoscaler=RpsPolicy(target_rps_per_instance=10.0,
                                           utilization=1.0),
                      max_instances=64, cold_start_s=0.0),
        clock=clock,
    )
    _drive(d, clock, rate=50.0, seconds=4.0)
    # ~50 rps / 10 per instance -> ~5 instances, NOT one per steer miss
    assert 4 <= d.n_instances <= 8
    assert d.stats["cold_starts"] == d.stats["prewarmed"] == d.n_instances


def test_rps_policy_bootstraps_from_concurrency_without_estimate():
    clock = FakeClock()
    d = Deployment(
        "f",
        ScalingPolicy(autoscaler=RpsPolicy(), max_instances=8,
                      target_concurrency=2, cold_start_s=0.0),
        clock=clock,
    )
    # no holding estimate, 3 requests held in flight: ceil((n+1)/2) instances
    insts = [d.steer()[0] for _ in range(3)]
    assert d.n_instances == 2
    assert len({i.instance_id for i in insts}) == 2


def test_rps_capacity_derived_from_seeded_holding_time():
    clock = FakeClock()
    d = Deployment(
        "f",
        ScalingPolicy(autoscaler=RpsPolicy(utilization=1.0),
                      max_instances=64, cold_start_s=0.0),
        clock=clock,
    )
    d.seed_holding_estimate(0.1)        # 10 rps capacity per instance
    _drive(d, clock, rate=40.0, seconds=4.0)
    assert 3 <= d.n_instances <= 7      # ~40/10 = 4


def test_seed_holding_estimate_is_noop_for_legacy_policy():
    d = Deployment("f", ScalingPolicy(), clock=FakeClock())
    d.seed_holding_estimate(3.0)
    assert d._service_ewma == 0.0       # cap queue model unchanged bit-for-bit


# ---------------------------------------------------------------------------
# PredictivePolicy: pre-warming from the trend
# ---------------------------------------------------------------------------


def test_predictive_prewarms_ahead_of_ramp():
    clock = FakeClock()
    pred = Deployment(
        "f",
        ScalingPolicy(autoscaler=PredictivePolicy(utilization=1.0),
                      max_instances=256, cold_start_s=0.5),
        clock=clock,
    )
    rps = Deployment(
        "f",
        ScalingPolicy(autoscaler=RpsPolicy(utilization=1.0),
                      max_instances=256, cold_start_s=0.5),
        clock=clock,
    )
    for d in (pred, rps):
        d.seed_holding_estimate(0.2)
    # arrival rate ramps linearly 10 -> 170 rps over 4 s
    t = 0.0
    while t < 4.0:
        pred.steer()
        rps.steer()
        dt = 1.0 / (10.0 + 40.0 * t)
        clock.advance(dt)
        t += dt
    # the trend extrapolation provisions ahead of the rate-only policy
    assert pred.n_instances > rps.n_instances


def test_predictive_never_scales_below_current_rate():
    """On falling load the forecast clamps at the current rate: desired
    stays positive, so the proactive trim never empties the fleet."""
    clock = FakeClock()
    d = Deployment(
        "f",
        ScalingPolicy(autoscaler=PredictivePolicy(utilization=1.0),
                      max_instances=64, cold_start_s=0.0),
        clock=clock,
    )
    d.seed_holding_estimate(0.1)
    _drive(d, clock, rate=50.0, seconds=2.0)
    _drive(d, clock, rate=5.0, seconds=2.0)   # load falls off
    inst, wait = d.steer()                    # still at least one instance
    assert wait == 0.0


def test_predictive_prewarm_decays_on_falling_load():
    """Virtual-time prewarm decay: when a burst subsides, the predictive
    policy retires its surplus idle instances long before the keep-alive
    reaper would — the fleet follows the forecast down, keeping only the
    slack buffer, and every policy trim feeds the telemetry reap window."""
    clock = FakeClock()
    d = Deployment(
        "f",
        ScalingPolicy(autoscaler=PredictivePolicy(utilization=1.0),
                      max_instances=256, cold_start_s=0.0,
                      keep_alive_s=300.0),
        clock=clock,
    )
    d.seed_holding_estimate(0.2)
    _drive(d, clock, rate=100.0, seconds=2.0)     # burst provisions a fleet
    peak = d.n_instances
    assert peak >= 10
    _drive(d, clock, rate=2.0, seconds=5.0)       # trickle: forecast falls
    assert d.n_instances < peak // 2              # decayed, not reaped:
    assert d.stats["scale_downs"] > 0             # keep-alive is 300 s and
    assert clock.t < 10.0                         # only ~7 s have elapsed
    # the spill predictor sees policy trims exactly like keep-alive reaps
    assert d.telemetry.n_reaps == d.stats["scale_downs"]


def test_predictive_scale_down_opt_out_keeps_reap_only():
    """scale_down=False restores the legacy behaviour: inside keep-alive
    the fleet only ever grows, however far the forecast falls."""
    clock = FakeClock()
    d = Deployment(
        "f",
        ScalingPolicy(
            autoscaler=PredictivePolicy(utilization=1.0, scale_down=False),
            max_instances=256, cold_start_s=0.0, keep_alive_s=300.0,
        ),
        clock=clock,
    )
    d.seed_holding_estimate(0.2)
    _drive(d, clock, rate=100.0, seconds=2.0)
    peak = d.n_instances
    _drive(d, clock, rate=2.0, seconds=5.0)
    assert d.n_instances >= peak
    assert d.stats["scale_downs"] == 0


def test_retire_surplus_skips_busy_instances():
    """Only idle instances are eligible: a busy fleet at peak load never
    loses an in-flight request to the trim."""
    clock = FakeClock()
    d = Deployment(
        "f",
        ScalingPolicy(autoscaler=PredictivePolicy(utilization=1.0),
                      max_instances=64, cold_start_s=0.0,
                      target_concurrency=4),
        clock=clock,
    )
    d.seed_holding_estimate(0.2)
    # occupy a few instances and never release them
    busy = [d.steer()[0] for _ in range(3)]
    d._retire_surplus(clock(), want=0)
    alive = set(d.instances)
    assert {i.instance_id for i in busy} <= alive


# ---------------------------------------------------------------------------
# Engine integration: policies selectable per deployment, fewer cold starts
# ---------------------------------------------------------------------------


def _burst_engine(autoscaler):
    eng = WorkflowEngine(records="columnar")
    eng.register(
        "f", lambda ctx, x: x,
        policy=ScalingPolicy(max_instances=64, target_concurrency=1,
                             autoscaler=autoscaler),
        service_time=0.05,
    )
    return eng


@pytest.mark.parametrize("autoscaler", ["rps", "predictive"])
def test_rate_policies_cold_start_less_than_legacy_under_load(autoscaler):
    """At high offered load the reactive policy boots one instance per
    arrival caught mid cold-start; rate-driven policies provision the
    steady-state fleet."""
    def cold_starts(policy):
        eng = _burst_engine(policy)
        rep = LoadGenerator(eng, "f").run_open(rate_rps=300.0, duration_s=5.0)
        assert rep.n_requests > 0
        return rep.n_cold_starts, rep

    legacy, _ = cold_starts(None)
    rated, rep = cold_starts(autoscaler)
    assert rated < legacy
    assert rep.autoscaler == autoscaler
    assert rep.n_prewarmed > 0          # scale-up was proactive, not reactive


def test_loadgen_reports_per_run_control_plane_deltas():
    eng = _burst_engine("rps")
    gen = LoadGenerator(eng, "f")
    first = gen.run_open(rate_rps=100.0, duration_s=2.0)
    second = gen.run_open(rate_rps=100.0, duration_s=2.0)
    assert first.n_cold_starts > 0
    # the fleet from run 1 is still warm: run 2's deltas are much smaller
    assert second.n_cold_starts <= first.n_cold_starts
    assert second.n_prewarmed <= first.n_prewarmed


def test_dag_bind_selects_autoscaler_for_all_stages():
    from repro.core.workloads import DAGS

    eng = WorkflowEngine(records="columnar")
    DAGS["vid"].bind(eng, default_route="xdt", bytes_scale=1e-5,
                     autoscaler="rps")
    for dep in eng.control.deployments.values():
        assert isinstance(dep.autoscaler, RpsPolicy)
        assert dep.telemetry is not None


def test_execute_on_cluster_autoscaled_stages_pay_cold_starts():
    from repro.core.workloads import VID_DAG
    from repro.core.dag import execute_on_cluster

    base = execute_on_cluster(VID_DAG, "xdt", seed=0, deterministic=True)
    assert base.control is None         # default: pre-provisioned fleet
    run = execute_on_cluster(VID_DAG, "xdt", seed=0, deterministic=True,
                             autoscaler="concurrency")
    stats = {n: d.stats for n, d in run.control.deployments.items()}
    assert sum(s["cold_starts"] for s in stats.values()) > 0
    # cold-start gates extend the critical path vs the pre-provisioned run
    assert run.latency_s > base.latency_s
    # every steered instance was released at stage completion
    assert all(d.in_flight_total == 0
               for d in run.control.deployments.values())
