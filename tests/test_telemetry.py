"""Telemetry substrate: rate/trend windows, gauges, medium feeds."""
import pytest

from repro.core.cluster import Simulator
from repro.core.clock import VirtualClock
from repro.core.cost import transfer_fee_usd
from repro.core.telemetry import (
    DecayGauge,
    DecayRate,
    DecayedLinear,
    DeploymentTelemetry,
    MediumTelemetry,
    TelemetryHub,
)


# ---------------------------------------------------------------------------
# Estimators
# ---------------------------------------------------------------------------


def test_decay_rate_tracks_steady_rate():
    r = DecayRate(tau_s=2.0)
    for k in range(400):
        r.record(k * 0.01)              # 100 events/s for 4 s
    assert r.rate(4.0) == pytest.approx(100.0, rel=0.1)


def test_decay_rate_warmup_correction_sees_early_ramp():
    """A plain EWMA underestimates by elapsed/tau during warmup; the
    corrected estimator reports the true rate within a few samples."""
    r = DecayRate(tau_s=2.0)
    for k in range(20):
        r.record(k * 0.0025)            # 400 events/s, only 50 ms observed
    assert r.rate(0.05) == pytest.approx(400.0, rel=0.3)


def test_decay_rate_decays_when_idle():
    r = DecayRate(tau_s=1.0)
    for k in range(100):
        r.record(k * 0.01)
    busy = r.rate(1.0)
    assert r.rate(6.0) < 0.01 * busy    # 5 tau idle: rate nearly gone


def test_decay_gauge_converges_to_level():
    g = DecayGauge(tau_s=1.0)
    for k in range(200):
        g.sample(k * 0.05, 8.0)
    assert g.value() == pytest.approx(8.0)


def test_decayed_linear_fits_intercept_and_slope():
    m = DecayedLinear()
    for x, y in [(0.0, 1.0), (1.0, 3.0), (2.0, 5.0), (3.0, 7.0)]:
        m.add(x, y)
    assert m.predict(10.0) == pytest.approx(21.0, rel=0.05)


def test_decayed_linear_single_size_collapses_to_mean():
    m = DecayedLinear()
    for _ in range(5):
        m.add(2.0, 10.0)
    assert m.predict(2.0) == pytest.approx(10.0)
    assert m.predict(100.0) == pytest.approx(10.0)  # no slope signal: flat


# ---------------------------------------------------------------------------
# Deployment telemetry
# ---------------------------------------------------------------------------


def test_arrival_trend_positive_while_ramping():
    tel = DeploymentTelemetry(lambda: 0.0)
    t, dt = 0.0, 0.1
    while t < 4.0:
        tel.record_arrival(t, 1)
        dt = max(0.002, dt * 0.97)      # accelerating arrivals
        t += dt
    rate, slope = tel.arrival_trend(t)
    assert rate > 0
    assert slope > 0


def test_trend_flat_on_steady_load():
    tel = DeploymentTelemetry(lambda: 0.0)
    for k in range(500):
        tel.record_arrival(k * 0.02, 1)  # 50/s steady
    rate, slope = tel.arrival_trend(10.0)
    assert rate == pytest.approx(50.0, rel=0.15)
    assert abs(slope) < 0.2 * rate


def test_snapshot_reports_cold_starts_and_concurrency():
    tel = DeploymentTelemetry(lambda: 0.0)
    for k in range(100):
        tel.record_arrival(k * 0.1, 4)
    tel.record_cold_start(9.9)
    snap = tel.snapshot(10.0)
    assert snap["n_arrivals"] == 100
    assert snap["concurrency"] == pytest.approx(4.0)
    assert snap["cold_start_rate"] > 0


# ---------------------------------------------------------------------------
# Medium telemetry + hub
# ---------------------------------------------------------------------------


def test_medium_telemetry_latency_and_fee_models():
    tel = MediumTelemetry()
    # per-op-dominated medium: fee flat in size, latency grows with size
    for nbytes, secs in [(1 << 20, 0.05), (8 << 20, 0.12), (32 << 20, 0.40)]:
        tel.record(nbytes, secs, transfer_fee_usd("s3", nbytes))
    assert tel.n == 3
    assert tel.predict_seconds(8 << 20) == pytest.approx(0.12, rel=0.5)
    # the fee model learns the per-object (intercept) structure
    assert tel.predict_fee_usd(16 << 20) == pytest.approx(
        transfer_fee_usd("s3", 16 << 20), rel=0.2
    )
    assert tel.p99_s() == pytest.approx(0.40)
    assert tel.usd_per_gb() > 0


def test_hub_create_on_first_use_and_sampling_flag():
    sim = Simulator()
    hub = TelemetryHub(VirtualClock(sim))
    assert not hub.has_media_samples()
    hub.record_transfer("xdt", 1 << 20, 0.01, 0.0)
    assert hub.has_media_samples()
    assert hub.medium("xdt").n == 1
    assert "xdt" in hub.media_snapshot()
    dep = hub.deployment("f")
    assert hub.deployment("f") is dep   # cached, clock shared


# ---------------------------------------------------------------------------
# Batched arrivals + tenant namespace
# ---------------------------------------------------------------------------


def test_record_n_matches_n_single_records():
    a, b = DecayRate(tau_s=2.0), DecayRate(tau_s=2.0)
    for t, n in [(0.1, 3), (0.4, 1), (0.45, 7), (2.0, 2)]:
        a.record_n(t, n)
        for _ in range(n):
            b.record(t)
    assert a.rate(3.0) == pytest.approx(b.rate(3.0))


def test_record_arrivals_matches_loop_of_record_arrival():
    batched = DeploymentTelemetry(lambda: 0.0)
    looped = DeploymentTelemetry(lambda: 0.0)
    for t, n in [(0.0, 5), (0.5, 2), (0.55, 9)]:
        batched.record_arrivals(t, n, in_flight=n)
        for _ in range(n):
            looped.record_arrival(t, n)
    sa, sb = batched.snapshot(1.0), looped.snapshot(1.0)
    assert sa["n_arrivals"] == sb["n_arrivals"] == 16
    assert sa["arrival_rps"] == pytest.approx(sb["arrival_rps"])
    assert sa["arrival_slope_rps_per_s"] == pytest.approx(
        sb["arrival_slope_rps_per_s"]
    )


def test_hub_tenant_namespace_is_separate_from_deployments():
    hub = TelemetryHub(lambda: 0.0)
    dep = hub.deployment("acme")
    ten = hub.tenant("acme")
    assert dep is not ten
    assert hub.tenant("acme") is ten           # create-on-first-use cache
    ten.record_arrivals(0.0, 4)
    snap = hub.tenants_snapshot()
    assert snap["acme"]["n_arrivals"] == 4
    # deployment-side counters untouched by tenant-side records
    assert hub.deployment("acme").n_arrivals == 0
