"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, registry, smoke_config
from repro.data import ShardedLoader
from repro.models import (
    cache_shapes,
    init_params,
    make_decode_fn,
    make_loss_fn,
    make_prefill_fn,
    param_shapes,
)
from repro.optim import OptConfig, adamw_init
from repro.train import make_train_step

ASSIGNED_DIMS = {
    # arch: (L, d_model, H, KV, d_ff, vocab)  — assignment-fixed numbers
    "smollm_360m": (32, 960, 15, 5, 2560, 49152),
    "granite_8b": (36, 4096, 32, 8, 14336, 49152),
    "qwen3_4b": (36, 2560, 32, 8, 9728, 151936),
    "starcoder2_15b": (40, 6144, 48, 4, 24576, 49152),
    "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
    "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163840),
    "falcon_mamba_7b": (64, 4096, None, None, 0, 65024),
    "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
    "llava_next_mistral_7b": (32, 4096, 32, 8, 14336, 32000),
    "zamba2_1p2b": (38, 2048, 32, 32, 8192, 32000),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    L, d, H, KV, ff, V = ASSIGNED_DIMS[arch]
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == V
    if H is not None:
        assert cfg.n_heads == H and cfg.n_kv_heads == KV
    assert cfg.d_ff == ff


def test_registry_covers_all_ten():
    assert len(registry()) == 10


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One forward/train step on CPU: correct shapes, finite loss."""
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = ShardedLoader(cfg, global_batch=4, seq_len=16).batch_at(0)
    step = make_train_step(cfg, None, OptConfig(warmup_steps=2, total_steps=8),
                           remat="none", donate=False)
    p2, o2, m = step(params, adamw_init(params), batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == b.shape


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_loss_decreases(arch):
    """A few steps on a fixed batch must reduce the loss (learnable system)."""
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = ShardedLoader(cfg, global_batch=4, seq_len=16).batch_at(0)
    step = make_train_step(cfg, None,
                           OptConfig(peak_lr=3e-3, warmup_steps=1, total_steps=30),
                           remat="none", donate=False)
    opt = adamw_init(params)
    first = None
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        first = float(m["loss"]) if first is None else first
    assert float(m["loss"]) < first


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if get_config(a).has_decode])
def test_prefill_decode_consistency(arch):
    """Greedy decode over a prompt == argmax of the teacher-forced forward.

    prefill(prompt[:n]) then decode(token n) must give the same logits as
    prefill(prompt[:n+1])'s last position — the KV/state handoff is exact.
    """
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompt = np.arange(1, 9) % cfg.vocab
    pad_to = 16 + cfg.frontend_seq
    prefill = make_prefill_fn(cfg, None, remat="none", pad_to=pad_to)
    decode = make_decode_fn(cfg, None)

    def batch(toks):
        b = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":      # modality frontend stub: fixed patches
            rng = np.random.default_rng(0)
            b["patches"] = jnp.asarray(
                rng.standard_normal((1, cfg.frontend_seq, cfg.d_model)) * 0.1,
                jnp.bfloat16,
            )
        return b

    logits_a, cache = prefill(params, batch(prompt[None, :-1]))
    logits_b, _ = decode(params, cache, jnp.asarray(prompt[None, -1:], jnp.int32))

    logits_full, _ = prefill(params, batch(prompt[None]))
    np.testing.assert_allclose(
        np.asarray(logits_b, np.float32), np.asarray(logits_full, np.float32),
        rtol=0.05, atol=0.15,
    )


def test_encoder_has_no_decode():
    cfg = get_config("hubert_xlarge")
    assert not cfg.has_decode
    with pytest.raises(AssertionError):
        from repro.serving import ServingEngine

        ServingEngine(cfg, {}, max_batch=1)


def test_subquadratic_flags():
    """Only SSM/hybrid archs run long_500k (DESIGN §Arch-applicability)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        expect = cfg.family in ("ssm", "hybrid")
        assert cfg.subquadratic == expect, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_sane(arch):
    """n_params within a loose band of the arch's advertised size."""
    expected = {
        "smollm_360m": 0.36e9, "granite_8b": 8e9, "qwen3_4b": 4e9,
        "starcoder2_15b": 15e9, "llama4_scout_17b_a16e": 17e9 * 6,  # total w/ experts
        "moonshot_v1_16b_a3b": 16e9, "falcon_mamba_7b": 7e9,
        "hubert_xlarge": 1e9, "llava_next_mistral_7b": 7e9, "zamba2_1p2b": 1.2e9,
    }[arch]
    n = get_config(arch).n_params()
    assert 0.3 * expected < n < 3.0 * expected, (arch, n, expected)


def test_moe_active_params_less_than_total():
    cfg = get_config("moonshot_v1_16b_a3b")
    assert cfg.n_active_params() < 0.35 * cfg.n_params()   # 16B total / ~3B active


def test_cache_shapes_cover_families():
    for arch, keys in [
        ("granite_8b", {"k", "v", "pos"}),
        ("falcon_mamba_7b", {"conv", "ssm", "pos"}),
        ("zamba2_1p2b", {"k", "v", "conv", "ssm", "pos"}),
    ]:
        cfg = smoke_config(arch)
        assert set(cache_shapes(cfg, 2, 8)) == keys


def test_param_shapes_match_init():
    cfg = smoke_config("qwen3_4b")
    shapes = param_shapes(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    is_spec = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)

    def walk(s, p):
        if is_spec(s):
            assert tuple(p.shape) == s[0]
            return
        for k in s:
            walk(s[k], p[k])

    walk(shapes, params)


def test_qk_norm_present_only_for_qwen():
    assert get_config("qwen3_4b").qk_norm
    assert not get_config("granite_8b").qk_norm
    p = param_shapes(smoke_config("qwen3_4b"))
    assert "q_norm" in p["blocks"]["attn"]


def test_vlm_prefix_changes_logits():
    """The VLM patch prefix must actually condition the text logits."""
    cfg = smoke_config("llava_next_mistral_7b")
    params = init_params(cfg, jax.random.PRNGKey(2))
    loss_fn = make_loss_fn(cfg, None, remat="none")
    B, S_img, S_txt = 2, cfg.frontend_seq, 8
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S_txt)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S_txt)), jnp.int32),
        "patches": jnp.asarray(rng.standard_normal((B, S_img, cfg.d_model)) * 0.1,
                               jnp.bfloat16),
    }
    l1 = float(loss_fn(params, batch))
    batch2 = dict(batch, patches=batch["patches"] * 3.0 + 1.0)
    l2 = float(loss_fn(params, batch2))
    assert l1 != l2
