"""Graph optimizer (core/dagopt.py): fusion, co-placement, predictive spill.

Four families of guarantees:

* **Fusion is guarded** — 1:1 sync chains merge (compute summed, edge
  deleted outright), but never across evictable, external, fan, or
  orchestration boundaries, and never when the stages' scaling policies
  differ.
* **Optimized dominates** — on fixed seeds the optimized VID/SET/MR runs
  are never costlier and never slower than the un-optimized ones on any
  single backend (MR must be a structural no-op).
* **Co-placement is honored end to end** — the scheduler's
  ``steer(prefer=...)`` picks the producer's node when slots allow, both
  lowerings count the edge's pulls as local, and the cluster lowering's
  shared-memory path is faster than the NIC path it replaces.
* **Predictive spill closes the retry loop** — with a telemetry feed
  showing producer reaps + consumer cold starts, the staged edge is
  rewritten durable and a ``kill_producer`` mid-run no longer forces the
  producer-death retry (the un-optimized run dies with zero retries
  allowed; the optimized one completes).

The un-optimized path's bit-for-bit goldens stay in ``tests/test_dag.py``
— this file only ever hands plans to runs that asked for them.
"""
import math

import pytest

from repro.core.dag import Edge, SizeRoute, Stage, WorkflowDAG, execute_on_cluster
from repro.core.dagopt import (
    CoPlacement,
    PlacementPlan,
    PredictiveSpill,
    SyncChainFusion,
    optimize,
)
from repro.core.errors import RetriesExhausted
from repro.core.scheduler import ControlPlane, ScalingPolicy
from repro.core.telemetry import TelemetryHub
from repro.core.workflow import WorkflowEngine
from repro.core.workloads import BACKENDS, DAGS


def _chain(**kw):
    """a --sync--> b --sync--> c, all fan 1 (the maximally fusible chain)."""
    stages = [
        Stage("a", compute_s=0.1),
        Stage("b", compute_s=0.2, **kw),
        Stage("c", compute_s=0.3),
    ]
    edges = [
        Edge("a", "b", 1 << 20, label="ab", handoff="sync"),
        Edge("b", "c", 1 << 20, label="bc", handoff="sync"),
    ]
    return WorkflowDAG("chain", stages, edges)


# ---------------------------------------------------------------------------
# SyncChainFusion
# ---------------------------------------------------------------------------


def test_fusion_merges_whole_sync_chain():
    dag = _chain()
    opt, plan = optimize(dag, passes=("fuse",))
    assert [s.name for s in opt.stages] == ["a+b+c"]
    assert opt.edges == ()
    assert opt.stages[0].compute_s == pytest.approx(0.6)
    assert plan.fused == {"a+b+c": ("a", "b", "c")}
    assert set(plan.eliminated) == {"ab", "bc"}
    # provenance stays resolvable after re-fusion: every eliminated edge
    # points at the chain's FINAL stage name, not a dangling intermediate
    for absorbed_into in plan.eliminated.values():
        assert absorbed_into in plan.fused


def test_fusion_refuses_fan_boundary():
    dag = WorkflowDAG(
        "d",
        [Stage("p", compute_s=0.1), Stage("w", fan=4, compute_s=0.1)],
        [Edge("p", "w", 1 << 20, label="scatter", handoff="sync")],
    )
    opt, plan = optimize(dag, passes=("fuse",))
    assert opt.by_name.keys() == dag.by_name.keys()
    assert not plan.fused
    assert any("fan boundary" in n for n in plan.notes)


def test_fusion_refuses_evictable_boundary():
    opt, plan = optimize(_chain(evictable=True), passes=("fuse",))
    # b is evictable: neither ab nor bc may fuse across it
    assert "b" in opt.by_name
    assert not plan.fused or all(
        "b" not in members for members in plan.fused.values()
    )
    assert any("evictable boundary" in n for n in plan.notes)


def test_fusion_refuses_external_and_staged_edges():
    dag = WorkflowDAG(
        "d",
        [Stage("driver"), Stage("m", fan=1, blocking=False)],
        [Edge(None, "m", 1 << 20, label="in", handoff="external", route="s3")],
    )
    opt, plan = optimize(dag, passes=("fuse",))
    assert not plan.fused            # external edges are not chains at all
    staged = WorkflowDAG(
        "d",
        [Stage("driver"), Stage("w", fan=1, blocking=False)],
        [Edge("driver", "w", 1 << 20, label="bulk", handoff="staged")],
    )
    opt, plan = optimize(staged, passes=("fuse",))
    assert not plan.fused            # only sync handoffs fuse


def test_fusion_refuses_producers_with_side_edges():
    """A producer with any other out-edge must not fuse: the sibling's
    data would be published after the fused (summed) compute — fusing
    could SLOW the graph, which the pass's contract forbids."""
    sibling = WorkflowDAG(
        "d",
        [Stage("p", compute_s=0.1), Stage("c", compute_s=0.5),
         Stage("d", fan=2, compute_s=0.1)],
        [Edge("p", "c", 1 << 10, label="pc", handoff="sync"),
         Edge("p", "d", 1 << 20, label="pd", handoff="sync")],
    )
    opt, plan = optimize(sibling, passes=("fuse",))
    assert not plan.fused
    assert any("other out-edges" in n for n in plan.notes)
    # two fan-1 sync children previously ran CONCURRENTLY: also refused
    twins = WorkflowDAG(
        "d",
        [Stage("p", compute_s=0.1), Stage("c1", compute_s=0.5),
         Stage("c2", compute_s=0.5)],
        [Edge("p", "c1", 1 << 10, label="pc1", handoff="sync"),
         Edge("p", "c2", 1 << 10, label="pc2", handoff="sync")],
    )
    opt, plan = optimize(twins, passes=("fuse",))
    assert not plan.fused


def test_coplacement_slots_bound_is_per_producer_node():
    """Two consumer stages affined to one producer count against ONE
    node's slot budget — the bound is per node, not per edge."""
    dag = WorkflowDAG(
        "d",
        [Stage("p", compute_s=0.1),
         Stage("a", fan=5, compute_s=0.1, blocking=False),
         Stage("b", fan=5, compute_s=0.1, blocking=False)],
        [Edge("p", "a", 1 << 20, label="pa", handoff="staged"),
         Edge("p", "b", 1 << 20, label="pb", handoff="staged")],
    )
    _, plan = CoPlacement(slots_per_node=8).apply(dag, PlacementPlan())
    assert plan.affinity == {"a": "p"}        # b would overflow the node
    assert any("already packed" in n for n in plan.notes)
    _, plan2 = CoPlacement(slots_per_node=10).apply(dag, PlacementPlan())
    assert plan2.affinity == {"a": "p", "b": "p"}


def test_fusion_refuses_incompatible_scaling_policies():
    scaling = lambda s: ScalingPolicy(
        max_instances=4 if s.name == "b" else 64, target_concurrency=1
    )
    opt, plan = optimize(_chain(), passes=("fuse",), scaling=scaling)
    assert not plan.fused
    assert any("incompatible scaling" in n for n in plan.notes)
    # a uniform factory fuses the whole chain again
    opt, plan = optimize(
        _chain(), passes=("fuse",), scaling=lambda s: ScalingPolicy()
    )
    assert plan.fused == {"a+b+c": ("a", "b", "c")}


def test_fused_vid_eliminates_fragment_edge():
    opt, plan = DAGS["vid"].optimize(passes=("fuse",))
    assert plan.fused == {"streaming+decoder": ("streaming", "decoder")}
    assert plan.eliminated == {"fragment": "streaming+decoder"}
    assert {e.label for e in opt.edges} == {"frames"}
    run = execute_on_cluster(opt, "s3", seed=0, deterministic=True)
    # the fused run performs NO storage ops for the dead edge: only frames
    assert run.edge_usage["frames"].n_puts == 4
    assert run.bill.n_invocations == 5          # was 6: one fewer function


# ---------------------------------------------------------------------------
# Optimized dominates (the fig10 gate, asserted here on fixed seeds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wl", list(DAGS))
@pytest.mark.parametrize("backend", BACKENDS)
def test_optimized_never_costlier_nor_slower(wl, backend):
    dag = DAGS[wl]
    opt, plan = dag.optimize()
    for seed in (0, 1):
        base = execute_on_cluster(dag, backend, seed=seed, deterministic=True)
        run = execute_on_cluster(
            opt, backend, seed=seed, deterministic=True, plan=plan
        )
        assert run.cost().total <= base.cost().total * (1 + 1e-12)
        assert run.latency_s <= base.latency_s * (1 + 1e-12)


def test_optimized_vid_strictly_dominates_on_xdt():
    """VID fuses + co-places: the win must be strict, not a wash."""
    dag = DAGS["vid"]
    opt, plan = dag.optimize()
    base = execute_on_cluster(dag, "xdt", seed=0, deterministic=True)
    run = execute_on_cluster(opt, "xdt", seed=0, deterministic=True, plan=plan)
    assert run.latency_s < base.latency_s
    assert run.cost().total < base.cost().total
    assert run.edge_usage["frames"].n_local == 4    # every recognizer local


def test_mr_is_a_structural_noop():
    """Nothing in MR fuses or co-places (shuffle pulls from every mapper);
    the optimizer must leave it bit-identical, not merely 'close'."""
    dag = DAGS["mr"]
    opt, plan = dag.optimize()
    assert plan.is_noop()
    base = execute_on_cluster(dag, "xdt", seed=3)
    run = execute_on_cluster(opt, "xdt", seed=3, plan=plan)
    assert run.latency_s == base.latency_s
    assert run.cost().total == base.cost().total


# ---------------------------------------------------------------------------
# Co-placement: scheduler + both lowerings
# ---------------------------------------------------------------------------


def test_steer_prefer_picks_affine_instance_when_slots_allow():
    t = [0.0]
    cp = ControlPlane(clock=lambda: t[0])
    dep = cp.register("w", ScalingPolicy(max_instances=8, target_concurrency=1,
                                         cold_start_s=0.0))
    a, _ = dep.steer()
    b, _ = dep.steer()
    dep.release(a.instance_id)
    dep.release(b.instance_id)
    # both idle: prefer b's coords and the affine path must return b, not
    # the heap's least-loaded tie-break (lowest instance id = a)
    inst, wait = dep.steer(prefer=b.coords)
    assert inst.instance_id == b.instance_id
    assert dep.stats["affine_hits"] == 1
    # b is now saturated (target_concurrency=1): the hint falls back
    inst2, _ = dep.steer(prefer=b.coords)
    assert inst2.instance_id != b.instance_id
    assert dep.stats["affine_hits"] == 1


def test_steer_prefer_ignores_cold_instances():
    t = [0.0]
    cp = ControlPlane(clock=lambda: t[0])
    dep = cp.register("w", ScalingPolicy(max_instances=8, target_concurrency=1,
                                         cold_start_s=5.0))
    cold, _ = dep.steer()                 # spawns cold, ready at t=5
    # the affine hint must not wait on a booting instance
    inst, _ = dep.steer(prefer=cold.coords)
    assert inst.instance_id != cold.instance_id
    assert dep.stats["affine_hits"] == 0


def test_coplacement_skips_multi_producer_and_oversized_fans():
    plan = PlacementPlan()
    dag = DAGS["mr"]
    _, plan = CoPlacement().apply(dag, plan)
    assert not plan.affinity
    big = WorkflowDAG(
        "d",
        [Stage("p", compute_s=0.1),
         Stage("w", fan=9, compute_s=0.1, blocking=False)],
        [Edge("p", "w", 1 << 20, label="bulk", handoff="staged")],
    )
    _, plan2 = CoPlacement(slots_per_node=8).apply(big, PlacementPlan())
    assert not plan2.affinity
    assert any("slots/node" in n for n in plan2.notes)
    _, plan3 = CoPlacement(slots_per_node=9).apply(big, PlacementPlan())
    assert plan3.affinity == {"w": "p"}


def test_cluster_local_pull_beats_nic_path():
    """SET on xdt: co-placement must strictly cut the broadcast time."""
    dag = DAGS["set"]
    opt, plan = dag.optimize(passes=("coplace",))
    assert plan.affinity == {"trainer": "driver"}
    base = execute_on_cluster(dag, "xdt", seed=0, deterministic=True)
    run = execute_on_cluster(opt, "xdt", seed=0, deterministic=True, plan=plan)
    assert run.latency_s < base.latency_s * 0.75
    u = run.edge_usage["dataset"]
    assert u.n_local == u.media.get("xdt")      # every dataset pull was local
    # storage-routed runs are untouched by affinity: identical latency
    s3_base = execute_on_cluster(dag, "s3", seed=0, deterministic=True)
    s3_run = execute_on_cluster(opt, "s3", seed=0, deterministic=True, plan=plan)
    assert s3_run.latency_s == s3_base.latency_s


def test_engine_binding_honors_affinity_and_counts_local_pulls():
    dag = DAGS["vid"]
    opt, plan = dag.optimize()
    eng = WorkflowEngine(backend="xdt")
    binding = opt.bind(eng, default_route=SizeRoute(), bytes_scale=1e-4,
                       plan=plan)
    for _ in range(4):                   # sequential: fleets stay warm
        eng.run(binding.entry, 1.0)
    eng.assert_at_most_once()
    assert binding.edge_usage["frames"].n_local >= 4
    dep = eng.control.deployments["vid.recognition"]
    assert dep.stats["affine_hits"] > 0
    assert eng.transfer.stats.local_pulls == (
        sum(u.n_local for u in binding.edge_usage.values())
    )


def test_engine_binding_honors_wave_to_wave_affinity():
    """Both lowerings must honor the SAME plan: an edge whose producer is a
    wave stage (not the entry) still gets its affinity hint on the engine —
    the entry forwards the producer's coords from its result."""
    dag = WorkflowDAG(
        "waves",
        [Stage("e", compute_s=0.0),
         Stage("a", fan=1, compute_s=0.01, blocking=False),
         Stage("b", fan=2, compute_s=0.01, blocking=False)],
        [Edge("e", "a", 1 << 16, label="ea", handoff="staged"),
         Edge("a", "b", 1 << 16, label="ab", handoff="staged")],
    )
    opt, plan = dag.optimize(passes=("coplace",))
    assert plan.affinity == {"a": "e", "b": "a"}
    # cluster lowering: b's pulls from a are local
    run = execute_on_cluster(opt, "xdt", seed=0, deterministic=True, plan=plan)
    assert run.edge_usage["ab"].n_local == 2
    # engine lowering: the same edge is local too (coords forwarded)
    eng = WorkflowEngine(backend="xdt")
    binding = opt.bind(eng, default_route="xdt", bytes_scale=1e-2, plan=plan)
    for _ in range(3):
        eng.run(binding.entry, 1.0)
    eng.assert_at_most_once()
    assert binding.edge_usage["ab"].n_local > 0


def test_engine_plan_for_wrong_dag_is_rejected():
    plan = PlacementPlan(affinity={"ghost": "nobody"})
    eng = WorkflowEngine(backend="xdt")
    with pytest.raises(ValueError, match="unknown stage"):
        DAGS["vid"].bind(eng, plan=plan)
    with pytest.raises(ValueError, match="unknown stage"):
        execute_on_cluster(DAGS["vid"], "xdt", plan=plan)


# ---------------------------------------------------------------------------
# Predictive spill
# ---------------------------------------------------------------------------


def _spill_scenario():
    dag = WorkflowDAG(
        "flaky",
        [Stage("p", compute_s=0.0),
         Stage("w", fan=2, compute_s=0.0, blocking=False)],
        [Edge("p", "w", 1 << 16, label="d", handoff="staged")],
    )
    t = [0.0]
    hub = TelemetryHub(lambda: t[0])
    for i in range(20):
        t[0] = i * 0.05
        # the producer fleet is being reaped hard, consumers cold-start on
        # every arrival: the keep-alive race is predictably lost
        hub.deployment("p").record_reap(t[0])
        hub.deployment("w").record_arrival(t[0], 0)
        hub.deployment("w").record_cold_start(t[0])
    return dag, hub


def test_reap_window_feeds_lifetime_prediction():
    t = [0.0]
    hub = TelemetryHub(lambda: t[0])
    feed = hub.deployment("p")
    assert feed.expected_instance_lifetime_s(0.0) == math.inf
    for i in range(10):
        t[0] = i * 0.1
        feed.record_reap(t[0])
    life = feed.expected_instance_lifetime_s(t[0])
    assert 0.0 < life < 1.0
    assert feed.snapshot()["n_reaps"] == 10.0


def test_scheduler_records_reaps_into_telemetry():
    t = [0.0]
    cp = ControlPlane(clock=lambda: t[0])
    dep = cp.register("w", ScalingPolicy(
        max_instances=4, keep_alive_s=1.0, cold_start_s=0.0, autoscaler="rps",
    ))
    inst, _ = dep.steer()
    dep.release(inst.instance_id)
    t[0] = 5.0                           # idle past keep-alive
    dep.steer()                          # reaps on entry
    assert dep.stats["scale_downs"] >= 1
    assert dep.telemetry.n_reaps == dep.stats["scale_downs"]


def test_spill_rewrites_staged_edge_to_durable():
    dag, hub = _spill_scenario()
    opt, plan = dag.optimize(telemetry=hub)
    assert plan.spilled == {"d": "s3"}
    assert opt.edges[0].route == "s3"
    # the original declaration is untouched
    assert dag.edges[0].route == "default"


def test_spill_never_guesses_without_telemetry():
    dag, hub = _spill_scenario()
    opt, plan = dag.optimize()                    # no hub
    assert not plan.spilled
    # a healthy feed (no reaps, no cold starts) also spills nothing
    t = [0.0]
    healthy = TelemetryHub(lambda: t[0])
    healthy.deployment("p")
    healthy.deployment("w")
    opt, plan = dag.optimize(telemetry=healthy)
    assert not plan.spilled


def test_spill_respects_pinned_durable_and_evictable_edges():
    t = [99.0]
    hub = TelemetryHub(lambda: t[0])
    hub.deployment("p").record_reap(99.0)
    pinned = WorkflowDAG(
        "d", [Stage("p"), Stage("w", blocking=False)],
        [Edge("p", "w", 1 << 16, label="d", handoff="staged", route="s3")],
    )
    _, plan = PredictiveSpill(telemetry=hub).apply(pinned, PlacementPlan())
    assert not plan.spilled
    evict = WorkflowDAG(
        "d", [Stage("p", evictable=True), Stage("w", blocking=False)],
        [Edge("p", "w", 1 << 16, label="d", handoff="staged")],
    )
    _, plan = PredictiveSpill(telemetry=hub).apply(evict, PlacementPlan())
    assert not plan.spilled
    assert any("evictable" in n for n in plan.notes)


def test_spill_saves_the_producer_death_retry():
    """The acceptance test: kill the producer after its puts.  Un-optimized
    (instance-resident medium) the run dies with retries disabled; the
    spilled edge survives in durable storage and completes first try."""
    dag, hub = _spill_scenario()
    opt, plan = dag.optimize(telemetry=hub)

    def run_with_kill(the_dag, the_plan):
        eng = WorkflowEngine(backend="xdt", max_retries=0)
        binding = the_dag.bind(
            eng, default_route="xdt", bytes_scale=1e-1, plan=the_plan
        )
        orig = binding._put_for_consumers
        killed = []

        def sabotage(ctx, edge, fill):
            out = orig(ctx, edge, fill)
            if not killed:
                killed.append(True)
                eng.transfer.kill_producer()
            return out

        binding._put_for_consumers = sabotage
        result = eng.run(binding.entry, 1.0)
        assert killed
        eng.assert_at_most_once()
        return result

    with pytest.raises(RetriesExhausted):
        run_with_kill(dag, None)
    run_with_kill(opt, plan)             # spilled: completes, zero retries


# ---------------------------------------------------------------------------
# optimize() plumbing
# ---------------------------------------------------------------------------


def test_optimize_rejects_unknown_pass():
    with pytest.raises(ValueError, match="pass must be one of"):
        optimize(DAGS["vid"], passes=("nope",))


def test_optimize_accepts_pass_instances_and_preserves_order():
    opt, plan = optimize(
        DAGS["vid"], passes=(SyncChainFusion(), CoPlacement(slots_per_node=2)),
    )
    assert plan.fused                    # fuse ran
    assert not plan.affinity             # 4 recognizers > 2 slots: withheld
    assert any("slots/node" in n for n in plan.notes)


def test_registered_pass_overrides_builtin_name():
    """register_pass documents idempotent overwrite: a class registered
    over a stock name must actually run in place of the built-in."""
    from repro.core.dagopt import GraphPass, _PASS_REGISTRY, register_pass

    ran = []

    class NoSpill(GraphPass):
        name = "spill"

        def apply(self, dag, plan):
            ran.append(True)
            return dag, plan

    original = _PASS_REGISTRY["spill"]
    try:
        register_pass(NoSpill)
        optimize(DAGS["set"], passes=("spill",))
        assert ran
    finally:
        register_pass(original)
        assert _PASS_REGISTRY["spill"] is PredictiveSpill


def test_plan_describe_is_human_readable():
    _, plan = DAGS["vid"].optimize()
    text = plan.describe()
    assert "streaming+decoder" in text and "recognition" in text
    assert PlacementPlan().describe() == "no-op"
