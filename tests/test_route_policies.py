"""RoutePolicy edge cases: SizeRoute handoff rules, evictable durability
across producer-death retries, AdaptiveRoute fallback + feedback routing."""
import numpy as np
import pytest

from repro.core import WorkflowEngine
from repro.core.dag import (
    AdaptiveRoute,
    Edge,
    SizeRoute,
    Stage,
    WorkflowDAG,
    execute_on_cluster,
)
from repro.core.cost import transfer_fee_usd
from repro.core.errors import RetriesExhausted
from repro.core.scheduler import ScalingPolicy
from repro.core.telemetry import TelemetryHub


def _edge(**kw):
    kw.setdefault("src", "p")
    kw.setdefault("dst", "c")
    kw.setdefault("nbytes", 64)
    return Edge(**kw)


# ---------------------------------------------------------------------------
# SizeRoute: inline only exists on sync handoffs
# ---------------------------------------------------------------------------


def test_sizeroute_inlines_only_small_sync_objects():
    r = SizeRoute(inline_under=1 << 10)
    assert r.resolve(_edge(handoff="sync"), 64, False) == "inline"
    assert r.resolve(_edge(handoff="sync"), 1 << 20, False) == "xdt"


@pytest.mark.parametrize("handoff", ["staged", "external"])
def test_sizeroute_never_inlines_staged_or_external(handoff):
    """Inline only exists where an invoke accompanies the payload: staged
    fan-in/out edges fetch without one, and external input predates the
    workflow entirely."""
    r = SizeRoute(inline_under=1 << 30)      # everything is "small enough"
    src = None if handoff == "external" else "p"
    medium = r.resolve(_edge(src=src, handoff=handoff), 64, False)
    assert medium != "inline"
    if handoff == "external":
        assert medium == r.durable           # storage only: durable default


def test_sizeroute_evictable_producer_goes_durable():
    r = SizeRoute()
    for handoff in ("sync", "staged"):
        src = "p"
        assert r.resolve(_edge(src=src, handoff=handoff), 64, True) == "s3"


# ---------------------------------------------------------------------------
# Evictable producers stay durable across producer-death retries
# ---------------------------------------------------------------------------


def _death_engine(medium, deaths):
    """producer puts on `medium`; the producer instance dies before the
    consumer's get on the first `deaths` attempts."""
    eng = WorkflowEngine()
    state = {"left": deaths}

    def flow(ctx, x):
        ref = ctx.put(np.ones(8, np.float32), n_retrievals=1, backend=medium)
        if state["left"] > 0:
            state["left"] -= 1
            eng.transfer.kill_producer()
        return float(np.sum(ctx.get(ref)))

    eng.register("flow", flow, policy=ScalingPolicy(max_instances=4))
    return eng


def test_evictable_routing_survives_producer_death_and_retries():
    """The durable medium an evictable producer's edge resolves to really is
    durable: the object outlives kill_producer() on every retry attempt."""
    route = SizeRoute()
    medium = route.resolve(_edge(handoff="staged"), 2 << 20, True)
    eng = _death_engine(medium, deaths=3)    # > max_retries: EVERY attempt
    assert eng.run("flow", 0) == 8.0         # first attempt already survives
    eng.assert_at_most_once()


def test_instance_resident_medium_dies_with_producer_for_contrast():
    route = SizeRoute()
    medium = route.resolve(_edge(handoff="staged"), 2 << 20, False)
    assert medium == "xdt"
    eng = _death_engine(medium, deaths=3)    # dies on every retry too
    with pytest.raises(RetriesExhausted):
        eng.run("flow", 0)


def test_engine_retry_recovers_when_death_is_transient():
    route = SizeRoute()
    medium = route.resolve(_edge(handoff="staged"), 2 << 20, False)
    eng = _death_engine(medium, deaths=1)    # only the first attempt dies
    assert eng.run("flow", 0) == 8.0
    assert eng.executed_count("flow") == 2   # the orchestrator retried


# ---------------------------------------------------------------------------
# AdaptiveRoute
# ---------------------------------------------------------------------------


def test_adaptive_falls_back_to_static_without_samples():
    hub = TelemetryHub()
    r = AdaptiveRoute(telemetry=hub)
    edge_small = _edge(handoff="sync", nbytes=64)
    edge_big = _edge(handoff="sync", nbytes=64 << 20)
    assert not hub.has_media_samples()
    # empty feed: exactly the static SizeRoute decision
    assert r.resolve(edge_small, 64, False) == "inline"
    assert r.resolve(edge_big, 64 << 20, False) == "xdt"
    assert r.resolve(edge_big, 64 << 20, True) == "s3"
    # unbound hub behaves the same
    assert AdaptiveRoute().resolve(edge_small, 64, False) == "inline"


def test_adaptive_picks_cheapest_observed_medium():
    hub = TelemetryHub()
    nb = 8 << 20
    hub.record_transfer("s3", nb, 0.5, transfer_fee_usd("s3", nb))
    hub.record_transfer("xdt", nb, 0.05, 0.0)
    r = AdaptiveRoute(telemetry=hub)
    assert r.resolve(_edge(handoff="staged", nbytes=nb), nb, False) == "xdt"


def test_adaptive_respects_latency_budget():
    """With a budget only media whose observed p99 fits are eligible; the
    cheapest of those wins even when a cheaper-but-slower one exists."""
    hub = TelemetryHub()
    nb = 8 << 20
    for _ in range(4):
        hub.record_transfer("xdt", nb, 0.30, 0.0)   # free but slow (observed)
        hub.record_transfer("s3", nb, 0.60, transfer_fee_usd("s3", nb))
        hub.record_transfer("elasticache", nb, 0.02,
                            transfer_fee_usd("elasticache", nb))
    r = AdaptiveRoute(telemetry=hub)
    tight = _edge(handoff="staged", nbytes=nb, latency_budget_s=0.1)
    loose = _edge(handoff="staged", nbytes=nb, latency_budget_s=1.0)
    assert r.resolve(tight, nb, False) == "elasticache"
    assert r.resolve(loose, nb, False) == "xdt"


def test_adaptive_probe_recovers_mispriced_medium():
    """The decaying exploration probe: one freak observation must not lock
    a medium out forever.  A pure-observed router (explore_every=0) never
    re-tries the loser; the probing router steers occasional traffic back
    to it, the honest samples wash the freak out of the fee model, and the
    medium recovers the traffic on its own merits."""
    nb = 64 << 10
    honest_ec = transfer_fee_usd("elasticache", nb)      # ~1.3e-6: cheapest
    honest_s3 = transfer_fee_usd("s3", nb)
    freak = 4.0 * honest_s3            # one mispriced pull made EC look dear

    def feed(hub, m):
        fee = honest_ec if m == "elasticache" else honest_s3
        hub.record_transfer(m, nb, 0.02, fee)

    def run(route, hub, n):
        picks = []
        for _ in range(n):
            m = route.resolve(_edge(handoff="staged", nbytes=nb), nb, True)
            picks.append(m)
            feed(hub, m)               # the steered pull feeds the hub
        return picks

    hub = TelemetryHub()
    hub.record_transfer("elasticache", nb, 2.0, freak)
    for _ in range(8):
        feed(hub, "s3")
    locked = AdaptiveRoute(telemetry=hub, explore_every=0)
    assert set(run(locked, hub, 48)) == {"s3"}           # locked out forever

    hub = TelemetryHub()
    hub.record_transfer("elasticache", nb, 2.0, freak)
    for _ in range(8):
        feed(hub, "s3")
    r = AdaptiveRoute(telemetry=hub, explore_every=4, explore_growth=1.5)
    picks = run(r, hub, 48)
    assert "elasticache" in picks                        # a probe re-tried it
    assert picks[-1] == "elasticache"                    # ...and it won back
    # with the model recovered, even a probe-free router now agrees
    again = AdaptiveRoute(telemetry=hub, explore_every=0)
    assert again.resolve(_edge(handoff="staged", nbytes=nb), nb, True) == \
        "elasticache"


def test_adaptive_probe_never_fires_on_budget_edges():
    """Learning never risks an SLO: edges with a latency budget always get
    the scored pick, however skewed the observation counts."""
    nb = 64 << 10
    hub = TelemetryHub()
    hub.record_transfer("elasticache", nb, 2.0, 1.0)     # slow AND dear
    for _ in range(64):
        hub.record_transfer("s3", nb, 0.02, transfer_fee_usd("s3", nb))
    r = AdaptiveRoute(telemetry=hub, explore_every=1, explore_growth=1.0)
    budgeted = _edge(handoff="staged", nbytes=nb, latency_budget_s=0.1)
    assert all(r.resolve(budgeted, nb, True) == "s3" for _ in range(32))


def test_adaptive_hard_constraints_dominate_scores():
    hub = TelemetryHub()
    nb = 64
    hub.record_transfer("xdt", nb, 0.001, 0.0)
    r = AdaptiveRoute(telemetry=hub)
    # evictable: only durable media are candidates, however cheap xdt looks
    assert r.resolve(_edge(handoff="staged"), nb, True) in ("s3", "elasticache")
    # external: storage only
    ext = _edge(src=None, handoff="external")
    assert r.resolve(ext, nb, False) in ("s3", "elasticache")


def test_adaptive_on_cluster_lowering_matches_best_medium():
    """execute_on_cluster feeds the hub per resolved object, so within one
    run the router converges onto the cheapest feasible media; the adaptive
    run is never costlier than the best fixed single backend."""
    dag = WorkflowDAG(
        "w",
        [Stage("driver", compute_s=0.01),
         Stage("worker", fan=4, compute_s=0.02, blocking=False)],
        [Edge("driver", "worker", 4 << 20, label="d2w", handoff="staged",
              fanout="broadcast", n_objects=4)],
    )
    costs = {}
    for backend in ("s3", "elasticache", "xdt"):
        costs[backend] = execute_on_cluster(
            dag, backend, seed=0, deterministic=True
        ).cost().total
    route = AdaptiveRoute()
    run = execute_on_cluster(dag, route, seed=0, deterministic=True)
    assert route.telemetry is not None        # hub auto-bound
    assert route.telemetry.has_media_samples()
    assert run.cost().total <= min(costs.values()) * (1 + 1e-9)


def test_adaptive_route_rebinds_across_runs():
    """A route instance reused across cluster runs gets a FRESH run-local
    hub each time (auto-bound hubs are replaced, user-supplied ones kept),
    so a later cell never routes off an earlier run's dead feed."""
    dag = WorkflowDAG(
        "w3",
        [Stage("a", compute_s=0.0), Stage("b", blocking=True)],
        [Edge("a", "b", 1 << 20, label="ab", handoff="sync")],
    )
    route = AdaptiveRoute()
    execute_on_cluster(dag, route, seed=0, deterministic=True)
    first_hub = route.telemetry
    assert first_hub is not None and first_hub.has_media_samples()
    execute_on_cluster(dag, route, seed=1, deterministic=True)
    assert route.telemetry is not first_hub
    # an explicit user hub survives re-execution
    mine = TelemetryHub()
    pinned = AdaptiveRoute(telemetry=mine)
    execute_on_cluster(dag, pinned, seed=0, deterministic=True)
    assert pinned.telemetry is mine


def test_staged_media_sticky_from_put_to_get():
    """A stateful route whose answer drifts between the producer's put and
    the consumer's get must not split one object across media: the medium
    is decided once at stage time, so a storage GET can never be billed for
    an object that was never PUT to that service."""

    class Flappy(AdaptiveRoute):
        def __init__(self):
            super().__init__(telemetry=TelemetryHub())
            self.calls = 0

        def resolve(self, edge, nbytes, evictable):
            self.calls += 1
            return "s3" if self.calls % 2 else "xdt"   # flips every resolve

    dag = WorkflowDAG(
        "w4",
        [Stage("driver", compute_s=0.01),
         Stage("worker", fan=2, compute_s=0.01, blocking=False)],
        [Edge("driver", "worker", 1 << 20, label="d2w", handoff="staged",
              fanout="partition", n_objects=3)],
    )
    run = execute_on_cluster(dag, Flappy(), seed=0, deterministic=True)
    u = run.edge_usage["d2w"]
    # every object fetched on the exact medium it was staged on: the edge's
    # S3 get count equals its S3 put count (1 retrieval per object)
    assert u.n_gets == u.n_puts
    assert u.media.get("s3", 0) == u.n_gets
    acct = run.media_storage_ops()["s3"]
    assert acct.n_gets == acct.n_puts


def test_fee_feed_apportions_put_across_retrievals():
    """A fan-out object's one-time put fee is split across its permitted
    retrievals in the telemetry feed: the observed per-pull $ matches the
    real marginal bill instead of overcounting one PUT per consumer."""
    from repro.core.transfer import TransferEngine

    engine = TransferEngine("s3", telemetry=True)
    fan = 8
    ref = engine.put(np.ones(256, np.float32), n_retrievals=fan)
    for _ in range(fan):
        engine.get(ref)
    tel = engine.telemetry.medium("s3")
    nb = 256 * 4
    expected = transfer_fee_usd("s3", nb, n_gets=fan)  # PUT + fan GETs
    assert tel.n == fan
    assert tel.fee_usd_total == pytest.approx(expected)


def test_adaptive_timed_reprobe_recovers_blacked_out_primary():
    """Blacklist recovery (the ROADMAP chaos gap): a fig12-style
    ``medium_blackout`` poisons the primary's windowed p99 with penalty
    samples, so a probe-free router (``explore_every=0``) filters it out of
    the budget-feasible set on every resolve — and with no traffic its
    window never refills, so the blackout outlives the fault.  The
    time-decayed re-probe routes the primary one object once it has gone
    unpicked long enough, which is exactly what restores its traffic after
    the window closes."""
    from repro.core.dag import FixedRoute
    from repro.core.faults import FaultInjector, FaultPlan

    nb = 64 << 10
    at_s, duration_s = 1.0, 6.0
    window_end = at_s + duration_s
    edge = _edge(handoff="staged", nbytes=nb, latency_budget_s=0.06)

    def run(reprobe_after_s):
        eng = WorkflowEngine(backend="xdt", max_retries=2)
        eng.transfer.telemetry = TelemetryHub(eng.transfer.clock)
        route = AdaptiveRoute(
            telemetry=eng.transfer.telemetry,
            static=FixedRoute("elasticache"),     # the primary under attack
            explore_every=0,                      # count probes disabled
            reprobe_after_s=reprobe_after_s,
        )
        picks = []

        def flow(ctx, x):
            medium = route.resolve(edge, nb, True)
            picks.append((eng.sim.now, medium))
            ref = ctx.put(np.ones(nb // 4, np.float32), backend=medium)
            return float(np.sum(ctx.get(ref)))

        eng.register("flow", flow, policy=ScalingPolicy(max_instances=8))
        plan = FaultPlan.medium_blackout(
            medium="elasticache", at_s=at_s, duration_s=duration_s, seed=7
        )
        FaultInjector(eng, plan).install()
        for i in range(30):
            eng.sim.schedule_abs(float(i), lambda: eng.submit("flow", 1.0))
        eng.drain()
        assert eng.failed_requests == 0          # route-around still holds
        assert eng.retry_max <= eng.max_retries
        return picks

    locked = run(0.0)
    # pre-fault the primary carries the traffic...
    assert any(m == "elasticache" for t, m in locked if t < at_s)
    # ...but once penalty samples poison its p99, a probe-free router never
    # routes to it again, even long after the window closed
    assert all(m != "elasticache" for t, m in locked if t >= window_end)

    recovered = run(2.0)
    healthy_picks = [
        m for t, m in recovered if t >= window_end and m == "elasticache"
    ]
    assert len(healthy_picks) >= 2               # probed again, repeatedly
    # and the probes really ran: the primary's feed refilled post-window
    assert recovered[-1][1] in ("s3", "elasticache")


def test_adaptive_engine_lowering_binds_transfer_telemetry():
    """dag.bind wires the engine's TransferEngine telemetry into an unbound
    AdaptiveRoute, so routing feeds on the engine's real pulls."""
    dag = WorkflowDAG(
        "w2",
        [Stage("a", compute_s=0.0), Stage("b", blocking=True)],
        [Edge("a", "b", 1 << 20, label="ab", handoff="sync")],
    )
    eng = WorkflowEngine(backend="xdt")
    assert eng.transfer.telemetry is None    # off by default (hot-path cost)
    route = AdaptiveRoute()
    binding = dag.bind(eng, default_route=route, bytes_scale=1e-3)
    assert eng.transfer.telemetry is not None  # switched on by the binding
    assert route.telemetry is eng.transfer.telemetry
    eng.run(binding.entry, 1.0)
    assert eng.transfer.telemetry.has_media_samples()
    assert binding.edge_usage["ab"].n_gets > 0
