"""AWS cost model units (paper §6.5.1 pricing snapshot)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost import (
    CostBreakdown,
    WorkflowCostInputs,
    combine_cost_inputs,
    elasticache_storage_cost,
    lambda_compute_cost,
    s3_storage_cost,
    tenant_bills,
    workflow_cost,
    xdt_storage_cost,
)


def test_lambda_pricing_anchor():
    """1M invocations = $0.20; 1 GB-s = $0.0000166667."""
    assert lambda_compute_cost(0.0, 1_000_000) == pytest.approx(0.20)
    assert lambda_compute_cost(2.0, 0, mem_gb=1.0) == pytest.approx(2 * 0.0000166667)


def test_paper_memory_footprint_default():
    """Paper fixes 512 MB for all functions."""
    one_sec = lambda_compute_cost(1.0, 0)
    assert one_sec == pytest.approx(0.5 * 0.0000166667)


def test_s3_request_fees():
    assert s3_storage_cost(1000, 0) == pytest.approx(0.005)
    assert s3_storage_cost(0, 1000) == pytest.approx(0.0004)


def test_s3_residency_negligible_for_ephemeral():
    """Seconds-lived GBs cost ~nothing on S3 — request fees dominate."""
    fee = s3_storage_cost(1, 1, gb_seconds=10.0)
    assert fee == pytest.approx(0.005 / 1e3 + 0.0004 / 1e3, rel=0.05)


def test_elasticache_hour_granularity():
    """Cache capacity is billed >= 1 hour even for seconds-lived data —
    the structural reason EC is 17-772x more expensive than XDT."""
    assert elasticache_storage_cost(1.0, hours=0.001) == pytest.approx(0.02)
    assert elasticache_storage_cost(1.0, hours=2.5) == pytest.approx(0.06)


def test_s3_vs_ec_700x_anchor():
    """Paper §2.3.1: S3 $0.02/GB-month vs EC $0.02/GB-hour ~= 700x."""
    gb_month_s3 = 0.023
    gb_month_ec = 0.02 * 24 * 30
    assert 500 < gb_month_ec / gb_month_s3 < 900


def test_xdt_zero():
    assert xdt_storage_cost() == 0.0


def test_workflow_cost_dispatch():
    inputs = WorkflowCostInputs(
        n_function_invocations=10, billed_duration_s=5.0,
        n_storage_puts=4, n_storage_gets=8,
        storage_gb_seconds=1.0, peak_resident_gb=0.5,
    )
    s3 = workflow_cost(inputs, "s3")
    ec = workflow_cost(inputs, "elasticache")
    xdt = workflow_cost(inputs, "xdt")
    assert s3.compute == ec.compute == xdt.compute
    assert xdt.storage == 0.0
    assert ec.storage == pytest.approx(0.5 * 0.02)
    assert s3.storage > 0
    with pytest.raises(ValueError):
        workflow_cost(inputs, "dynamo")


@settings(max_examples=40, deadline=None)
@given(
    invs=st.integers(0, 10_000),
    dur=st.floats(0, 1e4, allow_nan=False),
    puts=st.integers(0, 10_000),
    gets=st.integers(0, 10_000),
    peak=st.floats(0, 100, allow_nan=False),
)
def test_property_costs_monotone_nonnegative(invs, dur, puts, gets, peak):
    inputs = WorkflowCostInputs(invs, dur, puts, gets, 0.0, peak)
    for backend in ("s3", "elasticache", "xdt"):
        c = workflow_cost(inputs, backend)
        assert c.compute >= 0 and c.storage >= 0
        bigger = workflow_cost(
            WorkflowCostInputs(invs + 1, dur + 1, puts + 1, gets + 1, 0.0, peak + 1),
            backend,
        )
        assert bigger.total >= c.total


def test_breakdown_micro_usd():
    c = CostBreakdown(compute=17e-6, storage=0.0)
    m = c.as_micro_usd()
    assert m["total_uUSD"] == pytest.approx(17.0)


# ---------------------------------------------------------------------------
# Multi-tenant attribution: linearity of the fee structures
# ---------------------------------------------------------------------------


def test_combine_cost_inputs_sums_every_field():
    a = WorkflowCostInputs(10, 5.0, 3, 6, 2.0, 0.5)
    b = WorkflowCostInputs(20, 1.5, 1, 2, 4.0, 1.5)
    tot = combine_cost_inputs([a, b])
    assert tot == WorkflowCostInputs(30, 6.5, 4, 8, 6.0, 2.0)


@given(
    rows=st.lists(
        st.tuples(
            st.integers(0, 50_000),
            st.floats(0, 1e4, allow_nan=False),
            st.integers(0, 50_000),
            st.integers(0, 50_000),
            st.floats(0, 1e3, allow_nan=False),
            st.floats(0, 50, allow_nan=False),
        ),
        min_size=1,
        max_size=8,
    )
)
@settings(max_examples=60, deadline=None)
def test_tenant_bills_sum_to_combined_bill(rows):
    """The attribution invariant the multi-tenant benchmark gates on:
    per-tenant bills under any backend sum exactly (fp tolerance) to the
    bill of the combined accounting — every fee structure is linear in the
    inputs once peaks are summed as co-resident worst case."""
    parts = {
        f"t{i}": WorkflowCostInputs(*row) for i, row in enumerate(rows)
    }
    combined = combine_cost_inputs(parts.values())
    for backend in ("s3", "elasticache", "xdt", "hybrid"):
        bills = tenant_bills(parts, backend)
        assert sum(b.total for b in bills.values()) == pytest.approx(
            workflow_cost(combined, backend).total, rel=1e-12, abs=1e-15
        )
