"""Declarative WorkflowDAG layer: differential equivalence, routing, costs.

Three families of guarantees:

* **The refactor changed nothing** — the DAG interpreter with a fixed single
  backend reproduces the legacy hand-rolled workload generators bit-for-bit.
  The goldens below are SHA-256 fingerprints over full-precision
  (``float.hex``) latency / cost / breakdown / cost-input values of the
  pre-refactor implementation (commit 4e2bbf9) across seeds 0-2 in both
  jitter and deterministic modes.  Any divergence in any field at any seed
  changes the checksum.
* **Per-edge routing is sound** — ``SizeRoute`` picks inline only on sync
  handoffs under the cutoff, durable storage for evictable producers; the
  hybrid configuration is never costlier (or slower beyond noise) than the
  best single backend; mixed runs bill each medium by its own fee structure,
  edge-attributably.
* **Both lowerings agree** — the engine binding (``dag.bind``) moves every
  edge's objects over the medium its policy resolves, and bills the same
  per-medium request fees as the cluster interpretation (including the
  external original-input S3 GETs that never touch the transfer engine).
"""
import hashlib

import pytest

from repro.core.cost import S3_GET_USD, S3_PUT_USD
from repro.core.dag import (
    Edge,
    FixedRoute,
    SizeRoute,
    Stage,
    WorkflowDAG,
    execute_on_cluster,
)
from repro.core.workflow import WorkflowEngine
from repro.core.workloads import (
    BACKENDS,
    DAGS,
    WORKLOADS,
    run_mr,
    run_vid,
)

# ---------------------------------------------------------------------------
# Differential equivalence with the legacy hand-rolled generators
# ---------------------------------------------------------------------------

#: sha256[:16] over the legacy implementation's full-precision results
#: (seeds 0,1,2 x jitter/deterministic), captured at commit 4e2bbf9.
#: The raw put/get tallies are NOT part of the fingerprint: legacy MR kept
#: the pinned-S3 input GETs out of ``inputs.n_storage_gets`` (it priced them
#: in a separate side-channel); the unified per-media accounting reports
#: every medium's ops in the aggregate.  Same bill, honest op counts.
GOLDEN = {
    ("vid", "s3"): "237a882fca6c1028",
    ("vid", "elasticache"): "e57675cac6f0aa65",
    ("vid", "xdt"): "f496a5ffc9b9b4b8",
    ("set", "s3"): "a55df8d0a4898875",
    ("set", "elasticache"): "eda212aa68fd5b5f",
    ("set", "xdt"): "e92547bfef844786",
    ("mr", "s3"): "9321bdfd6d5fae09",
    ("mr", "elasticache"): "c72d14b3e11104ec",
    ("mr", "xdt"): "5e69490306f92baa",
}


def _fingerprint(res) -> str:
    fx = lambda v: float(v).hex()      # media-less runs sum to int 0
    parts = [fx(res.latency_s), fx(res.cost.compute), fx(res.cost.storage)]
    parts += [f"{k}={fx(v)}" for k, v in sorted(res.breakdown.items())]
    parts += [
        str(res.inputs.n_function_invocations),
        fx(res.inputs.billed_duration_s),
        fx(res.inputs.storage_gb_seconds), fx(res.inputs.peak_resident_gb),
    ]
    return "|".join(parts)


@pytest.mark.parametrize("wl", list(WORKLOADS))
@pytest.mark.parametrize("backend", BACKENDS)
def test_dag_lowering_matches_legacy_bit_for_bit(wl, backend):
    fn = WORKLOADS[wl]
    blob = ";".join(
        _fingerprint(fn(backend, seed=s, deterministic=d))
        for s in (0, 1, 2) for d in (False, True)
    )
    got = hashlib.sha256(blob.encode()).hexdigest()[:16]
    assert got == GOLDEN[(wl, backend)], (
        f"{wl}/{backend}: DAG interpretation diverged from the legacy "
        f"hand-rolled generator (latency/cost/breakdown no longer bit-identical)"
    )


def test_raw_latency_anchor():
    """One directly inspectable value in case the checksum ever breaks."""
    r = run_vid("s3", seed=0, deterministic=True)
    assert r.latency_s.hex() == "0x1.32709035eda2ap+0"


# ---------------------------------------------------------------------------
# Routing policies
# ---------------------------------------------------------------------------


def test_size_route_inline_only_on_sync_handoffs():
    route = SizeRoute(inline_under=1 << 10)
    sync = Edge("a", "b", 1, label="s", handoff="sync")
    staged = Edge("a", "b", 1, label="t", handoff="staged")
    assert route.resolve(sync, 256, evictable=False) == "inline"
    # staged edges fetch without an invoke: inlining would ADD a hop
    assert route.resolve(staged, 256, evictable=False) == "xdt"
    assert route.resolve(sync, 4096, evictable=False) == "xdt"
    assert route.resolve(sync, 256, evictable=True) == "s3"


def test_route_resolver_applies_default_and_evictable():
    dag = WorkflowDAG(
        "d",
        stages=[Stage("p", evictable=True), Stage("c", blocking=False)],
        edges=[Edge("p", "c", 2048, label="e", handoff="staged")],
    )
    resolve = dag.route_resolver(SizeRoute(inline_under=1 << 20))
    # producer is evictable -> durable medium regardless of size
    assert resolve(dag.edges[0], 2048) == "s3"
    assert dag.route_resolver("elasticache")(dag.edges[0], 2048) == "elasticache"
    assert dag.route_resolver(FixedRoute("xdt"))(dag.edges[0], 1) == "xdt"


def test_hybrid_run_reports_mixed_media_per_edge():
    r = run_mr("hybrid", seed=0, deterministic=True)
    assert r.edge_media["input"] == "s3"          # pinned: ORIGINAL input
    assert r.edge_media["shuffle"] == "xdt"       # bulk slices over the NIC
    # the S3-routed edge carries exactly its own request fees
    input_edge = r.edges["input"]
    expect = input_edge["n_puts"] * S3_PUT_USD + input_edge["n_gets"] * S3_GET_USD
    assert input_edge["storage_uUSD"] == pytest.approx(expect * 1e6)
    assert r.edges["shuffle"]["storage_uUSD"] == 0.0


@pytest.mark.parametrize("wl", list(WORKLOADS))
def test_hybrid_never_costlier_than_best_single_backend(wl):
    """The acceptance criterion: per-edge routing dominates every
    single-backend configuration on cost (and doesn't give up latency)."""
    fn = WORKLOADS[wl]
    singles = {b: fn(b, seed=0, deterministic=True) for b in BACKENDS}
    hybrid = fn("hybrid", seed=0, deterministic=True)
    best_cost = min(r.cost.total for r in singles.values())
    assert hybrid.cost.total <= best_cost * (1 + 1e-12)
    best_latency = min(r.latency_s for r in singles.values())
    assert hybrid.latency_s <= best_latency * 1.05


# ---------------------------------------------------------------------------
# Graph validation
# ---------------------------------------------------------------------------


def test_validation_rejects_bad_graphs():
    with pytest.raises(ValueError, match="duplicate stage"):
        WorkflowDAG("d", [Stage("a"), Stage("a")], [])
    with pytest.raises(ValueError, match="unknown src"):
        WorkflowDAG("d", [Stage("a")], [Edge("zz", "a", 1, handoff="staged")])
    with pytest.raises(ValueError, match="entry stage must have fan=1"):
        WorkflowDAG("d", [Stage("a", fan=2)], [])
    with pytest.raises(ValueError, match="requires handoff='external'"):
        Edge(None, "a", 1, handoff="sync")
    with pytest.raises(ValueError, match="must route to storage"):
        WorkflowDAG(
            "d", [Stage("a"), Stage("b", blocking=False)],
            [Edge(None, "b", 1, route="xdt", handoff="external")],
        )
    with pytest.raises(ValueError, match="mixed blocking and orchestrated"):
        WorkflowDAG(
            "d",
            [Stage("a"), Stage("b"), Stage("c", blocking=False)],
            [Edge("a", "b", 1, label="x", handoff="sync")],
        )
    with pytest.raises(ValueError, match="cycle"):
        WorkflowDAG(
            "d",
            [Stage("a"), Stage("b", blocking=False), Stage("c", blocking=False)],
            [Edge("b", "c", 1, label="x", handoff="staged"),
             Edge("c", "b", 1, label="y", handoff="staged")],
        )


def test_blocking_dag_rejects_gather_edges():
    """vSwarm blocking chains return results via the call tree; a staged
    gather edge back into the entry would be PUT (and billed) but never
    fetched — the declaration must be rejected, not half-executed."""
    with pytest.raises(ValueError, match="gather edges into the entry"):
        WorkflowDAG(
            "d", [Stage("a"), Stage("b")],
            [Edge("a", "b", 1 << 20, label="x", handoff="sync"),
             Edge("b", "a", 1 << 20, label="r", handoff="staged")],
        )
    with pytest.raises(ValueError, match="gather edges into the entry"):
        WorkflowDAG(
            "d", [Stage("a", gather_compute_s=0.1), Stage("b")],
            [Edge("a", "b", 1 << 20, label="x", handoff="sync")],
        )


def test_aggregate_hybrid_medium_rejected_per_edge():
    """'hybrid' is a two-tier aggregate backend whose ops cannot be
    attributed per edge; routing an edge to it must fail at send time on
    both lowerings (the run-level 'hybrid' label means a RoutePolicy)."""
    dag = WorkflowDAG(
        "d", [Stage("a"), Stage("b", blocking=False)],
        [Edge("a", "b", 1 << 20, label="x", handoff="staged", route="hybrid")],
    )
    with pytest.raises(ValueError, match="per-edge routable media"):
        execute_on_cluster(dag, "xdt", seed=0, deterministic=True)
    eng = WorkflowEngine(backend="xdt")
    binding = dag.bind(eng, default_route="xdt", bytes_scale=1e-3)
    with pytest.raises(ValueError, match="per-edge routable media"):
        eng.run(binding.entry, 1.0)


def test_external_edge_policy_must_resolve_to_storage():
    """A RoutePolicy can't be statically checked, so an external edge whose
    policy lands on an instance-resident medium must fail at send time —
    original input predates the workflow and its GET fees must be billed."""
    dag = WorkflowDAG(
        "d", [Stage("a"), Stage("b", blocking=False)],
        [Edge(None, "b", 1 << 20, label="in", handoff="external",
              route=FixedRoute("xdt"))],    # bypasses the static str check
    )
    # a policy landing on an instance-resident medium -> rejected at send
    with pytest.raises(ValueError, match="must resolve to storage"):
        execute_on_cluster(dag, "s3", seed=0, deterministic=True)
    # SizeRoute understands external edges: durable storage, never inline/xdt
    durable = WorkflowDAG(
        "d", [Stage("a"), Stage("b", blocking=False)],
        [Edge(None, "b", 1 << 20, label="in", handoff="external",
              route=SizeRoute())],
    )
    run = execute_on_cluster(durable, "xdt", seed=0, deterministic=True)
    assert run.edge_media["in"] == "s3"


# ---------------------------------------------------------------------------
# Engine lowering (dag.bind)
# ---------------------------------------------------------------------------


def _bind(dag, route, bytes_scale=1e-4):
    eng = WorkflowEngine(backend="xdt")
    binding = dag.bind(eng, default_route=route, bytes_scale=bytes_scale)
    return eng, binding


@pytest.mark.parametrize("wl", list(DAGS))
def test_engine_lowering_runs_every_workload(wl):
    eng, binding = _bind(DAGS[wl], SizeRoute())
    eng.run(binding.entry, 1.0)
    eng.assert_at_most_once()
    # every declared edge actually moved objects
    for edge in DAGS[wl].edges:
        u = binding.edge_usage[edge.label]
        assert u.n_gets > 0, edge.label
        assert u.bytes_moved > 0, edge.label


def test_engine_lowering_routes_per_edge_and_prices_media():
    """A mixed DAG on the engine: the S3-pinned edge's objects really go
    through the s3 medium (ref-sealed), and the run's storage bill equals
    that edge's request fees."""
    dag = WorkflowDAG(
        "mixed",
        stages=[Stage("p", compute_s=0.01),
                Stage("w", fan=2, compute_s=0.01, blocking=False)],
        edges=[
            Edge("p", "w", 1 << 20, label="bulk", handoff="staged"),
            Edge("w", "p", 1 << 10, label="back", handoff="staged", route="s3"),
        ],
    )
    eng, binding = _bind(dag, "xdt", bytes_scale=1e-2)
    eng.run(binding.entry, 1.0)
    media = binding.media_storage_ops()
    assert set(media) == {"s3"}
    assert media["s3"].n_puts == 2 and media["s3"].n_gets == 2
    cost = binding.cost()
    assert cost.storage == pytest.approx(2 * S3_PUT_USD + 2 * S3_GET_USD)
    report = binding.edge_report()
    assert report["bulk"]["media"] == {"xdt": 2}
    assert report["back"]["media"] == {"s3": 2}
    assert report["back"]["storage_uUSD"] == pytest.approx(
        (2 * S3_PUT_USD + 2 * S3_GET_USD) * 1e6
    )
    assert report["bulk"]["storage_uUSD"] == 0.0


def test_engine_lowering_bills_external_input_fees():
    """MR's original-input reads bypass the transfer engine but are real S3
    request fees; the binding's media report must include them (the cluster
    lowering bills the same GETs)."""
    eng, binding = _bind(DAGS["mr"], "xdt", bytes_scale=1e-5)
    eng.run(binding.entry, 1.0)
    media = binding.media_storage_ops()
    n_mappers = DAGS["mr"].by_name["mapper"].fan
    assert media["s3"].n_gets == n_mappers        # one input object per mapper
    assert binding.cost().storage == pytest.approx(n_mappers * S3_GET_USD)


def test_engine_lowering_retries_survive_producer_death():
    """The binding reuses the engine's producer-death retry machinery: kill
    the producer instance mid-run and the request still completes."""
    dag = WorkflowDAG(
        "flaky",
        stages=[Stage("p", compute_s=0.0),
                Stage("w", fan=2, compute_s=0.0, blocking=False)],
        edges=[Edge("p", "w", 1 << 16, label="d", handoff="staged")],
    )
    eng, binding = _bind(dag, "xdt", bytes_scale=1e-1)
    killed = []

    orig = binding._put_for_consumers

    def sabotage(ctx, edge, fill):
        out = orig(ctx, edge, fill)
        if not killed:                 # first attempt: producer dies after put
            killed.append(True)
            eng.transfer.kill_producer()
        return out

    binding._put_for_consumers = sabotage
    eng.run(binding.entry, 1.0)        # raises if retries don't recover
    assert killed
    eng.assert_at_most_once()
