"""``dag.compile()`` vs the deprecated spellings: same bits, loud warnings.

``execute_on_cluster`` and ``WorkflowDAG.bind`` are kept as thin
DeprecationWarning shims over the one compile surface.  These tests pin
both halves of that contract: each shim warns exactly once per call, and
on fixed seeds the shim and ``compile(...)`` produce bit-identical runs
(latency, cost, per-edge media) — a shim that drifts from the real path
is worse than no shim.

This file (and the goldens in ``tests/test_dag.py``) intentionally calls
the deprecated entry points; ``tests/test_api_surface.py`` keeps new
call sites from appearing anywhere else in the repo.
"""
import warnings

import pytest

from repro.core.dag import SizeRoute, execute_on_cluster
from repro.core.workflow import WorkflowEngine
from repro.core.workloads import DAGS


def test_execute_on_cluster_warns():
    with pytest.warns(DeprecationWarning, match="compile"):
        execute_on_cluster(DAGS["vid"], "s3", seed=0, deterministic=True)


def test_bind_warns():
    eng = WorkflowEngine(backend="xdt")
    with pytest.warns(DeprecationWarning, match="compile"):
        DAGS["vid"].bind(eng, default_route=SizeRoute(), bytes_scale=1e-4)


def test_compile_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        DAGS["vid"].compile(target="cluster", backend="s3").run(
            seed=0, deterministic=True)
        eng = WorkflowEngine(backend="xdt")
        DAGS["vid"].compile(target="engine", engine=eng,
                            backend=SizeRoute(), bytes_scale=1e-4)


@pytest.mark.parametrize("name", sorted(DAGS))
@pytest.mark.parametrize("backend", ["s3", "elasticache", "xdt"])
def test_cluster_parity_bit_identical(name, backend):
    dag = DAGS[name]
    for seed, deterministic in ((0, True), (0, False), (3, False)):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            old = execute_on_cluster(
                dag, backend, seed=seed, deterministic=deterministic)
        new = dag.compile(target="cluster", backend=backend).run(
            seed=seed, deterministic=deterministic)
        assert new.latency_s == old.latency_s
        assert new.cost().total == old.cost().total
        assert new.edge_media == old.edge_media
        assert new.marks == old.marks


@pytest.mark.parametrize("name", sorted(DAGS))
def test_engine_parity_bit_identical(name):
    def drive(make_binding):
        eng = WorkflowEngine(backend="xdt")
        binding = make_binding(eng)
        for i in range(3):
            eng.sim.schedule_abs(i * 0.5,
                                 lambda: eng.submit(binding.entry, 1.0))
        eng.drain()
        return (
            [(r.status, r.latency_s) for r in eng.requests],
            binding.cost().total,
            {label: dict(u.media) for label, u in binding.edge_usage.items()},
        )

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = drive(lambda eng: DAGS[name].bind(
            eng, default_route=SizeRoute(), bytes_scale=1e-4))
    new = drive(lambda eng: DAGS[name].compile(
        target="engine", engine=eng, backend=SizeRoute(), bytes_scale=1e-4))
    assert new == old


def test_compile_rejects_cross_target_options():
    dag = DAGS["vid"]
    with pytest.raises(ValueError, match="backend"):
        dag.compile(target="cluster")
    with pytest.raises(ValueError, match="engine"):
        dag.compile(target="engine")
    with pytest.raises(ValueError, match="engine-only"):
        dag.compile(target="cluster", backend="s3", handlers={})
    eng = WorkflowEngine(backend="xdt")
    with pytest.raises(ValueError, match="no engine"):
        dag.compile(target="cluster", backend="s3", engine=eng)
    with pytest.raises(ValueError, match="unknown compile target"):
        dag.compile(target="gpu")
