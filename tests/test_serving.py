"""Serving: continuous batching engine + disaggregated XDT handoff."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import init_params, make_decode_fn, make_prefill_fn
from repro.serving import DisaggregatedServer, ServingEngine

import jax.numpy as jnp


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("smollm_360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new, max_len=32):
    """Sequential single-request greedy decode (no batching engine)."""
    prefill = make_prefill_fn(cfg, None, remat="none", pad_to=max_len)
    decode = make_decode_fn(cfg, None)
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompt)[None]})
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, cache = decode(params, cache,
                               jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
    return toks


def test_engine_matches_sequential_reference(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, max_batch=2, max_len=32)
    prompt = np.arange(1, 6)
    rid = eng.submit(prompt, max_new_tokens=6)
    done = eng.run_until_drained()
    assert done[rid].generated == _greedy_reference(cfg, params, prompt, 6)


def test_continuous_batching_ragged(setup):
    """Requests of different lengths batched together stay exact."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, max_batch=3, max_len=32)
    prompts = [np.arange(1, 4), np.arange(2, 10), np.arange(1, 7)]
    rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    done = eng.run_until_drained()
    for rid, p in zip(rids, prompts):
        assert done[rid].generated == _greedy_reference(cfg, params, p, 5)


def test_slot_reuse(setup):
    """More requests than slots: slots are recycled, everyone completes."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, max_batch=2, max_len=32)
    rids = [eng.submit(np.arange(1, 5) + i, max_new_tokens=4) for i in range(5)]
    done = eng.run_until_drained()
    assert set(done) == set(rids)


def test_disagg_xdt_equals_staged(setup):
    """The XDT handoff and the through-storage handoff produce bit-identical
    generations — only latency/cost differ (paper's API-preserving claim)."""
    cfg, params = setup
    outs = {}
    for backend in ("xdt", "staged"):
        srv = DisaggregatedServer(cfg, params, n_decode_pods=2, max_batch=2,
                                  max_len=32, backend=backend)
        rids = [srv.submit(np.arange(1, 5) + i, max_new_tokens=5) for i in range(4)]
        done = srv.run_until_drained()
        outs[backend] = {r: done[r].generated for r in rids}
    assert outs["xdt"] == outs["staged"]


def test_disagg_matches_single_pod(setup):
    cfg, params = setup
    srv = DisaggregatedServer(cfg, params, n_decode_pods=2, max_batch=2,
                              max_len=32, backend="xdt")
    prompt = np.arange(1, 6)
    rid = srv.submit(prompt, max_new_tokens=6)
    done = srv.run_until_drained()
    assert done[rid].generated == _greedy_reference(cfg, params, prompt, 6)


def test_disagg_placement_spreads_load(setup):
    """The control plane steers consecutive handoffs to different decode
    pods (least-loaded policy) — placement decided before data moves."""
    cfg, params = setup
    srv = DisaggregatedServer(cfg, params, n_decode_pods=2, max_batch=4,
                              max_len=32, backend="xdt")
    for i in range(4):
        srv.submit(np.arange(1, 4) + i, max_new_tokens=3)
    pods = set(srv.pod_of_request.values())
    assert pods == {0, 1}


def test_disagg_handoff_report(setup):
    cfg, params = setup
    srv = DisaggregatedServer(cfg, params, n_decode_pods=1, max_batch=2,
                              max_len=32, backend="xdt")
    srv.submit(np.arange(1, 5), max_new_tokens=3)
    srv.run_until_drained()
    rep = srv.handoff_report()
    assert rep["handoffs"] == 1
    assert rep["avg_cache_bytes"] > 0
    # XDT handoff beats both storage baselines for the same cache size
    assert rep["modeled_latency_s_if_xdt"] < rep["modeled_latency_s_if_s3"]
    assert rep["modeled_latency_s_if_xdt"] <= rep["modeled_latency_s_if_elasticache"]


def test_disagg_ssm_arch():
    """The handoff also carries SSM states (falcon-mamba family)."""
    cfg = smoke_config("falcon_mamba_7b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    srv = DisaggregatedServer(cfg, params, n_decode_pods=2, max_batch=2,
                              max_len=24, backend="xdt")
    prompt = np.arange(1, 6)
    rid = srv.submit(prompt, max_new_tokens=4)
    done = srv.run_until_drained()
    assert done[rid].generated == _greedy_reference(cfg, params, prompt, 4,
                                                    max_len=24)
