"""Real-world workload models vs the paper's Fig. 7 / Table 2 anchors.

Assertions are BANDS around the paper's reported numbers; exact per-u$
figures cannot be reverse-engineered from the paper (documented deviations
in EXPERIMENTS.md §Paper-claims).  Averages over 10 seeds mirror the paper's
10-measurement protocol.
"""
import numpy as np
import pytest

from repro.core.workloads import BACKENDS, WORKLOADS


@pytest.fixture(scope="module")
def results():
    out = {}
    for name, fn in WORKLOADS.items():
        agg = {}
        for b in BACKENDS:
            rs = [fn(b, seed=s) for s in range(10)]
            agg[b] = {
                "latency": float(np.mean([r.latency_s for r in rs])),
                "cost": float(np.mean([r.cost.total for r in rs])),
                "compute": float(np.mean([r.cost.compute for r in rs])),
                "storage": float(np.mean([r.cost.storage for r in rs])),
                "breakdown": rs[0].breakdown,
            }
        out[name] = agg
    return out


def _speedup(res, wl, baseline):
    return res[wl][baseline]["latency"] / res[wl]["xdt"]["latency"]


def _cost_ratio(res, wl, baseline):
    return res[wl][baseline]["cost"] / res[wl]["xdt"]["cost"]


# ------------------------------------------------------------------ Fig. 7


def test_vid_speedups(results):
    """Paper: VID 1.36x vs S3, 1.02x vs EC."""
    assert 1.25 < _speedup(results, "vid", "s3") < 1.65
    assert 1.00 <= _speedup(results, "vid", "elasticache") < 1.12


def test_set_speedups(results):
    """Paper: SET 3.4x vs S3, 1.05x vs EC."""
    assert 2.0 < _speedup(results, "set", "s3") < 3.8
    assert 1.00 <= _speedup(results, "set", "elasticache") < 1.25


def test_mr_speedups(results):
    """Paper: MR 1.26x vs S3, 1.05x vs EC."""
    assert 1.15 < _speedup(results, "mr", "s3") < 1.55
    assert 1.00 <= _speedup(results, "mr", "elasticache") < 1.35


def test_abstract_speedup_band(results):
    """Abstract: XDT delivers 1.3-3.4x over S3 across real workloads."""
    sus = [_speedup(results, wl, "s3") for wl in WORKLOADS]
    assert min(sus) > 1.2
    assert max(sus) < 4.0


# ------------------------------------------------------------------ Table 2


def test_vid_cost_ratios(results):
    """Paper Table 2: VID 3x cheaper than S3-based, 56x than EC-based."""
    assert 1.8 < _cost_ratio(results, "vid", "s3") < 4.5
    assert 18 < _cost_ratio(results, "vid", "elasticache") < 80


def test_set_cost_ratios(results):
    """Paper Table 2: SET 2x cheaper than S3, 17x than EC."""
    assert 2.0 < _cost_ratio(results, "set", "s3") < 8.0
    assert 15 < _cost_ratio(results, "set", "elasticache") < 80


def test_mr_cost_ratios(results):
    """Paper Table 2: MR 5x cheaper than S3, 772x than EC (EC dominated by
    provisioned-capacity cost of the multi-GB shuffle)."""
    assert 2.5 < _cost_ratio(results, "mr", "s3") < 6.5
    assert 40 < _cost_ratio(results, "mr", "elasticache") < 900


def test_xdt_storage_cost_is_zero(results):
    """XDT's defining property: no intermediate-service bill at all (only
    the unavoidable S3 fees for ORIGINAL input, in MR)."""
    assert results["vid"]["xdt"]["storage"] == 0.0
    assert results["set"]["xdt"]["storage"] == 0.0
    assert results["mr"]["xdt"]["storage"] < 10e-6      # input-read fees only


def test_ec_storage_dominates_ec_cost(results):
    """Paper §7.2: EC storage cost exceeds compute by 1-2 orders of
    magnitude — the cost barrier the title refers to."""
    for wl in WORKLOADS:
        ec = results[wl]["elasticache"]
        assert ec["storage"] > 10 * ec["compute"], wl


# ----------------------------------------------------- latency breakdowns


def test_vid_transfer_fraction_shrinks(results):
    """Paper: VID spends 39% of time in transfers on S3, 4% on XDT."""
    def frac(b):
        bd = results["vid"][b]["breakdown"]
        tr = bd["fragment_transfer"] + bd["frames_transfer"]
        return tr / sum(bd.values())

    assert frac("s3") > 0.25
    assert frac("xdt") < 0.10


def test_mr_shuffle_collapse(results):
    """Paper: mapper-put/reducer-get shrink 23.4x/4.8x vs S3 with XDT."""
    s3 = results["mr"]["s3"]["breakdown"]
    xdt = results["mr"]["xdt"]["breakdown"]
    s3_shuffle = s3["mapper_put"] + s3["reducer_get"]
    xdt_shuffle = xdt["mapper_put"] + xdt["reducer_get"]
    assert s3_shuffle > 4 * xdt_shuffle


def test_mr_input_not_optimized(results):
    """The original-input S3 read is identical across backends."""
    reads = [results["mr"][b]["breakdown"]["input_read_s3"] for b in BACKENDS]
    assert max(reads) / min(reads) < 1.35       # jitter only


def test_determinism():
    from repro.core.workloads import run_vid

    a = run_vid("xdt", seed=5, deterministic=True)
    b = run_vid("xdt", seed=9, deterministic=True)
    assert a.latency_s == b.latency_s
