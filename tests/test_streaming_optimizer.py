"""Optimizer x streaming: co-placement memcpy pulls and spill-plan composition.

The optimizer passes were built against whole-object edges; these tests pin
that they compose with the streaming fast path:

* a co-placed consumer drains its producer's stream at shared-memory speed
  (``local=True`` pulls) on the engine lowering — the same plan that makes
  whole-object pulls local makes chunk pulls local;
* a :class:`PlacementPlan` and an :class:`OnlineSpill` compose on BOTH
  lowerings: the reap-window spill still splits a live stream durable while
  the plan's affinity hints stay honored, and billing stays whole-object.
"""
import pytest

from repro.core import Edge, Stage, TelemetryHub, WorkflowDAG, WorkflowEngine
from repro.core.dag import FixedRoute, execute_on_cluster
from repro.core.dagopt import OnlineSpill

CHUNK = 1 << 20
NBYTES = 8 << 20


def _pipe(producer_s=0.05):
    return WorkflowDAG(
        "pipe",
        [Stage("p", compute_s=producer_s), Stage("c", compute_s=0.01)],
        [Edge("p", "c", NBYTES, label="feed", handoff="sync",
              streaming=True, chunk_bytes=CHUNK)],
    )


class _Feed:
    def __init__(self, life_s):
        self.life_s = life_s

    def expected_instance_lifetime_s(self, now):
        return self.life_s


def _engine_cell(dag, plan=None, spill=None, runs=4):
    eng = WorkflowEngine(backend="xdt")
    binding = dag.bind(eng, default_route=FixedRoute("xdt"), plan=plan,
                       online_spill=spill)
    for _ in range(runs):                 # sequential: fleets stay warm
        eng.run(binding.entry, 1.0)
    eng.assert_at_most_once()
    return eng, binding


# -- co-placed streaming pulls go shared-memory ------------------------------


def test_engine_coplaced_stream_drains_at_memcpy_speed():
    dag = _pipe()
    opt, plan = dag.optimize(passes=("coplace",))
    assert plan.affinity == {"c": "p"}
    base_eng, base = _engine_cell(dag)
    eng, binding = _engine_cell(opt, plan=plan)
    bu = base.edge_usage["feed"]
    u = binding.edge_usage["feed"]
    assert bu.n_local == 0
    # warm affine runs drain every chunk via memcpy; the engine-wide local
    # counter must agree with the per-edge tally
    assert u.n_local >= 3 * (NBYTES // CHUNK)
    assert eng.transfer.stats.local_pulls == u.n_local
    # memcpy is strictly cheaper than the NIC path in modeled seconds
    assert u.modeled_s < bu.modeled_s
    # and locality never rewrites billing: same ops either way
    assert (u.n_puts, u.n_gets) == (bu.n_puts, bu.n_gets)


# -- PlacementPlan x OnlineSpill composition ---------------------------------


def _spill(life_s=1.0, patience=2):
    hub = TelemetryHub(lambda: 0.0)
    hub.deployments["p"] = _Feed(life_s)
    return OnlineSpill(hub, durable="s3", pressure_patience=patience)


def test_cluster_plan_and_online_spill_compose():
    # eta shrinks chunk by chunk, so a reap window between the first and
    # last chunk's eta splits the stream mid-flight — with the co-placement
    # plan active at the same time
    dag = _pipe(producer_s=1.0)
    opt, plan = dag.optimize(passes=("coplace",))
    assert plan.affinity == {"c": "p"}
    sp = _spill()
    run = execute_on_cluster(opt, "xdt", seed=0, deterministic=True,
                             plan=plan, online_spill=sp)
    assert sp.spills and {s[0] for s in sp.spills} == {"feed"}
    media = run.edge_usage["feed"].media
    assert media.get("s3") and media.get("xdt"), media
    assert len(sp.spills) < NBYTES // CHUNK      # strictly partial spill


def test_engine_plan_and_online_spill_compose():
    dag = _pipe(producer_s=1.0)
    opt, plan = dag.optimize(passes=("coplace",))
    sp = _spill()
    eng, binding = _engine_cell(opt, plan=plan, spill=sp, runs=2)
    assert eng.failed_requests == 0
    assert sp.spills and {s[0] for s in sp.spills} == {"feed"}
    u = binding.edge_usage["feed"]
    assert u.media.get("s3") and u.media.get("xdt"), u.media
    # one PUT + one GET per (object, medium), even split across media:
    # 2 runs x 2 media
    assert u.n_puts == 4 and u.n_gets == 4
    # spilled chunks pull from durable storage, never memcpy
    assert u.n_local <= u.media.get("xdt", 0)


@pytest.mark.parametrize("backend", ("xdt", "s3"))
def test_plan_is_a_latency_noop_for_storage_routes(backend):
    # storage-routed streams are untouched by affinity: identical latency
    # with and without the plan on the cluster lowering
    dag = _pipe()
    opt, plan = dag.optimize(passes=("coplace",))
    base = execute_on_cluster(dag, backend, seed=0, deterministic=True)
    run = execute_on_cluster(opt, backend, seed=0, deterministic=True,
                             plan=plan)
    if backend == "s3":
        assert run.latency_s == base.latency_s
    else:
        assert run.latency_s <= base.latency_s * (1 + 1e-9)
