"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs ref.py oracles.

All kernels run in interpret mode on CPU (the kernel BODY executes, so the
blocking/indexing/accumulator logic is what's validated; the TPU lowering
shares that body).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention as decode_kernel
from repro.kernels.flash_attention import flash_attention as flash_kernel
from repro.kernels.mamba_scan import mamba_scan as mamba_kernel
from repro.kernels.xdt_pull import xdt_pull as pull_kernel

TOL = {
    jnp.float32: dict(rtol=2e-5, atol=2e-5),
    jnp.bfloat16: dict(rtol=2e-2, atol=2e-2),
}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------- flash


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,KV,hd,bq,bk",
    [
        (1, 128, 128, 4, 4, 64, 64, 64),     # MHA square
        (2, 128, 128, 8, 2, 32, 128, 64),    # GQA 4:1
        (1, 256, 128, 6, 1, 64, 64, 128),    # MQA, Sq != Sk
        (1, 64, 256, 4, 2, 128, 64, 64),     # cross lengths, wide head
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, Sq, Sk, H, KV, hd, bq, bk, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Sq, H, hd), dtype)
    k = _rand(ks[1], (B, Sk, KV, hd), dtype)
    v = _rand(ks[2], (B, Sk, KV, hd), dtype)
    out = flash_kernel(q, k, v, causal=causal, block_q=bq, block_k=bk,
                       interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_flash_attention_q_offset():
    """q_offset shifts the causal mask (the context-parallel contract)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 64, 4, 32), jnp.float32)
    k = _rand(ks[1], (1, 128, 4, 32), jnp.float32)
    v = _rand(ks[2], (1, 128, 4, 32), jnp.float32)
    out = flash_kernel(q, k, v, causal=True, q_offset=64, block_q=64,
                       block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_matches_chunked_attention_layer():
    """Kernel == the model library's chunked_attention (same contract)."""
    from repro.models.layers import chunked_attention

    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (2, 128, 4, 32), jnp.float32)
    k = _rand(ks[1], (2, 128, 2, 32), jnp.float32)
    v = _rand(ks[2], (2, 128, 2, 32), jnp.float32)
    out = flash_kernel(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    want = chunked_attention(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- decode


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,H,KV,hd,bt",
    [
        (2, 256, 8, 2, 64, 64),
        (4, 512, 4, 4, 32, 128),
        (1, 1024, 16, 2, 64, 256),
        (3, 128, 2, 1, 128, 128),
    ],
)
def test_decode_attention_sweep(B, T, H, KV, hd, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = _rand(ks[0], (B, H, hd), dtype)
    k = _rand(ks[1], (B, T, KV, hd), dtype)
    v = _rand(ks[2], (B, T, KV, hd), dtype)
    lengths = jax.random.randint(ks[3], (B,), 0, T - 1)
    out = decode_kernel(q, k, v, lengths, block_t=bt, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_decode_attention_ragged_lengths():
    """Each sequence masks independently at its own length."""
    B, T, H, KV, hd = 4, 256, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(ks[0], (B, H, hd), jnp.float32)
    k = _rand(ks[1], (B, T, KV, hd), jnp.float32)
    v = _rand(ks[2], (B, T, KV, hd), jnp.float32)
    lengths = jnp.asarray([0, 31, 128, 255])
    out = decode_kernel(q, k, v, lengths, block_t=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_matches_model_decode_layer():
    """Kernel == decode_attention_layer's math for the same KV/positions."""
    from repro.models.config import ModelConfig
    from repro.models.layers import decode_attention_layer

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=64, head_dim=16)
    B, T = 2, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    p = {
        "wq": _rand(ks[0], (64, 4, 16), jnp.float32) * 0.1,
        "wk": _rand(ks[1], (64, 2, 16), jnp.float32) * 0.1,
        "wv": _rand(ks[2], (64, 2, 16), jnp.float32) * 0.1,
        "wo": _rand(ks[3], (4, 16, 64), jnp.float32) * 0.1,
    }
    x = _rand(ks[4], (B, 1, 64), jnp.float32)
    cache_k = _rand(ks[5], (B, T, 2, 16), jnp.float32)
    cache_v = _rand(ks[5], (B, T, 2, 16), jnp.float32)
    pos = jnp.asarray([3, 17])
    out_layer, nk, nv = decode_attention_layer(x, p, cfg, cache_k, cache_v, pos)

    # reproduce with the kernel on the updated cache
    from repro.models.layers import _project_qkv, apply_rope, rope_angles

    q, k_new, v_new = _project_qkv(x, p, cfg)
    cos, sin = rope_angles(pos[:, None], cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    out_k = decode_kernel(q[:, 0], nk, nv, pos, block_t=64, interpret=True)
    out_k = jnp.einsum("bk,kd->bd", out_k.reshape(B, -1),
                       p["wo"].reshape(4 * 16, 64))
    np.testing.assert_allclose(
        np.asarray(out_layer[:, 0]), np.asarray(out_k), rtol=2e-4, atol=2e-4
    )


# ---------------------------------------------------------------- mamba


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,d_in,ds,chunk,bd",
    [
        (2, 64, 128, 16, 32, 64),
        (1, 128, 256, 8, 64, 128),
        (2, 32, 64, 4, 32, 64),      # single chunk
        (1, 256, 128, 16, 64, 32),   # many chunks, narrow channel block
    ],
)
def test_mamba_scan_sweep(B, S, d_in, ds, chunk, bd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    x = _rand(ks[0], (B, S, d_in), dtype) * 0.5
    dt = jax.nn.softplus(_rand(ks[1], (B, S, d_in), dtype))
    Bi = _rand(ks[2], (B, S, ds), dtype) * 0.3
    Ci = _rand(ks[3], (B, S, ds), dtype) * 0.3
    A = -jnp.exp(_rand(ks[4], (d_in, ds), jnp.float32) * 0.3)
    D = jnp.ones((d_in,), jnp.float32)
    y, h = mamba_kernel(x, dt, Bi, Ci, A, D, chunk=chunk, block_d=bd, interpret=True)
    yr, hr = ref.mamba_scan_ref(x, dt, Bi, Ci, A, D)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), **TOL[dtype]
    )
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-4, atol=1e-4)


def test_mamba_scan_carried_state():
    """Scanning [first half] then [second half with h0] == one full scan."""
    B, S, d_in, ds = 1, 64, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = _rand(ks[0], (B, S, d_in), jnp.float32) * 0.5
    dt = jax.nn.softplus(_rand(ks[1], (B, S, d_in), jnp.float32))
    Bi = _rand(ks[2], (B, S, ds), jnp.float32) * 0.3
    Ci = _rand(ks[3], (B, S, ds), jnp.float32) * 0.3
    A = -jnp.exp(_rand(ks[4], (d_in, ds), jnp.float32) * 0.3)
    D = jnp.ones((d_in,), jnp.float32)
    y_full, h_full = mamba_kernel(x, dt, Bi, Ci, A, D, chunk=32, block_d=64,
                                  interpret=True)
    h = S // 2
    y1, h1 = mamba_kernel(x[:, :h], dt[:, :h], Bi[:, :h], Ci[:, :h], A, D,
                          chunk=32, block_d=64, interpret=True)
    y2, h2 = mamba_kernel(x[:, h:], dt[:, h:], Bi[:, h:], Ci[:, h:], A, D,
                          h0=h1, chunk=32, block_d=64, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=1e-5, atol=1e-5)


def test_mamba_scan_matches_model_block():
    """Kernel == models.ssm.mamba1_mix for the same inputs."""
    from repro.models.ssm import mamba1_mix

    B, S, d_in, ds = 2, 64, 128, 8
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    x = _rand(ks[0], (B, S, d_in), jnp.float32) * 0.5
    dt = jax.nn.softplus(_rand(ks[1], (B, S, d_in), jnp.float32))
    Bi = _rand(ks[2], (B, S, ds), jnp.float32) * 0.3
    Ci = _rand(ks[3], (B, S, ds), jnp.float32) * 0.3
    A = -jnp.exp(_rand(ks[4], (d_in, ds), jnp.float32) * 0.3)
    D = jnp.ones((d_in,), jnp.float32)
    y_k, h_k = mamba_kernel(x, dt, Bi, Ci, A, D, chunk=32, block_d=128, interpret=True)
    y_m, h_m = mamba1_mix(x, dt, Bi, Ci, A, D, chunk=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- xdt_pull


@pytest.mark.parametrize("src_dtype,out_dtype", [
    (jnp.int8, jnp.bfloat16),
    (jnp.int8, jnp.float32),
    (jnp.bfloat16, jnp.float32),
    (jnp.float32, jnp.bfloat16),
])
@pytest.mark.parametrize("N,Dm,bn", [(512, 128, 128), (1024, 64, 512), (256, 256, 256)])
def test_xdt_pull_sweep(N, Dm, bn, src_dtype, out_dtype):
    key = jax.random.PRNGKey(9)
    if src_dtype == jnp.int8:
        src = jax.random.randint(key, (N, Dm), -127, 127, jnp.int32).astype(jnp.int8)
        scale = jnp.abs(jax.random.normal(key, (N,), jnp.float32)) * 0.01 + 1e-4
    else:
        src = _rand(key, (N, Dm), src_dtype)
        scale = None
    out = pull_kernel(src, scale, out_dtype=out_dtype, block_n=bn, interpret=True)
    want = ref.xdt_pull_ref(src, scale, out_dtype=out_dtype)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=1e-2, atol=1e-4,
    )


def test_xdt_pull_roundtrip_quantized_cache():
    """int8-compress a KV cache, pull+dequant, verify reconstruction error
    bounded by one quantization step per element."""
    from repro.optim.compression import int8_compress

    key = jax.random.PRNGKey(10)
    kv = jax.random.normal(key, (512, 128), jnp.float32)
    q, scale = int8_compress(kv)
    out = pull_kernel(q, jnp.full((512,), scale), out_dtype=jnp.float32,
                      block_n=128, interpret=True)
    assert float(jnp.max(jnp.abs(out - kv))) <= float(scale) + 1e-6


# ---------------------------------------------------------------- dispatch


def test_ops_fallback_on_ragged_shapes():
    """Non-divisible shapes route to the oracle, same numerics contract."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = _rand(ks[0], (1, 100, 3, 24), jnp.float32)     # 100 % 128 != 0
    k = _rand(ks[1], (1, 100, 3, 24), jnp.float32)
    v = _rand(ks[2], (1, 100, 3, 24), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
