"""Multi-device assertions, run in a subprocess with 8 host devices.

pytest itself must see ONE device (per the assignment: only the dry-run
forces a device count), so every check that needs a real mesh lives here and
``tests/test_multidevice.py`` invokes this file once in a subprocess,
asserting on the emitted JSON.

Each check returns {"ok": bool, ...details}; failures carry the mismatch.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import json
import tempfile
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.core.patterns import build_pattern_fn
from repro.data import ShardedLoader
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, make_loss_fn, param_shapes
from repro.models.moe import moe_dense_oracle
from repro.optim import OptConfig, adamw_init
from repro.optim.compression import compressed_psum
from repro.train import make_train_step


def check_patterns():
    mesh = make_host_mesh(data=1, model=8)
    n = 8
    x = jnp.arange(n * 4, dtype=jnp.float32).reshape(n, 4)
    fn = build_pattern_fn(mesh, "model", "1-1", src=2, dst=5)
    ok = bool((fn(x)[5] == x[2]).all())
    fn = build_pattern_fn(mesh, "model", "broadcast", src=3)
    ok &= bool((fn(x) == x[3][None]).all())
    fn = build_pattern_fn(mesh, "model", "gather", dst=1)
    ok &= bool((fn(x)[1] == x).all())
    fn = build_pattern_fn(mesh, "model", "gather_all")
    out = fn(x)
    ok &= bool(all((out[i] == x).all() for i in range(n)))
    xs = jnp.arange(n * n * 4, dtype=jnp.float32).reshape(n, n, 4)
    fn = build_pattern_fn(mesh, "model", "scatter", src=0)
    ok &= bool((fn(xs) == xs[0]).all())
    xa = jnp.arange(n * n * 4, dtype=jnp.float32).reshape(n * n, 4)
    fn = build_pattern_fn(mesh, "model", "all_to_all")
    expect = xa.reshape(n, n, 4).swapaxes(0, 1).reshape(n * n, 4)
    ok &= bool((fn(xa) == expect).all())
    return {"ok": ok}


def check_sharded_train_matches_single():
    """Same smoke config, same batch: (2,2)-mesh loss == no-mesh loss."""
    cfg = smoke_config("qwen3_4b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = ShardedLoader(cfg, global_batch=4, seq_len=16).batch_at(0)
    loss_single = float(make_loss_fn(cfg, None, remat="none")(params, batch))

    mesh = make_host_mesh(data=2, model=2)
    rules = ShardingRules(mesh)
    shapes = param_shapes(cfg)

    def put(spec, val):
        _, axes = spec
        return jax.device_put(val, rules.named(list(axes), val.shape))

    is_spec = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
    params_sh = jax.tree.map(put, shapes, params, is_leaf=is_spec)
    batch_sh = {
        k: jax.device_put(v, rules.named(["batch"] + [None] * (v.ndim - 1), v.shape))
        for k, v in batch.items()
    }
    with mesh:
        loss_mesh = float(jax.jit(make_loss_fn(cfg, mesh, remat="none"))(params_sh, batch_sh))
    return {
        "ok": abs(loss_single - loss_mesh) < 5e-2,
        "single": loss_single,
        "mesh": loss_mesh,
    }


def check_seq_parallel_attention():
    """smollm (15 heads -> seq plan on 4-way model axis) matches no-mesh."""
    import dataclasses

    cfg = smoke_config("smollm_360m")
    cfg = dataclasses.replace(cfg, n_heads=3, n_kv_heads=1)  # 3 % 4 != 0 -> seq plan
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = ShardedLoader(cfg, global_batch=2, seq_len=16).batch_at(0)
    loss_single = float(make_loss_fn(cfg, None, remat="none")(params, batch))
    mesh = make_host_mesh(data=1, model=4)
    from repro.models.layers import plan_attention

    plan = plan_attention(cfg, mesh)
    with mesh:
        loss_mesh = float(jax.jit(make_loss_fn(cfg, mesh, remat="none"))(params, batch))
    return {
        "ok": plan.mode == "seq" and abs(loss_single - loss_mesh) < 5e-2,
        "plan": plan.mode,
        "single": loss_single,
        "mesh": loss_mesh,
    }


def check_moe_ep_matches_oracle():
    """Expert-parallel dispatch == dense oracle under generous capacity."""
    import dataclasses

    cfg = smoke_config("moonshot_v1_16b_a3b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0)
    )
    params = init_params(cfg, jax.random.PRNGKey(2))
    bp = jax.tree.map(lambda v: v[0], params["blocks"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model), jnp.float32)

    from repro.models.moe import moe_layer

    ref, aux_ref = moe_dense_oracle(x, bp, cfg.moe)
    mesh = make_host_mesh(data=2, model=4)
    with mesh:
        out, aux = jax.jit(lambda x, bp: moe_layer(x, bp, cfg, mesh))(x, bp)
    err = float(jnp.max(jnp.abs(out - ref)))
    return {"ok": err < 2e-2, "max_err": err}


def check_compressed_psum():
    """int8 compressed all-reduce: mean within quant error; EF shrinks it."""
    mesh = make_host_mesh(data=8, model=1)
    n = 8
    g = jax.random.normal(jax.random.PRNGKey(4), (n, 64), jnp.float32)
    exact = g.mean(axis=0)

    def local(gi):
        out, res = compressed_psum(gi[0], "data")
        return out[None], res[None]

    fn = jax.jit(
        shard_map(
            local, mesh=mesh, in_specs=P("data"), out_specs=(P("data"), P("data")),
            check_vma=False,
        )
    )
    out, res = fn(g)
    err = float(jnp.max(jnp.abs(out[0] - exact)))
    amax = float(jnp.max(jnp.abs(g)))
    bound = amax / 127.0  # one quantization step
    # error feedback: re-reduce the SAME grads with carried residual; the
    # two-step average must beat one step's quant error
    out2, _ = jax.jit(
        shard_map(
            lambda gi, ri: tuple(x[None] for x in compressed_psum(gi[0], "data", ri[0])),
            mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data")), check_vma=False,
        )
    )(g, res)
    two_step = (out[0] + out2[0]) / 2.0
    err_ef = float(jnp.max(jnp.abs(two_step - exact)))
    return {
        "ok": err <= bound + 1e-6 and err_ef <= err + 1e-9,
        "err": err, "bound": bound, "err_ef": err_ef,
    }


def check_elastic_checkpoint():
    """Save sharded on (4,2); restore bit-identical onto (2,4) and (8,1)."""
    from repro.checkpoint import CheckpointStore

    cfg = smoke_config("granite_8b")
    params = init_params(cfg, jax.random.PRNGKey(5))
    shapes = param_shapes(cfg)
    is_spec = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
    axes_tree = jax.tree.map(lambda s: tuple(s[1]), shapes, is_leaf=is_spec)

    def shard_onto(mesh):
        rules = ShardingRules(mesh)
        return jax.tree.map(
            lambda spec, v: jax.device_put(v, rules.named(list(spec[1]), v.shape)),
            shapes, params, is_leaf=is_spec,
        )

    mesh_a = make_host_mesh(data=4, model=2)
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(7, {"params": shard_onto(mesh_a)}, {"params": axes_tree})
        ok = True
        deltas = []
        for dm in [(2, 4), (8, 1)]:
            mesh_b = make_host_mesh(data=dm[0], model=dm[1])
            restored = store.restore(
                7, {"params": params}, mesh=mesh_b,
                logical_axes={"params": axes_tree},
            )
            flat_a = jax.tree.leaves(params)
            flat_b = jax.tree.leaves(restored["params"])
            delta = max(
                float(jnp.max(jnp.abs(a.astype(jnp.float32) - np.asarray(b, np.float32))))
                if a.size else 0.0
                for a, b in zip(flat_a, flat_b)
            )
            deltas.append(delta)
            ok &= delta == 0.0
    return {"ok": ok, "deltas": deltas}


def check_grad_accum_equivalence():
    """grad_accum=2 step == grad_accum=1 step on the same global batch."""
    cfg = smoke_config("granite_8b")
    params = init_params(cfg, jax.random.PRNGKey(6))
    batch = ShardedLoader(cfg, global_batch=4, seq_len=8).batch_at(0)
    opt = adamw_init(params)
    ocfg = OptConfig(warmup_steps=1, total_steps=10)
    p1, _, m1 = make_train_step(cfg, None, ocfg, remat="none", grad_accum=1, donate=False)(
        params, opt, batch
    )
    p2, _, m2 = make_train_step(cfg, None, ocfg, remat="none", grad_accum=2, donate=False)(
        params, opt, batch
    )
    dp = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
    )
    return {
        "ok": dp < 5e-2 and abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2,
        "param_delta": dp,
        "loss_delta": abs(float(m1["loss"]) - float(m2["loss"])),
    }


CHECKS = {
    "patterns": check_patterns,
    "sharded_train": check_sharded_train_matches_single,
    "seq_parallel_attention": check_seq_parallel_attention,
    "moe_ep_oracle": check_moe_ep_matches_oracle,
    "compressed_psum": check_compressed_psum,
    "elastic_checkpoint": check_elastic_checkpoint,
    "grad_accum": check_grad_accum_equivalence,
}


def main():
    results = {}
    for name, fn in CHECKS.items():
        try:
            results[name] = fn()
        except Exception as e:
            results[name] = {
                "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-1500:],
            }
    print(json.dumps(results))


if __name__ == "__main__":
    main()
