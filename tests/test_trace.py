"""Trace-driven multi-tenant frontend: synthetic trace shapes, batched
bucket replay, and vectorized per-tenant attribution."""
import numpy as np
import pytest

from repro.core import (
    ScalingPolicy,
    TelemetryHub,
    TraceConfig,
    TraceReplayDriver,
    WorkflowEngine,
    synthesize_trace,
)


# ---------------------------------------------------------------------------
# Trace synthesis
# ---------------------------------------------------------------------------


def _total(trace):
    return sum(len(sizes) for _, sizes in trace)


def test_trace_is_deterministic_per_seed():
    cfg = TraceConfig(duration_s=30.0, base_rps=20.0, shape="bursty")
    a = synthesize_trace(np.random.default_rng(7), cfg)
    b = synthesize_trace(np.random.default_rng(7), cfg)
    c = synthesize_trace(np.random.default_rng(8), cfg)
    assert len(a) == len(b)
    assert all(ta == tb and np.array_equal(sa, sb)
               for (ta, sa), (tb, sb) in zip(a, b))
    assert [t for t, _ in a] != [t for t, _ in c]


@pytest.mark.parametrize("shape", TraceConfig.SHAPES)
def test_trace_shapes_are_quantized_and_bounded(shape):
    cfg = TraceConfig(duration_s=40.0, base_rps=30.0, shape=shape,
                      bucket_s=0.05)
    trace = synthesize_trace(np.random.default_rng(3), cfg)
    assert _total(trace) > 100
    times = np.array([t for t, _ in trace])
    assert (times >= 0).all() and (times < cfg.duration_s).all()
    # every timestamp sits on the bucket grid
    ticks = np.rint(times / cfg.bucket_s)
    assert np.allclose(times, ticks * cfg.bucket_s)
    assert np.array_equal(np.sort(times), times)     # buckets in order
    sizes = np.concatenate([s for _, s in trace])
    assert sizes.min() >= 64                         # payload floor
    assert sizes.dtype == np.int64


def test_trace_thinning_tracks_target_rate():
    """Thinned arrival counts land near duration * mean-rate for each shape
    (diurnal/bursty time-average over full periods == base)."""
    rng = np.random.default_rng(11)
    base, dur = 50.0, 120.0
    for shape in ("steady", "diurnal"):
        cfg = TraceConfig(duration_s=dur, base_rps=base, shape=shape,
                          diurnal_period_s=30.0)
        n = _total(synthesize_trace(rng, cfg))
        assert abs(n - base * dur) < 4 * np.sqrt(base * dur)


def test_trace_rejects_unknown_shape():
    with pytest.raises(ValueError, match="shape"):
        TraceConfig(shape="sawtooth")


# ---------------------------------------------------------------------------
# Replay + attribution
# ---------------------------------------------------------------------------


def _engine_with_entry(n_entries=1):
    eng = WorkflowEngine(seed=5, records="columnar")
    pol = ScalingPolicy(max_instances=64, target_concurrency=4)
    for i in range(n_entries):
        eng.register(f"entry{i}", lambda ctx, nbytes: int(nbytes),
                     policy=pol, service_time=0.002)
    return eng


def test_replay_requires_columnar_records():
    eng = WorkflowEngine()
    with pytest.raises(ValueError, match="columnar"):
        TraceReplayDriver(eng)


def test_replay_rejects_empty_entries():
    drv = TraceReplayDriver(_engine_with_entry())
    with pytest.raises(ValueError, match="entry"):
        drv.schedule("t0", (), [(0.0, np.array([64]))])


def test_per_tenant_attribution_partitions_the_request_log():
    eng = _engine_with_entry(n_entries=2)
    hub = TelemetryHub(clock=lambda: eng.sim.now)
    drv = TraceReplayDriver(eng, telemetry=hub)
    rng = np.random.default_rng(42)
    scheduled = {}
    for k, shape in enumerate(("steady", "diurnal", "bursty")):
        cfg = TraceConfig(duration_s=10.0, base_rps=30.0, shape=shape)
        scheduled[f"tenant-{k}"] = drv.schedule(
            f"tenant-{k}", ("entry0", "entry1"),
            synthesize_trace(rng, cfg, phase=0.7 * k),
        )
    eng.sim.run()
    log = eng.request_log
    assert len(log) == sum(scheduled.values())
    # span-derived ids partition the log exactly: no overlap, full coverage
    by_tenant = drv.request_tenants()
    all_ids = np.concatenate(list(by_tenant.values()))
    assert len(np.unique(all_ids)) == len(all_ids) == len(log)
    assert {t: len(v) for t, v in by_tenant.items()} == scheduled
    # vectorized latency summary agrees with the span partition
    summary = drv.per_tenant_latency()
    assert set(summary) == set(scheduled)
    for tenant, row in summary.items():
        assert row["n"] == scheduled[tenant]
        assert row["ok"] == row["n"]
        assert 0.0 < row["p50_s"] <= row["p99_s"]
    # telemetry saw every tenant's arrivals
    snap = hub.tenants_snapshot()
    assert set(snap) == set(scheduled)


def test_bucket_lands_as_one_batch():
    """A bucket with n arrivals issues n contiguous request ids at one
    simulated timestamp (the submit_batch fast path)."""
    eng = _engine_with_entry()
    drv = TraceReplayDriver(eng)
    trace = [(0.25, np.array([100, 200, 300], dtype=np.int64))]
    assert drv.schedule("t", ("entry0",), trace) == 3
    eng.sim.run()
    assert drv._spans == [(1, 3, "t")]
    # all three requests share the bucket's quantized start time: their
    # recorded latencies are measured from t=0.25, so none exceeds sim.now
    assert len(eng.request_log) == 3
    assert max(eng.request_log.latencies_s) <= eng.sim.now - 0.25 + 1e-9


def test_payload_fn_shapes_submitted_payloads():
    seen = []
    eng = WorkflowEngine(seed=1, records="columnar")
    eng.register("entry0", lambda ctx, p: seen.append(p),
                 service_time=0.001)
    drv = TraceReplayDriver(eng, payload_fn=lambda nbytes: {"nb": nbytes})
    drv.schedule("t", ("entry0",), [(0.0, np.array([777]))])
    eng.sim.run()
    assert seen == [{"nb": 777}]
