"""Optimizer, schedule, and gradient-compression unit tests."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import (
    OptConfig,
    adamw_init,
    adamw_update,
    global_norm,
    int8_compress,
    int8_decompress,
    warmup_cosine,
)
from repro.optim.compression import compress_with_feedback


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    cfg = OptConfig(peak_lr=0.3, warmup_steps=1, total_steps=200, weight_decay=0.0)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_weight_decay_pulls_to_zero():
    params = {"w": jnp.asarray([1.0])}
    state = adamw_init(params)
    cfg = OptConfig(peak_lr=0.1, warmup_steps=1, total_steps=100, weight_decay=0.5)
    zero_grad = {"w": jnp.asarray([0.0])}
    for _ in range(50):
        params, state, _ = adamw_update(params, zero_grad, state, cfg)
    assert abs(float(params["w"][0])) < 0.2


def test_grad_clipping():
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    cfg = OptConfig(peak_lr=1.0, warmup_steps=1, total_steps=10, clip_norm=1.0,
                    weight_decay=0.0)
    huge = {"w": jnp.full(4, 1e6)}
    p2, state, gnorm = adamw_update(params, huge, state, cfg)
    assert float(gnorm) == pytest.approx(2e6)
    # first-step Adam update magnitude is ~lr regardless of clip, but the
    # moments must reflect the CLIPPED gradient
    assert float(jnp.max(jnp.abs(state["mu"]["w"]))) <= 0.1 * (1e6 / 2e6) * 2


def test_bf16_params_f32_moments():
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = adamw_init(params)
    assert state["mu"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones(4, jnp.bfloat16)}
    p2, s2, _ = adamw_update(params, g, state, OptConfig())
    assert p2["w"].dtype == jnp.bfloat16
    assert s2["nu"]["w"].dtype == jnp.float32


def test_warmup_cosine_shape():
    sched = warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    steps = jnp.arange(0, 101)
    lrs = jax.vmap(sched)(steps)
    assert float(lrs[0]) == 0.0
    assert float(lrs[10]) == pytest.approx(1e-3, rel=1e-5)
    # monotone warmup
    assert bool(jnp.all(jnp.diff(lrs[:11]) >= 0))
    # cosine decay to final_frac * peak
    assert float(lrs[100]) == pytest.approx(1e-4, rel=1e-3)
    assert bool(jnp.all(jnp.diff(lrs[10:]) <= 1e-9))


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)


# ------------------------------------------------------------ compression


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_int8_roundtrip_bounded(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,), jnp.float32) * 10
    q, scale = int8_compress(x)
    err = jnp.max(jnp.abs(int8_decompress(q, scale) - x))
    assert float(err) <= float(scale) / 2 + 1e-6   # half-step rounding bound


def test_int8_preserves_amax():
    x = jnp.asarray([-7.0, 3.0, 7.0])
    q, scale = int8_compress(x)
    assert int(q[2]) == 127 and int(q[0]) == -127


def test_error_feedback_accumulates_residual():
    """EF: the sum of quantized emissions tracks the sum of true grads."""
    rng = jax.random.PRNGKey(0)
    true_sum = jnp.zeros(32)
    emitted_sum = jnp.zeros(32)
    residual = None
    for i in range(20):
        g = jax.random.normal(jax.random.fold_in(rng, i), (32,)) * 0.1
        true_sum = true_sum + g
        q, scale, residual = compress_with_feedback(g, residual)
        emitted_sum = emitted_sum + int8_decompress(q, scale)
    # without EF the error would be ~20 half-steps; with EF it is ~1 step
    final_err = float(jnp.max(jnp.abs(emitted_sum - true_sum)))
    q_last, scale_last = int8_compress(true_sum / 20)
    assert final_err < 4 * float(scale_last) + 1e-3
