"""Data pipeline: determinism, elastic sharding, XDT-mediated prefetch."""
import time

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.core.buffers import BufferRegistry
from repro.core.transfer import TransferEngine
from repro.data import ShardedLoader, SyntheticCorpus
from repro.data.prefetch import PrefetchingFeed


def test_corpus_deterministic():
    c = SyntheticCorpus(vocab=128, seed=3)
    a = c.sample(42, 64)
    b = c.sample(42, 64)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 128


def test_corpus_distinct_indices():
    c = SyntheticCorpus(vocab=1024)
    assert not np.array_equal(c.sample(1, 64), c.sample(2, 64))


def test_corpus_has_learnable_structure():
    """Bigram injection: consecutive-token correlation is present."""
    c = SyntheticCorpus(vocab=256)
    toks = np.concatenate([c.sample(i, 256) for i in range(8)])
    follows = ((toks[1:] == (toks[:-1] * 31 + 7) % 256).mean())
    assert follows > 0.2          # ~half the positions by construction


def test_loader_shards_are_disjoint_and_cover():
    cfg = smoke_config("granite_8b")
    R, GB = 4, 8
    loaders = [ShardedLoader(cfg, GB, 16, data_rank=r, data_ranks=R) for r in range(R)]
    batches = [l.batch_at(step=2) for l in loaders]
    merged = np.concatenate([b["tokens"] for b in batches])
    single = ShardedLoader(cfg, GB, 16).batch_at(2)["tokens"]
    # same global sample set regardless of R (order differs by rank layout)
    assert sorted(map(tuple, merged.tolist())) == sorted(map(tuple, single.tolist()))


def test_loader_elastic_reshape_preserves_global_batch():
    """R=2 and R=8 produce the same global batch at every step — the
    checkpoint-restart-on-different-topology guarantee."""
    cfg = smoke_config("qwen3_4b")
    GB = 8
    for step in (0, 3):
        sets = []
        for R in (2, 8):
            rows = np.concatenate([
                ShardedLoader(cfg, GB, 8, r, R).batch_at(step)["tokens"]
                for r in range(R)
            ])
            sets.append(sorted(map(tuple, rows.tolist())))
        assert sets[0] == sets[1]


def test_loader_modalities():
    enc = smoke_config("hubert_xlarge")
    b = ShardedLoader(enc, 2, 8).batch_at(0)
    assert "frames" in b and "tokens" not in b
    assert b["frames"].shape == (2, 8, enc.d_model)

    vlm = smoke_config("llava_next_mistral_7b")
    b = ShardedLoader(vlm, 2, 8).batch_at(0)
    assert set(b) == {"tokens", "labels", "patches"}


@settings(max_examples=25, deadline=None)
@given(step=st.integers(0, 1000), rank=st.integers(0, 3))
def test_property_loader_pure_function_of_step(step, rank):
    cfg = smoke_config("smollm_360m")
    l = ShardedLoader(cfg, 8, 8, data_rank=rank, data_ranks=4)
    a, b = l.batch_at(step), l.batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


# ------------------------------------------------------------- prefetch


def test_prefetch_feed_delivers_in_order():
    cfg = smoke_config("smollm_360m")
    loader = ShardedLoader(cfg, 2, 8)
    feed = PrefetchingFeed(loader.batch_at, depth=2)
    try:
        for step in range(5):
            batch = feed.get_batch(step)
            np.testing.assert_array_equal(
                np.asarray(batch["tokens"]), loader.batch_at(step)["tokens"]
            )
    finally:
        feed.close()


def test_prefetch_survives_producer_death():
    """Killing the producer mid-stream -> consumer regenerates from the
    deterministic index (the paper's re-invoke recovery, applied to data)."""
    cfg = smoke_config("smollm_360m")
    loader = ShardedLoader(cfg, 2, 8)
    engine = TransferEngine("xdt", registry=BufferRegistry(max_slots=2))
    feed = PrefetchingFeed(loader.batch_at, depth=2, engine=engine, timeout_s=2.0)
    try:
        _ = feed.get_batch(0)
        engine.kill_producer()          # all buffered refs die
        batch = feed.get_batch(1)       # must still be exact
        np.testing.assert_array_equal(
            np.asarray(batch["tokens"]), loader.batch_at(1)["tokens"]
        )
    finally:
        feed.close()


def test_prefetch_flow_control_backpressure():
    """Bounded registry slots: the producer thread cannot run unboundedly
    ahead of the consumer."""
    cfg = smoke_config("smollm_360m")
    loader = ShardedLoader(cfg, 2, 8)
    engine = TransferEngine("xdt", registry=BufferRegistry(max_slots=2))
    feed = PrefetchingFeed(loader.batch_at, depth=2, engine=engine)
    try:
        time.sleep(0.5)                  # let the producer run ahead
        assert engine.registry.stats().slots_in_use <= 2
    finally:
        feed.close()
