"""Substrate scalability: O(1)-ish steering, run-queue wakeups, bounded memory.

Three families of guarantees from the event-loop/scheduler optimization pass:

* **Semantics preserved** — the heap-based ``steer()`` picks exactly the
  instance the legacy linear scan picked (differential test over randomized
  load/release/clock sequences), and fixed-seed open-loop sweeps reproduce
  the per-request latency checksums committed in ``results/BENCH_engine.json``
  bit-for-bit.
* **Zero-delay chains don't recurse** — immediate wakeups go through the run
  queue, so completion cascades thousands deep execute iteratively.
* **Memory is bounded** — at-most-once is a high-watermark integer, not an
  ever-growing id set; columnar record mode retains no per-request objects.
"""
import hashlib
import json
import os
import random

import numpy as np
import pytest

from repro.core import LoadGenerator, WorkflowEngine
from repro.core.cluster import Simulator
from repro.core.loadgen import poisson_arrival_times
from repro.core.scheduler import Deployment, Instance, ScalingPolicy

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results", "BENCH_engine.json")


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# Zero-delay wakeups: run queue, not recursion
# ---------------------------------------------------------------------------


def test_deep_zero_delay_completion_chain_no_recursion():
    """A completion cascade thousands of processes deep used to recurse
    through Event.set -> waiter -> step -> set ... and blow the Python stack;
    the run queue executes it iteratively at one virtual instant."""
    sim = Simulator()
    depth = 5 * sys_recursion_limit()

    def relay(prev):
        yield prev
        return None

    prev = sim.timeout(1.0)
    tail = None
    for _ in range(depth):
        tail = sim.spawn(relay(prev))
        prev = tail.done
    sim.run()
    assert tail.done.fired
    assert sim.now == 1.0


def sys_recursion_limit():
    import sys

    return sys.getrecursionlimit()


def test_deep_zero_debt_call_chain_in_engine():
    """Generator handlers chained via ctx.call: every link completes at the
    same virtual instant (zero service time), so the fan-in cascade is one
    long zero-delay chain through the engine."""
    eng = WorkflowEngine()
    depth = 400   # legacy engine recursed ~6 frames per link: dead < 200

    def link(ctx, k):
        if k > 0:
            out = yield ctx.call("link", k - 1)
            return out + 1
        return 0
        yield  # pragma: no cover

    eng.register("link", link,
                 policy=ScalingPolicy(max_instances=depth + 1,
                                      target_concurrency=1))
    assert eng.run("link", depth) == depth
    eng.assert_at_most_once()


def test_already_fired_event_wakeup_is_deferred_not_recursive():
    sim = Simulator()
    ev = sim.timeout(0.0)
    sim.run()
    assert ev.fired
    hits = []
    ev.add_waiter(lambda: hits.append(1))
    assert hits == []          # deferred through the run queue...
    sim.run()
    assert hits == [1]         # ...and delivered at the same instant


# ---------------------------------------------------------------------------
# Differential: optimized steer() == legacy linear scan
# ---------------------------------------------------------------------------


class LegacyDeployment:
    """The pre-optimization O(n) Deployment, verbatim modulo cosmetics."""

    def __init__(self, policy, clock):
        self.policy = policy
        self.clock = clock
        self.instances = {}
        self._next = 0
        self.stats = {"cold_starts": 0, "scale_downs": 0}
        for _ in range(policy.min_instances):
            self._spawn(cold=False)

    def _spawn(self, cold=True):
        iid = self._next
        self._next += 1
        now = self.clock()
        inst = Instance(
            instance_id=iid, coords=(iid,), last_used=now,
            ready_at=now + (self.policy.cold_start_s if cold else 0.0),
        )
        if cold:
            self.stats["cold_starts"] += 1
        self.instances[iid] = inst
        return inst

    def _reap_idle(self):
        now = self.clock()
        alive = len(self.instances)
        for iid, inst in list(self.instances.items()):
            if alive <= self.policy.min_instances:
                break
            if inst.in_flight == 0 and now - inst.last_used > self.policy.keep_alive_s:
                del self.instances[iid]
                alive -= 1
                self.stats["scale_downs"] += 1

    def steer(self):
        self._reap_idle()
        now = self.clock()
        ready = [
            i for i in self.instances.values()
            if i.ready_at <= now and i.in_flight < self.policy.target_concurrency
        ]
        if ready:
            inst = min(ready, key=lambda i: (i.in_flight, i.instance_id))
            wait = 0.0
        elif len(self.instances) < self.policy.max_instances:
            inst = self._spawn(cold=True)
            wait = max(0.0, inst.ready_at - now)
        else:
            inst = min(self.instances.values(),
                       key=lambda i: (i.in_flight, i.instance_id))
            wait = 0.0
        inst.in_flight += 1
        inst.last_used = now
        return inst, wait

    def release(self, instance_id):
        inst = self.instances.get(instance_id)
        if inst is not None:
            inst.in_flight = max(0, inst.in_flight - 1)
            inst.last_used = self.clock()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize(
    "policy_kw",
    [
        dict(min_instances=0, max_instances=6, target_concurrency=1,
             keep_alive_s=8.0, cold_start_s=2.0),
        dict(min_instances=2, max_instances=4, target_concurrency=3,
             keep_alive_s=5.0, cold_start_s=1.0),
        dict(min_instances=1, max_instances=12, target_concurrency=2,
             keep_alive_s=20.0, cold_start_s=3.0),
    ],
)
def test_steer_differential_vs_legacy_linear_scan(seed, policy_kw):
    """Property test: under randomized steer/release/advance sequences the
    heap-based deployment picks the same instance ids and waits as the
    legacy O(n) scan (queue model off = legacy cap behaviour).  Integer
    clock steps keep both keep-alive predicates float-exact."""
    rng = random.Random(seed)
    clock = FakeClock()
    new = Deployment("f", ScalingPolicy(queue_wait_model=False, **policy_kw),
                     clock=clock)
    old = LegacyDeployment(ScalingPolicy(queue_wait_model=False, **policy_kw),
                           clock)
    outstanding = []
    for step in range(600):
        op = rng.random()
        if op < 0.5:
            a, wa = new.steer()
            b, wb = old.steer()
            assert a.instance_id == b.instance_id, (step, policy_kw)
            assert wa == wb, (step, policy_kw)
            outstanding.append(a.instance_id)
        elif op < 0.8 and outstanding:
            iid = outstanding.pop(rng.randrange(len(outstanding)))
            new.release(iid)
            old.release(iid)
        else:
            clock.advance(float(rng.randint(1, 6)))
        assert set(new.instances) == set(old.instances), (step, policy_kw)
        assert new.n_instances == len(old.instances)


# ---------------------------------------------------------------------------
# Queue wait at the max_instances cap (ROADMAP bug)
# ---------------------------------------------------------------------------


def test_saturated_cap_models_queue_wait_from_depth():
    clock = FakeClock()
    d = Deployment("f", ScalingPolicy(min_instances=1, max_instances=1,
                                      target_concurrency=1, cold_start_s=0.0),
                   clock=clock)
    # train the holding-time estimate: two 2-second requests
    for _ in range(2):
        inst, _ = d.steer()
        clock.advance(2.0)
        d.release(inst.instance_id)
    a, wa = d.steer()                  # occupies the only instance
    b, wb = d.steer()                  # queued behind a
    c, wc = d.steer()                  # queued behind a and b
    assert b.instance_id == a.instance_id == c.instance_id
    assert wa == 0.0
    assert wb == pytest.approx(2.0)    # one request ahead x ~2s holding time
    assert wc > wb                     # deeper queue, longer modeled wait
    assert d.stats["queued"] == 2


def test_residual_work_shrinks_as_service_elapses():
    """Per-instance residual-work model: a request queued behind one that is
    already half-served waits only the REMAINING holding time.  The old
    deployment-wide excess*EWMA model charged the full holding time no matter
    how long the request ahead had been running."""
    clock = FakeClock()
    d = Deployment("f", ScalingPolicy(min_instances=1, max_instances=1,
                                      target_concurrency=1, cold_start_s=0.0),
                   clock=clock)
    for _ in range(2):                 # train the holding estimate to ~2s
        inst, _ = d.steer()
        clock.advance(2.0)
        d.release(inst.instance_id)
    a, _ = d.steer()                   # occupies the only instance at t
    clock.advance(1.5)                 # a has been in service for 1.5s
    _, wait = d.steer()                # queued behind a
    assert wait == pytest.approx(0.5)  # only a's residual 2.0 - 1.5 remains


def test_cap_queue_wait_prefers_instance_local_holding_estimate():
    """The chosen instance's own holding-time EWMA drives its queue model;
    the fleet-wide estimate is only a fallback for fresh instances."""
    clock = FakeClock()
    d = Deployment("f", ScalingPolicy(min_instances=2, max_instances=2,
                                      target_concurrency=1, cold_start_s=0.0),
                   clock=clock)
    # distinct service times per instance: 1s on one, 5s on the other
    (a, _), (b, _) = d.steer(), d.steer()
    clock.advance(1.0)
    d.release(a.instance_id)
    clock.advance(4.0)
    d.release(b.instance_id)
    assert a.service_ewma == pytest.approx(1.0)
    assert b.service_ewma == pytest.approx(5.0)
    # saturate both, then queue one more: it lands on the least-loaded (tie ->
    # lowest id = a) and its wait reflects THAT instance's 1s holding time
    d.steer(), d.steer()
    inst, wait = d.steer()
    assert inst.instance_id == a.instance_id
    assert wait == pytest.approx(1.0)


def test_degenerate_zero_target_concurrency_does_not_crash():
    """target_concurrency=0 makes every request excess; the queue position
    must clamp to the requests actually in flight instead of indexing past
    the starts deque."""
    clock = FakeClock()
    d = Deployment("f", ScalingPolicy(min_instances=1, max_instances=1,
                                      target_concurrency=0, cold_start_s=0.0),
                   clock=clock)
    inst, _ = d.steer()
    clock.advance(2.0)
    d.release(inst.instance_id)
    waits = [d.steer()[1] for _ in range(3)]
    assert waits == sorted(waits)      # deeper queue, no shorter wait


def test_queue_wait_model_off_restores_legacy_zero_wait():
    clock = FakeClock()
    d = Deployment("f", ScalingPolicy(min_instances=1, max_instances=1,
                                      target_concurrency=1, cold_start_s=0.0,
                                      queue_wait_model=False),
                   clock=clock)
    inst, _ = d.steer()
    clock.advance(2.0)
    d.release(inst.instance_id)
    d.steer()
    _, wait = d.steer()
    assert wait == 0.0


def test_saturated_fleet_latency_rises_beyond_cap():
    """End-to-end: beyond the cap, modeled queue wait makes p50 latency grow
    with offered load instead of flat-lining (the fig8 underestimate)."""

    def run(rate, queue_model):
        eng = WorkflowEngine(records="columnar")
        pol = ScalingPolicy(max_instances=4, target_concurrency=1,
                            queue_wait_model=queue_model)
        eng.register("f", lambda ctx, x: x, policy=pol, service_time=0.05)
        rep = LoadGenerator(eng, "f").run_open(rate_rps=rate, duration_s=10.0)
        return rep.p50_s

    saturated = run(400.0, True)        # 400 rps >> 4 / 0.05s = 80 rps capacity
    legacy = run(400.0, False)
    assert saturated > 5 * legacy       # queueing now visible in latency


# ---------------------------------------------------------------------------
# Memory-bounded bookkeeping
# ---------------------------------------------------------------------------


def test_at_most_once_high_watermark_not_id_set():
    eng = WorkflowEngine()
    eng.register("f", lambda ctx, x: x)
    for i in range(50):
        eng.submit("f", i)
    eng.drain()
    assert not hasattr(eng, "_executed_ids")
    assert eng._invocation_watermark == 50
    eng.assert_at_most_once()


def test_columnar_records_match_object_records():
    def build(records):
        eng = WorkflowEngine(seed=7, records=records)
        eng.register("worker", lambda ctx, x: x + 1,
                     policy=ScalingPolicy(max_instances=8), service_time=0.02)

        def entry(ctx, x):
            outs = yield ctx.scatter_async("worker", [x, x + 1])
            return sum(outs)

        eng.register("entry", entry, policy=ScalingPolicy(max_instances=8),
                     service_time=0.01)
        gen = LoadGenerator(eng, "entry")
        rep = gen.run_open(rate_rps=40.0, duration_s=2.0)
        return eng, rep

    obj_eng, obj_rep = build("objects")
    col_eng, col_rep = build("columnar")
    assert col_rep.n_requests == obj_rep.n_requests > 0
    np.testing.assert_array_equal(col_rep.latencies_s, obj_rep.latencies_s)
    assert col_eng.executed_count() == obj_eng.executed_count()
    assert col_eng.executed_count("worker") == obj_eng.executed_count("worker")
    assert col_eng.billed_virtual_seconds() == pytest.approx(
        obj_eng.billed_virtual_seconds()
    )
    assert col_eng.latency_records() == obj_eng.latency_records()
    # columnar mode retains no per-request objects
    assert col_eng.requests == []
    assert len(col_eng.request_log) == col_rep.n_requests
    # record views materialize lazily and agree, including negative indices
    assert col_eng.records[0].function == obj_eng.records[0].function
    assert col_eng.records[0].t_end == obj_eng.records[0].t_end
    assert col_eng.records[-1].invocation_id == obj_eng.records[-1].invocation_id
    col_eng.assert_at_most_once()


def test_columnar_negative_index_preserves_error_code():
    from repro.core.workflow import InvocationLog

    log = InvocationLog()
    log.append(1, "f", 0, "error", "XDT.ProducerGone", 0.0, 1.0)
    assert log[0].error_code == "XDT.ProducerGone"
    assert log[-1].error_code == "XDT.ProducerGone"


def test_high_inflight_put_does_not_deadlock_virtual_time():
    """Regression: the default 256-slot buffer budget wall-clock-blocked
    ``put()`` once a few hundred requests were in flight — a permanent
    deadlock on the single-threaded virtual-time engine.  The workflow
    engine's default registry is now sized for sweep-scale concurrency."""
    eng = WorkflowEngine()
    eng.register(
        "hold",
        lambda ctx, x: ctx.put(np.ones(4), n_retrievals=1),
        policy=ScalingPolicy(max_instances=512, target_concurrency=1),
        service_time=1.0,   # all puts alive simultaneously
    )
    for i in range(400):    # > legacy 256-slot budget
        eng.submit("hold", i)
    reqs = eng.drain()
    assert sum(1 for r in reqs if r.status == "ok") == 400


def test_columnar_cost_isolation_across_runs():
    eng = WorkflowEngine(backend="s3", records="columnar")
    eng.register("f", lambda ctx, x: x, policy=ScalingPolicy(max_instances=8))
    gen = LoadGenerator(eng, "f")
    first = gen.run_closed(n_clients=2, requests_per_client=3)
    second = gen.run_closed(n_clients=2, requests_per_client=3)
    assert first.n_requests == second.n_requests == 6
    assert second.cost_inputs.n_function_invocations == (
        first.cost_inputs.n_function_invocations
    )


# ---------------------------------------------------------------------------
# Fixed-seed reproducibility anchors
# ---------------------------------------------------------------------------


def test_vectorized_arrivals_match_sequential_draws():
    for rate, dur in [(50.0, 20.0), (300.0, 20.0)]:
        r1 = np.random.default_rng(99)
        t, legacy = 0.0, []
        while True:
            t += float(r1.exponential(1.0 / rate))
            if t >= dur:
                break
            legacy.append(t)
        vec = poisson_arrival_times(np.random.default_rng(99), rate, dur)
        np.testing.assert_array_equal(np.asarray(legacy), vec)


@pytest.mark.skipif(not os.path.exists(RESULTS),
                    reason="no committed BENCH_engine.json")
def test_fixed_seed_latency_checksums_match_committed_baseline():
    """Bit-identical per-request latencies versus the perf-trajectory file:
    any change to steering, debt accounting, or event ordering that shifts a
    single latency float shows up here."""
    from benchmarks.bench_engine import SMOKE, build_engine

    with open(RESULTS) as f:
        committed = json.load(f)
    rows = committed["smoke"]["rows"]
    assert rows, "committed benchmark has no smoke rows"
    for row in rows:
        eng = build_engine(row["backend"], seed=SMOKE["seed"])
        rep = LoadGenerator(eng, "driver").run_open(
            rate_rps=row["offered_rps"], duration_s=SMOKE["duration_s"]
        )
        lat = np.asarray(rep.latencies_s, dtype=np.float64)
        checksum = hashlib.sha256(lat.tobytes()).hexdigest()[:16]
        assert rep.n_requests == row["n_requests"], row
        assert checksum == row["latency_checksum"], row
