"""Deployment-sharded simulation: planning, epoch barriers, and the
differential identity guarantee (sharded == single-shard, byte for byte)."""
import numpy as np
import pytest

from repro.core import LoadGenerator, ScalingPolicy, WorkflowEngine
from repro.core.shard import GroupSpec, ShardPlan, ShardRunner


# ---------------------------------------------------------------------------
# A small self-contained deployment group: fan-out workflow + open-loop load
# ---------------------------------------------------------------------------


def _build_group(engine: WorkflowEngine, spec: GroupSpec):
    prefix = spec.name

    def worker(ctx, x):
        ref = ctx.put(np.full((32,), float(x % 5), dtype=np.float32),
                      n_retrievals=1)
        return float(ctx.get(ref)[0])

    def driver(ctx, x):
        a, b = yield [ctx.call(f"{prefix}/worker", x),
                      ctx.call(f"{prefix}/worker", x + 1)]
        return a + b

    pol = ScalingPolicy(max_instances=32, target_concurrency=1)
    engine.register(f"{prefix}/worker", worker, policy=pol,
                    service_time=0.004)
    engine.register(f"{prefix}/driver", driver, policy=pol,
                    service_time=0.002)
    gen = LoadGenerator(engine, f"{prefix}/driver")

    def drive():
        gen.schedule_open(rate_rps=40.0, duration_s=2.0)

    return drive


def _specs(n=4):
    return [
        GroupSpec(name=f"g{i}", build=_build_group, seed=100 + i)
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Planning: connected components of the interaction graph
# ---------------------------------------------------------------------------


def test_plan_isolates_independent_groups():
    plan = ShardPlan.plan(_specs(4), n_shards=2)
    assert len(plan.cells) == 4                  # no interactions: 4 cells
    assert plan.n_shards == 2
    # round-robin lanes cover every cell exactly once
    covered = sorted(i for shard in plan.shards for i in shard)
    assert covered == [0, 1, 2, 3]


def test_plan_unions_shared_media_and_calls():
    specs = [
        GroupSpec("a", _build_group, shared_media=("redis-0",)),
        GroupSpec("b", _build_group, shared_media=("redis-0",)),
        GroupSpec("c", _build_group, calls=("d",)),
        GroupSpec("d", _build_group),
        GroupSpec("e", _build_group),
    ]
    plan = ShardPlan.plan(specs, n_shards=3)
    names = sorted(tuple(s.name for s in c.specs) for c in plan.cells)
    assert names == [("a", "b"), ("c", "d"), ("e",)]


def test_plan_rejects_unknown_callee_and_duplicates():
    with pytest.raises(ValueError, match="unknown group"):
        ShardPlan.plan([GroupSpec("a", _build_group, calls=("ghost",))])
    with pytest.raises(ValueError, match="duplicate group names"):
        ShardPlan.plan([GroupSpec("a", _build_group),
                        GroupSpec("a", _build_group)])


# ---------------------------------------------------------------------------
# Differential identity: sharding must never change the simulation
# ---------------------------------------------------------------------------


def _merged_bytes(run):
    log = run.request_log
    return (log.request_ids.tobytes(), log.latencies_s.tobytes(),
            log.ok_flags.tobytes())


def test_sharded_run_is_byte_identical_to_single_shard():
    """The tentpole guarantee: merged RequestLog columns and per-medium
    media_acct totals from a 2+-shard run are byte-identical to the
    single-shard run of the same plan on fixed seeds."""
    single = ShardRunner(ShardPlan.plan(_specs(), n_shards=1),
                         epoch_s=0.5).run(duration_s=2.0)
    sharded = ShardRunner(ShardPlan.plan(_specs(), n_shards=3),
                          epoch_s=0.5).run(duration_s=2.0)
    assert single.n_shards == 1 and sharded.n_shards == 3
    assert len(single.request_log) > 100
    assert _merged_bytes(single) == _merged_bytes(sharded)
    assert single.media_totals == sharded.media_totals
    assert single.events_processed == sharded.events_processed
    assert single.billed_s == sharded.billed_s
    # invocation columns merge deterministically too
    assert (single.invocation_log.invocation_ids.tobytes()
            == sharded.invocation_log.invocation_ids.tobytes())
    assert (single.invocation_log.t_ends.tobytes()
            == sharded.invocation_log.t_ends.tobytes())


def test_epoch_barrier_interleaves_lanes():
    """Every cell reaches barrier k before any cell enters epoch k+1, and
    the caller observes each barrier in order."""
    barriers = []
    runner = ShardRunner(
        ShardPlan.plan(_specs(3), n_shards=2), epoch_s=0.25,
        on_epoch=lambda k, t: barriers.append((k, t)),
    )
    run = runner.run(duration_s=1.0)
    assert barriers == [(0, 0.25), (1, 0.5), (2, 0.75), (3, 1.0)]
    assert run.epochs == 4
    assert run.n_cells == 3


def test_process_workers_match_inline_lanes():
    """Forked shard workers produce the same merged bytes as in-process
    lanes (fork-only: skipped where the start method is unavailable)."""
    import multiprocessing

    if "fork" not in multiprocessing.get_all_start_methods():
        pytest.skip("fork start method unavailable")
    inline = ShardRunner(ShardPlan.plan(_specs(), n_shards=2),
                         epoch_s=1.0).run(duration_s=2.0)
    procs = ShardRunner(ShardPlan.plan(_specs(), n_shards=2),
                        epoch_s=1.0, workers="process").run(duration_s=2.0)
    assert _merged_bytes(inline) == _merged_bytes(procs)
    assert inline.media_totals == procs.media_totals
    assert inline.events_processed == procs.events_processed


def test_merge_namespaces_ids_per_cell():
    from repro.core.shard import ID_STRIDE

    runs = ShardRunner(ShardPlan.plan(_specs(2), n_shards=1),
                       epoch_s=1.0).run(duration_s=1.0)
    rids = np.asarray(runs.request_log.request_ids)
    cells = rids // ID_STRIDE
    assert set(cells.tolist()) == {0, 1}          # both cells contributed
    # within a cell, local ids restart at 1
    assert (rids[cells == 1] % ID_STRIDE).min() == 1


def test_interacting_groups_co_simulate():
    """A cross-group ctx.call edge lands both groups on one engine, so the
    callee's functions are resolvable from the caller's workflows."""
    def build_callee(engine, spec):
        engine.register(f"{spec.name}/leaf", lambda ctx, x: x * 2,
                        service_time=0.001)
        return None

    def build_caller(engine, spec):
        def entry(ctx, x):
            out = yield ctx.call("callee/leaf", x)
            return out

        engine.register(f"{spec.name}/entry", entry, service_time=0.001)
        gen = LoadGenerator(engine, f"{spec.name}/entry")
        return lambda: gen.schedule_open(rate_rps=20.0, duration_s=1.0)

    specs = [
        GroupSpec("callee", build_callee),
        GroupSpec("caller", build_caller, calls=("callee",)),
    ]
    plan = ShardPlan.plan(specs, n_shards=2)
    assert len(plan.cells) == 1
    run = ShardRunner(plan).run(duration_s=1.0)
    assert len(run.request_log) > 5
    assert all(run.request_log.ok_flags)
