"""Workflow engine: at-most-once invocations, producer-death recovery."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RetriesExhausted, WorkflowEngine, XDTProducerGone
from repro.core.scheduler import ScalingPolicy


def test_chain_invocation():
    eng = WorkflowEngine()
    eng.register("consumer", lambda ctx, x: x + 1)
    eng.register("producer", lambda ctx, x: ctx.invoke("consumer", x * 2))
    assert eng.run("producer", 5) == 11
    eng.assert_at_most_once()
    assert eng.executed_count("consumer") == 1


def test_put_get_edge():
    eng = WorkflowEngine()

    def producer(ctx, x):
        ref = ctx.put(jnp.full((4,), x, jnp.float32), n_retrievals=1)
        return ctx.invoke("consumer", ref)

    eng.register("producer", producer)
    eng.register("consumer", lambda ctx, ref: float(ctx.get(ref).sum()))
    assert eng.run("producer", 3) == 12.0


def test_scatter_gather():
    eng = WorkflowEngine()

    def mapper(ctx, shard):
        return ctx.put(jnp.asarray(shard) * 2, n_retrievals=1)

    def driver(ctx, data):
        refs = ctx.scatter("mapper", [data[i::2] for i in range(2)])
        parts = ctx.gather(refs)
        return sum(float(p.sum()) for p in parts)

    eng.register("mapper", mapper)
    eng.register("driver", driver)
    assert eng.run("driver", np.arange(6.0)) == 2 * np.arange(6.0).sum()


def test_broadcast_refcount():
    eng = WorkflowEngine()
    seen = []

    def worker(ctx, ref):
        seen.append(float(ctx.get(ref).sum()))
        return None

    def driver(ctx, x):
        ctx.broadcast("worker", jnp.ones((4,)) * x, fan=3)
        return len(seen)

    eng.register("worker", worker)
    eng.register("driver", driver)
    assert eng.run("driver", 2.0) == 3
    assert seen == [8.0, 8.0, 8.0]
    # the broadcast object was freed after its Nth (=3rd) retrieval
    assert eng.transfer.registry.stats().slots_in_use == 0


def test_producer_gone_triggers_orchestrator_retry():
    """Consumer hits XDTProducerGone -> orchestrator re-invokes the producer
    sub-workflow with the same original arguments (at-least-once recovery)."""
    eng = WorkflowEngine(max_retries=2)
    attempts = []

    def producer(ctx, x):
        ref = ctx.put(jnp.ones((2,)) * x)
        attempts.append(x)
        if len(attempts) == 1:
            eng.transfer.kill_producer()  # instance dies before the pull
        return ctx.invoke("consumer", ref)

    eng.register("producer", producer)
    eng.register("consumer", lambda ctx, ref: float(ctx.get(ref).sum()))
    assert eng.run("producer", 4.0) == 8.0
    assert attempts == [4.0, 4.0]        # same original argument re-invoked
    eng.assert_at_most_once()            # but fresh invocation ids


def test_retry_budget_exhaustion():
    eng = WorkflowEngine(max_retries=1)

    def producer(ctx, x):
        ref = ctx.put(jnp.ones((2,)))
        eng.transfer.kill_producer()     # always dies
        return ctx.invoke("consumer", ref)

    eng.register("producer", producer)
    eng.register("consumer", lambda ctx, ref: ctx.get(ref))
    with pytest.raises(RetriesExhausted) as ei:
        eng.run("producer", 0)
    # the terminal error names the transient cause that spent the budget
    assert isinstance(ei.value.cause, XDTProducerGone)
    assert eng.requests[-1].status == "failed"
    assert eng.failed_requests == 1
    assert eng.failed_codes == {"XDT.ProducerGone": 1}


def test_error_records():
    eng = WorkflowEngine(max_retries=0)

    def failing(ctx, x):
        ref = ctx.put(jnp.ones((2,)))
        eng.transfer.kill_producer()
        return ctx.get(ref)

    eng.register("failing", failing)
    with pytest.raises(RetriesExhausted):
        eng.run("failing", 0)
    # invocation records keep the raw transient code; the request-level
    # terminal status is "failed" with the budget-exhaustion wrapper
    errs = [r for r in eng.records if r.status == "error"]
    assert errs and errs[0].error_code == "XDT.ProducerGone"


def test_unknown_function():
    eng = WorkflowEngine()
    with pytest.raises(KeyError):
        eng.run("nope", 0)


def test_scaling_policy_respected():
    eng = WorkflowEngine()
    eng.register("f", lambda ctx, x: x, policy=ScalingPolicy(max_instances=2))
    for i in range(5):
        eng.run("f", i)
    dep = eng.control.deployments["f"]
    assert dep.n_instances <= 2
