"""BufferRegistry: refcounted retrievals, flow control, instance lifetime."""
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.buffers import BufferRegistry
from repro.core.errors import (
    XDTObjectExhausted,
    XDTProducerGone,
    XDTTimeout,
    XDTWouldBlock,
)


def test_put_get_roundtrip():
    reg = BufferRegistry()
    bid, epoch = reg.put({"x": 1}, n_retrievals=1)
    assert reg.get(bid, epoch) == {"x": 1}


def test_n_retrievals_then_exhausted():
    reg = BufferRegistry()
    bid, ep = reg.put("obj", n_retrievals=3)
    for _ in range(3):
        assert reg.get(bid, ep) == "obj"
    with pytest.raises(XDTObjectExhausted):
        reg.get(bid, ep)


def test_free_on_last_retrieval_releases_bytes():
    reg = BufferRegistry(max_bytes=1000)
    bid, ep = reg.put(b"x" * 600, n_retrievals=2)
    assert reg.stats().bytes_in_use == 600
    reg.get(bid, ep)
    assert reg.stats().bytes_in_use == 600      # one pull left
    reg.get(bid, ep)
    assert reg.stats().bytes_in_use == 0        # freed on the Nth pull


def test_producer_death_invalidates_epoch():
    reg = BufferRegistry()
    bid, ep = reg.put("obj", n_retrievals=5)
    assert reg.kill_instance() == 1
    with pytest.raises(XDTProducerGone):
        reg.get(bid, ep)


def test_nonblocking_put_raises_when_full():
    reg = BufferRegistry(max_slots=1)
    reg.put("a")
    with pytest.raises(XDTWouldBlock):
        reg.put("b", block=False)


def test_blocking_put_timeout():
    reg = BufferRegistry(max_slots=1)
    reg.put("a")
    with pytest.raises(XDTTimeout):
        reg.put("b", block=True, timeout=0.05)


def test_blocking_put_unblocks_on_get():
    """Flow control: a blocked put proceeds when a retrieval frees a slot."""
    reg = BufferRegistry(max_slots=1)
    bid, ep = reg.put("a")
    result = {}

    def blocked_put():
        result["id"] = reg.put("b", block=True, timeout=5.0)

    t = threading.Thread(target=blocked_put)
    t.start()
    reg.get(bid, ep)          # frees the slot
    t.join(timeout=5.0)
    assert "id" in result
    bid2, ep2 = result["id"]
    assert reg.get(bid2, ep2) == "b"
    assert reg.stats().blocked_puts >= 1


def test_oversized_object_admitted_when_empty():
    """A single object larger than the byte budget still streams through."""
    reg = BufferRegistry(max_bytes=10)
    bid, ep = reg.put(b"x" * 100)
    assert reg.get(bid, ep) == b"x" * 100


def test_ttl_sweep():
    now = [0.0]
    reg = BufferRegistry(clock=lambda: now[0])
    reg.put("old")
    now[0] = 100.0
    bid, ep = reg.put("fresh")
    assert reg.expire_older_than(50.0) == 1
    assert reg.get(bid, ep) == "fresh"


def test_stats_accounting():
    reg = BufferRegistry()
    bid, ep = reg.put(b"x" * 10, n_retrievals=2)
    reg.put(b"y" * 20)
    reg.get(bid, ep)
    s = reg.stats()
    assert s.puts == 2 and s.gets == 1
    assert s.high_water_bytes == 30
    assert s.slots_in_use == 2


@settings(max_examples=60, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.integers(1, 4), st.integers(1, 100)),  # (n_retrievals, nbytes)
        min_size=1, max_size=30,
    )
)
def test_property_bytes_conserved(ops):
    """Invariant: bytes_in_use == sum of nbytes of live (unexhausted) objects,
    regardless of the put/get interleaving."""
    reg = BufferRegistry(max_slots=1000, max_bytes=1 << 30)
    live = {}
    for n, nb in ops:
        bid, ep = reg.put(b"z" * nb, n_retrievals=n)
        live[bid] = [n, nb, ep]
        # drain every other object by one retrieval
        for obid in list(live):
            if obid % 2 == 0:
                reg.get(obid, live[obid][2])
                live[obid][0] -= 1
                if live[obid][0] == 0:
                    del live[obid]
    expect = sum(nb for _, nb, _ in live.values())
    assert reg.stats().bytes_in_use == expect
    assert reg.stats().slots_in_use == len(live)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 16))
def test_property_exactly_n_retrievals(n):
    reg = BufferRegistry()
    bid, ep = reg.put("o", n_retrievals=n)
    for _ in range(n):
        reg.get(bid, ep)
    with pytest.raises(XDTObjectExhausted):
        reg.get(bid, ep)
