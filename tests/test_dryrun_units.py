"""Dry-run machinery units: HLO parsing, cell applicability, input specs.

These never build the 512-device mesh (pytest sees one device); the full
lower+compile sweep runs via ``python -m repro.launch.dryrun`` and its
results are validated in test_dryrun_results.py.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.dryrun import _shape_bytes, parse_collective_bytes
from repro.launch.input_specs import SHAPE_CELLS, cell_applicable, input_specs


def test_shape_bytes():
    assert _shape_bytes("bf16[16,4096]") == 16 * 4096 * 2
    assert _shape_bytes("f32[8]") == 32
    assert _shape_bytes("(bf16[4,4], f32[2])") == 32 + 8
    assert _shape_bytes("u8[3]") == 3
    assert _shape_bytes("token[]") == 0


def test_parse_collective_bytes():
    hlo = """
  %ag = bf16[32,128] all-gather(bf16[2,128] %x), replica_groups={}
  %ar = f32[64] all-reduce(f32[64] %y), to_apply=%sum
  %rs.1 = f32[8] reduce-scatter(f32[64] %z), dimensions={0}
  %cp = bf16[16,16] collective-permute(bf16[16,16] %w)
  %a2a = f32[4,4] all-to-all(f32[4,4] %v)
  %ars = f32[64] all-reduce-start(f32[64] %q), to_apply=%sum
  %ard = f32[64] all-reduce-done(f32[64] %ars)
"""
    out = parse_collective_bytes(hlo)
    assert out["all-gather"] == 32 * 128 * 2
    # all-reduce counts 2x (ring = reduce-scatter + all-gather), and the
    # start/done pair counts once
    assert out["all-reduce"] == 2 * (64 * 4) * 2
    assert out["reduce-scatter"] == 8 * 4
    assert out["collective-permute"] == 16 * 16 * 2
    assert out["all-to-all"] == 4 * 4 * 4


def test_parse_ignores_non_collectives():
    hlo = "%d = f32[128,128] dot(f32[128,64] %a, f32[64,128] %b)"
    assert parse_collective_bytes(hlo) == {}


# ------------------------------------------------------------ applicability


def test_cell_matrix_counts():
    """40 assigned cells: 31 applicable + 9 documented skips
    (hubert: decode_32k + long_500k; 7 quadratic archs: long_500k)."""
    total = applicable = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPE_CELLS:
            total += 1
            ok, why = cell_applicable(cfg, shape)
            if ok:
                applicable += 1
            else:
                assert why, f"{arch}/{shape} skip must carry a reason"
    assert total == 40
    assert applicable == 31


def test_encoder_skips_decode_cells():
    cfg = get_config("hubert_xlarge")
    assert cell_applicable(cfg, "train_4k")[0]
    assert cell_applicable(cfg, "prefill_32k")[0]
    assert not cell_applicable(cfg, "decode_32k")[0]
    assert not cell_applicable(cfg, "long_500k")[0]


def test_long_context_only_subquadratic():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ok, _ = cell_applicable(cfg, "long_500k")
        assert ok == (cfg.family in ("ssm", "hybrid")), arch


# ------------------------------------------------------------ input specs


@pytest.mark.parametrize("shape", list(SHAPE_CELLS))
def test_input_specs_abstract_no_allocation(shape):
    """Specs are ShapeDtypeStructs with the assignment's exact global dims."""
    cfg = get_config("granite_8b")
    if not cell_applicable(cfg, shape)[0]:
        pytest.skip("n/a")
    specs = input_specs(cfg, shape, mesh=None)
    cell = SHAPE_CELLS[shape]
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    if cell["kind"] == "train":
        assert specs["batch"]["tokens"].shape == (cell["batch"], cell["seq"])
        assert specs["opt_state"]["mu"]["embed"].dtype == jnp.float32
    elif cell["kind"] == "prefill":
        assert specs["batch"]["tokens"].shape == (cell["batch"], cell["seq"])
        assert "labels" not in specs["batch"]
    else:
        assert specs["tokens"].shape == (cell["batch"], 1)
        assert specs["cache"]["k"].shape[2] == cell["seq"]


def test_vlm_specs_split_patch_and_text():
    cfg = get_config("llava_next_mistral_7b")
    specs = input_specs(cfg, "train_4k", mesh=None)
    s_img = cfg.frontend_seq
    assert specs["batch"]["patches"].shape == (256, s_img, cfg.d_model)
    assert specs["batch"]["tokens"].shape == (256, 4096 - s_img)


def test_encoder_specs_use_frames():
    cfg = get_config("hubert_xlarge")
    specs = input_specs(cfg, "train_4k", mesh=None)
    assert "frames" in specs["batch"] and "tokens" not in specs["batch"]
