"""Streaming fast path: coalesced span kernels, credit backpressure, auto
chunk sizing.

The PR's tentpole invariants:

* the coalesced span-kernel path (``STREAM_COALESCE``) is a pure wall-time
  optimization — virtual-time results are bit-identical to the per-event
  legacy path on both producer shapes (instant burst and compute-paced);
* ``Edge(max_inflight_chunks=w)`` provably bounds the producer's resident
  chunk footprint to ``w * chunk_bytes`` where the unbounded stream buffers
  the whole object;
* under persistent zero-credit (a structurally slower consumer),
  ``OnlineSpill.on_pressure`` diverts the remaining stream durable and the
  request completes with zero retries;
* ``chunk_bytes="auto"`` resolves to a concrete split on both lowerings
  and keeps the once-per-(object, medium) billing contract;
* a credit window on a wave-mode gather edge is rejected at bind time
  (the entry drains gathers only after the producer wave returns, so a
  blocked producer would deadlock).
"""
import pytest

import repro.core.dag as dagmod
from repro.core import Edge, Stage, TelemetryHub, WorkflowDAG, WorkflowEngine
from repro.core.dag import FixedRoute, execute_on_cluster
from repro.core.dagopt import OnlineSpill

CHUNK = 1 << 20
NBYTES = 8 << 20                       # 8 chunks per object


def _pipe(chunk=CHUNK, producer_s=0.0, consumer_s=0.01, **edge_kw):
    return WorkflowDAG(
        "pipe",
        [Stage("p", compute_s=producer_s), Stage("c", compute_s=consumer_s)],
        [Edge("p", "c", NBYTES, label="feed", handoff="sync",
              streaming=True, chunk_bytes=chunk, **edge_kw)],
    )


def _run_engine(dag, backend="xdt", spill=None, coalesce=True):
    prev = dagmod.STREAM_COALESCE
    dagmod.STREAM_COALESCE = coalesce
    try:
        eng = WorkflowEngine(backend="xdt")
        binding = dag.bind(eng, default_route=FixedRoute(backend),
                           online_spill=spill)
        eng.submit(binding.entry, 1.0)
        eng.drain()
        (req,) = eng.requests
        return eng, binding, req
    finally:
        dagmod.STREAM_COALESCE = prev


# -- coalesced span kernels are invisible in virtual time --------------------


@pytest.mark.parametrize("producer_s", (0.0, 0.5))
@pytest.mark.parametrize("backend", ("xdt", "s3"))
def test_engine_coalesced_is_bit_identical_to_legacy(producer_s, backend):
    # producer_s=0 publishes every chunk at one instant (maximal span
    # coalescing); producer_s>0 paces chunks to distinct offsets (scalar
    # path) — both must match the legacy per-event interpreter exactly
    results = {}
    for mode in (True, False):
        eng, binding, req = _run_engine(
            _pipe(producer_s=producer_s), backend=backend, coalesce=mode
        )
        assert req.status == "ok"
        results[mode] = (
            req.latency_s,
            eng.sim.now,
            binding.cost().total,
            binding.edge_usage["feed"].n_puts,
            binding.edge_usage["feed"].n_gets,
        )
    assert results[True] == results[False]


def test_engine_coalesced_bounded_stream_matches_legacy():
    # the credit gate truncates spans to the available window; the
    # publication schedule (and so all virtual time) must still be
    # identical to the legacy scalar path under the same window
    results = {}
    for mode in (True, False):
        eng, binding, req = _run_engine(
            _pipe(max_inflight_chunks=3), coalesce=mode
        )
        assert req.status == "ok"
        results[mode] = (
            req.latency_s,
            eng.transfer.stats.peak_inflight_chunk_bytes,
            binding.cost().total,
        )
    assert results[True] == results[False]


# -- credit-based backpressure -----------------------------------------------


def test_engine_unbounded_stream_buffers_the_whole_object():
    # a zero-compute producer bursts every chunk before the consumer runs
    # once: without credits the full object is resident at the producer
    eng, binding, req = _run_engine(_pipe())
    assert req.status == "ok"
    assert eng.transfer.stats.peak_inflight_chunk_bytes == NBYTES


def test_engine_credit_window_bounds_peak_inflight():
    window = 2
    eng, binding, req = _run_engine(_pipe(max_inflight_chunks=window))
    assert req.status == "ok"
    assert eng.failed_requests == 0 and eng.retry_max == 0
    assert 0 < eng.transfer.stats.peak_inflight_chunk_bytes <= window * CHUNK
    # no spill configured: every chunk still rode the fast path
    u = binding.edge_usage["feed"]
    assert u.media == {"xdt": NBYTES // CHUNK}


def test_cluster_credit_window_bounds_peak_inflight():
    window = 2
    base = execute_on_cluster(_pipe(), "xdt", seed=0, deterministic=True)
    run = execute_on_cluster(
        _pipe(max_inflight_chunks=window), "xdt", seed=0, deterministic=True
    )
    bu = base.edge_usage["feed"]
    u = run.edge_usage["feed"]
    assert bu.peak_inflight_chunk_bytes == NBYTES       # burst buffers it all
    assert 0 < u.peak_inflight_chunk_bytes <= window * CHUNK
    # bounded sender memory may cost latency, never correctness
    assert u.media == bu.media
    assert run.latency_s >= base.latency_s


def test_storage_routed_chunks_do_not_consume_credits():
    # durable chunks leave the producer immediately — the credit window
    # only meters instance-resident media, so an s3 stream never parks
    eng, binding, req = _run_engine(_pipe(max_inflight_chunks=1),
                                    backend="s3")
    assert req.status == "ok"
    assert eng.transfer.stats.peak_inflight_chunk_bytes == 0.0
    assert binding.edge_usage["feed"].media == {"s3": NBYTES // CHUNK}


def test_pressure_spill_unsticks_a_slow_consumer_with_zero_retries():
    # window 2, patience 2, zero-compute producer: publishes 2, parks
    # (streak 1), drains, publishes 2 more, parks again (streak 2) ->
    # pressure spill diverts the remaining stream durable.  The request
    # completes first try with the footprint still bounded.
    hub = TelemetryHub(lambda: 0.0)
    sp = OnlineSpill(hub, durable="s3", pressure_patience=2)
    eng, binding, req = _run_engine(_pipe(max_inflight_chunks=2), spill=sp)
    assert req.status == "ok"
    assert eng.failed_requests == 0 and eng.retry_max == 0
    assert sp.pressure_spills
    label, medium, _now = sp.pressure_spills[0]    # records the pressured medium
    assert label == "feed" and medium == "xdt"
    media = binding.edge_usage["feed"].media
    assert media.get("xdt") and media.get("s3")
    assert sum(media.values()) == NBYTES // CHUNK
    assert eng.transfer.stats.peak_inflight_chunk_bytes <= 2 * CHUNK
    # billing still coalesces: one PUT per (object, medium)
    assert binding.edge_usage["feed"].n_puts == 2


def test_wave_gather_credit_window_is_rejected_at_bind():
    dag = WorkflowDAG(
        "gather",
        [Stage("driver", compute_s=0.0),
         Stage("m", fan=2, compute_s=0.01, blocking=False)],
        [Edge("driver", "m", 1 << 16, label="scatter", handoff="staged"),
         Edge("m", "driver", 4 << 20, label="collect", handoff="staged",
              streaming=True, chunk_bytes=CHUNK, max_inflight_chunks=2)],
    )
    eng = WorkflowEngine(backend="xdt")
    with pytest.raises(ValueError, match="deadlock"):
        dag.bind(eng, default_route=FixedRoute("xdt"))
    # the same edge without credits binds (and runs) fine
    ok = WorkflowDAG(
        dag.name, dag.stages,
        [dag.edges[0],
         Edge("m", "driver", 4 << 20, label="collect", handoff="staged",
              streaming=True, chunk_bytes=CHUNK)],
    )
    eng2 = WorkflowEngine(backend="xdt")
    binding = ok.bind(eng2, default_route=FixedRoute("xdt"))
    eng2.submit(binding.entry, 1.0)
    eng2.drain()
    assert eng2.requests[0].status == "ok"


# -- telemetry-tuned chunk sizing --------------------------------------------


@pytest.mark.parametrize("backend", ("xdt", "s3"))
def test_auto_chunk_bytes_runs_on_both_lowerings(backend):
    dag = _pipe(chunk="auto", producer_s=0.3)
    run = execute_on_cluster(dag, backend, seed=0, deterministic=True)
    u = run.edge_usage["feed"]
    assert sum(u.media_bytes.values()) == NBYTES
    assert u.n_puts <= 1 and u.n_gets <= 1     # billing stays whole-object
    eng, binding, req = _run_engine(dag, backend=backend)
    assert req.status == "ok"
    eu = binding.edge_usage["feed"]
    assert eu.n_puts == 1 and eu.n_gets == 1
    assert eu.media == {backend: sum(eu.media.values())}


def test_auto_chunk_bytes_never_loses_to_store_then_fetch():
    # the analytic prior clamps auto streaming to the store-then-fetch
    # equivalent, exactly like fixed chunk sizes
    plain = WorkflowDAG(
        "pipe",
        [Stage("p", compute_s=0.3), Stage("c", compute_s=0.01)],
        [Edge("p", "c", NBYTES, label="feed", handoff="sync")],
    )
    base = execute_on_cluster(plain, "s3", seed=0, deterministic=True)
    run = execute_on_cluster(_pipe(chunk="auto", producer_s=0.3), "s3",
                             seed=0, deterministic=True)
    assert run.latency_s <= base.latency_s * (1 + 1e-9)
    assert run.cost().total <= base.cost().total * (1 + 1e-9)
