"""Mesh-level behaviour (8 host devices, subprocess — see conftest)."""


def _assert_ok(results, name):
    r = results[name]
    assert r.get("ok"), f"{name}: {r}"


def test_collective_patterns(multidevice_results):
    """1-1/scatter/gather/gather_all/broadcast/all_to_all semantics on a mesh."""
    _assert_ok(multidevice_results, "patterns")


def test_sharded_train_matches_single_device(multidevice_results):
    """(2 data, 2 model) loss equals the unsharded loss on the same batch."""
    _assert_ok(multidevice_results, "sharded_train")


def test_seq_parallel_attention_plan(multidevice_results):
    """Heads that don't divide the model axis switch to the seq plan and
    still reproduce the unsharded numerics."""
    _assert_ok(multidevice_results, "seq_parallel_attention")


def test_moe_expert_parallel_matches_dense_oracle(multidevice_results):
    """EP-sharded MoE dispatch == dense all-experts oracle (high capacity)."""
    _assert_ok(multidevice_results, "moe_ep_oracle")


def test_compressed_psum_within_quant_bound(multidevice_results):
    """int8 compressed all-reduce error <= 1 quant step; EF doesn't regress."""
    _assert_ok(multidevice_results, "compressed_psum")


def test_elastic_checkpoint_reshape(multidevice_results):
    """Checkpoint saved on (4,2) restores bit-identically on (2,4) and (8,1)."""
    _assert_ok(multidevice_results, "elastic_checkpoint")


def test_grad_accum_equivalence(multidevice_results):
    """Microbatched accumulation reproduces the single-shot step."""
    _assert_ok(multidevice_results, "grad_accum")
