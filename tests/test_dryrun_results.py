"""Validate the recorded multi-pod dry-run sweep (results/dryrun.json).

The sweep itself is produced by ``PYTHONPATH=src python -m
repro.launch.dryrun --arch all --shape all --mesh both`` (30-60 min); these
tests assert its OUTPUT is complete and coherent, so CI catches a stale or
partially-failed sweep without re-lowering 512-device programs on every run.
"""
import json
import os

import pytest

from repro.configs import ARCH_IDS
from repro.launch.input_specs import SHAPE_CELLS

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results", "dryrun.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(RESULTS),
    reason="dry-run sweep not recorded yet (run repro.launch.dryrun)",
)


@pytest.fixture(scope="module")
def results():
    with open(RESULTS) as f:
        return json.load(f)


def test_all_80_cells_present(results):
    want = {
        f"{a}|{s}|{m}"
        for a in ARCH_IDS for s in SHAPE_CELLS for m in ("single", "multi")
    }
    assert want <= set(results), sorted(want - set(results))[:5]


def test_no_failures(results):
    failed = [k for k, v in results.items() if v["status"] == "failed"]
    assert not failed, failed


def test_skip_set_matches_design(results):
    """18 documented skips: encoder decode cells + long_500k on quadratic."""
    skips = {k for k, v in results.items() if v["status"] == "skipped"}
    assert len(skips) == 18
    for k in skips:
        arch, shape, _ = k.split("|")
        assert (
            (arch == "hubert_xlarge" and shape in ("decode_32k", "long_500k"))
            or shape == "long_500k"
        ), k
        assert results[k]["reason"]


def test_multi_pod_halves_per_device_flops(results):
    """The pod axis is DP for training: 2 pods => ~half the per-device
    batch => ~half the per-device FLOPs."""
    for arch in ARCH_IDS:
        single = results[f"{arch}|train_4k|single"]
        multi = results[f"{arch}|train_4k|multi"]
        if single["status"] != "ok" or multi["status"] != "ok":
            continue
        ratio = multi["flops_per_device"] / single["flops_per_device"]
        assert 0.4 < ratio < 0.75, (arch, ratio)


def test_memory_analysis_recorded(results):
    for k, v in results.items():
        if v["status"] == "ok":
            assert v["memory"]["peak_estimate_bytes"] > 0, k
            assert v["n_devices"] in (256, 512), k


def test_collectives_present_in_train_cells(results):
    """TP sharding must induce collectives; a train step with zero
    collective bytes means the sharding silently degenerated."""
    for arch in ARCH_IDS:
        v = results[f"{arch}|train_4k|single"]
        if v["status"] != "ok":
            continue
        coll = v["collective_bytes_per_device"]
        assert sum(coll.values()) > 0, arch
