"""Streaming edges: chunk protocol, billing equivalence, mid-stream faults.

The tentpole invariants, pinned on both lowerings:

* a FixedRoute streaming edge *bills identically* to the whole-object edge —
  per-chunk route resolution plus once-per-(object, medium) request billing
  must coalesce to one PUT + one (ranged) GET per object, on every backend;
* streaming never loses on makespan (the modeled finish clamps to the
  store-then-fetch equivalent);
* a producer killed mid-stream surfaces as a normal bounded retry — chunks
  already pulled don't exempt the consumer from the producer's death;
* ``OnlineSpill`` redirects the *remaining* chunks of a live stream to
  durable media when the producer's reap window closes in.
"""
import dataclasses

import pytest

from repro.core import (
    Edge,
    Stage,
    TelemetryHub,
    WorkflowDAG,
    WorkflowEngine,
)
from repro.core.dag import (
    FixedRoute,
    SizeRoute,
    critical_path_lower_bound,
    execute_on_cluster,
)
from repro.core.dagopt import OnlineSpill
from repro.core.workloads import DAGS

BACKENDS = ("s3", "elasticache", "xdt")
STREAM_EDGES = {"vid": ("fragment", "frames"), "mr": ("shuffle",)}
CHUNK = 1 << 20


def _variant(dag, chunk_bytes=CHUNK):
    edges = [
        dataclasses.replace(e, streaming=True, chunk_bytes=chunk_bytes)
        if e.label in STREAM_EDGES[dag.name] else e
        for e in dag.edges
    ]
    return WorkflowDAG(dag.name, dag.stages, edges)


# -- declaration-time validation ---------------------------------------------


def test_streaming_edge_validation():
    with pytest.raises(ValueError, match="chunk_bytes"):
        Edge("p", "c", 1 << 20, streaming=True)
    with pytest.raises(ValueError, match="chunk_bytes requires"):
        Edge("p", "c", 1 << 20, chunk_bytes=4096)
    with pytest.raises(ValueError, match="external"):
        Edge(None, "c", 1 << 20, handoff="external", route="s3",
             streaming=True, chunk_bytes=4096)
    with pytest.raises(ValueError, match="inline"):
        Edge("p", "c", 1 << 20, route="inline", streaming=True,
             chunk_bytes=4096)


def test_chunk_sizes_cover_the_object_exactly():
    e = Edge("p", "c", (3 << 20) + 7, streaming=True, chunk_bytes=1 << 20)
    sizes = e.chunk_sizes()
    assert sizes == (1 << 20, 1 << 20, 1 << 20, 7)
    assert sum(sizes) == e.nbytes
    # an object smaller than one chunk is a single piece
    small = Edge("p", "c", 100, streaming=True, chunk_bytes=1 << 20)
    assert small.chunk_sizes() == (100,)


def test_size_route_never_inlines_streaming_edges():
    # 3 MB rides inline unchunked (under the 6 MB activator cap) but chunks
    # outlive the sync message, so the same edge streamed must pick storage
    route = SizeRoute(inline_under=6 << 20)
    plain = Edge("p", "c", 3 << 20, handoff="sync")
    streamed = Edge("p", "c", 3 << 20, handoff="sync",
                    streaming=True, chunk_bytes=1 << 20)
    assert route.resolve(plain, plain.nbytes, False) == "inline"
    assert route.resolve(streamed, streamed.nbytes, False) != "inline"


# -- billing equivalence (satellite: per-chunk route-decision equivalence) ---


@pytest.mark.parametrize("wl", ("vid", "mr"))
@pytest.mark.parametrize("backend", BACKENDS)
def test_cluster_streaming_bills_like_whole_object(wl, backend):
    dag = DAGS[wl]
    base = execute_on_cluster(dag, backend, seed=0, deterministic=True)
    run = execute_on_cluster(_variant(dag), backend, seed=0,
                             deterministic=True)
    for label, u in base.edge_usage.items():
        su = run.edge_usage[label]
        assert su.n_puts == u.n_puts, label
        assert su.n_gets == u.n_gets, label
        assert su.media == u.media, label
    # chunking overlaps, it never adds: makespan and cost both clamp
    assert run.latency_s <= base.latency_s * (1 + 1e-9)
    assert run.cost().total <= base.cost().total * (1 + 1e-9)
    assert run.latency_s >= critical_path_lower_bound(dag, backend=backend)


@pytest.mark.parametrize("wl", ("vid", "mr"))
@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_streaming_bills_like_whole_object(wl, backend):
    def cell(d):
        eng = WorkflowEngine(backend="xdt")
        binding = d.bind(eng, default_route=FixedRoute(backend))
        eng.submit(binding.entry, 1.0)
        eng.drain()
        (req,) = eng.requests
        assert req.status == "ok"
        return req.latency_s, binding.cost(), binding.edge_usage

    base_lat, base_cost, base_usage = cell(DAGS[wl])
    lat, cost, usage = cell(_variant(DAGS[wl]))
    for label, u in base_usage.items():
        su = usage[label]
        assert (su.n_puts, su.n_gets) == (u.n_puts, u.n_gets), label
        assert set(su.media) == set(u.media), label
    assert lat <= base_lat * (1 + 1e-9)
    # request fees coalesce exactly; residency-priced media may only shrink
    assert cost.storage <= base_cost.storage * (1 + 1e-9)


def test_streaming_false_is_bit_identical():
    # the streaming code paths must be invisible when no edge streams:
    # same DAG object, interpreted twice, before/after a streaming variant
    # of it was built and run
    dag = DAGS["vid"]
    before = execute_on_cluster(dag, "xdt", seed=0, deterministic=True)
    execute_on_cluster(_variant(dag), "xdt", seed=0, deterministic=True)
    after = execute_on_cluster(dag, "xdt", seed=0, deterministic=True)
    assert before.latency_s == after.latency_s
    assert before.cost().total == after.cost().total


# -- mid-stream producer death (satellite: bounded retries) ------------------


def test_kill_producer_mid_stream_is_a_bounded_retry():
    dag = WorkflowDAG(
        "pipe",
        [Stage("p", compute_s=1.0), Stage("c", compute_s=0.01)],
        [Edge("p", "c", 8 << 20, label="feed", handoff="sync",
              streaming=True, chunk_bytes=1 << 20)],
    )
    eng = WorkflowEngine(backend="xdt", max_retries=2)
    binding = dag.bind(eng, default_route=FixedRoute("xdt"))
    eng.submit(binding.entry, 1.0)
    # the producer paces chunks across its 1 s compute; killing the instance
    # mid-production drops every already-published xdt chunk, so the
    # partially-drained consumer's next pull dies with the producer
    eng.sim.schedule_abs(1.0, eng.transfer.kill_producer)
    eng.drain()
    (req,) = eng.requests
    assert req.status == "ok"
    assert eng.failed_requests == 0
    assert 1 <= eng.retry_max <= eng.max_retries


def test_kill_producer_mid_stream_exhausts_cleanly():
    # every attempt dies mid-stream -> terminal "failed", never unbounded
    dag = WorkflowDAG(
        "pipe",
        [Stage("p", compute_s=1.0), Stage("c", compute_s=0.01)],
        [Edge("p", "c", 8 << 20, label="feed", handoff="sync",
              streaming=True, chunk_bytes=1 << 20)],
    )
    eng = WorkflowEngine(backend="xdt", max_retries=1)
    binding = dag.bind(eng, default_route=FixedRoute("xdt"))
    eng.submit(binding.entry, 1.0)
    # kill right after every 3rd chunk lands — published but not yet
    # pulled, so EVERY attempt dies mid-stream, deterministically
    orig_put = eng.transfer.put_chunk
    pushes = [0]

    def dying_put(*a, **kw):
        ref = orig_put(*a, **kw)
        pushes[0] += 1
        if pushes[0] % 3 == 0:
            eng.transfer.kill_producer()
        return ref

    eng.transfer.put_chunk = dying_put
    eng.drain()
    (req,) = eng.requests
    assert req.status == "failed"
    assert eng.retry_max <= eng.max_retries
    assert eng._inflight_requests == 0           # terminal, not wedged


# -- online spill (the carried-over PredictiveSpill gap) ---------------------


class _Feed:
    def __init__(self, life_s):
        self.life_s = life_s

    def expected_instance_lifetime_s(self, now):
        return self.life_s


def test_online_spill_redirects_when_reap_window_closes():
    hub = TelemetryHub(lambda: 0.0)
    hub.deployments["p"] = _Feed(0.05)           # reaped almost immediately
    dag = WorkflowDAG(
        "pipe",
        [Stage("p", compute_s=0.1), Stage("c", compute_s=0.01)],
        [Edge("p", "c", 2 << 20, label="feed", handoff="sync",
              streaming=True, chunk_bytes=1 << 20)],
    )
    edge = dag.edges[0]
    sp = OnlineSpill(hub, durable="s3")
    assert sp.medium_for(dag, edge, "xdt", now=0.0, eta_s=1.0) == "s3"
    assert sp.spills and sp.spills[0][0] == "feed"
    # a durable pick passes through untouched (and records nothing)
    n = len(sp.spills)
    assert sp.medium_for(dag, edge, "s3", now=0.0, eta_s=1.0) == "s3"
    assert len(sp.spills) == n
    # a long-lived producer keeps the fast path
    hub.deployments["p"] = _Feed(1e9)
    assert sp.medium_for(dag, edge, "xdt", now=0.0, eta_s=1.0) == "xdt"


def test_online_spill_rejects_ephemeral_targets():
    with pytest.raises(ValueError, match="durable"):
        OnlineSpill(TelemetryHub(lambda: 0.0), durable="xdt")


def test_online_spill_splits_a_live_stream_on_cluster():
    # eta shrinks chunk by chunk (less of the stream left to pull), so a
    # reap window between the first and last chunk's eta spills the early,
    # at-risk chunks durable and leaves the late ones on the fast path —
    # one logical object, split across media mid-stream
    hub = TelemetryHub(lambda: 0.0)
    dag = WorkflowDAG(
        "pipe",
        [Stage("p", compute_s=1.0), Stage("c", compute_s=0.01)],
        [Edge("p", "c", 8 << 20, label="feed", handoff="sync",
              streaming=True, chunk_bytes=1 << 20)],
    )
    hub.deployments["p"] = _Feed(1.0)
    sp = OnlineSpill(hub, durable="s3")
    run = execute_on_cluster(dag, "xdt", seed=0, deterministic=True,
                             online_spill=sp)
    assert sp.spills and {s[0] for s in sp.spills} == {"feed"}
    media = run.edge_usage["feed"].media
    assert media.get("s3") and media.get("xdt"), media
    # and spilling is strictly partial: fewer spills than chunks
    assert len(sp.spills) < len(dag.edges[0].chunk_sizes())
