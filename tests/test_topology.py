"""Edge-cloud continuum (core/topology.py) + the shared Registry.

Four families of guarantees:

* **Coord is a tuple** — equality, hashing, and indexing are inherited from
  the plain coords tuples both lowerings already use, so a typed Coord and
  the tuple it wraps are interchangeable everywhere (``as_coord`` coerces).
* **Crossings are ordered** — deeper tier crossings never get cheaper,
  faster, or lower-latency; level <= SAME_ZONE is always free.
* **Flat is bit-identical** — no topology, a single-zone topology, and the
  pre-topology goldens all agree exactly (latency hex anchor included), on
  both lowerings.
* **Tier-aware placement dominates** — on the topology workloads the
  optimizer's zone assignment is never costlier/slower than naive
  round-robin spread, and strictly cheaper where a wrong zone crosses the
  edge uplink.
"""
import pytest

from repro.core.cluster import DEFAULT_NET
from repro.core.cost import TIER_EGRESS_USD_PER_GB, egress_fee_usd
from repro.core.registry import Registry
from repro.core.scheduler import ControlPlane, Deployment, ScalingPolicy
from repro.core.topology import FLAT_TOPOLOGY, Coord, Topology, Zone, as_coord
from repro.core.workloads import (
    DAGS,
    TOPO_DAGS,
    TOPO_WORKLOADS,
    TOPOLOGIES,
    run_vid,
)


# ---------------------------------------------------------------------------
# Coord: typed coordinates that stay plain tuples
# ---------------------------------------------------------------------------


def test_coord_is_its_tuple():
    c = Coord((2, 5), zone="z1", region="us", site="cloud")
    assert c == (2, 5)
    assert hash(c) == hash((2, 5))
    assert c[1] == 5
    assert {c: "x"}[(2, 5)] == "x"          # dict interop both directions
    assert c.zone == "z1" and c.region == "us" and c.site == "cloud"
    assert c.path == ("cloud", "us", "z1")


def test_as_coord_coercion():
    assert as_coord(None) is None
    c = Coord((1,), zone="z0")
    assert as_coord(c) is c                  # pass-through, metadata kept
    t = as_coord((3, 4))
    assert isinstance(t, Coord) and t == (3, 4) and t.zone is None
    assert as_coord([7]) == (7,)
    with pytest.raises(TypeError):
        as_coord("node-3")


# ---------------------------------------------------------------------------
# Topology: hierarchy, crossings, zone assignment
# ---------------------------------------------------------------------------


def test_flat_topology_is_flat():
    assert FLAT_TOPOLOGY.is_flat
    assert Topology().is_flat
    assert not Topology(zones=(Zone("a"), Zone("b"))).is_flat


def test_crossing_levels():
    t = Topology(zones=(
        Zone("z0", region="us"), Zone("z1", region="us"),
        Zone("eu", region="eu"),
        Zone("edge", region="site-0", site="edge"),
    ))
    assert t.crossing(0, 0) == 1             # same zone
    assert t.crossing(0, 1) == 2             # cross zone, same region
    assert t.crossing(0, 2) == 3             # cross region
    assert t.crossing(0, 3) == 4             # cloud <-> edge site
    assert t.crossing(3, 0) == t.crossing(0, 3)


def test_service_zone_prefers_cloud():
    t = Topology(zones=(
        Zone("edge", region="s", site="edge"), Zone("cloud", region="us"),
    ))
    assert t.zones[t.service_zone].name == "cloud"


def test_zone_assignment_precedence():
    t = Topology(
        zones=(Zone("a"), Zone("b"), Zone("c")),
        pin={"pinned": ("c",)},
    )
    # pins > plan > round-robin (k-th unpinned stage -> zone k % n)
    zones = t.assign_stage_zones(
        ["pinned", "s0", "s1"], plan_zones={"s1": "c"}
    )
    assert [t.zones[zones["pinned"][0]].name] == ["c"]
    assert t.zones[zones["s0"][0]].name == "a"    # first unpinned: k=0
    assert t.zones[zones["s1"][0]].name == "c"    # plan wins over k=1 -> "b"


def test_tier_rates_are_monotone():
    fees = [egress_fee_usd(lv, 1 << 30) for lv in range(5)]
    assert fees[0] == fees[1] == 0.0         # intra-zone is never billed
    assert fees[1] <= fees[2] <= fees[3] <= fees[4]
    assert fees[4] > fees[2] > 0.0
    assert len(TIER_EGRESS_USD_PER_GB) == 5
    net = DEFAULT_NET
    assert net.tier_bw(2) >= net.tier_bw(3) >= net.tier_bw(4)
    assert net.tier_rtt(2) <= net.tier_rtt(3) <= net.tier_rtt(4)


# ---------------------------------------------------------------------------
# Flat identity: the continuum machinery must be invisible when unused
# ---------------------------------------------------------------------------


def test_pre_topology_golden_latency_anchor():
    # pinned from before topology landed: any drift here means the flat
    # path is performing different float ops than the seed did
    r = run_vid("s3", seed=0, deterministic=True)
    assert r.latency_s.hex() == "0x1.32709035eda2ap+0"


@pytest.mark.parametrize("backend", ["s3", "elasticache", "xdt"])
def test_single_zone_topology_bit_identical_cluster(backend):
    single = Topology()
    for dag in DAGS.values():
        base = dag.compile(target="cluster", backend=backend).run(
            seed=0, deterministic=True)
        topo = dag.compile(target="cluster", backend=backend,
                           topology=single).run(seed=0, deterministic=True)
        assert topo.latency_s == base.latency_s
        assert topo.cost().total == base.cost().total
        assert topo.cost().egress == 0.0


def test_single_zone_topology_bit_identical_engine():
    from repro.core.workflow import WorkflowEngine

    def one(topology):
        eng = WorkflowEngine(backend="xdt")
        binding = DAGS["vid"].compile(
            target="engine", engine=eng, topology=topology, bytes_scale=1e-4,
        )
        eng.run(binding.entry, 1.0)
        return eng.requests[0].latency_s, binding.cost().total

    assert one(None) == one(Topology())


# ---------------------------------------------------------------------------
# Tier-aware placement: never worse, strictly better when naive crosses
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(TOPO_WORKLOADS))
@pytest.mark.parametrize("backend", ["s3", "xdt"])
def test_tier_aware_never_worse(name, backend):
    dag, topo = TOPO_DAGS[name], TOPOLOGIES[name]
    _, plan = dag.optimize(topology=topo, backend=backend)
    flat = TOPO_WORKLOADS[name](backend, seed=0, deterministic=True)
    aware = TOPO_WORKLOADS[name](backend, seed=0, deterministic=True,
                                 plan=plan)
    assert aware.cost.total <= flat.cost.total * (1 + 1e-9)
    assert aware.latency_s <= flat.latency_s * (1 + 1e-9)


def test_edge_collector_moves_to_cloud():
    dag, topo = TOPO_DAGS["edge"], TOPOLOGIES["edge"]
    _, plan = dag.optimize(topology=topo, backend="s3")
    assert plan.zones["driver"] == "cloud"
    flat = TOPO_WORKLOADS["edge"]("s3", seed=0, deterministic=True)
    aware = TOPO_WORKLOADS["edge"]("s3", seed=0, deterministic=True,
                                   plan=plan)
    # naive drops the collector on edge-0: every model gather and service
    # leg crosses the edge uplink, which bills egress and costs latency
    assert aware.cost.total < flat.cost.total
    assert aware.latency_s < flat.latency_s
    assert aware.cost.egress < flat.cost.egress


def test_geo_driver_zone_depends_on_backend():
    dag, topo = TOPO_DAGS["geo"], TOPOLOGIES["geo"]
    _, s3_plan = dag.optimize(topology=topo, backend="s3")
    _, xdt_plan = dag.optimize(topology=topo, backend="xdt")
    assert s3_plan.zones["driver"] == "us-hub"     # storage home zone
    assert xdt_plan.zones["driver"] == "us-shard"  # next to resident peers


# ---------------------------------------------------------------------------
# Zone-affine steering + Coord at the control-plane surfaces
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_steer_zone_fallback():
    t = Topology(zones=(Zone("a"), Zone("b")))
    # two prewarmed instances, one per zone, via a topology-aware placer
    d = Deployment(
        "f", ScalingPolicy(min_instances=2),
        placer=lambda i: t.coord((i % 2, i), i % 2), clock=_FakeClock(),
    )
    want = Coord((1, 99), zone="b")          # no instance at these coords
    inst, _ = d.steer(prefer=want)
    assert inst.coords.zone == "b"           # same-zone fallback, not luck
    inst2, _ = d.steer(prefer=(1, 99))       # plain tuple: no zone, no hint
    assert inst2 is not inst or inst.in_flight == 2


def test_kill_node_accepts_tuple_and_coord():
    cp = ControlPlane(clock=_FakeClock())
    cp.register("f", ScalingPolicy(min_instances=2))
    (iid,) = cp.deployments["f"].instances_at((0,))
    assert cp.kill_node(Coord((0,))) == 1    # typed spelling, same node
    assert iid not in cp.deployments["f"].instances
    assert cp.kill_node((0,)) == 0           # already dead; tuple accepted


# ---------------------------------------------------------------------------
# Registry: the shared name->class mapping behind register_*
# ---------------------------------------------------------------------------


def test_registry_mapping_protocol():
    reg = Registry("widget")

    @reg.register
    class Sprocket:
        name = "sprocket"

    assert reg["sprocket"] is Sprocket
    assert "sprocket" in reg and len(reg) == 1
    assert sorted(reg) == ["sprocket"]
    with pytest.raises(KeyError):
        reg["missing"]


def test_registry_duplicate_policies():
    class A:
        name = "x"

    class B:
        name = "x"

    replace = Registry("widget")
    replace.register(A)
    replace.register(B)
    assert replace["x"] is B
    strict = Registry("widget", on_duplicate="error")
    strict.register(A)
    with pytest.raises(ValueError):
        strict.register(B)


def test_public_registries_still_serve_call_sites():
    from repro.core.dagopt import available_passes
    from repro.core.scheduler import available_autoscalers
    from repro.core.transfer import available_backends

    assert {"s3", "elasticache", "xdt"} <= set(available_backends())
    assert {"fuse", "coplace", "spill"} <= set(available_passes())
    assert "concurrency" in available_autoscalers()
