"""XDTRef capability tokens: mint/open roundtrip, unforgeability, opacity."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.errors import XDTRefInvalid
from repro.core.refs import ObjectDescriptor, RefMinter, RefPayload, XDTRef


def _payload(producer=(0, 1), buffer_id=7, epoch=3, n=2):
    return RefPayload(
        producer=producer,
        buffer_id=buffer_id,
        epoch=epoch,
        desc=ObjectDescriptor(shape=(4, 8), dtype="bfloat16", nbytes=64, n_retrievals=n),
    )


def test_roundtrip():
    m = RefMinter(key=b"k" * 32)
    ref = m.mint(_payload())
    out = m.open(ref)
    assert out == _payload()


def test_token_is_opaque():
    """The token must not leak producer coordinates or buffer ids in clear."""
    m = RefMinter(key=b"k" * 32)
    ref = m.mint(_payload(producer=(123456789,), buffer_id=987654321))
    assert b"123456789" not in ref.token
    assert b"987654321" not in ref.token
    assert "XDTRef" in repr(ref) and "123456789" not in repr(ref)


def test_tamper_detected():
    m = RefMinter(key=b"k" * 32)
    ref = m.mint(_payload())
    for i in range(len(ref.token)):
        bad = bytearray(ref.token)
        bad[i] ^= 0x01
        with pytest.raises(XDTRefInvalid):
            m.open(XDTRef(bytes(bad)))


def test_truncation_detected():
    m = RefMinter(key=b"k" * 32)
    ref = m.mint(_payload())
    for cut in (0, 1, len(ref.token) // 2, len(ref.token) - 1):
        with pytest.raises(XDTRefInvalid):
            m.open(XDTRef(ref.token[:cut]))


def test_cross_minter_rejection():
    """A ref minted in one trust domain cannot be opened in another."""
    a, b = RefMinter(key=b"a" * 32), RefMinter(key=b"b" * 32)
    with pytest.raises(XDTRefInvalid):
        b.open(a.mint(_payload()))


def test_user_cannot_mint():
    """Forged tokens (random bytes of plausible length) never authenticate."""
    m = RefMinter(key=b"k" * 32)
    import hashlib

    for seed in range(20):
        forged = hashlib.sha256(bytes([seed])).digest() + b"\x00" * 24
        with pytest.raises(XDTRefInvalid):
            m.open(XDTRef(forged))


def test_hex_roundtrip():
    m = RefMinter(key=b"k" * 32)
    ref = m.mint(_payload())
    assert m.open(XDTRef.from_hex(ref.hex())) == _payload()


def test_nonces_unique_tokens_differ():
    m = RefMinter(key=b"k" * 32)
    r1, r2 = m.mint(_payload()), m.mint(_payload())
    assert r1.token != r2.token            # same payload, fresh nonce
    assert m.open(r1) == m.open(r2)


@settings(max_examples=50, deadline=None)
@given(
    producer=st.tuples(st.integers(0, 511), st.integers(0, 15)),
    buffer_id=st.integers(0, 2**31),
    epoch=st.integers(0, 2**20),
    shape=st.lists(st.integers(1, 1 << 16), min_size=0, max_size=5),
    n=st.integers(1, 64),
)
def test_property_roundtrip(producer, buffer_id, epoch, shape, n):
    m = RefMinter(key=b"p" * 32)
    p = RefPayload(
        producer=producer, buffer_id=buffer_id, epoch=epoch,
        desc=ObjectDescriptor(tuple(shape), "float32", 4 * max(1, n), n_retrievals=n),
    )
    assert m.open(m.mint(p)) == p


@settings(max_examples=50, deadline=None)
@given(flip=st.integers(0, 10_000), data=st.binary(min_size=30, max_size=200))
def test_property_random_bytes_rejected(flip, data):
    m = RefMinter(key=b"p" * 32)
    with pytest.raises(XDTRefInvalid):
        m.open(XDTRef(data))
