"""Event-driven workflow engine: concurrency, virtual time, load generation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    LoadGenerator,
    RetriesExhausted,
    WorkflowEngine,
)
from repro.core.scheduler import ScalingPolicy


def _policy(**kw):
    kw.setdefault("max_instances", 16)
    kw.setdefault("target_concurrency", 1)
    return ScalingPolicy(**kw)


# --------------------------------------------------------------- concurrency


def test_two_requests_overlap_in_virtual_time():
    eng = WorkflowEngine()
    eng.register("work", lambda ctx, x: x * 2, policy=_policy(), service_time=0.2)
    a = eng.submit("work", 1)
    b = eng.submit("work", 2)
    eng.drain()
    assert (a.result, b.result) == (2, 4)
    recs = [r for r in eng.records if r.function == "work"]
    assert len(recs) == 2
    assert recs[0].overlaps(recs[1])               # genuinely concurrent
    assert recs[0].instance_id != recs[1].instance_id  # scale-up, not queueing
    # each paid its own cold start + control hop + service time, concurrently
    assert a.latency_s == pytest.approx(0.5 + 0.0023 + 0.2)
    assert b.latency_s == pytest.approx(a.latency_s)
    eng.assert_at_most_once()


def test_fan_out_fan_in_overlaps():
    """Generator handler: scatter_async workers run concurrently, so the
    fan-out costs one worker's service time, not fan x service time."""
    eng = WorkflowEngine()
    eng.register("worker", lambda ctx, x: x + 1, policy=_policy(),
                 service_time=0.3)

    def driver(ctx, xs):
        results = yield ctx.scatter_async("worker", xs)
        return sum(results)

    eng.register("driver", driver, policy=_policy())
    out = eng.run("driver", [1, 2, 3, 4])
    assert out == 2 + 3 + 4 + 5
    workers = [r for r in eng.records if r.function == "worker"]
    assert len(workers) == 4
    for i in range(1, 4):
        assert workers[0].overlaps(workers[i])
    req = eng.requests[-1]
    # far below the 4 * 0.3 sequential bound (cold starts + one 0.3 wave)
    assert req.latency_s < 0.5 + 0.3 + 0.5 + 0.1


def test_generator_chain_with_async_call():
    eng = WorkflowEngine()
    eng.register("double", lambda ctx, x: x * 2, policy=_policy())

    def entry(ctx, x):
        h = ctx.call("double", x)
        doubled = yield h
        yield 0.05                       # explicit virtual compute
        return doubled + 1

    eng.register("entry", entry, policy=_policy())
    assert eng.run("entry", 10) == 21


def test_generator_handler_rejected_inline():
    eng = WorkflowEngine()

    def gen_handler(ctx, x):
        yield 0.1
        return x

    eng.register("g", gen_handler)
    eng.register("caller", lambda ctx, x: ctx.invoke("g", x))
    with pytest.raises(TypeError, match="inline"):
        eng.run("caller", 0)


def test_producer_death_retry_in_concurrent_path():
    """XDTProducerGone inside a ctx.call sub-invocation escalates through the
    fan-in to the orchestrator, which re-invokes the entry workflow."""
    eng = WorkflowEngine(max_retries=2)
    attempts = []

    def producer(ctx, x):
        ref = ctx.put(jnp.ones((2,)) * x)
        attempts.append(x)
        if len(attempts) == 1:
            eng.transfer.kill_producer()
        return ref

    def consumer(ctx, ref):
        return float(ctx.get(ref).sum())

    def driver(ctx, x):
        ref = yield ctx.call("producer", x)
        out = yield ctx.call("consumer", ref)
        return out

    eng.register("producer", producer)
    eng.register("consumer", consumer)
    eng.register("driver", driver)
    assert eng.run("driver", 4.0) == 8.0
    assert attempts == [4.0, 4.0]
    assert eng.requests[-1].attempts == 2
    eng.assert_at_most_once()


def test_retry_budget_exhaustion_concurrent():
    eng = WorkflowEngine(max_retries=1)

    def producer(ctx, x):
        ref = ctx.put(jnp.ones((2,)))
        eng.transfer.kill_producer()
        return ctx.invoke("consumer", ref)

    eng.register("producer", producer)
    eng.register("consumer", lambda ctx, ref: ctx.get(ref))
    with pytest.raises(RetriesExhausted):
        eng.run("producer", 0)
    assert eng.requests[-1].status == "failed"


# ------------------------------------------------------------ virtual timing


def test_cold_start_gates_first_request_only():
    eng = WorkflowEngine()
    eng.register("f", lambda ctx, x: x,
                 policy=_policy(cold_start_s=0.5, keep_alive_s=60.0))
    eng.run("f", 0)
    first = eng.requests[-1].latency_s
    eng.run("f", 0)                       # warm instance: no cold start
    second = eng.requests[-1].latency_s
    assert first == pytest.approx(0.5 + 0.0023)
    assert second == pytest.approx(0.0023)


def test_prewarmed_min_instances_skip_cold_start():
    eng = WorkflowEngine()
    eng.register("f", lambda ctx, x: x,
                 policy=_policy(min_instances=1, cold_start_s=0.5))
    eng.run("f", 0)
    assert eng.requests[-1].latency_s == pytest.approx(0.0023)


def test_transfer_debt_becomes_virtual_latency():
    """A put/get edge charges the modeled backend latency to the request."""
    lat = {}
    for backend in ("xdt", "s3"):
        eng = WorkflowEngine(backend=backend)
        eng.register("consumer", lambda ctx, ref: float(ctx.get(ref).sum()),
                     policy=_policy(min_instances=1))

        def producer(ctx, x):
            ref = ctx.put(jnp.full((1024,), x, jnp.float32), n_retrievals=1)
            return ctx.invoke("consumer", ref)

        eng.register("producer", producer, policy=_policy(min_instances=1))
        assert eng.run("producer", 2.0) == 2.0 * 1024
        lat[backend] = eng.requests[-1].latency_s
    assert lat["s3"] > lat["xdt"]         # through-storage pays the round-trip


def test_blocking_run_api_unchanged_for_sync_workflows():
    eng = WorkflowEngine()
    eng.register("consumer", lambda ctx, x: x + 1)
    eng.register("producer", lambda ctx, x: ctx.invoke("consumer", x * 2))
    assert eng.run("producer", 5) == 11
    assert eng.requests[-1].status == "ok"
    assert eng.requests[-1].latency_s > 0


# ----------------------------------------------------------------- load gen


def _loaded_engine(backend="xdt", seed=0):
    eng = WorkflowEngine(seed=seed, backend=backend)
    eng.register("worker", lambda ctx, ref: float(ctx.get(ref).sum()),
                 policy=_policy(max_instances=32))

    def entry(ctx, i):
        ref = ctx.put(jnp.full((256,), float(i), jnp.float32), n_retrievals=1)
        h = ctx.call("worker", ref)
        out = yield h
        return out

    eng.register("entry", entry, policy=_policy(max_instances=32),
                 service_time=0.01)
    return eng


def test_closed_loop_load_generator():
    eng = _loaded_engine()
    rep = LoadGenerator(eng, "entry").run_closed(
        n_clients=4, requests_per_client=3, think_time_s=0.05
    )
    assert rep.mode == "closed"
    assert rep.n_requests == 12 and rep.n_ok == 12
    assert rep.achieved_rps > 0
    assert 0 < rep.p50_s <= rep.p99_s
    assert len(rep.latencies_s) == 12


def test_open_loop_load_generator_deterministic():
    reps = [
        LoadGenerator(_loaded_engine(seed=7), "entry").run_open(
            rate_rps=20.0, duration_s=2.0
        )
        for _ in range(2)
    ]
    assert reps[0].n_requests == reps[1].n_requests > 0
    np.testing.assert_allclose(reps[0].latencies_s, reps[1].latencies_s)


def test_foreign_exception_recorded_as_error():
    """Non-XDT handler exceptions surface to the caller AND are recorded
    with status "error" (no stable code), not silently marked ok."""
    eng = WorkflowEngine()

    def bad(ctx, x):
        raise ValueError("boom")

    eng.register("bad", bad)
    with pytest.raises(ValueError):
        eng.run("bad", 0)
    rec = [r for r in eng.records if r.function == "bad"][0]
    assert rec.status == "error" and rec.error_code is None
    # same through the inline path
    eng.register("caller", lambda ctx, x: ctx.invoke("bad", x))
    with pytest.raises(ValueError):
        eng.run("caller", 0)
    assert all(r.status == "error" for r in eng.records if r.function == "bad")


def test_load_report_isolated_across_runs():
    """Reusing one engine/generator: each report prices only its own run."""
    eng = _loaded_engine("s3")
    gen = LoadGenerator(eng, "entry")
    first = gen.run_closed(n_clients=2, requests_per_client=3)
    second = gen.run_closed(n_clients=2, requests_per_client=3)
    assert second.n_requests == first.n_requests == 6
    assert second.cost_inputs.n_storage_puts == first.cost_inputs.n_storage_puts
    assert second.cost_inputs.n_function_invocations == (
        first.cost_inputs.n_function_invocations
    )
    # warm instances make the second run cheaper-or-equal, never ~2x
    assert second.usd_per_1k_requests <= first.usd_per_1k_requests * 1.05


def test_load_report_prices_backends_apart():
    """Through-storage pays request fees; XDT's storage bill is zero."""
    costs = {}
    for backend in ("xdt", "s3"):
        rep = LoadGenerator(_loaded_engine(backend), "entry").run_closed(
            n_clients=2, requests_per_client=4
        )
        costs[backend] = rep
    assert costs["s3"].cost_inputs.n_storage_puts > 0
    assert costs["xdt"].cost_inputs.n_storage_puts == 0
    assert (
        costs["s3"].usd_per_1k_requests > costs["xdt"].usd_per_1k_requests > 0
    )
