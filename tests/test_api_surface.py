"""Lint gate: no NEW call sites of the deprecated run entry points.

``execute_on_cluster(...)`` and ``dag.bind(...)`` survive only as
DeprecationWarning shims over ``dag.compile()``; the migration left call
sites in exactly two places — the shims themselves (``core/dag.py``) and
the test files that pin shim behavior and pre-migration goldens.  This
grep-based check walks every tracked ``.py`` file and fails if a file
grows MORE call sites than its frozen baseline (or a new file introduces
any), pointing the author at ``dag.compile()``.

Shrinking a count is always legal: tighten the baseline when you migrate
a file.  The patterns are word-bounded, so the private
``_execute_on_cluster`` implementation does not count.
"""
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks", "examples", "tests", "tools")

EXECUTE = re.compile(r"\bexecute_on_cluster\(")
BIND = re.compile(r"\.bind\(")

#: file -> (max execute_on_cluster(...) sites, max .bind(...) sites).
#: The shims live in core/dag.py; every other entry is a test file that
#: deliberately exercises the deprecated spelling (parity + goldens).
BASELINE = {
    "src/repro/core/dag.py": (2, 2),
    "tests/test_api_parity.py": (2, 2),
    "tests/test_autoscaler_policies.py": (2, 1),
    "tests/test_chunk_billing_hypothesis.py": (2, 1),
    "tests/test_dag.py": (3, 2),
    "tests/test_dagopt.py": (13, 4),
    "tests/test_faults.py": (6, 6),
    "tests/test_route_policies.py": (6, 1),
    "tests/test_streaming.py": (6, 3),
    "tests/test_streaming_fastpath.py": (5, 3),
    "tests/test_streaming_optimizer.py": (3, 1),
}


def _census():
    rows = {}
    for d in SCAN_DIRS:
        root = REPO / d
        if not root.exists():
            continue
        for f in sorted(root.rglob("*.py")):
            if "__pycache__" in f.parts or f == Path(__file__).resolve():
                continue   # this file names the patterns in its own docstring
            text = f.read_text()
            n_exec = len(EXECUTE.findall(text))
            n_bind = len(BIND.findall(text))
            if n_exec or n_bind:
                rows[str(f.relative_to(REPO))] = (n_exec, n_bind)
    return rows

def test_no_new_deprecated_call_sites():
    offenders = []
    for path, (n_exec, n_bind) in _census().items():
        max_exec, max_bind = BASELINE.get(path, (0, 0))
        if n_exec > max_exec or n_bind > max_bind:
            offenders.append(
                f"  {path}: execute_on_cluster x{n_exec} (allowed "
                f"{max_exec}), .bind x{n_bind} (allowed {max_bind})"
            )
    assert not offenders, (
        "new call sites of deprecated run entry points:\n"
        + "\n".join(offenders)
        + "\nuse dag.compile(target='cluster'|'engine', ...).run(...) / "
        "the returned DagBinding instead; the deprecated spellings are "
        "shims kept only for their pinned tests"
    )


def test_baseline_is_not_stale():
    # entries for files that no longer contain any call site rot silently;
    # force the allowlist to track reality in both directions
    census = _census()
    stale = [p for p in BASELINE if p not in census]
    assert not stale, (
        f"baseline entries with zero remaining call sites: {stale} — "
        "delete them from BASELINE"
    )
