"""Calibrated simulator vs the paper's measured anchors (Figs 2, 5, 6)."""
import numpy as np
import pytest

from repro.core import (
    InlineTooLarge,
    effective_bandwidth_Bps,
    measure_pattern,
)
from repro.core.cluster import LAMBDA_NET, Simulator


# ---------------------------------------------------------------- event loop


def test_simulator_determinism():
    t1, _ = measure_pattern("1-1", "s3", 1 << 20, seed=7)
    t2, _ = measure_pattern("1-1", "s3", 1 << 20, seed=7)
    assert t1 == t2


def test_simulator_event_ordering():
    sim = Simulator()
    order = []
    sim.schedule(2.0, lambda: order.append("b"))
    sim.schedule(1.0, lambda: order.append("a"))
    sim.schedule(3.0, lambda: order.append("c"))
    sim.run()
    assert order == ["a", "b", "c"]
    assert sim.now == 3.0


def test_fifo_link_serializes():
    sim = Simulator()
    from repro.core.cluster import FifoLink

    link = FifoLink(sim, bw_Bps=100.0)
    e1 = link.transfer(100)   # 1 s
    e2 = link.transfer(100)   # queued behind e1 -> finishes at 2 s
    sim.run()
    assert e1.fired and e2.fired
    assert sim.now == pytest.approx(2.0)


# ------------------------------------------------------------ Fig. 2 anchors


def test_fig2_inline_vs_s3_100kb():
    """Paper: inline latency 8.1x lower than S3 at 100 KB (Lambda testbed)."""
    n = 100 << 10
    t_inline, _ = measure_pattern("1-1", "inline", n, net=LAMBDA_NET, deterministic=True)
    t_s3, _ = measure_pattern("1-1", "s3", n, net=LAMBDA_NET, deterministic=True)
    ratio = t_s3 / t_inline
    assert 6.0 < ratio < 11.0, ratio


def test_fig2_inline_vs_elasticache_100kb():
    """Paper: inline 1.3x lower latency than ElastiCache at 100 KB."""
    n = 100 << 10
    t_inline, _ = measure_pattern("1-1", "inline", n, net=LAMBDA_NET, deterministic=True)
    t_ec, _ = measure_pattern("1-1", "elasticache", n, net=LAMBDA_NET, deterministic=True)
    ratio = t_ec / t_inline
    assert 1.05 < ratio < 1.8, ratio


def test_fig2_inline_size_cap():
    with pytest.raises(InlineTooLarge):
        measure_pattern("1-1", "inline", 7 << 20)          # > 6 MB


# ------------------------------------------------------------ Fig. 5 anchors


def _median_tail(backend, nbytes, n=60):
    ts = [measure_pattern("1-1", backend, nbytes, seed=s)[0] for s in range(n)]
    return float(np.median(ts)), float(np.percentile(ts, 99))


def test_fig5_small_object_ordering():
    """10 KB: EC median ~89% below S3; XDT ~12% below EC."""
    n = 10 << 10
    m_s3, _ = _median_tail("s3", n)
    m_ec, _ = _median_tail("elasticache", n)
    m_xdt, _ = _median_tail("xdt", n)
    assert m_ec < 0.25 * m_s3          # >= 75% reduction (paper: 89%)
    assert m_xdt < m_ec                # XDT strictly better
    assert m_xdt > 0.6 * m_ec          # but in the "few %..15%" band, not 10x


def test_fig5_large_object_ordering():
    """10 MB: EC ~87% below S3; XDT median ~45% below EC."""
    n = 10 << 20
    m_s3, t_s3 = _median_tail("s3", n)
    m_ec, t_ec = _median_tail("elasticache", n)
    m_xdt, t_xdt = _median_tail("xdt", n)
    assert m_ec < 0.3 * m_s3
    assert 0.4 < m_xdt / m_ec < 0.75   # paper: 45% lower median
    assert t_xdt < t_ec                 # tails too


# ------------------------------------------------------------ Fig. 6 anchors


@pytest.mark.parametrize("pattern", ["scatter", "gather", "broadcast"])
@pytest.mark.parametrize("fan", [4, 16])
def test_fig6_collective_ordering(pattern, fan):
    """XDT matches-or-beats EC, and EC beats S3, for every pattern x fan."""
    n = 10 << 20
    t_s3, _ = measure_pattern(pattern, "s3", n, fan=fan, deterministic=True)
    t_ec, _ = measure_pattern(pattern, "elasticache", n, fan=fan, deterministic=True)
    t_xdt, _ = measure_pattern(pattern, "xdt", n, fan=fan, deterministic=True)
    assert t_xdt <= t_ec * 1.02, (pattern, fan, t_xdt, t_ec)
    assert t_ec < t_s3, (pattern, fan)


def test_fig6_effective_bandwidth_fan32():
    """Paper: at fan 32 / 10 MB, XDT 16.4 Gb/s (82% of 20 Gb/s NIC),
    EC 14.0 Gb/s, S3 5.5 Gb/s."""
    n = 10 << 20
    bw_xdt = effective_bandwidth_Bps("gather", "xdt", n, fan=32)
    bw_ec = effective_bandwidth_Bps("gather", "elasticache", n, fan=32)
    bw_s3 = effective_bandwidth_Bps("gather", "s3", n, fan=32)
    gbps = lambda b: b * 8 / 1e9
    assert 14.5 < gbps(bw_xdt) < 17.5, gbps(bw_xdt)   # ~16.4
    assert 12.0 < gbps(bw_ec) < 15.5, gbps(bw_ec)     # ~14.0
    assert 4.0 < gbps(bw_s3) < 7.0, gbps(bw_s3)       # ~5.5
    assert bw_xdt > bw_ec > bw_s3


def test_storage_accounting_in_sim():
    _, cluster = measure_pattern("gather", "s3", 1 << 20, fan=4, deterministic=True)
    acct = cluster.accounting("s3")
    assert acct.n_storage_puts == 4
    assert acct.n_storage_gets == 4
