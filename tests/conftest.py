"""Shared fixtures.  NOTE: no XLA_FLAGS here — pytest must see ONE device;
multi-device assertions run via tests/_multidevice_checks.py in a subprocess
(see tests/test_multidevice.py) and the dry-run sets its own flag."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# `hypothesis` is optional (requirements-dev.txt): fall back to the
# deterministic stub so the property tests still run without it.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    if os.path.dirname(os.path.abspath(__file__)) not in sys.path:
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies


@pytest.fixture(scope="session")
def multidevice_results():
    """Run the 8-device check battery once; tests assert on its JSON."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_multidevice_checks.py")],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, f"multidevice subprocess failed:\n{proc.stderr[-3000:]}"
    line = proc.stdout.strip().splitlines()[-1]
    return json.loads(line)
