"""§Perf hillclimb knobs: every optimization must be numerics-preserving.

These run mesh-free on CPU (the mesh-level checks for zero1 / fsdp /
seq_shard / moe-a2a live in tests/_multidevice_checks.py and the hillclimb
artifacts); here we pin the single-device contracts.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import ShardedLoader
from repro.models import init_params, make_decode_fn, make_loss_fn, make_prefill_fn


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config("granite_8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = ShardedLoader(cfg, 4, 16).batch_at(0)
    return cfg, params, batch


def test_loss_chunk_matches_full(dense_setup):
    """Streamed CE == monolithic CE, in value AND gradient."""
    cfg, params, batch = dense_setup
    full = make_loss_fn(cfg, None, remat="none")
    chunked = make_loss_fn(dataclasses.replace(cfg, loss_chunk=4), None, remat="none")
    assert abs(float(full(params, batch)) - float(chunked(params, batch))) < 1e-4
    gf = jax.grad(full)(params, batch)
    gc = jax.grad(chunked)(params, batch)
    for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gc)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-2, atol=2e-3,
        )


def test_loss_chunk_ragged_falls_back(dense_setup):
    """Chunk sizes that don't divide S transparently use the full path."""
    cfg, params, batch = dense_setup
    odd = make_loss_fn(dataclasses.replace(cfg, loss_chunk=7), None, remat="none")
    full = make_loss_fn(cfg, None, remat="none")
    assert abs(float(odd(params, batch)) - float(full(params, batch))) < 1e-5


def test_decode_scatter_update_exact(dense_setup):
    """Scatter KV update == one-hot rewrite, logits and cache bit-equal."""
    cfg, params, _ = dense_setup
    prompt = np.arange(1, 9)
    prefill = make_prefill_fn(cfg, None, remat="none", pad_to=16)
    _, cache = prefill(params, {"tokens": jnp.asarray(prompt[None, :-1])})
    toks = jnp.asarray(prompt[None, -1:], jnp.int32)
    l1, c1 = make_decode_fn(cfg, None)(params, cache, toks)
    cfg2 = dataclasses.replace(cfg, decode_scatter_update=True)
    l2, c2 = make_decode_fn(cfg2, None)(params, cache, toks)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_seq_shard_and_fsdp_noop_without_mesh(dense_setup):
    """Mesh-free lowering ignores the layout knobs (identical loss)."""
    cfg, params, batch = dense_setup
    base = float(make_loss_fn(cfg, None, remat="none")(params, batch))
    for kw in ({"seq_shard_acts": True}, {"fsdp_params": True}):
        v = float(make_loss_fn(dataclasses.replace(cfg, **kw), None,
                               remat="none")(params, batch))
        assert v == pytest.approx(base, abs=1e-6), kw


def test_moe_a2a_single_shard_degenerates():
    """dispatch='a2a' without a model axis falls back to single-rank EP."""
    cfg = smoke_config("moonshot_v1_16b_a3b")
    params = init_params(cfg, jax.random.PRNGKey(2))
    batch = ShardedLoader(cfg, 2, 8).batch_at(0)
    base = float(make_loss_fn(cfg, None, remat="none")(params, batch))
    cfg2 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="a2a"))
    v = float(make_loss_fn(cfg2, None, remat="none")(params, batch))
    assert v == pytest.approx(base, abs=1e-5)


def test_zero1_resolve_layout():
    """ZeRO-1 spec: DP axes land on the first free divisible dim only."""
    import os
    import subprocess
    import sys

    # needs >1 device: verified in tests/_multidevice_checks.py; here we
    # check the pure resolver logic on a trivial mesh via direct call
    from repro.distributed.sharding import ShardingRules
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(data=1, model=1)
    rules = ShardingRules(mesh)
    spec = rules.zero1_resolve(["embed", "d_ff"], [64, 128])
    # with 1-sized axes nothing shards, but resolution must not crash
    assert len(spec) == 2
