"""Fig 10 (extension): the graph optimizer, optimized vs. un-optimized.

The paper eliminates intermediate hops per edge; the graph optimizer
(:mod:`repro.core.dagopt`) eliminates edges and hops *structurally* —
fusing 1:1 sync chains (the transfer never happens), co-placing consumers
on their producer's node (XDT pulls become shared-memory copies), and
spilling at-risk staged edges to durable media ahead of predicted
keep-alive eviction.  This harness sweeps ``dag.optimize()`` against the
unmodified declarations over VID / SET / MR x the paper's three fixed
backends and reports per-cell p50 latency, mean cost, local-pull counts,
and the plan each workload got.

Expected shape (deterministic seeds): VID fuses streaming+decoder (the
30 MB fragment edge disappears on every backend) and co-places the
recognizers; SET co-places its trainers (the broadcast dataset goes
shared-memory on XDT); MR is a structural no-op (shuffle consumers pull
from every mapper — nothing to fuse or co-place), so its optimized runs
are bit-identical to the baseline.

``--smoke`` is the seconds-long CI subset with a hard gate: **the
optimized DAG is never costlier and never slower (p50) than the
un-optimized run on any workload x backend cell** — the optimizer must
dominate or stay out of the way; a pass that trades latency for cost (or
rewrites MR at all) is a bug.

Run:  PYTHONPATH=src python -m benchmarks.fig10_dag_opt [--smoke]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core.workloads import DAGS

from .common import fmt_s, save_json

RESULT_NAME = "fig10_dag_opt.json"

BACKENDS = ("s3", "elasticache", "xdt")
N_SEEDS = 10
SMOKE_SEEDS = 3


def _cell(dag, backend, n_seeds, plan=None):
    compiled = dag.compile(target="cluster", backend=backend, plan=plan)
    runs = [compiled.run(seed=s) for s in range(n_seeds)]
    det = compiled.run(seed=0, deterministic=True)
    return {
        "p50_latency_s": float(np.median([r.latency_s for r in runs])),
        "mean_total_uUSD": float(np.mean([r.cost().total for r in runs])) * 1e6,
        "det_latency_s": det.latency_s,
        "det_total_uUSD": det.cost().total * 1e6,
        "n_invocations": det.bill.n_invocations,
        "n_local_pulls": sum(u.n_local for u in det.edge_usage.values()),
    }


def run(n_seeds: int = N_SEEDS):
    out = {}
    for name, dag in DAGS.items():
        opt_dag, plan = dag.optimize()
        rows = {}
        for b in BACKENDS:
            rows[b] = {
                "base": _cell(dag, b, n_seeds),
                "opt": _cell(opt_dag, b, n_seeds, plan=plan),
            }
        out[name] = {
            "plan": plan.describe(),
            "fused": {k: list(v) for k, v in plan.fused.items()},
            "affinity": dict(plan.affinity),
            "spilled": dict(plan.spilled),
            "cells": rows,
        }
    return out


def check_optimized_dominates(out) -> None:
    """CI gate: per cell, optimized cost <= base and optimized p50 <= base.

    Raises (not assert: the gate must survive ``python -O``).  Equality is
    legal — MR's optimized graph IS the base graph — so the tolerance only
    absorbs float noise, never a real regression."""
    tol = 1 + 1e-9
    for name, data in out.items():
        for b, cell in data["cells"].items():
            base, opt = cell["base"], cell["opt"]
            if opt["mean_total_uUSD"] > base["mean_total_uUSD"] * tol:
                raise RuntimeError(
                    f"{name}/{b}: optimized costs {opt['mean_total_uUSD']:.2f}"
                    f"uUSD > un-optimized {base['mean_total_uUSD']:.2f}uUSD — "
                    "the graph optimizer must never lose on cost"
                )
            if opt["p50_latency_s"] > base["p50_latency_s"] * tol:
                raise RuntimeError(
                    f"{name}/{b}: optimized p50 {opt['p50_latency_s']:.4f}s > "
                    f"un-optimized {base['p50_latency_s']:.4f}s — the graph "
                    "optimizer must never lose on latency"
                )


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    out = run(n_seeds=SMOKE_SEEDS if smoke else N_SEEDS)
    print("# Fig 10 — graph optimizer: optimized vs un-optimized DAGs")
    for name, data in out.items():
        print(f"\n  {name.upper()}: {data['plan']}")
        for b, cell in data["cells"].items():
            base, opt = cell["base"], cell["opt"]
            speedup = (
                base["p50_latency_s"] / opt["p50_latency_s"]
                if opt["p50_latency_s"] > 0 else 1.0
            )
            saved = base["mean_total_uUSD"] - opt["mean_total_uUSD"]
            print(
                f"    {b:12s} p50 {fmt_s(base['p50_latency_s']):>9} -> "
                f"{fmt_s(opt['p50_latency_s']):>9} ({speedup:4.2f}x)  "
                f"cost {base['mean_total_uUSD']:8.1f} -> "
                f"{opt['mean_total_uUSD']:8.1f}uUSD (-{saved:.1f})  "
                f"local pulls {opt['n_local_pulls']}"
            )
    if not smoke:
        save_json(RESULT_NAME, out)      # artifact survives a gate trip
    check_optimized_dominates(out)
    print("\noptimizer-dominates gate: never costlier, never slower (p50) "
          "on any workload x backend OK")
    return out


#: benchmarks.run auto-discovery
HARNESS = {"name": "fig10", "full": main, "smoke": lambda: main(["--smoke"])}


if __name__ == "__main__":
    main()
