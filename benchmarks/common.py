"""Shared helpers for the benchmark harnesses."""
from __future__ import annotations

import json
import os
from typing import Any, Dict

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(REPO, "results")


def save_json(name: str, payload: Dict[str, Any]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def load_json(name: str) -> Dict[str, Any]:
    with open(os.path.join(RESULTS_DIR, name)) as f:
        return json.load(f)


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if n < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}TB"


def fmt_s(t: float) -> str:
    if t < 1e-3:
        return f"{t*1e6:.0f}us"
    if t < 1.0:
        return f"{t*1e3:.2f}ms"
    return f"{t:.3f}s"
