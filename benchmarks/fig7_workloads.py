"""Paper Fig. 7: end-to-end latency + breakdown for VID / SET / MR under
S3 / ElastiCache / XDT — plus the per-edge-routed ``hybrid`` column.

Paper anchors: speedups vs S3 — VID 1.36x, SET 3.4x, MR 1.26x; vs EC —
1.02-1.05x across workloads.

The ``hybrid`` column executes the same :class:`~repro.core.dag.WorkflowDAG`
with every ``route="default"`` edge resolved per object by
:data:`~repro.core.workloads.HYBRID_ROUTE` (inline under the activator
payload cap on sync handoffs, XDT otherwise, S3 for evictable producers) and
prices each edge by the medium it actually used.

The ``adaptive`` column executes the DAG with a fresh
:class:`~repro.core.dag.AdaptiveRoute` per run: routing starts on the static
fallback and converges onto the observed per-medium $/GB + p99 feed as the
run's own transfers populate the telemetry hub.

``--smoke`` is the seconds-long CI subset: 2 seeds, and a hard gate that the
routed configurations (hybrid AND adaptive) are never costlier than the best
single backend on any workload (per-edge routing must dominate, or the
router is mis-ranking media).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core.workloads import BACKENDS, ROUTED_BACKENDS, WORKLOADS

from .common import fmt_s, save_json

PAPER_SPEEDUPS = {"vid": (1.36, 1.02), "set": (3.4, 1.05), "mr": (1.26, 1.05)}


def run(n_seeds: int = 10, backends=ROUTED_BACKENDS):
    out = {}
    for name, fn in WORKLOADS.items():
        agg = {}
        for b in backends:
            rs = [fn(b, seed=s) for s in range(n_seeds)]
            agg[b] = {
                "latency_s": float(np.mean([r.latency_s for r in rs])),
                "total_uUSD": float(np.mean([r.cost.total for r in rs])) * 1e6,
                "breakdown": {
                    k: float(np.mean([r.breakdown[k] for r in rs]))
                    for k in rs[0].breakdown
                },
                "edge_media": rs[0].edge_media,
            }
        out[name] = agg
    return out


#: routed configurations the dominance gate applies to: each must beat the
#: best fixed single backend on cost (hybrid routes from static edge facts,
#: adaptive from the observed telemetry feed)
ROUTED_COLUMNS = ("hybrid", "adaptive")


def check_hybrid_dominates(out) -> None:
    """CI gate: on every workload, each routed configuration's total cost
    <= the best single backend's, and its latency <= the fastest single
    backend's + 5%.  Raises (not assert: the gate must survive
    ``python -O``)."""
    for name, agg in out.items():
        best_cost = min(agg[b]["total_uUSD"] for b in BACKENDS)
        best_lat = min(agg[b]["latency_s"] for b in BACKENDS)
        for col in ROUTED_COLUMNS:
            routed = agg[col]["total_uUSD"]
            if routed > best_cost * (1 + 1e-9):
                raise RuntimeError(
                    f"{name}: {col} costs {routed:.1f}uUSD > best single "
                    f"backend {best_cost:.1f}uUSD — per-edge routing should "
                    f"dominate"
                )
            routed_lat = agg[col]["latency_s"]
            if routed_lat > best_lat * 1.05:
                raise RuntimeError(
                    f"{name}: {col} latency {routed_lat:.3f}s > best single "
                    f"{best_lat:.3f}s + 5%"
                )


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    out = run(n_seeds=2 if smoke else 10)
    print("# Fig 7 — real-world workloads: latency breakdown (+hybrid routing)")
    for name, agg in out.items():
        xdt = agg["xdt"]["latency_s"]
        p_s3, p_ec = PAPER_SPEEDUPS[name]
        print(f"\n  {name.upper()}:")
        for b in ROUTED_BACKENDS:
            d = agg[b]
            su = d["latency_s"] / xdt
            note = ""
            if b == "s3":
                note = f"  -> XDT speedup {su:.2f}x (paper {p_s3}x)"
            elif b == "elasticache":
                note = f"  -> XDT speedup {su:.2f}x (paper {p_ec}x)"
            elif b in ROUTED_COLUMNS:
                media = ", ".join(
                    f"{e}:{m}" for e, m in d["edge_media"].items()
                )
                note = f"  [{media}]"
            print(f"    {b:12s} total={fmt_s(d['latency_s'])} "
                  f"cost={d['total_uUSD']:8.1f}uUSD{note}")
            if not smoke:
                for phase, t in d["breakdown"].items():
                    frac = t / d["latency_s"] * 100
                    print(f"        {phase:22s} {fmt_s(t):>9}  ({frac:4.1f}%)")
    if not smoke:
        save_json("fig7_workloads.json", out)    # artifact survives a gate trip
    check_hybrid_dominates(out)
    print("\nhybrid-dominates gate: cost <= best single backend on every "
          "workload OK")
    return out


#: benchmarks.run auto-discovery (smoke carries the routed-dominates gate)
HARNESS = {"name": "fig7", "full": main, "smoke": lambda: main(["--smoke"])}

if __name__ == "__main__":
    main()
