"""Paper Fig. 7: end-to-end latency + breakdown for VID / SET / MR under
S3 / ElastiCache / XDT.

Paper anchors: speedups vs S3 — VID 1.36x, SET 3.4x, MR 1.26x; vs EC —
1.02-1.05x across workloads.
"""
from __future__ import annotations

import numpy as np

from repro.core.workloads import BACKENDS, WORKLOADS

from .common import fmt_s, save_json

PAPER_SPEEDUPS = {"vid": (1.36, 1.02), "set": (3.4, 1.05), "mr": (1.26, 1.05)}


def run(n_seeds: int = 10):
    out = {}
    for name, fn in WORKLOADS.items():
        agg = {}
        for b in BACKENDS:
            rs = [fn(b, seed=s) for s in range(n_seeds)]
            agg[b] = {
                "latency_s": float(np.mean([r.latency_s for r in rs])),
                "breakdown": {
                    k: float(np.mean([r.breakdown[k] for r in rs]))
                    for k in rs[0].breakdown
                },
            }
        out[name] = agg
    return out


def main():
    out = run()
    print("# Fig 7 — real-world workloads: latency breakdown")
    for name, agg in out.items():
        xdt = agg["xdt"]["latency_s"]
        p_s3, p_ec = PAPER_SPEEDUPS[name]
        print(f"\n  {name.upper()}:")
        for b in BACKENDS:
            d = agg[b]
            su = d["latency_s"] / xdt
            note = ""
            if b == "s3":
                note = f"  -> XDT speedup {su:.2f}x (paper {p_s3}x)"
            elif b == "elasticache":
                note = f"  -> XDT speedup {su:.2f}x (paper {p_ec}x)"
            print(f"    {b:12s} total={fmt_s(d['latency_s'])}{note}")
            for phase, t in d["breakdown"].items():
                frac = t / d["latency_s"] * 100
                print(f"        {phase:22s} {fmt_s(t):>9}  ({frac:4.1f}%)")
    save_json("fig7_workloads.json", out)
    return out


if __name__ == "__main__":
    main()
