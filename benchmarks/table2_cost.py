"""Paper Table 2: per-invocation cost (compute / storage, micro-USD) for
S3 / ElastiCache / XDT configurations of VID, SET, MR — plus the
per-edge-routed ``hybrid`` configuration priced per medium.

Paper anchors: XDT 2-5x cheaper than S3-based, 17-772x cheaper than
EC-based configurations.

The hybrid rows price a *mixed*-backend run: each workflow edge moves over
the medium its :class:`~repro.core.dag.RoutePolicy` resolved (per object, at
send time), and :func:`repro.core.cost.routed_workflow_cost` bills each
medium's ops by its own fee structure.  The JSON artifact carries the
per-edge attribution table (medium, bytes, ops, storage micro-USD share) so
the bill is auditable edge by edge.
"""
from __future__ import annotations

import numpy as np

from repro.core.workloads import ROUTED_BACKENDS, WORKLOADS

from .common import save_json

PAPER = {
    # workload: {backend: (compute_uUSD, storage_uUSD)}
    "vid": {"s3": (37, 18), "elasticache": (14, 913), "xdt": (17, 0)},
    "set": {"s3": (95, 30), "elasticache": (69, 1104), "xdt": (70, 0)},
    "mr": {"s3": (180, 416), "elasticache": (125, 99667), "xdt": (129, 0)},
}


def run(n_seeds: int = 10):
    out = {}
    for name, fn in WORKLOADS.items():
        agg = {}
        for b in ROUTED_BACKENDS:
            rs = [fn(b, seed=s) for s in range(n_seeds)]
            agg[b] = {
                "compute_uUSD": float(np.mean([r.cost.compute for r in rs])) * 1e6,
                "storage_uUSD": float(np.mean([r.cost.storage for r in rs])) * 1e6,
                "edge_media": rs[0].edge_media,
                "edges": {
                    label: {
                        "media": row["media"],
                        "bytes": row["bytes"],
                        "n_puts": row["n_puts"],
                        "n_gets": row["n_gets"],
                        "storage_uUSD": float(np.mean(
                            [r.edges[label]["storage_uUSD"] for r in rs]
                        )),
                    }
                    for label, row in rs[0].edges.items()
                },
            }
            agg[b]["total_uUSD"] = agg[b]["compute_uUSD"] + agg[b]["storage_uUSD"]
        out[name] = agg
    return out


def main():
    out = run()
    print("# Table 2 — cost per invocation (uUSD): ours vs paper (+hybrid)")
    print(f"{'wl':>4} {'backend':>12} | {'comp':>8} {'stor':>9} {'total':>9} | "
          f"{'paper total':>11} | {'vs XDT':>7}")
    for name, agg in out.items():
        xdt_total = agg["xdt"]["total_uUSD"]
        for b in ROUTED_BACKENDS:
            d = agg[b]
            paper_total = (
                f"{sum(PAPER[name][b]):11d}" if b in PAPER[name]
                else f"{'—':>11}"
            )
            ratio = d["total_uUSD"] / xdt_total
            print(f"{name:>4} {b:>12} | {d['compute_uUSD']:8.1f} {d['storage_uUSD']:9.1f} "
                  f"{d['total_uUSD']:9.1f} | {paper_total} | {ratio:6.1f}x")
        # the hybrid bill, edge by edge (medium actually used + its fee share)
        hyb = agg["hybrid"]
        for label, e in hyb["edges"].items():
            print(f"{'':>4} {'':>12} |   edge {label:>14} -> "
                  f"{hyb['edge_media'][label]:<12} "
                  f"{e['storage_uUSD']:8.2f}uUSD storage")
    save_json("table2_cost.json", out)
    return out


#: benchmarks.run auto-discovery (table2 is already seconds-long)
HARNESS = {"name": "table2", "full": main, "smoke": main}

if __name__ == "__main__":
    main()
