"""Paper Table 2: per-invocation cost (compute / storage, micro-USD) for
S3 / ElastiCache / XDT configurations of VID, SET, MR.

Paper anchors: XDT 2-5x cheaper than S3-based, 17-772x cheaper than
EC-based configurations.
"""
from __future__ import annotations

import numpy as np

from repro.core.workloads import BACKENDS, WORKLOADS

from .common import save_json

PAPER = {
    # workload: {backend: (compute_uUSD, storage_uUSD)}
    "vid": {"s3": (37, 18), "elasticache": (14, 913), "xdt": (17, 0)},
    "set": {"s3": (95, 30), "elasticache": (69, 1104), "xdt": (70, 0)},
    "mr": {"s3": (180, 416), "elasticache": (125, 99667), "xdt": (129, 0)},
}


def run(n_seeds: int = 10):
    out = {}
    for name, fn in WORKLOADS.items():
        agg = {}
        for b in BACKENDS:
            rs = [fn(b, seed=s) for s in range(n_seeds)]
            agg[b] = {
                "compute_uUSD": float(np.mean([r.cost.compute for r in rs])) * 1e6,
                "storage_uUSD": float(np.mean([r.cost.storage for r in rs])) * 1e6,
            }
            agg[b]["total_uUSD"] = agg[b]["compute_uUSD"] + agg[b]["storage_uUSD"]
        out[name] = agg
    return out


def main():
    out = run()
    print("# Table 2 — cost per invocation (uUSD): ours vs paper")
    print(f"{'wl':>4} {'backend':>12} | {'comp':>8} {'stor':>9} {'total':>9} | "
          f"{'paper total':>11} | {'vs XDT':>7}")
    for name, agg in out.items():
        xdt_total = agg["xdt"]["total_uUSD"]
        for b in BACKENDS:
            d = agg[b]
            paper_total = sum(PAPER[name][b])
            ratio = d["total_uUSD"] / xdt_total
            print(f"{name:>4} {b:>12} | {d['compute_uUSD']:8.1f} {d['storage_uUSD']:9.1f} "
                  f"{d['total_uUSD']:9.1f} | {paper_total:11d} | {ratio:6.1f}x")
    save_json("table2_cost.json", out)
    return out


if __name__ == "__main__":
    main()
