"""Paper Fig. 6: collective patterns (scatter/gather/broadcast) at fan 4/16
for 10KB and 10MB objects, plus the fan-32 effective-bandwidth anchor.

Paper anchors: EC 7.8-11x lower latency than S3 (small), XDT matches or
beats EC; at fan 32 / 10MB gather, XDT 16.4 Gb/s (82% of NIC), EC 14.0,
S3 5.5.
"""
from __future__ import annotations

import numpy as np

from repro.core import effective_bandwidth_Bps, measure_pattern

from .common import fmt_s, save_json

BACKENDS = ["s3", "elasticache", "xdt"]
PATTERNS = ["scatter", "gather", "broadcast"]
FANS = [4, 16]
SIZES = {"10KB": 10 << 10, "10MB": 10 << 20}


def run(n_seeds: int = 10):
    grid = {}
    for label, nbytes in SIZES.items():
        for pattern in PATTERNS:
            for fan in FANS:
                cell = {}
                for b in BACKENDS:
                    ts = [
                        measure_pattern(pattern, b, nbytes, fan=fan, seed=s)[0]
                        for s in range(n_seeds)
                    ]
                    cell[b] = float(np.mean(ts))
                grid[f"{label}|{pattern}|fan{fan}"] = cell

    bw32 = {
        b: effective_bandwidth_Bps("gather", b, 10 << 20, fan=32) for b in BACKENDS
    }
    return {"grid": grid, "fan32_gather_10MB_bw_Bps": bw32}


def main():
    out = run()
    print("# Fig 6 — collective patterns (mean latency)")
    print(f"{'cell':>24} | {'s3':>10} | {'ec':>10} | {'xdt':>10} | xdt/ec")
    for key, cell in out["grid"].items():
        print(f"{key:>24} | {fmt_s(cell['s3']):>10} | {fmt_s(cell['elasticache']):>10}"
              f" | {fmt_s(cell['xdt']):>10} | {cell['xdt']/cell['elasticache']:.2f}")
    print("\nfan-32 gather 10MB effective BW (paper: XDT 16.4 / EC 14.0 / S3 5.5 Gb/s):")
    for b, bw in out["fan32_gather_10MB_bw_Bps"].items():
        print(f"  {b:12s} {bw*8/1e9:5.2f} Gb/s ({bw*8/20e9*100:.0f}% of 20Gb/s NIC)")
    save_json("fig6_collectives.json", out)
    return out


#: benchmarks.run auto-discovery
HARNESS = {"name": "fig6", "full": main, "smoke": lambda: run(2)}

if __name__ == "__main__":
    main()
