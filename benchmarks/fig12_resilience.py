"""Fig 12 (extension): resilience under injected faults — adaptive route-around.

The chaos harness (:mod:`repro.core.faults`) makes adversity a first-class
scenario axis: correlated spot evictions (a whole node dies, not one
producer), per-medium degradation windows (S3 throttle, ElastiCache failover
blackout, degraded xdt bandwidth), and cold-start storms.  This harness
sweeps **fault scenario x route policy x backend** on the engine lowering
(``dag.compile(target="engine")``) — the same seeded
:class:`~repro.core.faults.FaultPlan` replayed against a static route and an
:class:`~repro.core.dag.AdaptiveRoute` — plus the fault-aware
:class:`~repro.core.dagopt.PredictiveSpill` contrast on the cluster lowering
(``dag.compile(target="cluster")``).

``--smoke`` carries the CI gates (raise, not assert — they must survive
``python -O``):

* **adaptive-never-worse** — under every fault scenario, the AdaptiveRoute
  cell's cost AND p99 are <= the static cell's (same seeded plan, same
  arrivals).  The telemetry penalty feed is what makes this work: every
  injected failure records a pessimistic latency sample for the failing
  medium, so a budget-constrained adaptive edge leaves it within the
  observation window instead of riding the fault into the retry budget.
* **bounded retries** — no request exceeds ``max_retries`` in ANY cell;
  exhausted budgets surface as terminal ``failed`` statuses
  (:class:`~repro.core.errors.RetriesExhausted`), never raw crashes.
* **fault-aware spill wins** — a PredictiveSpill-optimized DAG completes an
  eviction-storm scenario with STRICTLY fewer retries than the un-optimized
  DAG (the plan schedules producer death; spilling staged edges durable is
  a certainty trade, not a prediction).
* **zero-cost harness** — with an empty FaultPlan the engine and cluster
  runs are bit-identical to runs without the harness (latency sums and
  costs compared exactly, no tolerance).

Results go to ``results/fig12_resilience.json``.

Run:  PYTHONPATH=src python -m benchmarks.fig12_resilience [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import sys

from repro.core import (
    AdaptiveRoute,
    Edge,
    FixedRoute,
    SizeRoute,
    Stage,
    TelemetryHub,
    WorkflowDAG,
    WorkflowEngine,
)
from repro.core.faults import (
    FaultInjector,
    FaultPlan,
    SLOGuard,
    SLOViolation,
    _p99,
)

from .common import save_json

RESULT_NAME = "fig12_resilience.json"

# -- the probe workflow ------------------------------------------------------
#: compute is deliberately expensive relative to the tiny objects: a retry
#: re-runs the whole request (driver + producers + consumers), so riding a
#: fault into the retry budget costs far more than one durable fee — the
#: economics the adaptive router is supposed to discover
DATA_BYTES = 64 << 10
#: per-object transfer latency budget: above every healthy medium's modeled
#: latency (s3 ~26ms is the slowest), below every injected-penalty sample
#: (>= 50ms) and every degraded pull — so the adaptive route only diverts
#: when a fault is actually observed
LATENCY_BUDGET_S = 0.06
PRODUCER_COMPUTE_S = 0.5
CONSUMER_COMPUTE_S = 0.02
DRIVER_COMPUTE_S = 0.01
BYTES_SCALE = 1e-2
MAX_RETRIES = 2
#: time-decayed re-probe: a medium the adaptive router has not picked for
#: this long gets one probe object regardless of its (possibly poisoned)
#: score — the blacklist-recovery escape hatch, now exercised under the
#: real fault scenarios instead of pinned to 0.  Long enough that the first
#: probe lands after the router has already diverted off the faulted
#: medium, short enough that several fire inside every scenario window.
REPROBE_AFTER_S = 5.0


def _dag() -> WorkflowDAG:
    return WorkflowDAG(
        "res",
        [
            Stage("driver", compute_s=DRIVER_COMPUTE_S),
            Stage("producer", fan=2, compute_s=PRODUCER_COMPUTE_S,
                  blocking=False),
            Stage("consumer", fan=2, compute_s=CONSUMER_COMPUTE_S,
                  blocking=False),
        ],
        [
            Edge("driver", "producer", 16 << 10, label="task",
                 handoff="staged", fanout="broadcast",
                 latency_budget_s=LATENCY_BUDGET_S),
            Edge("producer", "consumer", DATA_BYTES, label="data",
                 handoff="staged", fanout="partition",
                 latency_budget_s=LATENCY_BUDGET_S),
        ],
    )


# -- the scenario axis -------------------------------------------------------
#: scenario -> (fault plan factory, the static baseline medium under attack).
#: The "backend" column IS the backend axis: each degradation scenario
#: stresses a different registered medium, evictions stress the
#: instance-resident default, and the storm stresses no medium at all
#: (routing must then stay out of the way — adaptive == static).
def _scenarios(seed: int):
    return {
        "eviction_storm": {
            "backend": "xdt",
            "plan": FaultPlan.eviction_storm(
                at_s=1.0, n_evictions=4, spacing_s=2.0, seed=seed
            ),
            # in-flight requests at the first eviction retry once; adaptive
            # routes the rest durable, static keeps dying
            "adaptive_availability_min": 1.0,
        },
        "s3_throttle": {
            "backend": "s3",
            "plan": FaultPlan.medium_throttle(
                medium="s3", at_s=1.0, duration_s=30.0,
                slowdown=8.0, error_rate=0.5, seed=seed,
            ),
            "adaptive_availability_min": 1.0,
        },
        "elasticache_blackout": {
            "backend": "elasticache",
            "plan": FaultPlan.medium_blackout(
                medium="elasticache", at_s=1.0, duration_s=30.0, seed=seed
            ),
            "adaptive_availability_min": 1.0,
        },
        "xdt_degraded": {
            "backend": "xdt",
            "plan": FaultPlan.medium_throttle(
                medium="xdt", at_s=1.0, duration_s=30.0,
                slowdown=60.0, error_rate=0.0, seed=seed,
            ),
            "adaptive_availability_min": 1.0,
        },
        "cold_start_storm": {
            "backend": "xdt",
            "plan": FaultPlan.cold_start_storm(
                at_s=1.0, duration_s=10.0, multiplier=8.0,
                max_instances_cap=2, seed=seed,
            ),
            "adaptive_availability_min": 1.0,
        },
    }


def _route(kind: str, backend: str):
    """The policy axis: a static route pinned to the medium under attack,
    and an AdaptiveRoute falling back to that same static pick until the
    telemetry window has samples.  Count probing stays off (these edges
    carry latency budgets, where it never fires anyway); the time-decayed
    re-probe is ON, so a medium the fault window poisoned gets periodic
    probe traffic and can rejoin the feasible set once healthy."""
    static = (
        SizeRoute() if backend == "size" else FixedRoute(backend)
    )
    if kind == "static":
        return static
    return AdaptiveRoute(
        static=static, explore_every=0, reprobe_after_s=REPROBE_AFTER_S
    )


def run_cell(
    plan: FaultPlan, route_kind: str, backend: str,
    n_requests: int, gap_s: float,
):
    """One (scenario, policy) cell: same seeded plan, same arrival times."""
    eng = WorkflowEngine(backend="xdt", max_retries=MAX_RETRIES)
    binding = _dag().compile(
        target="engine", engine=eng,
        backend=_route(route_kind, backend), bytes_scale=BYTES_SCALE,
    )
    # every cell gets a hub BEFORE the injector installs: adaptive cells
    # already have one (compile wires it for AdaptiveRoute), but static
    # cells would otherwise drop the injector's fault-timeline records
    # (TelemetryHub recording is purely observational — it never changes
    # modeled latency or cost, so the static baselines are unaffected)
    if eng.transfer.telemetry is None:
        eng.transfer.telemetry = TelemetryHub(eng.transfer.clock)
    FaultInjector(eng, plan).install()
    for i in range(n_requests):
        eng.sim.schedule_abs(
            i * gap_s, lambda: eng.submit(binding.entry, 1.0)
        )
    eng.drain()
    report = SLOGuard(availability_min=0.0).check(eng, route_kind)
    ok_lat = [r.latency_s for r in eng.requests if r.status == "ok"]
    cost = binding.cost().total
    return {
        # dominance metrics: a cell that completes nothing earns infinity —
        # raw cost would reward the static route for failing cheaply
        "usd_per_ok": cost / report.n_ok if report.n_ok else float("inf"),
        "p99_ok_s": _p99(ok_lat) if ok_lat else float("inf"),
        "n_requests": report.n_requests,
        "n_ok": report.n_ok,
        "n_failed": report.n_failed,
        "availability": report.availability,
        "p99_s": report.p99_s,
        "cost_usd": cost,
        "retry_total": report.retry_total,
        "retry_max": report.retry_max,
        "max_retries": eng.max_retries,
        "unbounded": report.retry_max > eng.max_retries,
        "terminal_gap": eng._inflight_requests,
        "failed_codes": dict(eng.failed_codes),
        "edge_media": {
            label: dict(u.media) for label, u in binding.edge_usage.items()
        },
        "fault_timeline": [
            {"t_s": t, "kind": kind, "detail": detail}
            for t, kind, detail in eng.transfer.telemetry.faults
        ],
    }


def run_spill_contrast(seed: int):
    """Cluster-lowering gate: the fault-aware PredictiveSpill must complete
    an eviction storm with strictly fewer retries than the raw DAG."""
    from repro.core.workloads import DAGS

    dag = DAGS["mr"]
    plan = FaultPlan.eviction_storm(
        at_s=0.05, n_evictions=2, spacing_s=0.1, seed=seed
    )
    base = dag.compile(target="cluster", backend="xdt", faults=plan).run(
        seed=0, deterministic=True
    )
    opt_dag, pplan = dag.optimize(fault_plan=plan)
    opt = opt_dag.compile(
        target="cluster", backend="xdt", plan=pplan, faults=plan
    ).run(seed=0, deterministic=True)
    return {
        "base_retries": base.faults.retries,
        "opt_retries": opt.faults.retries,
        "base_latency_s": base.latency_s,
        "opt_latency_s": opt.latency_s,
        "spilled": dict(pplan.spilled),
    }


def run_identity_check():
    """Zero-cost-when-unused: an empty FaultPlan must leave both lowerings
    bit-identical to runs without the harness (exact equality, no eps)."""
    from repro.core.workloads import DAGS

    empty = FaultPlan()

    def engine_run(with_plan: bool):
        eng = WorkflowEngine(backend="xdt", max_retries=MAX_RETRIES)
        binding = _dag().compile(
            target="engine", engine=eng, backend=SizeRoute(),
            bytes_scale=BYTES_SCALE,
        )
        if with_plan:
            FaultInjector(eng, empty).install()
        for i in range(4):
            eng.sim.schedule_abs(
                i * 0.5, lambda: eng.submit(binding.entry, 1.0)
            )
        eng.drain()
        return (
            sum(lat for _, lat in eng.latency_records()),
            binding.cost().total,
        )

    eng_bare, eng_planned = engine_run(False), engine_run(True)
    bare = DAGS["mr"].compile(target="cluster", backend="xdt").run(
        seed=0, deterministic=True
    )
    planned = DAGS["mr"].compile(
        target="cluster", backend="xdt", faults=empty
    ).run(seed=0, deterministic=True)
    return {
        "engine_latency_sum": [eng_bare[0], eng_planned[0]],
        "engine_cost_usd": [eng_bare[1], eng_planned[1]],
        "cluster_latency_s": [bare.latency_s, planned.latency_s],
        "cluster_cost_usd": [bare.cost().total, planned.cost().total],
        "identical": (
            eng_bare == eng_planned
            and bare.latency_s == planned.latency_s
            and bare.cost().total == planned.cost().total
        ),
    }


def run_sweep(n_requests: int, gap_s: float, seed: int, quiet: bool = False):
    scenarios = _scenarios(seed)
    out = {}
    for name, spec in scenarios.items():
        cells = {}
        for kind in ("static", "adaptive"):
            # a fresh plan per cell: the seeded RNG replays identically
            plan_spec = _scenarios(seed)[name]
            cells[kind] = run_cell(
                plan_spec["plan"], kind, spec["backend"], n_requests, gap_s
            )
        # the injector replays the same seeded plan in both cells, so the
        # (time, kind) schedule is cell-independent — hoist the timeline to
        # a per-scenario section.  The detail column IS cell-dependent for
        # evictions (instances/buffers killed depend on what the cell had
        # running), so the replay claim compares the schedule only.
        timelines = {k: c.pop("fault_timeline") for k, c in cells.items()}
        schedule = lambda tl: [(e["t_s"], e["kind"]) for e in tl]  # noqa: E731
        out[name] = {
            "backend": spec["backend"],
            "adaptive_availability_min": spec["adaptive_availability_min"],
            "fault_timeline": timelines["adaptive"],
            "fault_timeline_replay_identical": (
                schedule(timelines["static"]) == schedule(timelines["adaptive"])
            ),
            "cells": cells,
        }
        if not quiet:
            s, a = cells["static"], cells["adaptive"]
            print(
                f"  {name:<22} [{spec['backend']:<11}] "
                f"static: p99 {s['p99_s']:7.3f}s ${s['cost_usd']*1e6:8.2f}u "
                f"retries {s['retry_total']:>3} fail {s['n_failed']:>2} | "
                f"adaptive: p99 {a['p99_s']:7.3f}s "
                f"${a['cost_usd']*1e6:8.2f}u "
                f"retries {a['retry_total']:>3} fail {a['n_failed']:>2}"
            )
            replay = (
                "schedule replayed identically in both cells"
                if out[name]["fault_timeline_replay_identical"]
                else "SCHEDULES DIVERGED ACROSS CELLS"
            )
            print(f"    fault timeline ({replay}; detail from the "
                  "adaptive cell):")
            for entry in timelines["adaptive"]:
                print(
                    f"      {entry['t_s']:9.3f}s  {entry['kind']:<14} "
                    f"{entry['detail']}"
                )
    return out


def check_gates(out) -> None:
    """CI gates; raises SLOViolation / RuntimeError on any failure."""
    for name, row in out["scenarios"].items():
        cells = row["cells"]
        for kind, cell in cells.items():
            if cell["unbounded"]:
                raise SLOViolation(
                    f"{name}/{kind}: a request retried {cell['retry_max']}x "
                    f"past max_retries={cell['max_retries']}"
                )
            if cell["terminal_gap"]:
                raise SLOViolation(
                    f"{name}/{kind}: {cell['terminal_gap']} request(s) "
                    "never reached a terminal status"
                )
        # p99 is compared over successes, so it only means something when
        # the static cell's survivor set is not censored (a static route
        # that fails 45 of 48 requests leaves only the lucky cheap ones to
        # measure).  When static availability is below adaptive's, the
        # availability gap plus cost-per-success already decides dominance.
        keys = ["usd_per_ok"]
        if (cells["static"]["availability"]
                >= cells["adaptive"]["availability"]):
            keys.append("p99_ok_s")
        # tol covers the durable-pull premium on the request caught by the
        # FIRST eviction: it fails before any telemetry exists, and its
        # retry already routes durable (~1ms slower than static's xdt
        # retry).  Everything structural stays strict: 0.1% is far below
        # any real routing mistake in these deterministic models.
        SLOGuard.require_dominates(
            cells["adaptive"], cells["static"],
            keys=tuple(keys), tol=1.001, label=name,
        )
        amin = row["adaptive_availability_min"]
        if cells["adaptive"]["availability"] < amin:
            raise SLOViolation(
                f"{name}: adaptive availability "
                f"{cells['adaptive']['availability']:.4f} < {amin}"
            )
    spill = out["spill_contrast"]
    if not spill["opt_retries"] < spill["base_retries"]:
        raise SLOViolation(
            f"fault-aware spill must strictly cut eviction-storm retries: "
            f"optimized {spill['opt_retries']} vs base "
            f"{spill['base_retries']}"
        )
    ident = out["identity"]
    if not ident["identical"]:
        raise RuntimeError(
            f"empty FaultPlan is not zero-cost: {ident}"
        )


def run(n_requests: int, gap_s: float, seed: int, quiet: bool = False):
    if not quiet:
        print("# scenario x policy sweep (engine lowering)")
    scenarios = run_sweep(n_requests, gap_s, seed, quiet=quiet)
    spill = run_spill_contrast(seed)
    ident = run_identity_check()
    if not quiet:
        print(
            f"# spill contrast (cluster lowering): base retries "
            f"{spill['base_retries']} -> optimized {spill['opt_retries']} "
            f"(spilled {spill['spilled']})"
        )
        print(f"# empty-plan identity: {ident['identical']}")
    return {
        "scenarios": scenarios,
        "spill_contrast": spill,
        "identity": ident,
        "config": {
            "n_requests": n_requests,
            "gap_s": gap_s,
            "seed": seed,
            "data_bytes": DATA_BYTES,
            "latency_budget_s": LATENCY_BUDGET_S,
            "max_retries": MAX_RETRIES,
        },
        "schema": 1,
    }


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="seconds-long CI subset (fewer requests)")
    p.add_argument("--check", action="store_true",
                   help="fail on gate violations (adaptive-never-worse, "
                        "bounded retries, spill contrast, zero-cost plan)")
    args = p.parse_args(sys.argv[1:] if argv is None else argv)

    print("# Fig 12 — resilience: fault scenario x route policy x backend")
    if args.smoke:
        out = run(n_requests=12, gap_s=0.75, seed=7)
    else:
        out = run(n_requests=48, gap_s=0.25, seed=7)
    path = save_json(RESULT_NAME, out)
    print(f"# wrote {path}")

    if args.check:
        try:
            check_gates(out)
        except (SLOViolation, RuntimeError) as e:
            print(f"# GATE FAILED: {e}")
            return 1
        print("# gates ok: adaptive never worse, retries bounded, "
              "spill wins, empty plan zero-cost")
    return 0


#: benchmarks.run auto-discovery (smoke carries the resilience CI gates)
HARNESS = {
    "name": "fig12",
    "full": lambda: main([]),
    "smoke": lambda: main(["--smoke", "--check"]),
}

if __name__ == "__main__":
    sys.exit(main())
