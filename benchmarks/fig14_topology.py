"""Fig 14 (extension): placement across the edge-cloud continuum.

The paper's cluster is flat — every node one RTT from every other and
from the storage services.  :mod:`repro.core.topology` generalizes that
to a node -> zone -> region (-> edge-site) hierarchy with per-crossing
bandwidth/RTT (:class:`NetConstants` tier links) and cross-tier egress
fees (:func:`repro.core.cost.egress_fee_usd`).  This harness sweeps the
two topology workloads over the paper's three fixed backends and
compares **flat placement** (the topology is real but unpinned stages
spread naively round-robin across zones) against **tier-aware
placement** (``dag.optimize(topology=..., backend=...)`` — CoPlacement's
greedy zone assignment, service-homed legs priced to the storage home
zone, resident legs priced producer->consumer).

Workloads (:data:`repro.core.workloads.TOPO_WORKLOADS`):

* **EDGE** — edge-ingest -> cloud-train fan-in.  Ingest pinned
  one-per-edge-site, trainer pinned to the cloud; naive placement drops
  the unpinned driver on ``edge-0`` so the model gather crosses the edge
  uplink.  Tier-aware homes it in the cloud on every backend.
* **GEO** — geo-sharded fan-in.  Shards pinned across one local and two
  remote regions; the right home for the unpinned driver depends on the
  backend — the hub (storage home) for service media, next to the
  same-region shards for direct media.  Tier-aware picks per backend;
  the service cells come out *identical* to naive (the gate's equality
  case is real, not vacuous).

``--smoke`` is the seconds-long CI subset with two hard gates:

1. **tier-aware dominance** — never costlier and never slower (p50)
   than flat placement on any workload x backend cell;
2. **flat identity** — a single-zone topology is *bit-identical*
   (latency and cost) to running with no topology at all, per cell.

Run:  PYTHONPATH=src python -m benchmarks.fig14_topology [--smoke]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core.topology import Topology
from repro.core.workloads import DAGS, TOPO_DAGS, TOPO_WORKLOADS, TOPOLOGIES

from .common import fmt_s, save_json

RESULT_NAME = "fig14_topology.json"

BACKENDS = ("s3", "elasticache", "xdt")
N_SEEDS = 10
SMOKE_SEEDS = 3


def _cell(name, backend, n_seeds, plan=None):
    fn = TOPO_WORKLOADS[name]
    runs = [fn(backend, seed=s, plan=plan) for s in range(n_seeds)]
    det = fn(backend, seed=0, deterministic=True, plan=plan)
    return {
        "p50_latency_s": float(np.median([r.latency_s for r in runs])),
        "mean_total_uUSD": float(np.mean([r.cost.total for r in runs])) * 1e6,
        "det_latency_s": det.latency_s,
        "det_total_uUSD": det.cost.total * 1e6,
        "det_egress_uUSD": det.cost.egress * 1e6,
    }


def run(n_seeds: int = N_SEEDS):
    out = {}
    for name, dag in TOPO_DAGS.items():
        topo = TOPOLOGIES[name]
        rows = {}
        for b in BACKENDS:
            # the tier-aware plan is per backend: service-homed media pull
            # toward the storage home zone, direct media toward peers
            _, plan = dag.optimize(topology=topo, backend=b)
            rows[b] = {
                "flat": _cell(name, b, n_seeds),
                "aware": _cell(name, b, n_seeds, plan=plan),
                "zones": dict(plan.zones),
            }
        out[name] = {"topology": topo.describe(), "cells": rows}
    return out


def check_tier_aware_dominates(out) -> None:
    """CI gate: per cell, tier-aware cost <= flat and p50 <= flat.

    Raises (not assert: the gate must survive ``python -O``).  Equality
    is legal — GEO's service-homed cells place the driver exactly where
    naive round-robin does — so the tolerance only absorbs float noise,
    never a real regression."""
    tol = 1 + 1e-9
    for name, data in out.items():
        for b, cell in data["cells"].items():
            flat, aware = cell["flat"], cell["aware"]
            if aware["mean_total_uUSD"] > flat["mean_total_uUSD"] * tol:
                raise RuntimeError(
                    f"{name}/{b}: tier-aware costs "
                    f"{aware['mean_total_uUSD']:.2f}uUSD > flat "
                    f"{flat['mean_total_uUSD']:.2f}uUSD — tier-aware "
                    "placement must never lose on cost"
                )
            if aware["p50_latency_s"] > flat["p50_latency_s"] * tol:
                raise RuntimeError(
                    f"{name}/{b}: tier-aware p50 "
                    f"{aware['p50_latency_s']:.4f}s > flat "
                    f"{flat['p50_latency_s']:.4f}s — tier-aware "
                    "placement must never lose on latency"
                )


def check_flat_identity() -> None:
    """CI gate: a single-zone topology is bit-identical to no topology.

    Covers the topology workloads AND the paper's flat workloads — the
    continuum machinery must be invisible when there is nothing to
    cross (sha goldens and BENCH_engine checksums depend on it)."""
    single = Topology()
    for name, dag in {**DAGS, **TOPO_DAGS}.items():
        for b in BACKENDS:
            base = dag.compile(target="cluster", backend=b).run(
                seed=0, deterministic=True)
            topo = dag.compile(target="cluster", backend=b,
                               topology=single).run(seed=0, deterministic=True)
            if (base.latency_s != topo.latency_s
                    or base.cost().total != topo.cost().total):
                raise RuntimeError(
                    f"{name}/{b}: single-zone topology diverges from flat "
                    f"run ({topo.latency_s!r} vs {base.latency_s!r}) — a "
                    "degenerate topology must be bit-identical"
                )


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    out = run(n_seeds=SMOKE_SEEDS if smoke else N_SEEDS)
    print("# Fig 14 — edge-cloud continuum: flat vs tier-aware placement")
    for name, data in out.items():
        print(f"\n  {name.upper()}: {data['topology']}")
        for b, cell in data["cells"].items():
            flat, aware = cell["flat"], cell["aware"]
            speedup = (
                flat["p50_latency_s"] / aware["p50_latency_s"]
                if aware["p50_latency_s"] > 0 else 1.0
            )
            saved = flat["mean_total_uUSD"] - aware["mean_total_uUSD"]
            zones = ", ".join(f"{s}->{z}" for s, z in cell["zones"].items())
            print(
                f"    {b:12s} p50 {fmt_s(flat['p50_latency_s']):>9} -> "
                f"{fmt_s(aware['p50_latency_s']):>9} ({speedup:4.2f}x)  "
                f"cost {flat['mean_total_uUSD']:8.1f} -> "
                f"{aware['mean_total_uUSD']:8.1f}uUSD (-{saved:.1f})  "
                f"[{zones or 'all pinned'}]"
            )
    if not smoke:
        save_json(RESULT_NAME, out)      # artifact survives a gate trip
    check_tier_aware_dominates(out)
    print("\ntier-aware-dominates gate: never costlier, never slower (p50) "
          "on any workload x backend OK")
    check_flat_identity()
    print("flat-identity gate: single-zone topology bit-identical to flat "
          "run on every cell OK")
    return out


#: benchmarks.run auto-discovery
HARNESS = {"name": "fig14", "full": main, "smoke": lambda: main(["--smoke"])}


if __name__ == "__main__":
    main()
