"""Fig 8 (extension): throughput / tail latency / cost under concurrent load.

The paper's headline claims (2-5x cost, 1.3-3.4x latency vs S3) concern
*concurrent, autoscaled* workflows.  This harness sweeps offered load x
transfer backend over the event-driven workflow engine on virtual time:

* workflow: driver --scatter(fan)--> workers --refs--> reducer, with one
  ephemeral object per edge moved through the backend under test;
* open-loop Poisson arrivals at each offered-load point (queueing and cold
  starts actually bite, unlike closed-loop driving);
* reports p50/p99 end-to-end latency, achieved RPS, and $ per 1k requests
  from the calibrated cost model.

Run:  PYTHONPATH=src python -m benchmarks.fig8_throughput [--quick]
"""
from __future__ import annotations

import sys

import jax.numpy as jnp

from repro.core import LoadGenerator, ScalingPolicy, WorkflowEngine

from .common import fmt_s, save_json

BACKENDS = ["xdt", "s3", "elasticache"]
OFFERED_RPS = [4.0, 16.0, 64.0]
DURATION_S = 20.0          # virtual seconds per load point
FAN = 2                    # scatter width inside each request
EDGE_BYTES = 64 << 10      # ephemeral object per edge (real arrays move)
SERVICE_TIME = {"driver": 0.010, "worker": 0.030, "reducer": 0.015}


def build_engine(backend: str, seed: int = 0) -> WorkflowEngine:
    eng = WorkflowEngine(seed=seed, backend=backend)

    n = EDGE_BYTES // 4

    def worker(ctx, ref):
        x = ctx.get(ref)
        return ctx.put(x * 2.0, n_retrievals=1)

    def reducer(ctx, refs):
        return float(sum(ctx.get(r).sum() for r in refs))

    def driver(ctx, i):
        # generator handler: the fan-out edges genuinely overlap
        refs = [
            ctx.put(jnp.full((n,), float(i % 7), jnp.float32), n_retrievals=1)
            for _ in range(FAN)
        ]
        handles = yield [ctx.call("worker", r) for r in refs]
        total = yield ctx.call("reducer", handles)
        return total

    pol = lambda: ScalingPolicy(max_instances=64, target_concurrency=1)  # noqa: E731
    eng.register("worker", worker, policy=pol(), service_time=SERVICE_TIME["worker"])
    eng.register("reducer", reducer, policy=pol(), service_time=SERVICE_TIME["reducer"])
    eng.register("driver", driver, policy=pol(), service_time=SERVICE_TIME["driver"])
    return eng


def run(offered=None, duration_s=DURATION_S):
    offered = offered or OFFERED_RPS
    rows = []
    for backend in BACKENDS:
        for rate in offered:
            eng = build_engine(backend)
            gen = LoadGenerator(eng, "driver")
            rep = gen.run_open(rate_rps=rate, duration_s=duration_s)
            row = rep.as_row()
            row["n_cold_starts"] = sum(
                d.stats["cold_starts"] for d in eng.control.deployments.values()
            )
            rows.append(row)
    return {"rows": rows, "config": {
        "fan": FAN, "edge_bytes": EDGE_BYTES, "duration_s": duration_s,
        "offered_rps": offered, "service_time": SERVICE_TIME,
    }}


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    out = run(
        offered=[4.0, 16.0] if quick else None,
        duration_s=4.0 if quick else DURATION_S,
    )
    print("# Fig 8 — offered load x backend: p50/p99 latency, RPS, $/1k req")
    print(f"{'backend':>12} {'offered':>8} {'achieved':>9} {'p50':>10} "
          f"{'p99':>10} {'$/1k':>10} {'cold':>5}")
    for r in out["rows"]:
        print(f"{r['backend']:>12} {r['offered_rps']:>8.1f} "
              f"{r['achieved_rps']:>9.2f} {fmt_s(r['p50_s']):>10} "
              f"{fmt_s(r['p99_s']):>10} {r['usd_per_1k_requests']:>10.5f} "
              f"{r['n_cold_starts']:>5}")
    save_json("fig8_throughput.json", out)
    return out


if __name__ == "__main__":
    main()
