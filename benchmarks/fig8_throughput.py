"""Fig 8 (extension): throughput / tail latency / cost under concurrent load.

The paper's headline claims (2-5x cost, 1.3-3.4x latency vs S3) concern
*concurrent, autoscaled* workflows.  This harness sweeps offered load x
transfer backend over the event-driven workflow engine on virtual time:

* workflow: driver --scatter(fan)--> workers --refs--> reducer, with one
  ephemeral object per edge moved through the backend under test;
* open-loop Poisson arrivals at each offered-load point (queueing and cold
  starts actually bite, unlike closed-loop driving);
* reports p50/p99 end-to-end latency, achieved RPS, and $ per 1k requests
  from the calibrated cost model.

``--dag`` sweeps the *declarative* paper workloads instead: each
:class:`~repro.core.dag.WorkflowDAG` in ``repro.core.workloads.DAGS`` is
compiled onto the engine (``dag.bind``) per (route x offered load) cell —
including the per-edge-routed ``hybrid`` configuration, priced per medium by
the load generator's routed cost path.  Objects are down-scaled
(``DAG_BYTES_SCALE``) so real arrays still move on every edge at sweep
concurrency.

Run:  PYTHONPATH=src python -m benchmarks.fig8_throughput [--quick] [--dag]
"""
from __future__ import annotations

import sys

import jax.numpy as jnp

from repro.core import LoadGenerator, ScalingPolicy, WorkflowEngine

from .common import fmt_s, save_json

BACKENDS = ["xdt", "s3", "elasticache"]
OFFERED_RPS = [4.0, 16.0, 64.0]
DURATION_S = 20.0          # virtual seconds per load point
FAN = 2                    # scatter width inside each request
EDGE_BYTES = 64 << 10      # ephemeral object per edge (real arrays move)
SERVICE_TIME = {"driver": 0.010, "worker": 0.030, "reducer": 0.015}

# -- DAG sweep (declarative paper workloads over the engine) ---------------
DAG_ROUTES = ["xdt", "s3", "elasticache", "hybrid"]
DAG_OFFERED_RPS = [1.0, 4.0]
DAG_DURATION_S = 10.0
DAG_BYTES_SCALE = 1e-5     # scale declared edge bytes to sweep-size arrays


def build_engine(backend: str, seed: int = 0) -> WorkflowEngine:
    eng = WorkflowEngine(seed=seed, backend=backend)

    n = EDGE_BYTES // 4

    def worker(ctx, ref):
        x = ctx.get(ref)
        return ctx.put(x * 2.0, n_retrievals=1)

    def reducer(ctx, refs):
        return float(sum(ctx.get(r).sum() for r in refs))

    def driver(ctx, i):
        # generator handler: the fan-out edges genuinely overlap
        refs = [
            ctx.put(jnp.full((n,), float(i % 7), jnp.float32), n_retrievals=1)
            for _ in range(FAN)
        ]
        handles = yield [ctx.call("worker", r) for r in refs]
        total = yield ctx.call("reducer", handles)
        return total

    pol = lambda: ScalingPolicy(max_instances=64, target_concurrency=1)  # noqa: E731
    eng.register("worker", worker, policy=pol(), service_time=SERVICE_TIME["worker"])
    eng.register("reducer", reducer, policy=pol(), service_time=SERVICE_TIME["reducer"])
    eng.register("driver", driver, policy=pol(), service_time=SERVICE_TIME["driver"])
    return eng


def run(offered=None, duration_s=DURATION_S):
    offered = offered or OFFERED_RPS
    rows = []
    for backend in BACKENDS:
        for rate in offered:
            eng = build_engine(backend)
            gen = LoadGenerator(eng, "driver")
            rep = gen.run_open(rate_rps=rate, duration_s=duration_s)
            row = rep.as_row()
            row["n_cold_starts"] = sum(
                d.stats["cold_starts"] for d in eng.control.deployments.values()
            )
            rows.append(row)
    return {"rows": rows, "config": {
        "fan": FAN, "edge_bytes": EDGE_BYTES, "duration_s": duration_s,
        "offered_rps": offered, "service_time": SERVICE_TIME,
    }}


def build_dag_binding(workload: str, route: str, seed: int = 0):
    """One (DAG workload, route) cell: a fresh engine + compiled binding."""
    from repro.core.workloads import DAGS, HYBRID_ROUTE

    eng = WorkflowEngine(seed=seed, backend="xdt", records="columnar")
    binding = DAGS[workload].compile(
        target="engine", engine=eng,
        backend=HYBRID_ROUTE if route == "hybrid" else route,
        bytes_scale=DAG_BYTES_SCALE,
    )
    return eng, binding


def run_dag(workloads=None, offered=None, duration_s=DAG_DURATION_S):
    from repro.core.workloads import DAGS

    workloads = workloads or list(DAGS)
    offered = offered or DAG_OFFERED_RPS
    rows = []
    for workload in workloads:
        for route in DAG_ROUTES:
            for rate in offered:
                eng, binding = build_dag_binding(workload, route)
                rep = LoadGenerator(eng, binding).run_open(
                    rate_rps=rate, duration_s=duration_s
                )
                row = rep.as_row()
                row["workflow"] = workload
                row["backend"] = route          # short label, not describe()
                row["n_cold_starts"] = sum(
                    d.stats["cold_starts"]
                    for d in eng.control.deployments.values()
                )
                row["edges"] = binding.edge_report()
                rows.append(row)
    return {"rows": rows, "config": {
        "workloads": workloads, "routes": DAG_ROUTES, "offered_rps": offered,
        "duration_s": duration_s, "bytes_scale": DAG_BYTES_SCALE,
    }}


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    if "--dag" in argv:
        out = run_dag(
            offered=[2.0] if quick else None,
            duration_s=4.0 if quick else DAG_DURATION_S,
        )
        print("# Fig 8 (DAG) — workload x route x load: p50/p99, RPS, $/1k req")
        print(f"{'workflow':>9} {'route':>12} {'offered':>8} {'achieved':>9} "
              f"{'p50':>10} {'p99':>10} {'$/1k':>10} {'cold':>5}")
        for r in out["rows"]:
            print(f"{r['workflow']:>9} {r['backend']:>12} "
                  f"{r['offered_rps']:>8.1f} {r['achieved_rps']:>9.2f} "
                  f"{fmt_s(r['p50_s']):>10} {fmt_s(r['p99_s']):>10} "
                  f"{r['usd_per_1k_requests']:>10.5f} {r['n_cold_starts']:>5}")
        save_json("fig8_dag_throughput.json", out)
        return out
    out = run(
        offered=[4.0, 16.0] if quick else None,
        duration_s=4.0 if quick else DURATION_S,
    )
    print("# Fig 8 — offered load x backend: p50/p99 latency, RPS, $/1k req")
    print(f"{'backend':>12} {'offered':>8} {'achieved':>9} {'p50':>10} "
          f"{'p99':>10} {'$/1k':>10} {'cold':>5}")
    for r in out["rows"]:
        print(f"{r['backend']:>12} {r['offered_rps']:>8.1f} "
              f"{r['achieved_rps']:>9.2f} {fmt_s(r['p50_s']):>10} "
              f"{fmt_s(r['p99_s']):>10} {r['usd_per_1k_requests']:>10.5f} "
              f"{r['n_cold_starts']:>5}")
    save_json("fig8_throughput.json", out)
    return out


#: benchmarks.run auto-discovery: one module, two harnesses (engine sweep
#: and the DAG-compiled sweep)
HARNESSES = [
    {"name": "fig8", "full": lambda: main([]),
     "smoke": lambda: main(["--quick"])},
    {"name": "fig8dag", "full": lambda: main(["--dag"]),
     "smoke": lambda: main(["--dag", "--quick"])},
]

if __name__ == "__main__":
    main()
