"""§Perf hillclimb harness: lower a cell under named optimization variants
and record the roofline terms for each (hypothesis -> change -> measure).

Variants are config/optimizer knobs (all default-off, so the recorded
baseline is the paper-faithful implementation):

  loss_chunk     streamed cross-entropy (no (B,S,V) logits materialization)
  zero1          ZeRO-1 optimizer-state sharding over the data/pod axes
  seq_shard      Megatron sequence parallelism for inter-block activations
  moe_a2a        all-to-all expert dispatch (the paper's scatter/gather)
  scatter_kv     serve_step KV update via scatter instead of one-hot rewrite

Usage (needs the 512-device flag, so run as a module, NOT under pytest):

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --cell llama4_scout_17b_a16e:train_4k \
        --variants baseline,+moe_a2a,+loss_chunk,+zero1,combo
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import time
from typing import Any, Dict

from repro.configs import get_config
from repro.launch import dryrun as dr
from repro.launch.input_specs import SHAPE_CELLS, input_specs
from repro.launch.mesh import make_production_mesh
from repro.optim import OptConfig
from repro.train import make_train_step

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def apply_variant(cfg, variant: str):
    """Returns (cfg', zero1flag).  ``variant`` is a +-joined knob list."""
    zero1 = False
    for knob in variant.split("+"):
        knob = knob.strip()
        if knob in ("", "baseline"):
            continue
        if knob == "loss_chunk":
            cfg = dataclasses.replace(cfg, loss_chunk=512)
        elif knob == "zero1":
            zero1 = True
        elif knob == "seq_shard":
            cfg = dataclasses.replace(cfg, seq_shard_acts=True)
        elif knob == "moe_a2a":
            assert cfg.moe is not None, "moe_a2a needs an MoE arch"
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, dispatch="a2a"))
        elif knob == "scatter_kv":
            cfg = dataclasses.replace(cfg, decode_scatter_update=True)
        elif knob == "fsdp":
            cfg = dataclasses.replace(cfg, fsdp_params=True)
        elif knob == "combo":
            cfg = dataclasses.replace(cfg, loss_chunk=512, fsdp_params=True)
            if cfg.moe is not None:
                cfg = dataclasses.replace(
                    cfg, moe=dataclasses.replace(cfg.moe, dispatch="a2a"))
            zero1 = True
        else:
            raise ValueError(f"unknown knob {knob!r}")
    return cfg, zero1


_ORIG_BUILD_STEP = dr.build_step


def build_step_z(cfg, kind, mesh, specs, zero1):
    if kind == "train":
        step = make_train_step(cfg, mesh, OptConfig(zero1=zero1),
                               remat="full", donate=False)
        return step, (specs["params"], specs["opt_state"], specs["batch"])
    return _ORIG_BUILD_STEP(cfg, kind, mesh, specs)


def measure(arch: str, shape: str, variant: str, multi_pod=False) -> Dict[str, Any]:
    cfg0 = get_config(arch)
    cfg, zero1 = apply_variant(cfg0, variant)
    kind = SHAPE_CELLS[shape]["kind"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = input_specs(cfg, shape, mesh, zero1=zero1)
    step, args = build_step_z(cfg, kind, mesh, specs, zero1)
    t0 = time.time()
    with mesh:
        lowered = step.lower(*args)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
    # depth-extrapolated cost probes with the SAME variant knobs applied:
    # monkeypatch the probe-config factory (apply knobs on top of the probe
    # reductions) and the step builder (thread the zero1 flag through).
    orig_probe_cfg, orig_build = dr._probe_cfg, dr.build_step
    dr._probe_cfg = lambda c, L, chunked=False: apply_variant(
        orig_probe_cfg(cfg0, L, chunked=chunked), variant)[0]
    dr.build_step = lambda pcfg, pkind, pmesh, pspecs: build_step_z(
        pcfg, pkind, pmesh, pspecs, zero1)
    try:
        probes = dr.run_cost_probes(cfg, kind, shape, mesh)
    finally:
        dr._probe_cfg, dr.build_step = orig_probe_cfg, orig_build

    flops = probes["flops_per_device"]
    nbytes = probes["bytes_per_device"]
    coll = probes["collective_bytes_per_device"]
    t_c, t_m = flops / PEAK_FLOPS, nbytes / HBM_BW
    t_x = sum(coll.values()) / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    return {
        "arch": arch, "shape": shape, "variant": variant,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "bound_s": max(terms.values()),
        "bottleneck": max(terms, key=terms.get),
        "peak_mem_GiB": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                         + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
        "collective_bytes": coll,
        "flops_per_device": flops,
        "bytes_per_device": nbytes,
        "wall_s": round(time.time() - t0, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    results = []
    for v in args.variants.split(","):
        print(f"[hillclimb] {arch}:{shape} variant={v} ...", flush=True)
        try:
            rec = measure(arch, shape, v)
        except Exception as e:
            import traceback
            rec = {"arch": arch, "shape": shape, "variant": v,
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-1500:]}
        results.append(rec)
        if "error" in rec:
            print(f"    FAILED {rec['error'][:200]}")
        else:
            print(f"    C={rec['t_compute_s']*1e3:.0f}ms M={rec['t_memory_s']*1e3:.0f}ms "
                  f"X={rec['t_collective_s']*1e3:.0f}ms bound={rec['bottleneck']}"
                  f" peak={rec['peak_mem_GiB']:.1f}GiB", flush=True)
    # write next to the other harness artifacts (benchmarks.common.RESULTS_DIR
    # is absolute, so invocation cwd doesn't matter); imported lazily because
    # this module must set XLA_FLAGS before anything imports jax
    from .common import RESULTS_DIR

    out = args.out or os.path.join(
        RESULTS_DIR, f"hillclimb_{arch}_{shape}.json"
    )
    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
