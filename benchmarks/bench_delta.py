"""CI reporting helper: events/sec delta table vs the committed baseline.

Prints a GitHub-flavored-markdown table comparing the *committed*
``results/BENCH_engine.json`` smoke section (saved aside before the CI run
overwrites it) against the freshly measured one, per (backend x offered
load) cell plus the totals row.  CI appends the output to
``$GITHUB_STEP_SUMMARY`` so every PR shows its engine-throughput delta
next to the pass/fail tick — the hard gate itself stays in
``bench_engine --smoke --check`` (>30% regression fails the job); this
table is the trajectory's human-readable face.

Usage:  PYTHONPATH=src python -m benchmarks.bench_delta BASELINE.json [FRESH.json]
"""
from __future__ import annotations

import json
import os
import sys

from .common import RESULTS_DIR


def _smoke_rows(path):
    with open(path) as f:
        doc = json.load(f)
    smoke = doc.get("smoke") or {}
    rows = {
        (r["backend"], r["offered_rps"]): r for r in smoke.get("rows", [])
    }
    return rows, smoke.get("totals", {})


def _fmt_delta(base, fresh):
    if not base:
        return "n/a"
    pct = (fresh - base) / base * 100.0
    return f"{pct:+.1f}%"


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m benchmarks.bench_delta BASELINE.json [FRESH.json]")
        return 2
    baseline_path = argv[0]
    fresh_path = (
        argv[1] if len(argv) > 1
        else os.path.join(RESULTS_DIR, "BENCH_engine.json")
    )
    base_rows, base_tot = _smoke_rows(baseline_path)
    fresh_rows, fresh_tot = _smoke_rows(fresh_path)

    print("### Engine benchmark — smoke events/sec vs committed baseline")
    print()
    print("| backend | offered rps | baseline ev/s | fresh ev/s | delta |")
    print("|---|---:|---:|---:|---:|")
    for key in sorted(fresh_rows):
        fresh = fresh_rows[key]
        base = base_rows.get(key, {})
        b_eps = base.get("events_per_sec", 0.0)
        f_eps = fresh["events_per_sec"]
        print(f"| {key[0]} | {key[1]:.0f} | {b_eps:,.0f} | {f_eps:,.0f} "
              f"| {_fmt_delta(b_eps, f_eps)} |")
    b_eps = base_tot.get("events_per_sec", 0.0)
    f_eps = fresh_tot.get("events_per_sec", 0.0)
    print(f"| **total** | | **{b_eps:,.0f}** | **{f_eps:,.0f}** "
          f"| **{_fmt_delta(b_eps, f_eps)}** |")
    print()
    checks = [
        (k, base_rows[k]["latency_checksum"] == r["latency_checksum"])
        for k, r in fresh_rows.items() if k in base_rows
    ]
    if checks and all(ok for _, ok in checks):
        print("fixed-seed per-request latency checksums: **bit-identical** "
              "to the committed baseline (semantics unchanged)")
    elif checks:
        diff = [f"{k[0]}@{k[1]:.0f}" for k, ok in checks if not ok]
        print(f"latency checksums CHANGED at: {', '.join(diff)} — the sweep's "
              "virtual-time semantics differ from the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
