"""CI reporting helper: events/sec delta table vs the committed baseline.

Prints a GitHub-flavored-markdown table comparing the *committed*
``results/BENCH_engine.json`` smoke section (saved aside before the CI run
overwrites it) against the freshly measured one, per (backend x offered
load) cell plus the totals row.  CI appends the output to
``$GITHUB_STEP_SUMMARY`` so every PR shows its engine-throughput delta
next to the pass/fail tick — the hard gate itself stays in
``bench_engine --smoke --check`` (>30% regression fails the job); this
table is the trajectory's human-readable face.

With ``--fig11-baseline`` the table gains the multi-tenant sweep's cell —
smoke events/sec over the sharded tenant cells plus the co-resident
deployment count and the attribution-invariant gap — comparing the saved-
aside ``results/BENCH_fig11_multitenant.json`` against the fresh one.

When the engine results carry a streaming section (``streaming_smoke`` in
CI, ``streaming`` for full runs) the table gains the streaming fast-path's
own rows: per (backend x offered load), coalesced events/sec vs the
committed baseline, the coalesced/legacy speedup with its gate, and the
peak in-flight chunk bytes (the credit window's observable) — so a PR that
touches the span kernels or the backpressure path shows both its throughput
and its buffering footprint next to the scalar-path delta.

With ``--fig13-baseline`` it gains the streaming sweep's makespan-vs-bound
table: per workload x backend, the best streaming makespan's ratio to the
critical-path lower bound (1.0 = perfect overlap), fresh vs the committed
``results/fig13_streaming.json`` — so a PR that moves the streaming model
shows its distance-to-bound drift next to the throughput delta.

Usage:  PYTHONPATH=src python -m benchmarks.bench_delta BASELINE.json [FRESH.json]
            [--fig11-baseline FIG11_BASELINE.json [--fig11-fresh FIG11_FRESH.json]]
            [--fig13-baseline FIG13_BASELINE.json [--fig13-fresh FIG13_FRESH.json]]
"""
from __future__ import annotations

import json
import os
import sys

from .common import RESULTS_DIR


def _smoke_rows(path):
    with open(path) as f:
        doc = json.load(f)
    smoke = doc.get("smoke") or {}
    rows = {
        (r["backend"], r["offered_rps"]): r for r in smoke.get("rows", [])
    }
    return rows, smoke.get("totals", {})


def _fmt_delta(base, fresh):
    if not base:
        return "n/a"
    pct = (fresh - base) / base * 100.0
    return f"{pct:+.1f}%"


def _stream_rows(path):
    """Streaming section rows keyed by (backend, rate); smoke preferred."""
    with open(path) as f:
        doc = json.load(f)
    sec = doc.get("streaming_smoke") or doc.get("streaming") or {}
    rows = {
        (r["backend"], r["offered_rps"]): r for r in sec.get("rows", [])
    }
    return rows, sec.get("totals", {})


def _fmt_bytes(n):
    if n >= 1 << 20:
        return f"{n / (1 << 20):.1f} MiB"
    if n >= 1 << 10:
        return f"{n / (1 << 10):.0f} KiB"
    return f"{n:.0f} B"


def _streaming_section(baseline_path, fresh_path):
    base_rows, base_tot = _stream_rows(baseline_path)
    fresh_rows, fresh_tot = _stream_rows(fresh_path)
    if not fresh_rows:
        return
    print()
    print("### Streaming fast path — coalesced chunk events vs committed "
          "baseline")
    print()
    print("| backend | offered rps | baseline ev/s | fresh ev/s | delta "
          "| speedup vs legacy | peak inflight |")
    print("|---|---:|---:|---:|---:|---:|---:|")
    for key in sorted(fresh_rows):
        r = fresh_rows[key]
        b = base_rows.get(key, {})
        b_eps = (b.get("coalesced") or {}).get("events_per_sec", 0.0)
        f_eps = r["coalesced"]["events_per_sec"]
        peak = r["coalesced"]["peak_inflight_chunk_bytes"]
        print(f"| {key[0]} | {key[1]:.0f} | {b_eps:,.0f} | {f_eps:,.0f} "
              f"| {_fmt_delta(b_eps, f_eps)} | x{r['speedup']:.2f} "
              f"| {_fmt_bytes(peak)} |")
    b_eps = base_tot.get("events_per_sec_coalesced", 0.0)
    f_eps = fresh_tot.get("events_per_sec_coalesced", 0.0)
    gate = fresh_tot.get("speedup_gate", 0.0)
    print(f"| **total** | | **{b_eps:,.0f}** | **{f_eps:,.0f}** "
          f"| **{_fmt_delta(b_eps, f_eps)}** "
          f"| **x{fresh_tot.get('speedup', 0.0):.2f}** (gate x{gate:.1f}) "
          f"| |")
    print()
    if fresh_tot.get("bit_identical"):
        print("coalesced vs legacy per-request latency checksums: "
              "**bit-identical** in every cell (the fast path is a pure "
              "wall-time win)")
    else:
        diff = [
            f"{k[0]}@{k[1]:.0f}" for k, r in sorted(fresh_rows.items())
            if not r.get("bit_identical")
        ]
        print(f"coalesced vs legacy checksums DIVERGE at: {', '.join(diff)} "
              "— the span kernels changed virtual-time semantics")


def _fig11_totals(path):
    with open(path) as f:
        doc = json.load(f)
    return (doc.get("smoke") or {}).get("totals", {})


def _fig11_section(baseline_path, fresh_path):
    base = _fig11_totals(baseline_path)
    fresh = _fig11_totals(fresh_path)
    if not fresh:
        return
    b_eps = base.get("events_per_sec", 0.0)
    f_eps = fresh.get("events_per_sec", 0.0)
    print()
    print("### Multi-tenant sweep — smoke (sharded tenant cells)")
    print()
    print("| metric | baseline | fresh | delta |")
    print("|---|---:|---:|---:|")
    print(f"| events/sec | {b_eps:,.0f} | {f_eps:,.0f} "
          f"| {_fmt_delta(b_eps, f_eps)} |")
    print(f"| co-resident deployments | {base.get('max_n_deployments', 0):,} "
          f"| {fresh.get('max_n_deployments', 0):,} | |")
    print(f"| attribution gap (rel) "
          f"| {base.get('max_attribution_gap_rel', 0.0):.1e} "
          f"| {fresh.get('max_attribution_gap_rel', 0.0):.1e} | |")


def _fig13_best_ratios(path):
    """(workload, backend) -> best (makespan/bound) across chunk sizes."""
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for wl, rows in (doc.get("cluster") or {}).items():
        for backend, row in rows.items():
            cells = row.get("cells") or {}
            if cells:
                out[(wl, backend)] = {
                    "bound_s": row["bound_s"],
                    "base_ratio": row["base_ratio_vs_bound"],
                    "best_ratio": min(
                        c["ratio_vs_bound"] for c in cells.values()
                    ),
                }
    return out


def _fig13_section(baseline_path, fresh_path):
    base = _fig13_best_ratios(baseline_path)
    fresh = _fig13_best_ratios(fresh_path)
    if not fresh:
        return
    print()
    print("### Streaming edges — makespan vs critical-path bound "
          "(cluster lowering)")
    print()
    print("| workload / backend | bound | store-then-fetch "
          "| best stream (fresh) | baseline | drift |")
    print("|---|---:|---:|---:|---:|---:|")
    for key in sorted(fresh):
        f = fresh[key]
        b = base.get(key, {})
        b_ratio = b.get("best_ratio", 0.0)
        print(
            f"| {key[0]}/{key[1]} | {f['bound_s']:.3f}s "
            f"| {f['base_ratio']:.3f}x | {f['best_ratio']:.3f}x "
            f"| {b_ratio:.3f}x "
            f"| {_fmt_delta(b_ratio, f['best_ratio'])} |"
        )
    print()
    print("ratios are makespan / critical-path lower bound; 1.000x is "
          "perfect transfer/compute overlap, drift is fresh vs committed")


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)

    def _flag(name):
        if name in argv:
            i = argv.index(name)
            argv.pop(i)
            return argv.pop(i)
        return None

    fig11_baseline = _flag("--fig11-baseline")
    fig11_fresh = _flag("--fig11-fresh") or os.path.join(
        RESULTS_DIR, "BENCH_fig11_multitenant.json"
    )
    fig13_baseline = _flag("--fig13-baseline")
    fig13_fresh = _flag("--fig13-fresh") or os.path.join(
        RESULTS_DIR, "fig13_streaming.json"
    )
    if not argv:
        print("usage: python -m benchmarks.bench_delta BASELINE.json [FRESH.json]"
              " [--fig11-baseline FIG11.json [--fig11-fresh FIG11.json]]")
        return 2
    baseline_path = argv[0]
    fresh_path = (
        argv[1] if len(argv) > 1
        else os.path.join(RESULTS_DIR, "BENCH_engine.json")
    )
    base_rows, base_tot = _smoke_rows(baseline_path)
    fresh_rows, fresh_tot = _smoke_rows(fresh_path)

    print("### Engine benchmark — smoke events/sec vs committed baseline")
    print()
    print("| backend | offered rps | baseline ev/s | fresh ev/s | delta |")
    print("|---|---:|---:|---:|---:|")
    for key in sorted(fresh_rows):
        fresh = fresh_rows[key]
        base = base_rows.get(key, {})
        b_eps = base.get("events_per_sec", 0.0)
        f_eps = fresh["events_per_sec"]
        print(f"| {key[0]} | {key[1]:.0f} | {b_eps:,.0f} | {f_eps:,.0f} "
              f"| {_fmt_delta(b_eps, f_eps)} |")
    b_eps = base_tot.get("events_per_sec", 0.0)
    f_eps = fresh_tot.get("events_per_sec", 0.0)
    print(f"| **total** | | **{b_eps:,.0f}** | **{f_eps:,.0f}** "
          f"| **{_fmt_delta(b_eps, f_eps)}** |")
    print()
    checks = [
        (k, base_rows[k]["latency_checksum"] == r["latency_checksum"])
        for k, r in fresh_rows.items() if k in base_rows
    ]
    if checks and all(ok for _, ok in checks):
        print("fixed-seed per-request latency checksums: **bit-identical** "
              "to the committed baseline (semantics unchanged)")
    elif checks:
        diff = [f"{k[0]}@{k[1]:.0f}" for k, ok in checks if not ok]
        print(f"latency checksums CHANGED at: {', '.join(diff)} — the sweep's "
              "virtual-time semantics differ from the committed baseline")
    _streaming_section(baseline_path, fresh_path)
    if fig11_baseline and os.path.exists(fig11_baseline):
        _fig11_section(fig11_baseline, fig11_fresh)
    if fig13_baseline and os.path.exists(fig13_baseline):
        _fig13_section(fig13_baseline, fig13_fresh)
    return 0


if __name__ == "__main__":
    sys.exit(main())
