"""Paper Fig. 5: 1-1 transfer latency CDFs for S3 / EC / XDT at 10KB & 10MB.

Paper anchors: 10KB — EC median (tail) 89% (92%) below S3, XDT 12% (10%)
below EC.  10MB — EC 87% (90%) below S3, XDT 45% (34%) below EC.
"""
from __future__ import annotations

import numpy as np

from repro.core import measure_pattern

from .common import fmt_s, save_json

BACKENDS = ["s3", "elasticache", "xdt"]
SIZES = {"10KB": 10 << 10, "10MB": 10 << 20}


def run(n_samples: int = 200):
    out = {}
    for label, nbytes in SIZES.items():
        dists = {}
        for b in BACKENDS:
            ts = np.array(
                [measure_pattern("1-1", b, nbytes, seed=s)[0] for s in range(n_samples)]
            )
            dists[b] = {
                "median_s": float(np.median(ts)),
                "p99_s": float(np.percentile(ts, 99)),
                "cdf_x": np.sort(ts).tolist()[:: max(1, n_samples // 50)],
            }
        out[label] = dists
    return out


def main():
    out = run()
    print("# Fig 5 — 1-1 latency distributions (median / p99)")
    for label, dists in out.items():
        print(f"\n  {label}:")
        for b in BACKENDS:
            d = dists[b]
            print(f"    {b:12s} median={fmt_s(d['median_s'])}  p99={fmt_s(d['p99_s'])}")
        ec, s3, xdt = dists["elasticache"], dists["s3"], dists["xdt"]
        print(f"    EC vs S3 median: -{(1 - ec['median_s']/s3['median_s'])*100:.0f}% "
              f"(paper {'89' if label=='10KB' else '87'}%)  "
              f"XDT vs EC median: -{(1 - xdt['median_s']/ec['median_s'])*100:.0f}% "
              f"(paper {'12' if label=='10KB' else '45'}%)")
    save_json("fig5_latency_cdf.json", out)
    return out


#: benchmarks.run auto-discovery
HARNESS = {"name": "fig5", "full": main, "smoke": lambda: run(20)}

if __name__ == "__main__":
    main()
