"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Three terms per cell, derived from the compiled 512/256-device programs
(results/dryrun.json, produced by repro.launch.dryrun):

    compute    = HLO_FLOPs_per_device / peak_FLOPs            (197 TF bf16)
    memory     = HLO_bytes_per_device / HBM_bw                (819 GB/s)
    collective = collective_bytes_per_device / ICI_link_bw    (~50 GB/s)

The max of the three lower-bounds the step time; whichever dominates is the
cell's bottleneck.  The QUALITY score is the model-roofline fraction:

    ideal time      = useful work / hardware peak
      train/prefill : 6 (resp. 2) * N_active * tokens / (chips * peak_FLOPs)
      decode        : (param + cache bytes)/chip / HBM_bw   (stream once)
    fraction        = ideal time / max(compute, memory, collective)

A fraction of 1.0 means the compiled program is exactly the useful work,
placed on its natural roofline.  Fractions < 1 decompose into "wasted"
compute/bytes (remat, padding, recompute) and collective exposure.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, Optional

from repro.configs import get_config
from repro.launch.input_specs import SHAPE_CELLS

from .common import load_json, save_json

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def _tokens_global(shape: str) -> int:
    cell = SHAPE_CELLS[shape]
    if cell["kind"] == "train" or cell["kind"] == "prefill":
        return cell["batch"] * cell["seq"]
    return cell["batch"]              # decode: one token per sequence


def _cache_bytes(cfg, shape: str) -> int:
    from repro.models import cache_shapes
    import numpy as np

    cell = SHAPE_CELLS[shape]
    total = 0
    for _k, (shp, _axes, dtype) in cache_shapes(cfg, cell["batch"], cell["seq"]).items():
        n = 1
        for d in shp:
            n *= d
        total += n * np.dtype(dtype).itemsize
    return total


def ideal_time_s(arch: str, shape: str, n_chips: int) -> float:
    cfg = get_config(arch)
    kind = SHAPE_CELLS[shape]["kind"]
    n_active = cfg.n_active_params()
    toks = _tokens_global(shape)
    if kind == "train":
        return 6.0 * n_active * toks / (n_chips * PEAK_FLOPS)
    if kind == "prefill":
        return 2.0 * n_active * toks / (n_chips * PEAK_FLOPS)
    # decode: stream params once (bf16) + the full cache once per step
    param_bytes = 2 * cfg.n_params()
    return (param_bytes + _cache_bytes(cfg, shape)) / n_chips / HBM_BW


def analyse_cell(key: str, rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("status") != "ok":
        return None
    arch, shape, mesh = key.split("|")
    n = rec["n_devices"]
    t_compute = rec["flops_per_device"] / PEAK_FLOPS
    t_memory = rec["bytes_per_device"] / HBM_BW
    coll = rec.get("collective_bytes_per_device", {})
    t_coll = sum(coll.values()) / ICI_BW
    bound = max(t_compute, t_memory, t_coll)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    cfg = get_config(arch)
    model_flops = (
        (6.0 if SHAPE_CELLS[shape]["kind"] == "train" else 2.0)
        * cfg.n_active_params() * _tokens_global(shape)
    )
    t_ideal = ideal_time_s(arch, shape, n)
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "kind": rec["kind"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bottleneck": bottleneck,
        "step_lower_bound_s": bound,
        "model_flops": model_flops,
        "flops_utilization": model_flops / (rec["flops_per_device"] * n)
        if rec["flops_per_device"] else 0.0,
        "roofline_fraction": t_ideal / bound if bound > 0 else 0.0,
        "peak_mem_GiB": rec["memory"]["peak_estimate_bytes"] / 2**30,
        "collective_bytes": coll,
    }


def run(dryrun_file: str = "dryrun.json", mesh: str = "single"):
    data = load_json(dryrun_file)
    rows = []
    for key, rec in sorted(data.items()):
        if not key.endswith(f"|{mesh}"):
            continue
        row = analyse_cell(key, rec)
        if row:
            rows.append(row)
    return rows


def print_table(rows, title):
    print(f"\n# Roofline — {title}")
    print(f"{'arch':>24} {'shape':>12} | {'compute':>9} {'memory':>9} "
          f"{'collect':>9} | {'bound':>10} | {'frac':>5}")
    for r in rows:
        mark = {"compute": "C", "memory": "M", "collective": "X"}[r["bottleneck"]]
        print(f"{r['arch']:>24} {r['shape']:>12} | "
              f"{r['t_compute_s']*1e3:8.1f}m {r['t_memory_s']*1e3:8.1f}m "
              f"{r['t_collective_s']*1e3:8.1f}m | {mark}:{r['step_lower_bound_s']*1e3:8.1f}m"
              f" | {r['roofline_fraction']:5.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="dryrun.json")
    args = ap.parse_args([]) if __name__ != "__main__" else ap.parse_args()
    rows = run(args.dryrun, "single")
    print_table(rows, "single pod (16x16), per-device terms")
    save_json("roofline.json", {"single": rows})
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:3]
    most_coll = sorted(rows, key=lambda r: r["t_collective_s"] /
                       max(1e-12, r["step_lower_bound_s"]))[-3:]
    print("\nworst roofline fractions:",
          [(r["arch"], r["shape"], round(r["roofline_fraction"], 3)) for r in worst])
    print("most collective-bound:",
          [(r["arch"], r["shape"]) for r in most_coll])
    return rows


if __name__ == "__main__":
    main()
