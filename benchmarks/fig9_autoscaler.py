"""Fig 9 (extension): autoscaler policy x offered load on the workflow engine.

The paper's control plane is compatibility-constrained to Knative's
autoscaler; this harness measures what the *pluggable* policy layer
(:mod:`repro.core.scheduler`) buys once scale-up strategy is selectable per
deployment:

* ``concurrency`` — the legacy reactive policy (bit-for-bit baseline): every
  arrival that finds no ready instance boots one.  Under a load spike this
  spawns one instance per arrival caught mid cold-start — at high offered
  load the fleet races straight to ``max_instances`` and the cold-start
  count explodes.
* ``rps`` — Knative's requests-per-second mode: the fleet is sized from the
  observed arrival-rate window (capacity prior: the registered service
  time), so a spike provisions the steady-state fleet instead.
* ``predictive`` — pre-warms from the arrival-rate *trend* extrapolated over
  the cold-start horizon.

Workflow: the fig8 driver --scatter(2)--> workers --> reducer DAG, open-loop
Poisson arrivals per (policy x offered load) cell; each row reports p50/p99
latency, cold starts, pre-warms, buffered/queued requests, and $ per 1k
requests (cold-start waits inflate billed duration, so the cold-start gap
shows up in the bill too).

``--smoke`` is the seconds-long CI subset with two hard gates at the top
load point:

* ``predictive`` never incurs MORE cold starts than ``concurrency`` (else
  pre-warming is mis-forecasting);
* ``rps`` or ``predictive`` actually differs from the legacy policy on
  cold-start count (else the policy layer is dead code).

Run:  PYTHONPATH=src python -m benchmarks.fig9_autoscaler [--smoke]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import LoadGenerator, ScalingPolicy, WorkflowEngine
from repro.core.scheduler import available_autoscalers

from .common import fmt_s, save_json

RESULT_NAME = "fig9_autoscaler.json"

POLICIES = ["concurrency", "rps", "predictive"]
OFFERED_RPS = [25.0, 100.0, 400.0]
DURATION_S = 20.0
SMOKE_OFFERED = [50.0, 400.0]
SMOKE_DURATION_S = 6.0
SEED = 7

FAN = 2
EDGE_FLOATS = 16
SERVICE_TIME = {"driver": 0.010, "worker": 0.030, "reducer": 0.015}
MAX_INSTANCES = 64


def build_engine(autoscaler: str, seed: int = SEED) -> WorkflowEngine:
    """The fig8 scatter/gather workflow under a selectable scale-up policy."""
    eng = WorkflowEngine(seed=seed, backend="xdt", records="columnar")

    def worker(ctx, ref):
        x = ctx.get(ref)
        return ctx.put(x * 2.0, n_retrievals=1)

    def reducer(ctx, refs):
        return float(sum(ctx.get(r).sum() for r in refs))

    def driver(ctx, i):
        refs = [
            ctx.put(np.full((EDGE_FLOATS,), float(i % 7), np.float32),
                    n_retrievals=1)
            for _ in range(FAN)
        ]
        handles = yield [ctx.call("worker", r) for r in refs]
        total = yield ctx.call("reducer", handles)
        return total

    for name, fn in (("worker", worker), ("reducer", reducer), ("driver", driver)):
        eng.register(
            name, fn,
            policy=ScalingPolicy(max_instances=MAX_INSTANCES,
                                 target_concurrency=1, autoscaler=autoscaler),
            service_time=SERVICE_TIME[name],
        )
    return eng


def run(policies=None, offered=None, duration_s=DURATION_S):
    policies = policies or POLICIES
    offered = offered or OFFERED_RPS
    rows = []
    for policy in policies:
        for rate in offered:
            eng = build_engine(policy)
            rep = LoadGenerator(eng, "driver").run_open(
                rate_rps=rate, duration_s=duration_s
            )
            row = rep.as_row()
            row["autoscaler"] = policy
            row["n_instances_final"] = sum(
                d.n_instances for d in eng.control.deployments.values()
            )
            rows.append(row)
    return {"rows": rows, "config": {
        "policies": policies, "offered_rps": offered, "duration_s": duration_s,
        "seed": SEED, "fan": FAN, "service_time": SERVICE_TIME,
        "max_instances": MAX_INSTANCES,
        "available_autoscalers": list(available_autoscalers()),
    }}


def check_policies_differ(out) -> None:
    """CI gates at the top load point (raises; must survive ``python -O``):
    predictive never cold-starts more than the legacy concurrency policy,
    and at least one rate-driven policy actually diverges from it."""
    top = max(out["config"]["offered_rps"])
    cold = {
        r["autoscaler"]: r["n_cold_starts"]
        for r in out["rows"] if r["offered_rps"] == top
    }
    if cold["predictive"] > cold["concurrency"]:
        raise RuntimeError(
            f"predictive incurred {cold['predictive']} cold starts > legacy "
            f"concurrency's {cold['concurrency']} at {top:.0f} rps — "
            f"pre-warming should never lose to reactive scale-up"
        )
    if cold["rps"] == cold["concurrency"] == cold["predictive"]:
        raise RuntimeError(
            f"all policies produced {cold['concurrency']} cold starts at "
            f"{top:.0f} rps — the policy layer changed nothing"
        )


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    out = run(
        offered=SMOKE_OFFERED if smoke else None,
        duration_s=SMOKE_DURATION_S if smoke else DURATION_S,
    )
    print("# Fig 9 — autoscaler policy x offered load: tail latency, cold "
          "starts, $/1k req")
    print(f"{'policy':>12} {'offered':>8} {'p50':>10} {'p99':>10} "
          f"{'cold':>6} {'prewarm':>8} {'queued':>7} {'$/1k':>10}")
    for r in out["rows"]:
        print(f"{r['autoscaler']:>12} {r['offered_rps']:>8.0f} "
              f"{fmt_s(r['p50_s']):>10} {fmt_s(r['p99_s']):>10} "
              f"{r['n_cold_starts']:>6} {r['n_prewarmed']:>8} "
              f"{r['n_queued']:>7} {r['usd_per_1k_requests']:>10.5f}")
    save_json(RESULT_NAME, out)      # artifact survives a gate trip
    check_policies_differ(out)
    top = max(out["config"]["offered_rps"])
    print(f"\nautoscaler gates at {top:.0f} rps: predictive <= concurrency "
          f"cold starts, rate-driven policies differ from legacy OK")
    return 0


#: benchmarks.run auto-discovery (smoke carries the autoscaler policy gates)
HARNESS = {"name": "fig9", "full": lambda: main([]), "smoke": lambda: main(["--smoke"])}

if __name__ == "__main__":
    sys.exit(main())
