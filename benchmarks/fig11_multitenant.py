"""Fig 11: trace-driven multi-tenant sweeps on the sharded simulator.

Sweeps tenant count x arrival shape over the deployment-sharded substrate:
every tenant is one :class:`~repro.core.shard.GroupSpec` (a private cell —
no shared media, no cross-tenant calls) registering the paper's three
workflow DAGs (VID / SET / MR, §6.5) with byte-scaled payloads, driven by a
synthetic Azure-Functions-shaped arrival trace
(:func:`~repro.core.loadgen.synthesize_trace`) replayed as batched
same-timestamp buckets.  :class:`~repro.core.shard.ShardRunner` advances the
tenant cells on epoch barriers and merges the columnar logs
deterministically, so the sweep's results are independent of the shard
count (pinned by ``tests/test_shard.py``).

Reported per sweep point:

* substrate throughput — wall-clock events/sec across all tenant cells;
* per-tenant $-per-1k-requests (mean/min/max) from each cell's exact
  accounting (one tenant per cell: no proportional splitting), priced per
  medium via :func:`~repro.core.cost.routed_workflow_cost`;
* the **attribution invariant**: per-tenant bills sum to the untenanted
  global bill (linearity of the fee structures — see
  :func:`~repro.core.cost.combine_cost_inputs`);
* per-tenant and global p99 latency.

Results go to ``results/BENCH_fig11_multitenant.json``.  The smoke section
carries the CI gates: >=1000 co-resident deployments, the attribution
invariant at fp tolerance, and <=30% events/sec regression vs the committed
baseline (the same convention as ``bench_engine``).

Run:  PYTHONPATH=src python -m benchmarks.fig11_multitenant [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import (
    FixedRoute,
    GroupSpec,
    ShardPlan,
    ShardRunner,
    StorageOps,
    TraceConfig,
    TraceReplayDriver,
    WorkflowCostInputs,
    combine_cost_inputs,
    routed_workflow_cost,
    synthesize_trace,
)
from repro.core.workloads import DAGS

from .common import RESULTS_DIR, save_json

RESULT_NAME = "BENCH_fig11_multitenant.json"

#: the three paper workloads every tenant deploys (8 deployments/tenant)
DAG_NAMES = ("vid", "set", "mr")
#: ephemeral edges ride one priced medium; MR's original input stays pinned
#: to S3 by the DAG itself, so runs are mixed-media and priced per medium
BACKEND = "s3"
#: down-scale moved arrays so the sweep times the substrate, not numpy
#: (routing still sees the DECLARED edge sizes)
BYTES_SCALE = 1e-6

REFERENCE = {
    "tenants": [24, 48, 96],
    "shapes": ["steady", "diurnal", "bursty"],
    "duration_s": 10.0,
    "base_rps": 0.5,                  # per tenant, spread over the 3 DAGs
    "seed": 2024,
    "n_shards": 4,
    "epoch_s": 2.0,
    # the reference sweep is big enough to amortize fork + pipe overhead;
    # results are merged columnar so worker count never changes the numbers
    "workers": "process",
}
#: one point, sized to cross the >=1000 co-resident deployments gate
#: (128 tenants x 8 deployments) with a mixed shape population
SMOKE = {
    "tenants": [128],
    "shapes": ["mixed"],
    "duration_s": 4.0,
    "base_rps": 0.35,
    "seed": 2024,
    "n_shards": 4,
    "epoch_s": 2.0,
}


def tenant_spec(tid: int, shape: str, cfg: dict) -> GroupSpec:
    """One tenant: a private cell deploying VID+SET+MR, driven by its trace.

    ``shape="mixed"`` cycles the population through the three arrival
    shapes; the golden-ratio phase de-synchronizes tenants' diurnal peaks.
    """
    name = f"tenant-{tid:04d}"
    tenant_shape = (
        TraceConfig.SHAPES[tid % len(TraceConfig.SHAPES)]
        if shape == "mixed" else shape
    )

    def build(engine, spec):
        entries = tuple(
            DAGS[dag].compile(
                target="engine", engine=engine,
                backend=FixedRoute(BACKEND), bytes_scale=BYTES_SCALE,
            ).entry
            for dag in DAG_NAMES
        )
        driver = TraceReplayDriver(engine, payload_fn=lambda nb: nb % 7)
        trace = synthesize_trace(
            np.random.default_rng(cfg["seed"] * 100_003 + tid),
            TraceConfig(
                duration_s=cfg["duration_s"],
                base_rps=cfg["base_rps"],
                shape=tenant_shape,
            ),
            phase=0.618034 * tid,
        )
        return lambda: driver.schedule(spec.name, entries, trace)

    return GroupSpec(name=name, build=build, seed=cfg["seed"] + tid)


def _tenant_accounting(cell):
    """Exact per-tenant cost inputs + per-medium ops from its cell result."""
    media_ops = {
        medium: StorageOps(
            n_puts=int(tot["n_puts"]),
            n_gets=int(tot["n_gets"]),
            gb_seconds=tot["gb_seconds"],
            peak_resident_gb=tot["peak_resident_gb"],
        )
        for medium, tot in cell.media.items()
    }
    inputs = WorkflowCostInputs(
        n_function_invocations=len(cell.invocation_ids),
        billed_duration_s=cell.billed_s,
        n_storage_puts=sum(o.n_puts for o in media_ops.values()),
        n_storage_gets=sum(o.n_gets for o in media_ops.values()),
        storage_gb_seconds=sum(o.gb_seconds for o in media_ops.values()),
        peak_resident_gb=sum(o.peak_resident_gb for o in media_ops.values()),
    )
    return inputs, media_ops


def run_point(n_tenants: int, shape: str, cfg: dict, quiet: bool = False):
    specs = [tenant_spec(tid, shape, cfg) for tid in range(n_tenants)]
    plan = ShardPlan.plan(specs, n_shards=cfg["n_shards"])
    runner = ShardRunner(
        plan, epoch_s=cfg["epoch_s"],
        workers=cfg.get("workers", "inline"),   # smoke/CI stays inline
    )
    t0 = time.perf_counter()
    run = runner.run(duration_s=cfg["duration_s"])
    wall = time.perf_counter() - t0

    # exact per-tenant attribution: one tenant per cell
    parts, per_tenant_usd, p99s = {}, [], []
    media_global: dict = {}
    for name, cell in sorted(run.per_cell.items()):
        if not len(cell.request_ids):
            continue
        inputs, media_ops = _tenant_accounting(cell)
        parts[name] = inputs
        bill = routed_workflow_cost(inputs, media_ops)
        per_tenant_usd.append(
            bill.total / len(cell.request_ids) * 1000.0
        )
        p99s.append(float(np.percentile(cell.latencies_s, 99)))
        for medium, ops in media_ops.items():
            agg = media_global.setdefault(
                medium, dict(n_puts=0, n_gets=0, gb_seconds=0.0,
                             peak_resident_gb=0.0)
            )
            agg["n_puts"] += ops.n_puts
            agg["n_gets"] += ops.n_gets
            agg["gb_seconds"] += ops.gb_seconds
            agg["peak_resident_gb"] += ops.peak_resident_gb

    # the attribution invariant: tenant bills sum to the untenanted bill
    combined = combine_cost_inputs(parts.values())
    global_bill = routed_workflow_cost(
        combined, {m: StorageOps(**a) for m, a in media_global.items()}
    )
    sum_tenant_usd = sum(
        routed_workflow_cost(*_tenant_accounting(cell)).total
        for cell in run.per_cell.values() if len(cell.request_ids)
    )
    gap = abs(sum_tenant_usd - global_bill.total) / max(
        global_bill.total, 1e-30
    )

    lat = np.asarray(run.request_log.latencies_s)
    row = {
        "n_tenants": n_tenants,
        "shape": shape,
        "n_deployments": run.n_deployments,
        "n_cells": run.n_cells,
        "n_shards": run.n_shards,
        "n_requests": len(run.request_log),
        "n_invocations": combined.n_function_invocations,
        "events": run.events_processed,
        "wall_s": wall,
        "events_per_sec": run.events_processed / wall,
        "p99_s": float(np.percentile(lat, 99)) if len(lat) else 0.0,
        "tenant_p99_s_max": max(p99s) if p99s else 0.0,
        "tenant_usd_per_1k": {
            "mean": float(np.mean(per_tenant_usd)),
            "min": float(np.min(per_tenant_usd)),
            "max": float(np.max(per_tenant_usd)),
        } if per_tenant_usd else None,
        "global_usd": global_bill.total,
        "sum_tenant_usd": sum_tenant_usd,
        "attribution_gap_rel": gap,
    }
    if not quiet:
        print(
            f"{n_tenants:>5} tenants x {shape:<8}  "
            f"{row['n_deployments']:>5d} deps  {row['n_requests']:>6d} req  "
            f"{row['events']:>8d} ev  {wall:6.2f}s wall  "
            f"{row['events_per_sec']:>9.0f} ev/s  "
            f"p99 {row['p99_s']*1e3:7.1f} ms  "
            f"${row['global_usd']:.4f} (gap {gap:.1e})"
        )
    return row


def run_sweep(cfg, quiet: bool = False):
    rows = [
        run_point(n, shape, cfg, quiet=quiet)
        for n in cfg["tenants"]
        for shape in cfg["shapes"]
    ]
    total_events = sum(r["events"] for r in rows)
    total_wall = sum(r["wall_s"] for r in rows)
    return {
        "rows": rows,
        "config": {**cfg, "backend": BACKEND, "dags": list(DAG_NAMES),
                   "bytes_scale": BYTES_SCALE},
        "totals": {
            "n_requests": sum(r["n_requests"] for r in rows),
            "events": total_events,
            "wall_s": total_wall,
            "events_per_sec": total_events / total_wall,
            "max_attribution_gap_rel": max(
                r["attribution_gap_rel"] for r in rows
            ),
            "max_n_deployments": max(r["n_deployments"] for r in rows),
        },
    }


def _load_existing():
    path = os.path.join(RESULTS_DIR, RESULT_NAME)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def _check(out, baseline_eps) -> int:
    """CI gates on the smoke section; returns a process exit code."""
    tot = out["smoke"]["totals"]
    failures = []
    if tot["max_n_deployments"] < 1000:
        failures.append(
            f"co-resident deployments {tot['max_n_deployments']} < 1000"
        )
    if tot["max_attribution_gap_rel"] > 1e-9:
        failures.append(
            "per-tenant bills do not sum to the global bill "
            f"(rel gap {tot['max_attribution_gap_rel']:.3e})"
        )
    if baseline_eps is None:
        print("# --check: no committed baseline; recorded this run")
    elif tot["events_per_sec"] < 0.7 * baseline_eps:
        failures.append(
            f"smoke {tot['events_per_sec']:.0f} ev/s < 70% of committed "
            f"baseline {baseline_eps:.0f} ev/s"
        )
    else:
        print(
            f"# --check ok: smoke {tot['events_per_sec']:.0f} ev/s vs "
            f"committed baseline {baseline_eps:.0f} ev/s"
        )
    for f in failures:
        print(f"# GATE FAILED: {f}")
    return 1 if failures else 0


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="single-point CI subset (>=1000 deployments); "
                        "preserves the committed reference section")
    p.add_argument("--check", action="store_true",
                   help="fail on gate violations (deployment floor, "
                        "attribution invariant, events/sec regression)")
    args = p.parse_args(sys.argv[1:] if argv is None else argv)

    existing = _load_existing()
    baseline_eps = (existing.get("smoke") or {}).get("totals", {}).get(
        "events_per_sec"
    )

    out = dict(existing)
    if args.smoke:
        print("# fig11 --smoke: 128 tenants, mixed arrival shapes")
        out["smoke"] = run_sweep(SMOKE)
    else:
        print("# fig11 reference sweep: tenant count x arrival shape")
        out["reference"] = run_sweep(REFERENCE)
        print("# smoke subset (CI baseline)")
        out["smoke"] = run_sweep(SMOKE)
    out["schema"] = 1

    tot = out["smoke"]["totals"] if args.smoke else out["reference"]["totals"]
    print(f"# totals: {tot['n_requests']} requests, "
          f"{tot['events_per_sec']:.0f} events/s, "
          f"max attribution gap {tot['max_attribution_gap_rel']:.2e}")
    path = save_json(RESULT_NAME, out)
    print(f"# wrote {path}")

    if args.check:
        return _check(out, baseline_eps)
    return 0


#: benchmarks.run auto-discovery (smoke carries the multi-tenant CI gates)
HARNESS = {
    "name": "fig11",
    "full": lambda: main([]),
    "smoke": lambda: main(["--smoke", "--check"]),
}

if __name__ == "__main__":
    sys.exit(main())
