"""Engine benchmark: virtual-time substrate throughput on a fig8-style sweep.

Measures the *simulation substrate itself* — event loop, control plane,
workflow engine, transfer bookkeeping — not the modeled cluster: wall-clock
events/sec and simulated-requests/sec over an open-loop Poisson sweep
(3 backends x 4 load points, >=100k total requests at reference scale), plus
peak RSS and fixed-seed per-request latency checksums so optimizations that
change semantics are caught immediately.

The workload is the fig8 DAG (driver --scatter(FAN)--> workers --> reducer,
one ephemeral object per edge) with small numpy payloads: large enough to
exercise put/get/ref minting on every edge, small enough that the substrate —
not array math — is what is being timed.

Results go to ``results/BENCH_engine.json`` and are tracked PR-over-PR:

* ``reference`` — the full sweep (the perf-trajectory point of record).
* ``smoke``     — a seconds-long subset for CI; CI fails when smoke
  events/sec regresses >30% against the committed baseline.

Run:  PYTHONPATH=src python -m benchmarks.bench_engine [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import resource
import sys
import time

import numpy as np

from repro.core import LoadGenerator, ScalingPolicy, WorkflowEngine

from .common import RESULTS_DIR, fmt_s, save_json

RESULT_NAME = "BENCH_engine.json"

BACKENDS = ["xdt", "s3", "elasticache"]

# Reference sweep: >=100k total requests, always below the max_instances cap
# so per-request latencies are a pure function of the substrate's semantics
# (and therefore comparable bit-for-bit across optimization PRs).
REFERENCE = {
    "offered_rps": [50.0, 100.0, 200.0, 400.0],
    "duration_s": 45.0,
    "seed": 1234,
}
SMOKE = {
    "offered_rps": [50.0, 200.0],
    "duration_s": 4.0,
    "seed": 1234,
}

FAN = 2
EDGE_FLOATS = 16                   # tiny payload: time the substrate, not numpy
SERVICE_TIME = {"driver": 0.010, "worker": 0.030, "reducer": 0.015}
POLICY = dict(max_instances=1024, target_concurrency=1)


def build_engine(backend: str, seed: int, records: str = "columnar") -> WorkflowEngine:
    # Explicit sweep-scale buffer budget: the registry's blocking flow
    # control is wall-clock and deadlocks a single-threaded virtual-time
    # sweep once ~256 requests are in flight.  Constructed explicitly so the
    # same workload also runs on the pre-optimization substrate (the
    # baseline measurement this benchmark is compared against).
    from repro.core import Simulator
    from repro.core.buffers import BufferRegistry
    from repro.core.clock import VirtualClock
    from repro.core.transfer import TransferEngine

    sim = Simulator(seed=seed)
    clock = VirtualClock(sim)
    try:
        registry = BufferRegistry(
            max_slots=1 << 20, max_bytes=1 << 40, clock=clock, threadsafe=False
        )
    except TypeError:               # pre-optimization registry: always locked
        registry = BufferRegistry(max_slots=1 << 20, max_bytes=1 << 40, clock=clock)
    transfer = TransferEngine(backend, registry=registry, clock=clock)
    try:
        eng = WorkflowEngine(transfer=transfer, simulator=sim, records=records)
    except TypeError:               # pre-optimization engine: objects only
        eng = WorkflowEngine(transfer=transfer, simulator=sim)

    def worker(ctx, ref):
        x = ctx.get(ref)
        return ctx.put(x * 2.0, n_retrievals=1)

    def reducer(ctx, refs):
        return float(sum(ctx.get(r).sum() for r in refs))

    def driver(ctx, i):
        refs = [
            ctx.put(np.full((EDGE_FLOATS,), float(i % 7), np.float32),
                    n_retrievals=1)
            for _ in range(FAN)
        ]
        handles = yield [ctx.call("worker", r) for r in refs]
        total = yield ctx.call("reducer", handles)
        return total

    for name, fn in (("worker", worker), ("reducer", reducer), ("driver", driver)):
        eng.register(name, fn, policy=ScalingPolicy(**POLICY),
                     service_time=SERVICE_TIME[name])
    return eng


def _count_events(sim):
    """Events processed by the loop; falls back to counting schedules on
    simulators that predate the ``events_processed`` counter."""
    n = getattr(sim, "events_processed", None)
    if n is not None:
        return int(n)
    return int(getattr(sim, "_bench_scheduled", 0))


def _instrument(sim):
    if hasattr(sim, "events_processed"):
        return
    sim._bench_scheduled = 0
    orig = sim.schedule

    def counting_schedule(delay, fn):
        sim._bench_scheduled += 1
        orig(delay, fn)

    sim.schedule = counting_schedule


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_sweep(cfg, quiet=False):
    rows = []
    total_events = total_reqs = 0
    total_wall = 0.0
    for backend in BACKENDS:
        for rate in cfg["offered_rps"]:
            eng = build_engine(backend, seed=cfg["seed"])
            _instrument(eng.sim)
            gen = LoadGenerator(eng, "driver")
            t0 = time.perf_counter()
            rep = gen.run_open(rate_rps=rate, duration_s=cfg["duration_s"])
            wall = time.perf_counter() - t0
            events = _count_events(eng.sim)
            lat = np.asarray(rep.latencies_s, dtype=np.float64)
            row = {
                "backend": backend,
                "offered_rps": rate,
                "n_requests": rep.n_requests,
                "n_ok": rep.n_ok,
                "p50_s": rep.p50_s,
                "p99_s": rep.p99_s,
                "wall_s": wall,
                "events": events,
                "events_per_sec": events / wall,
                "requests_per_sec_wall": rep.n_requests / wall,
                "latency_checksum": hashlib.sha256(lat.tobytes()).hexdigest()[:16],
                "latency_sum_s": float(lat.sum()),
            }
            rows.append(row)
            total_events += events
            total_reqs += rep.n_requests
            total_wall += wall
            if not quiet:
                print(f"{backend:>12} {rate:>7.0f} rps  {rep.n_requests:>7d} req  "
                      f"{fmt_s(rep.p50_s):>9} p50  {fmt_s(rep.p99_s):>9} p99  "
                      f"{wall:7.2f}s wall  {row['events_per_sec']:>10.0f} ev/s  "
                      f"{row['latency_checksum']}")
    return {
        "rows": rows,
        "config": {**cfg, "backends": BACKENDS, "fan": FAN,
                   "edge_floats": EDGE_FLOATS, "service_time": SERVICE_TIME,
                   "policy": POLICY},
        "totals": {
            "n_requests": total_reqs,
            "events": total_events,
            "wall_s": total_wall,
            "events_per_sec": total_events / total_wall,
            "requests_per_sec": total_reqs / total_wall,
            "peak_rss_mb": _peak_rss_mb(),
        },
    }


def _load_existing():
    path = os.path.join(RESULTS_DIR, RESULT_NAME)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="seconds-long CI subset; preserves the committed "
                        "reference section")
    p.add_argument("--check", action="store_true",
                   help="fail (exit 1) on >30%% smoke events/sec regression "
                        "vs the committed baseline")
    args = p.parse_args(sys.argv[1:] if argv is None else argv)

    existing = _load_existing()
    baseline_eps = (existing.get("smoke") or {}).get("totals", {}).get(
        "events_per_sec"
    )

    if args.smoke:
        print("# bench_engine --smoke: 3 backends x 2 load points")
        out = dict(existing)
        out["smoke"] = run_sweep(SMOKE)
    else:
        print("# bench_engine reference sweep: 3 backends x 4 load points")
        out = dict(existing)
        out["reference"] = run_sweep(REFERENCE)
        print("# smoke subset (CI baseline)")
        out["smoke"] = run_sweep(SMOKE)

    out["schema"] = 1
    tot = out["smoke"]["totals"] if args.smoke else out["reference"]["totals"]
    print(f"# totals: {tot['n_requests']} requests, "
          f"{tot['events_per_sec']:.0f} events/s, "
          f"{tot['requests_per_sec']:.0f} req/s, "
          f"peak RSS {tot['peak_rss_mb']:.0f} MB")
    path = save_json(RESULT_NAME, out)
    print(f"# wrote {path}")

    if args.check:
        fresh = out["smoke"]["totals"]["events_per_sec"]
        if baseline_eps is None:
            print("# --check: no committed baseline; recorded this run")
        elif fresh < 0.7 * baseline_eps:
            print(f"# REGRESSION: smoke {fresh:.0f} ev/s < 70% of committed "
                  f"baseline {baseline_eps:.0f} ev/s")
            return 1
        else:
            print(f"# --check ok: smoke {fresh:.0f} ev/s vs committed "
                  f"baseline {baseline_eps:.0f} ev/s")
    return 0


#: benchmarks.run auto-discovery (smoke carries the events/sec regression gate)
HARNESS = {
    "name": "bench",
    "full": lambda: main([]),
    "smoke": lambda: main(["--smoke", "--check"]),
}

if __name__ == "__main__":
    sys.exit(main())
