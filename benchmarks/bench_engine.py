"""Engine benchmark: virtual-time substrate throughput on a fig8-style sweep.

Measures the *simulation substrate itself* — event loop, control plane,
workflow engine, transfer bookkeeping — not the modeled cluster: wall-clock
events/sec and simulated-requests/sec over an open-loop Poisson sweep
(3 backends x 4 load points, >=100k total requests at reference scale), plus
peak RSS and fixed-seed per-request latency checksums so optimizations that
change semantics are caught immediately.

The workload is the fig8 DAG (driver --scatter(FAN)--> workers --> reducer,
one ephemeral object per edge) with small numpy payloads: large enough to
exercise put/get/ref minting on every edge, small enough that the substrate —
not array math — is what is being timed.

Results go to ``results/BENCH_engine.json`` and are tracked PR-over-PR:

* ``reference`` — the full sweep (the perf-trajectory point of record).
* ``smoke``     — a seconds-long subset for CI; CI fails when smoke
  events/sec regresses >30% against the committed baseline.

Run:  PYTHONPATH=src python -m benchmarks.bench_engine [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import resource
import sys
import time

import numpy as np

from repro.core import LoadGenerator, ScalingPolicy, WorkflowEngine

from .common import RESULTS_DIR, fmt_s, save_json

RESULT_NAME = "BENCH_engine.json"

BACKENDS = ["xdt", "s3", "elasticache"]

# Reference sweep: >=100k total requests, always below the max_instances cap
# so per-request latencies are a pure function of the substrate's semantics
# (and therefore comparable bit-for-bit across optimization PRs).
REFERENCE = {
    "offered_rps": [50.0, 100.0, 200.0, 400.0],
    "duration_s": 45.0,
    "seed": 1234,
}
SMOKE = {
    "offered_rps": [50.0, 200.0],
    "duration_s": 4.0,
    "seed": 1234,
}

FAN = 2
EDGE_FLOATS = 16                   # tiny payload: time the substrate, not numpy
SERVICE_TIME = {"driver": 0.010, "worker": 0.030, "reducer": 0.015}
POLICY = dict(max_instances=1024, target_concurrency=1)

# Streaming-heavy scenario: one streamed edge, many chunks per request, so
# chunk publish/drain — not orchestration — dominates the event hot path.
# Every (backend, rate) cell runs twice, with the chunk-span fast path on
# (STREAM_COALESCE=True) and off (the pre-coalescing per-chunk behavior);
# per-request latency checksums must match bit-for-bit between the two and
# the fast path must clear SPEEDUP_GATE on coalesced/legacy events-per-sec.
STREAM_NBYTES = 32 << 20           # 128 chunks per request at 256 KiB
STREAM_CHUNK = 256 << 10
STREAM_SCALE = 1.0 / 1024.0        # 256 KiB chunk -> 64-float array
STREAM_BACKENDS = ["xdt", "s3"]    # fused single-owner + fused service kernels
REFERENCE_STREAM = {"offered_rps": [20.0, 50.0], "duration_s": 20.0, "seed": 1234}
SMOKE_STREAM = {"offered_rps": [20.0], "duration_s": 3.0, "seed": 1234}
SPEEDUP_GATE = {"reference": 2.0, "smoke": 1.4}


def build_engine(backend: str, seed: int, records: str = "columnar") -> WorkflowEngine:
    # Explicit sweep-scale buffer budget: the registry's blocking flow
    # control is wall-clock and deadlocks a single-threaded virtual-time
    # sweep once ~256 requests are in flight.  Constructed explicitly so the
    # same workload also runs on the pre-optimization substrate (the
    # baseline measurement this benchmark is compared against).
    from repro.core import Simulator
    from repro.core.buffers import BufferRegistry
    from repro.core.clock import VirtualClock
    from repro.core.transfer import TransferEngine

    sim = Simulator(seed=seed)
    clock = VirtualClock(sim)
    try:
        registry = BufferRegistry(
            max_slots=1 << 20, max_bytes=1 << 40, clock=clock, threadsafe=False
        )
    except TypeError:               # pre-optimization registry: always locked
        registry = BufferRegistry(max_slots=1 << 20, max_bytes=1 << 40, clock=clock)
    transfer = TransferEngine(backend, registry=registry, clock=clock)
    try:
        eng = WorkflowEngine(transfer=transfer, simulator=sim, records=records)
    except TypeError:               # pre-optimization engine: objects only
        eng = WorkflowEngine(transfer=transfer, simulator=sim)

    def worker(ctx, ref):
        x = ctx.get(ref)
        return ctx.put(x * 2.0, n_retrievals=1)

    def reducer(ctx, refs):
        return float(sum(ctx.get(r).sum() for r in refs))

    def driver(ctx, i):
        refs = [
            ctx.put(np.full((EDGE_FLOATS,), float(i % 7), np.float32),
                    n_retrievals=1)
            for _ in range(FAN)
        ]
        handles = yield [ctx.call("worker", r) for r in refs]
        total = yield ctx.call("reducer", handles)
        return total

    for name, fn in (("worker", worker), ("reducer", reducer), ("driver", driver)):
        eng.register(name, fn, policy=ScalingPolicy(**POLICY),
                     service_time=SERVICE_TIME[name])
    return eng


def _count_events(sim):
    """Events processed by the loop; falls back to counting schedules on
    simulators that predate the ``events_processed`` counter."""
    n = getattr(sim, "events_processed", None)
    if n is not None:
        return int(n)
    return int(getattr(sim, "_bench_scheduled", 0))


def _instrument(sim):
    if hasattr(sim, "events_processed"):
        return
    sim._bench_scheduled = 0
    orig = sim.schedule

    def counting_schedule(delay, fn):
        sim._bench_scheduled += 1
        orig(delay, fn)

    sim.schedule = counting_schedule


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_sweep(cfg, quiet=False):
    rows = []
    total_events = total_reqs = 0
    total_wall = 0.0
    for backend in BACKENDS:
        for rate in cfg["offered_rps"]:
            eng = build_engine(backend, seed=cfg["seed"])
            _instrument(eng.sim)
            gen = LoadGenerator(eng, "driver")
            t0 = time.perf_counter()
            rep = gen.run_open(rate_rps=rate, duration_s=cfg["duration_s"])
            wall = time.perf_counter() - t0
            events = _count_events(eng.sim)
            lat = np.asarray(rep.latencies_s, dtype=np.float64)
            row = {
                "backend": backend,
                "offered_rps": rate,
                "n_requests": rep.n_requests,
                "n_ok": rep.n_ok,
                "p50_s": rep.p50_s,
                "p99_s": rep.p99_s,
                "wall_s": wall,
                "events": events,
                "events_per_sec": events / wall,
                "requests_per_sec_wall": rep.n_requests / wall,
                "latency_checksum": hashlib.sha256(lat.tobytes()).hexdigest()[:16],
                "latency_sum_s": float(lat.sum()),
            }
            rows.append(row)
            total_events += events
            total_reqs += rep.n_requests
            total_wall += wall
            if not quiet:
                print(f"{backend:>12} {rate:>7.0f} rps  {rep.n_requests:>7d} req  "
                      f"{fmt_s(rep.p50_s):>9} p50  {fmt_s(rep.p99_s):>9} p99  "
                      f"{wall:7.2f}s wall  {row['events_per_sec']:>10.0f} ev/s  "
                      f"{row['latency_checksum']}")
    return {
        "rows": rows,
        "config": {**cfg, "backends": BACKENDS, "fan": FAN,
                   "edge_floats": EDGE_FLOATS, "service_time": SERVICE_TIME,
                   "policy": POLICY},
        "totals": {
            "n_requests": total_reqs,
            "events": total_events,
            "wall_s": total_wall,
            "events_per_sec": total_events / total_wall,
            "requests_per_sec": total_reqs / total_wall,
            "peak_rss_mb": _peak_rss_mb(),
        },
    }


def build_streaming_engine(backend: str, seed: int):
    """One streamed edge (src -> sink), bound to a fresh engine."""
    from repro.core import Simulator
    from repro.core.buffers import BufferRegistry
    from repro.core.clock import VirtualClock
    from repro.core.dag import Edge, FixedRoute, Stage, WorkflowDAG
    from repro.core.transfer import TransferEngine

    sim = Simulator(seed=seed)
    clock = VirtualClock(sim)
    registry = BufferRegistry(
        max_slots=1 << 20, max_bytes=1 << 40, clock=clock, threadsafe=False
    )
    transfer = TransferEngine(backend, registry=registry, clock=clock)
    eng = WorkflowEngine(transfer=transfer, simulator=sim, records="columnar")
    dag = WorkflowDAG(
        "stream",
        # compute_s=0 producer: the whole object publishes at one virtual
        # instant — the same-timestamp chunk runs the span kernels coalesce
        [Stage("src", compute_s=0.0), Stage("sink", compute_s=0.005)],
        [Edge("src", "sink", STREAM_NBYTES, label="feed", handoff="sync",
              streaming=True, chunk_bytes=STREAM_CHUNK)],
    )
    binding = dag.compile(
        target="engine", engine=eng, backend=FixedRoute(backend),
        bytes_scale=STREAM_SCALE,
    )
    return eng, binding


def run_stream_sweep(cfg, gate: float, quiet=False):
    from repro.core import dag as dagmod

    rows = []
    totals = {"legacy": [0, 0.0], "coalesced": [0, 0.0]}  # events, wall
    for backend in STREAM_BACKENDS:
        for rate in cfg["offered_rps"]:
            per_mode = {}
            for mode in ("legacy", "coalesced"):
                prev = dagmod.STREAM_COALESCE
                dagmod.STREAM_COALESCE = mode == "coalesced"
                try:
                    eng, binding = build_streaming_engine(backend, cfg["seed"])
                    gen = LoadGenerator(eng, binding.entry)
                    t0 = time.perf_counter()
                    rep = gen.run_open(
                        rate_rps=rate, duration_s=cfg["duration_s"]
                    )
                    wall = time.perf_counter() - t0
                finally:
                    dagmod.STREAM_COALESCE = prev
                events = _count_events(eng.sim)
                lat = np.asarray(rep.latencies_s, dtype=np.float64)
                per_mode[mode] = {
                    "n_requests": rep.n_requests,
                    "n_ok": rep.n_ok,
                    "p50_s": rep.p50_s,
                    "events": events,
                    "wall_s": wall,
                    "events_per_sec": events / wall,
                    "latency_checksum": hashlib.sha256(
                        lat.tobytes()
                    ).hexdigest()[:16],
                    "peak_inflight_chunk_bytes": float(
                        eng.transfer.stats.peak_inflight_chunk_bytes
                    ),
                }
                totals[mode][0] += events
                totals[mode][1] += wall
            row = {
                "backend": backend,
                "offered_rps": rate,
                "legacy": per_mode["legacy"],
                "coalesced": per_mode["coalesced"],
                "speedup": (per_mode["coalesced"]["events_per_sec"]
                            / per_mode["legacy"]["events_per_sec"]),
                "bit_identical": (per_mode["coalesced"]["latency_checksum"]
                                  == per_mode["legacy"]["latency_checksum"]),
            }
            rows.append(row)
            if not quiet:
                tick = "==" if row["bit_identical"] else "!="
                print(f"{backend:>12} {rate:>5.0f} rps  "
                      f"{per_mode['legacy']['events_per_sec']:>9.0f} ev/s legacy  "
                      f"{per_mode['coalesced']['events_per_sec']:>9.0f} ev/s coalesced  "
                      f"x{row['speedup']:.2f}  checksums {tick}")
    speedup = (totals["coalesced"][0] / totals["coalesced"][1]) / (
        totals["legacy"][0] / totals["legacy"][1]
    )
    return {
        "rows": rows,
        "config": {**cfg, "backends": STREAM_BACKENDS,
                   "nbytes": STREAM_NBYTES, "chunk_bytes": STREAM_CHUNK,
                   "bytes_scale": STREAM_SCALE},
        "totals": {
            "events_per_sec_legacy": totals["legacy"][0] / totals["legacy"][1],
            "events_per_sec_coalesced": (
                totals["coalesced"][0] / totals["coalesced"][1]
            ),
            "speedup": speedup,
            "speedup_gate": gate,
            "bit_identical": all(r["bit_identical"] for r in rows),
        },
    }


def _check_streaming(section) -> int:
    tot = section["totals"]
    rc = 0
    if not tot["bit_identical"]:
        bad = [f"{r['backend']}@{r['offered_rps']:.0f}"
               for r in section["rows"] if not r["bit_identical"]]
        print(f"# STREAMING: latency checksums diverge between coalesced "
              f"and legacy modes: {bad}")
        rc = 1
    if tot["speedup"] < tot["speedup_gate"]:
        print(f"# STREAMING REGRESSION: coalesced/legacy events/sec "
              f"x{tot['speedup']:.2f} < gate x{tot['speedup_gate']:.2f}")
        rc = 1
    if rc == 0:
        print(f"# streaming ok: x{tot['speedup']:.2f} coalesced/legacy "
              f"(gate x{tot['speedup_gate']:.2f}), checksums bit-identical")
    return rc


def _load_existing():
    path = os.path.join(RESULTS_DIR, RESULT_NAME)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="seconds-long CI subset; preserves the committed "
                        "reference section")
    p.add_argument("--check", action="store_true",
                   help="fail (exit 1) on >30%% smoke events/sec regression "
                        "vs the committed baseline")
    args = p.parse_args(sys.argv[1:] if argv is None else argv)

    existing = _load_existing()
    baseline_eps = (existing.get("smoke") or {}).get("totals", {}).get(
        "events_per_sec"
    )

    if args.smoke:
        print("# bench_engine --smoke: 3 backends x 2 load points")
        out = dict(existing)
        out["smoke"] = run_sweep(SMOKE)
        print("# streaming smoke: coalesced vs legacy chunk path")
        out["streaming_smoke"] = run_stream_sweep(
            SMOKE_STREAM, SPEEDUP_GATE["smoke"]
        )
    else:
        print("# bench_engine reference sweep: 3 backends x 4 load points")
        out = dict(existing)
        out["reference"] = run_sweep(REFERENCE)
        print("# smoke subset (CI baseline)")
        out["smoke"] = run_sweep(SMOKE)
        print("# streaming scenario: coalesced vs legacy chunk path")
        out["streaming"] = run_stream_sweep(
            REFERENCE_STREAM, SPEEDUP_GATE["reference"]
        )
        print("# streaming smoke subset (CI baseline)")
        out["streaming_smoke"] = run_stream_sweep(
            SMOKE_STREAM, SPEEDUP_GATE["smoke"]
        )

    out["schema"] = 2
    tot = out["smoke"]["totals"] if args.smoke else out["reference"]["totals"]
    print(f"# totals: {tot['n_requests']} requests, "
          f"{tot['events_per_sec']:.0f} events/s, "
          f"{tot['requests_per_sec']:.0f} req/s, "
          f"peak RSS {tot['peak_rss_mb']:.0f} MB")
    path = save_json(RESULT_NAME, out)
    print(f"# wrote {path}")

    if args.check:
        rc = 0
        fresh = out["smoke"]["totals"]["events_per_sec"]
        if baseline_eps is None:
            print("# --check: no committed baseline; recorded this run")
        elif fresh < 0.7 * baseline_eps:
            print(f"# REGRESSION: smoke {fresh:.0f} ev/s < 70% of committed "
                  f"baseline {baseline_eps:.0f} ev/s")
            rc = 1
        else:
            print(f"# --check ok: smoke {fresh:.0f} ev/s vs committed "
                  f"baseline {baseline_eps:.0f} ev/s")
        section = out.get("streaming") if not args.smoke else None
        rc |= _check_streaming(section or out["streaming_smoke"])
        return rc
    return 0


#: benchmarks.run auto-discovery (smoke carries the events/sec regression gate)
HARNESS = {
    "name": "bench",
    "full": lambda: main([]),
    "smoke": lambda: main(["--smoke", "--check"]),
}

if __name__ == "__main__":
    sys.exit(main())
