"""Paper Fig. 2: single 1-1 transfer latency + effective bandwidth vs size,
for inline / S3 / ElastiCache on the Lambda testbed constants.

Paper anchors: at 100 KB, inline beats S3 by 8.1x and EC by 1.3x; inline is
capped at 6 MB.
"""
from __future__ import annotations

import numpy as np

from repro.core import measure_pattern
from repro.core.cluster import LAMBDA_NET
from repro.core.errors import InlineTooLarge

from .common import fmt_s, save_json

SIZES = [1 << 10, 10 << 10, 100 << 10, 1 << 20, 6 << 20, 10 << 20, 100 << 20]
BACKENDS = ["inline", "s3", "elasticache", "xdt"]


def run(n_seeds: int = 10):
    rows = []
    for nbytes in SIZES:
        row = {"bytes": nbytes}
        for b in BACKENDS:
            try:
                ts = [
                    measure_pattern("1-1", b, nbytes, net=LAMBDA_NET, seed=s)[0]
                    for s in range(n_seeds)
                ]
                lat = float(np.mean(ts))
                row[b] = {"latency_s": lat, "bw_Bps": nbytes / lat}
            except InlineTooLarge:
                row[b] = {"latency_s": None, "bw_Bps": None, "capped": True}
        rows.append(row)

    anchors = {}
    at100k = next(r for r in rows if r["bytes"] == 100 << 10)
    anchors["inline_vs_s3_100KB"] = at100k["s3"]["latency_s"] / at100k["inline"]["latency_s"]
    anchors["inline_vs_ec_100KB"] = (
        at100k["elasticache"]["latency_s"] / at100k["inline"]["latency_s"]
    )
    return {"rows": rows, "anchors": anchors}


def main():
    out = run()
    print("# Fig 2 — single transfer: latency / effective BW vs size (Lambda)")
    print(f"{'size':>8} | " + " | ".join(f"{b:>22}" for b in BACKENDS))
    for r in out["rows"]:
        cells = []
        for b in BACKENDS:
            d = r[b]
            if d.get("capped"):
                cells.append(f"{'> 6MB cap':>22}")
            else:
                cells.append(f"{fmt_s(d['latency_s']):>9} {d['bw_Bps']*8/1e9:6.2f}Gb/s")
        print(f"{r['bytes']:>8} | " + " | ".join(cells))
    a = out["anchors"]
    print(f"\nanchors: inline vs S3 @100KB = {a['inline_vs_s3_100KB']:.1f}x "
          f"(paper 8.1x); inline vs EC = {a['inline_vs_ec_100KB']:.2f}x (paper 1.3x)")
    save_json("fig2_single_transfer.json", out)
    return out


#: benchmarks.run auto-discovery (fig2 is already seconds-long)
HARNESS = {"name": "fig2", "full": main, "smoke": main}

if __name__ == "__main__":
    main()
