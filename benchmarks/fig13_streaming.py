"""Fig 13 (extension): streaming edges — overlap transfer with compute.

The paper's XDT already eliminates the intermediate storage *hop*; streaming
edges (``Edge(streaming=True, chunk_bytes=...)``) eliminate the storage
*wait*: the producer publishes fixed-size chunks while still computing, the
consumer is data-triggered (steered on the first chunk, pulling as chunks
land) and only the tail that outlives the producer's compute is ever waited
on.  This harness sweeps **chunk size x workload x backend** on both
lowerings and reports how close streaming gets to
:func:`repro.core.dag.critical_path_lower_bound` — the makespan with
*perfect* overlap, which no chunking can beat.

Sections:

* **cluster** — ``dag.compile(target="cluster")`` (analytic overlap model)
  over VID / MR / SET x {s3, elasticache, xdt, hybrid} x chunk sizes, each
  cell vs the store-then-fetch baseline and the bound.
* **engine** — ``dag.compile(target="engine")`` on the event-driven engine
  (real virtual-clock chunk events, per-chunk route resolution) over
  VID / MR, same axes.

How the bound is computed: per stage, ``start + max(producer compute,
marginal transfer) + fixed overhead`` along the critical path — data must
be both produced and moved, so the best possible overlap hides the smaller
of the two (see ``critical_path_lower_bound``'s docstring for the
recurrence).  ``ratio`` columns are ``makespan / bound``; 1.0 is perfect.

``--check`` carries the CI gates (raise, not assert — they must survive
``python -O``):

* **never slower** — streaming makespan <= the store-then-fetch baseline on
  EVERY cell, both lowerings.  Chunking must never lose: the modeled finish
  clamps to the batch equivalent, and the engine's chunk protocol prices
  continuation chunks as ranged reads of one open object.
* **never costlier** — streaming cost <= the *same route decisions
  unchunked*: total cost on the cluster lowering; the storage bill on the
  engine lowering, where per-chunk requests must coalesce to the
  whole-object bill (one PUT + one ranged GET per object x medium) while
  the *compute* bill legitimately moves — a data-triggered consumer is
  billed while it waits for chunks (vSwarm semantics), which early
  activation trades against makespan.  On fixed backends the comparison IS
  the baseline cell; under the hybrid policy it re-runs with inlining
  disabled, because streaming refuses ``inline`` (chunks outlive the sync
  message) while the unchunked object may ride it — a route divergence,
  not a chunking cost.
* **bound approach** — on every workload x backend, at least one chunk size
  lands within ``BOUND_RATIO_MAX`` (1.25x) of the lower bound.

Results go to ``results/fig13_streaming.json``.

Run:  PYTHONPATH=src python -m benchmarks.fig13_streaming [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

from repro.core import SizeRoute, WorkflowDAG, WorkflowEngine
from repro.core.dag import (
    FixedRoute,
    critical_path_lower_bound,
)
from repro.core.workloads import DAGS, HYBRID_ROUTE

from .common import fmt_s, save_json

RESULT_NAME = "fig13_streaming.json"

#: which edges stream, per workload: every intermediate edge except MR's
#: pinned-S3 original input (external edges have no producer to stream from)
STREAM_EDGES = {
    "vid": ("fragment", "frames"),
    "mr": ("shuffle",),
    "set": ("dataset", "models"),
}
#: chunk-size axis (full sweep); --smoke drops the last entry
CHUNK_SIZES = (1 << 20, 4 << 20, 8 << 20)
SMOKE_CHUNK_SIZES = CHUNK_SIZES[:2]
#: backend axis: the paper's three fixed backends + the per-edge SizeRoute
BACKENDS = ("s3", "elasticache", "xdt", "hybrid")
#: bound-approach gate: best chunk size within 1.25x of the lower bound
BOUND_RATIO_MAX = 1.25
#: the engine lowering skips SET (gather-heavy, covered by the cluster
#: section) to keep the smoke seconds-long
ENGINE_WORKLOADS = ("vid", "mr")
_TOL = 1 + 1e-9
#: auto-vs-best-fixed comparisons absorb residency/recurrence ulps: auto may
#: pick a candidate outside the committed grid whose float path differs
_AUTO_TOL = 1 + 1e-6
#: the auto COST comparison allows 0.1% (the fig12 durable-premium
#: precedent): the tuner optimizes the makespan recurrence, and a faster
#: split publishes storage chunks EARLIER, so residency GB-seconds drift a
#: hair above the post-hoc cheapest fixed size (set/s3: 4.6% latency win
#: for +0.09% residency).  Request fees coalesce identically either way;
#: real cost regressions (a per-chunk billing bug) overshoot 0.1% by
#: orders of magnitude
_AUTO_COST_TOL = 1.001
#: backpressure cell: chunk size + credit window whose product bounds the
#: producer's in-flight bytes (gated below)
BP_CHUNK = 4 << 20
BP_WINDOW = 2


def streaming_variant(
    dag: WorkflowDAG, chunk_bytes, max_inflight: int = 0
) -> WorkflowDAG:
    """``dag`` with its STREAM_EDGES chunked at ``chunk_bytes`` (an int or
    ``"auto"``), optionally credit-bounded at ``max_inflight`` chunks."""
    edges = [
        dataclasses.replace(e, streaming=True, chunk_bytes=chunk_bytes,
                            max_inflight_chunks=max_inflight)
        if e.label in STREAM_EDGES[dag.name] else e
        for e in dag.edges
    ]
    return WorkflowDAG(dag.name, dag.stages, edges)


def _resolve(backend: str):
    return HYBRID_ROUTE if backend == "hybrid" else backend


# -- cluster lowering --------------------------------------------------------


def run_cluster(chunk_sizes, quiet: bool = False):
    out = {}
    for name, dag in DAGS.items():
        rows = {}
        for backend in BACKENDS:
            route = _resolve(backend)
            base = dag.compile(target="cluster", backend=route).run(
                seed=0, deterministic=True)
            bound = critical_path_lower_bound(dag, backend=route)
            cells = {}
            for cb in chunk_sizes:
                run = streaming_variant(dag, cb).compile(
                    target="cluster", backend=route,
                ).run(seed=0, deterministic=True)
                cells[str(cb)] = {
                    "latency_s": run.latency_s,
                    "total_uUSD": run.cost().total * 1e6,
                    "ratio_vs_bound": run.latency_s / bound,
                    "speedup_vs_base": base.latency_s / run.latency_s,
                }
            auto_run = streaming_variant(dag, "auto").compile(
                target="cluster", backend=route,
            ).run(seed=0, deterministic=True)
            bp_run = streaming_variant(
                dag, BP_CHUNK, max_inflight=BP_WINDOW,
            ).compile(target="cluster", backend=route).run(
                seed=0, deterministic=True)
            rows[backend] = {
                "base_latency_s": base.latency_s,
                "base_total_uUSD": base.cost().total * 1e6,
                "bound_s": bound,
                "base_ratio_vs_bound": base.latency_s / bound,
                "cells": cells,
                "auto": {
                    "latency_s": auto_run.latency_s,
                    "total_uUSD": auto_run.cost().total * 1e6,
                    "ratio_vs_bound": auto_run.latency_s / bound,
                },
                "backpressure": {
                    "latency_s": bp_run.latency_s,
                    "total_uUSD": bp_run.cost().total * 1e6,
                    "window": BP_WINDOW,
                    "chunk_bytes": BP_CHUNK,
                    "peak_inflight_chunk_bytes": {
                        label: bp_run.edge_usage[label]
                        .peak_inflight_chunk_bytes
                        for label in STREAM_EDGES[name]
                    },
                },
            }
            if not quiet:
                best = min(cells.values(), key=lambda c: c["latency_s"])
                print(
                    f"  {name:4s} {backend:12s} base {fmt_s(base.latency_s):>9}"
                    f" (ratio {base.latency_s / bound:5.3f}) -> best stream "
                    f"{fmt_s(best['latency_s']):>9} "
                    f"(ratio {best['ratio_vs_bound']:5.3f}, "
                    f"{best['speedup_vs_base']:4.2f}x)  "
                    f"bound {fmt_s(bound):>9}"
                )
        out[name] = rows
    return out


# -- engine lowering ---------------------------------------------------------


def _engine_cell(dag: WorkflowDAG, route):
    """One single-request run on the event-driven engine."""
    eng = WorkflowEngine(backend="xdt")
    binding = dag.compile(target="engine", engine=eng, backend=route)
    eng.submit(binding.entry, 1.0)
    eng.drain()
    req = eng.requests[0]
    if req.status != "ok":
        raise RuntimeError(f"{dag.name}: request ended {req.status!r}")
    usage = binding.edge_usage.values()
    cost = binding.cost()
    return {
        "latency_s": req.latency_s,
        "total_uUSD": cost.total * 1e6,
        "storage_uUSD": cost.storage * 1e6,
        "compute_uUSD": cost.compute * 1e6,
        "n_puts": sum(u.n_puts for u in usage),
        "n_gets": sum(u.n_gets for u in usage),
        "peak_inflight_chunk_bytes": float(
            eng.transfer.stats.peak_inflight_chunk_bytes
        ),
    }


def run_engine(chunk_sizes, quiet: bool = False):
    out = {}
    for name in ENGINE_WORKLOADS:
        dag = DAGS[name]
        rows = {}
        for backend in BACKENDS:
            route = _resolve(backend)
            base = _engine_cell(dag, route)
            # the "same route decisions unchunked" cost baseline: streaming
            # refuses inline, so under hybrid the fair cost comparison is an
            # unchunked run with inlining off (fixed backends never inline
            # these staged/sync bulk edges — the baseline IS that run)
            cost_base = (
                _engine_cell(dag, SizeRoute(inline_under=0))
                if backend == "hybrid" else base
            )
            cells = {}
            for cb in chunk_sizes:
                cells[str(cb)] = _engine_cell(streaming_variant(dag, cb), route)
            auto_cell = _engine_cell(streaming_variant(dag, "auto"), route)
            bp_cell = _engine_cell(
                streaming_variant(dag, BP_CHUNK, max_inflight=BP_WINDOW),
                route,
            )
            # the engine's transfer-level peak is global across edges: each
            # producer INSTANCE holds <= window chunks, so the provable
            # bound is window * chunk_bytes * sum(producer fan) over the
            # workload's streamed edges
            bp_cell["peak_bound_bytes"] = BP_WINDOW * BP_CHUNK * sum(
                dag.by_name[e.src].fan
                for e in dag.edges if e.label in STREAM_EDGES[name]
            )
            rows[backend] = {
                "base": base,
                "cost_base_storage_uUSD": cost_base["storage_uUSD"],
                "cells": cells,
                "auto": auto_cell,
                "backpressure": bp_cell,
            }
            if not quiet:
                best = min(cells.values(), key=lambda c: c["latency_s"])
                print(
                    f"  {name:4s} {backend:12s} "
                    f"base {fmt_s(base['latency_s']):>9} -> best stream "
                    f"{fmt_s(best['latency_s']):>9} "
                    f"({base['latency_s'] / best['latency_s']:4.2f}x)  "
                    f"storage {cost_base['storage_uUSD']:9.2f} -> "
                    f"{best['storage_uUSD']:9.2f}uUSD  "
                    f"compute {base['compute_uUSD']:8.2f} -> "
                    f"{best['compute_uUSD']:8.2f}uUSD"
                )
        out[name] = rows
    return out


# -- gates -------------------------------------------------------------------


def check_gates(out) -> None:
    """CI gates; raises RuntimeError on any violation."""
    for name, rows in out["cluster"].items():
        for backend, row in rows.items():
            ratios = []
            for cb, cell in row["cells"].items():
                if cell["latency_s"] > row["base_latency_s"] * _TOL:
                    raise RuntimeError(
                        f"cluster {name}/{backend}/{cb}: streaming "
                        f"{cell['latency_s']:.4f}s > store-then-fetch "
                        f"{row['base_latency_s']:.4f}s — chunking must "
                        "never lose on makespan"
                    )
                if cell["total_uUSD"] > row["base_total_uUSD"] * _TOL:
                    raise RuntimeError(
                        f"cluster {name}/{backend}/{cb}: streaming costs "
                        f"{cell['total_uUSD']:.2f}uUSD > unchunked "
                        f"{row['base_total_uUSD']:.2f}uUSD on the same "
                        "route decisions"
                    )
                ratios.append(cell["ratio_vs_bound"])
            if min(ratios) > BOUND_RATIO_MAX:
                raise RuntimeError(
                    f"cluster {name}/{backend}: best streaming makespan is "
                    f"{min(ratios):.3f}x the critical-path lower bound "
                    f"(gate: <= {BOUND_RATIO_MAX}x at some chunk size)"
                )
            best_lat = min(c["latency_s"] for c in row["cells"].values())
            best_cost = min(c["total_uUSD"] for c in row["cells"].values())
            auto = row["auto"]
            if auto["latency_s"] > best_lat * _AUTO_TOL:
                raise RuntimeError(
                    f"cluster {name}/{backend}: auto chunk size "
                    f"{auto['latency_s']:.4f}s > best fixed {best_lat:.4f}s "
                    "— telemetry-tuned sizing must never lose on makespan"
                )
            if auto["total_uUSD"] > best_cost * _AUTO_COST_TOL:
                raise RuntimeError(
                    f"cluster {name}/{backend}: auto chunk size costs "
                    f"{auto['total_uUSD']:.2f}uUSD > best fixed "
                    f"{best_cost:.2f}uUSD (+0.1% residency tolerance)"
                )
            bp = row["backpressure"]
            cap = bp["window"] * bp["chunk_bytes"] * _TOL
            for label, peak in bp["peak_inflight_chunk_bytes"].items():
                if peak > cap:
                    raise RuntimeError(
                        f"cluster {name}/{backend}: edge {label!r} peak "
                        f"in-flight {peak:.0f}B > credit bound "
                        f"{bp['window']} x {bp['chunk_bytes']}B — "
                        "backpressure must bound sender memory"
                    )
    for name, rows in out["engine"].items():
        for backend, row in rows.items():
            for cb, cell in row["cells"].items():
                if cell["latency_s"] > row["base"]["latency_s"] * _TOL:
                    raise RuntimeError(
                        f"engine {name}/{backend}/{cb}: streaming "
                        f"{cell['latency_s']:.4f}s > store-then-fetch "
                        f"{row['base']['latency_s']:.4f}s"
                    )
                if cell["storage_uUSD"] > (
                    row["cost_base_storage_uUSD"] * _TOL
                ):
                    raise RuntimeError(
                        f"engine {name}/{backend}/{cb}: streaming storage "
                        f"bill {cell['storage_uUSD']:.2f}uUSD > same-route "
                        f"unchunked {row['cost_base_storage_uUSD']:.2f}uUSD "
                        "— per-chunk requests must coalesce to the "
                        "whole-object bill"
                    )
            best_lat = min(c["latency_s"] for c in row["cells"].values())
            best_sto = min(c["storage_uUSD"] for c in row["cells"].values())
            auto = row["auto"]
            if auto["latency_s"] > best_lat * _AUTO_TOL:
                raise RuntimeError(
                    f"engine {name}/{backend}: auto chunk size "
                    f"{auto['latency_s']:.4f}s > best fixed {best_lat:.4f}s"
                )
            if auto["storage_uUSD"] > best_sto * _AUTO_COST_TOL:
                raise RuntimeError(
                    f"engine {name}/{backend}: auto chunk size storage "
                    f"{auto['storage_uUSD']:.2f}uUSD > best fixed "
                    f"{best_sto:.2f}uUSD (+0.1% residency tolerance)"
                )
            bp = row["backpressure"]
            if bp["peak_inflight_chunk_bytes"] > (
                bp["peak_bound_bytes"] * _TOL
            ):
                raise RuntimeError(
                    f"engine {name}/{backend}: peak in-flight "
                    f"{bp['peak_inflight_chunk_bytes']:.0f}B > credit "
                    f"bound {bp['peak_bound_bytes']}B"
                )


def run(chunk_sizes, quiet: bool = False):
    if not quiet:
        print("# cluster lowering (analytic overlap) vs critical-path bound")
    cluster = run_cluster(chunk_sizes, quiet=quiet)
    if not quiet:
        print("# engine lowering (event-driven chunk protocol)")
    engine = run_engine(chunk_sizes, quiet=quiet)
    return {
        "cluster": cluster,
        "engine": engine,
        "config": {
            "chunk_sizes": list(chunk_sizes),
            "stream_edges": {k: list(v) for k, v in STREAM_EDGES.items()},
            "bound_ratio_max": BOUND_RATIO_MAX,
            "backends": list(BACKENDS),
            "backpressure": {"window": BP_WINDOW, "chunk_bytes": BP_CHUNK},
        },
        "schema": 2,
    }


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="seconds-long CI subset (fewer chunk sizes)")
    p.add_argument("--check", action="store_true",
                   help="fail on gate violations (never slower, never "
                        "costlier, bound approach)")
    args = p.parse_args(sys.argv[1:] if argv is None else argv)

    print("# Fig 13 — streaming edges: chunk size x workload x backend")
    out = run(SMOKE_CHUNK_SIZES if args.smoke else CHUNK_SIZES)
    path = save_json(RESULT_NAME, out)
    print(f"# wrote {path}")

    if args.check:
        try:
            check_gates(out)
        except RuntimeError as e:
            print(f"# GATE FAILED: {e}")
            return 1
        print("# gates ok: streaming never slower, never costlier on the "
              f"same routes, within {BOUND_RATIO_MAX}x of the bound")
    return 0


#: benchmarks.run auto-discovery (smoke carries the streaming CI gates)
HARNESS = {
    "name": "fig13",
    "full": lambda: main(["--check"]),
    "smoke": lambda: main(["--smoke", "--check"]),
}

if __name__ == "__main__":
    sys.exit(main())
