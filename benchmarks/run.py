"""Benchmark driver: one CLI for every paper table/figure plus the engine
benchmark and the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig7,...] [--smoke]

Harnesses are **auto-discovered**: every module in this package that
exports a ``HARNESS`` spec (or a ``HARNESSES`` list) —
``{"name": ..., "full": callable, "smoke": callable}`` — is picked up and
CI-gated by default, so a new benchmark is wired the moment its file
lands (fig10, the graph-optimizer sweep, arrived exactly this way).
Modules without a spec are simply not driven from here: ``hillclimb``
needs its XLA host-device flag exported before jax imports and stays a
separate entry point; ``bench_delta`` is a CI reporting helper; the
roofline depends on a recorded dryrun sweep and keeps its special-cased
skip-with-notice behaviour below.

``--smoke`` swaps each harness for its seconds-long CI subset (fig7's
smoke additionally gates routed-dominates; fig9 gates the autoscaler
policies; fig10 gates optimized-dominates; bench gates events/sec
regression).  A harness that fails — by raising OR by returning a nonzero
exit code — makes run.py exit nonzero.  Writes JSON artifacts under
results/ and prints each harness's table.
"""
from __future__ import annotations

import argparse
import importlib
import os
import pkgutil
import sys
import time
import traceback

from .common import RESULTS_DIR

#: package modules that intentionally carry no HARNESS spec (anything else
#: without one fails discovery loudly, so a new benchmark cannot land
#: silently unwired)
NON_HARNESS_MODULES = {
    "common",      # shared helpers
    "run",         # this driver
    "roofline",    # depends on a recorded dryrun sweep (special-cased below)
    "hillclimb",   # needs the XLA flag set before jax imports; own entry point
    "bench_delta", # CI job-summary reporting helper, not a benchmark
}


def discover_harnesses():
    """name -> (full invocation, seconds-long smoke invocation)."""
    harnesses = {}
    pkg_path = os.path.dirname(os.path.abspath(__file__))
    for info in pkgutil.iter_modules([pkg_path]):
        if info.name in NON_HARNESS_MODULES or info.name.startswith("_"):
            continue
        mod = importlib.import_module(f"{__package__}.{info.name}")
        specs = getattr(mod, "HARNESSES", None)
        if specs is None:
            spec = getattr(mod, "HARNESS", None)
            specs = [spec] if spec is not None else None
        if not specs:
            raise RuntimeError(
                f"benchmarks.{info.name} exports no HARNESS spec; add one "
                "(or list the module in NON_HARNESS_MODULES) so it is "
                "CI-gated instead of silently unwired"
            )
        for spec in specs:
            name = spec["name"]
            if name in harnesses:
                raise RuntimeError(f"duplicate harness name {name!r}")
            harnesses[name] = (spec["full"], spec["smoke"])
    return harnesses


def run_roofline():
    dryrun_path = os.path.join(RESULTS_DIR, "dryrun.json")
    if not os.path.exists(dryrun_path):
        print("\n# Roofline — SKIPPED (run `python -m repro.launch.dryrun` "
              "to record the 512-device sweep first)")
        return
    from . import roofline
    from .common import save_json

    rows = roofline.run("dryrun.json", "single")
    roofline.print_table(rows, "single pod (16x16), per-device terms")
    save_json("roofline.json", {"single": rows})


def main():
    harnesses = discover_harnesses()
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list from: " + ",".join(harnesses) + ",roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long CI subset of every harness")
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else list(harnesses) + ["roofline"]

    failures = []
    for name in wanted:
        t0 = time.time()
        print(f"\n{'='*72}\n[benchmarks.run] {name}\n{'='*72}")
        try:
            if name == "roofline":
                run_roofline()
            else:
                full, smoke = harnesses[name]
                rc = (smoke if args.smoke else full)()
                # harnesses that gate via exit code (bench --check) return a
                # nonzero int instead of raising: treat it as a failure too,
                # or a tripped gate leaves run.py exiting 0 and CI's --smoke
                # pass is vacuous
                if isinstance(rc, int) and rc != 0:
                    raise RuntimeError(f"harness exited {rc}")
            print(f"[benchmarks.run] {name} done in {time.time()-t0:.1f}s")
        except Exception as e:
            failures.append(name)
            print(f"[benchmarks.run] {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()

    print(f"\n{'='*72}")
    if failures:
        print(f"benchmark summary: FAILURES in {failures}")
        return 1
    print("benchmark summary: all harnesses passed; artifacts in results/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
