"""Benchmark driver: one CLI for every paper table/figure plus the engine
benchmark and the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig7,...] [--smoke]

Every harness runs through the unified substrate: fig5/fig6/fig2 drive the
calibrated cluster simulator, fig7/table2 interpret the declarative
:class:`~repro.core.dag.WorkflowDAG` workloads (including the per-edge-routed
``hybrid``/``adaptive`` columns), fig8 sweeps the event-driven engine —
``fig8dag`` compiles the same DAGs onto it via ``dag.bind`` — fig9 sweeps
autoscaler policy x offered load, and ``bench`` tracks the substrate's
events/sec trajectory.

``--smoke`` swaps each harness for its seconds-long CI subset (fig7's smoke
additionally gates routed-dominates; fig9 gates predictive-vs-legacy cold
starts; bench additionally gates events/sec regression).  A harness that
fails — by raising OR by returning a nonzero exit code — makes run.py exit
nonzero.  Writes JSON artifacts under results/ and prints each harness's
table.  The roofline section reads results/dryrun.json (produced by
``python -m repro.launch.dryrun``); it is skipped with a notice if the sweep
has not been recorded yet.  The jax hillclimb harness
(``benchmarks.hillclimb``) needs the 512-host-device XLA flag set before jax
imports, so it stays a separate entry point.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from . import (
    bench_engine,
    fig2_single_transfer,
    fig5_latency_cdf,
    fig6_collectives,
    fig7_workloads,
    fig8_throughput,
    fig9_autoscaler,
    table2_cost,
)
from .common import RESULTS_DIR

#: name -> (full invocation, seconds-long smoke invocation)
HARNESSES = {
    "fig2": (fig2_single_transfer.main, fig2_single_transfer.main),
    "fig5": (fig5_latency_cdf.main, lambda: fig5_latency_cdf.run(20)),
    "fig6": (fig6_collectives.main, lambda: fig6_collectives.run(2)),
    "fig7": (fig7_workloads.main, lambda: fig7_workloads.main(["--smoke"])),
    "fig8": (lambda: fig8_throughput.main([]),
             lambda: fig8_throughput.main(["--quick"])),
    "fig8dag": (lambda: fig8_throughput.main(["--dag"]),
                lambda: fig8_throughput.main(["--dag", "--quick"])),
    "fig9": (lambda: fig9_autoscaler.main([]),
             lambda: fig9_autoscaler.main(["--smoke"])),
    "table2": (table2_cost.main, table2_cost.main),
    "bench": (lambda: bench_engine.main([]),
              lambda: bench_engine.main(["--smoke", "--check"])),
}


def run_roofline():
    dryrun_path = os.path.join(RESULTS_DIR, "dryrun.json")
    if not os.path.exists(dryrun_path):
        print("\n# Roofline — SKIPPED (run `python -m repro.launch.dryrun` "
              "to record the 512-device sweep first)")
        return
    from . import roofline
    from .common import save_json

    rows = roofline.run("dryrun.json", "single")
    roofline.print_table(rows, "single pod (16x16), per-device terms")
    save_json("roofline.json", {"single": rows})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list from: " + ",".join(HARNESSES) + ",roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long CI subset of every harness")
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else list(HARNESSES) + ["roofline"]

    failures = []
    for name in wanted:
        t0 = time.time()
        print(f"\n{'='*72}\n[benchmarks.run] {name}\n{'='*72}")
        try:
            if name == "roofline":
                run_roofline()
            else:
                full, smoke = HARNESSES[name]
                rc = (smoke if args.smoke else full)()
                # harnesses that gate via exit code (bench --check) return a
                # nonzero int instead of raising: treat it as a failure too,
                # or a tripped gate leaves run.py exiting 0 and CI's --smoke
                # pass is vacuous
                if isinstance(rc, int) and rc != 0:
                    raise RuntimeError(f"harness exited {rc}")
            print(f"[benchmarks.run] {name} done in {time.time()-t0:.1f}s")
        except Exception as e:
            failures.append(name)
            print(f"[benchmarks.run] {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()

    print(f"\n{'='*72}")
    if failures:
        print(f"benchmark summary: FAILURES in {failures}")
        return 1
    print("benchmark summary: all harnesses passed; artifacts in results/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
