"""Benchmark driver: one harness per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig5,...]

Writes JSON artifacts under results/ and prints each harness's table.
The roofline section reads results/dryrun.json (produced by
``python -m repro.launch.dryrun``); it is skipped with a notice if the
sweep has not been recorded yet.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from . import (
    fig2_single_transfer,
    fig5_latency_cdf,
    fig6_collectives,
    fig7_workloads,
    fig8_throughput,
    table2_cost,
)
from .common import RESULTS_DIR

HARNESSES = {
    "fig2": fig2_single_transfer.main,
    "fig5": fig5_latency_cdf.main,
    "fig6": fig6_collectives.main,
    "fig7": fig7_workloads.main,
    "fig8": lambda: fig8_throughput.main([]),
    "table2": table2_cost.main,
}


def run_roofline():
    dryrun_path = os.path.join(RESULTS_DIR, "dryrun.json")
    if not os.path.exists(dryrun_path):
        print("\n# Roofline — SKIPPED (run `python -m repro.launch.dryrun` "
              "to record the 512-device sweep first)")
        return
    from . import roofline
    from .common import save_json

    rows = roofline.run("dryrun.json", "single")
    roofline.print_table(rows, "single pod (16x16), per-device terms")
    save_json("roofline.json", {"single": rows})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list from: " + ",".join(HARNESSES) + ",roofline")
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else list(HARNESSES) + ["roofline"]

    failures = []
    for name in wanted:
        t0 = time.time()
        print(f"\n{'='*72}\n[benchmarks.run] {name}\n{'='*72}")
        try:
            if name == "roofline":
                run_roofline()
            else:
                HARNESSES[name]()
            print(f"[benchmarks.run] {name} done in {time.time()-t0:.1f}s")
        except Exception as e:
            failures.append(name)
            print(f"[benchmarks.run] {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()

    print(f"\n{'='*72}")
    if failures:
        print(f"benchmark summary: FAILURES in {failures}")
        return 1
    print("benchmark summary: all harnesses passed; artifacts in results/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
