from .pipeline import SyntheticCorpus, ShardedLoader, make_batch_specs
from .prefetch import PrefetchingFeed
