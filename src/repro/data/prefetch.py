"""Double-buffered host->device feed using the XDT substrate.

The input pipeline is a producer/consumer workflow: the loader thread is the
*producer function*, the training loop the *consumer*.  The producer ``put``s
each prepared batch into its :class:`BufferRegistry` (bounded slots -> flow
control back-pressures a loader that runs ahead) and hands the training loop
an :class:`XDTRef`; the loop ``get``s (pulls) exactly when it needs the
batch.  A slow or dead producer surfaces as ``XDTTimeout`` /
``XDTProducerGone`` on the consumer side, and the deterministic loader
regenerates from the sample index — the paper's re-invoke recovery, applied
to data.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Optional

import jax

from ..core.buffers import BufferRegistry
from ..core.errors import XDTError, XDTProducerGone, XDTTimeout
from ..core.transfer import TransferEngine


class PrefetchingFeed:
    """Wraps a batch-at-step callable with an XDT-mediated prefetch thread."""

    def __init__(
        self,
        batch_at: Callable[[int], Dict[str, Any]],
        depth: int = 2,
        sharding: Optional[Any] = None,
        engine: Optional[TransferEngine] = None,
        timeout_s: float = 30.0,
    ):
        self.batch_at = batch_at
        self.sharding = sharding
        self.timeout_s = timeout_s
        self.engine = engine or TransferEngine(
            "xdt", registry=BufferRegistry(max_slots=depth)
        )
        self._refs: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next_step = 0
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # -- producer thread ------------------------------------------------------
    def _producer(self) -> None:
        step = 0
        while not self._stop.is_set():
            try:
                batch = self.batch_at(step)
                ref = self.engine.put(
                    batch, n_retrievals=1, timeout=self.timeout_s
                )  # blocks on flow control when the consumer lags
                self._refs.put((step, ref))
                step += 1
            except XDTError:
                continue  # registry killed/timeout: retry same step
            except Exception:
                break

    # -- consumer side ----------------------------------------------------------
    def get_batch(self, step: int) -> Dict[str, Any]:
        """Pull the batch for ``step``; regenerate on producer failure."""
        while True:
            try:
                got_step, ref = self._refs.get(timeout=self.timeout_s)
            except queue.Empty:
                # producer wedged/dead: deterministic regeneration
                return self._materialize(self.batch_at(step))
            if got_step != step:
                continue  # stale ref from before a restart; drop it
            try:
                return self._materialize(self.engine.get(ref))
            except (XDTProducerGone, XDTTimeout):
                return self._materialize(self.batch_at(step))

    def _materialize(self, batch: Dict[str, Any]):
        if self.sharding is None:
            return batch
        return {
            k: jax.device_put(v, self.sharding[k] if isinstance(self.sharding, dict) else self.sharding)
            for k, v in batch.items()
        }

    def close(self) -> None:
        self._stop.set()
        self.engine.kill_producer()
        try:
            while True:
                self._refs.get_nowait()
        except queue.Empty:
            pass
