"""Deterministic synthetic data pipeline.

Every sample is a pure function of its global index (a counter-mode hash into
token space with a learnable-ish n-gram structure so losses actually
decrease), which buys the fault-tolerance property the trainer relies on:
*any* shard of *any* batch can be regenerated from (step, data_rank) alone —
the data-plane analogue of the paper's "re-invoke the producer with the same
original arguments" recovery.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from ..models.config import ModelConfig


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """xorshift-mult avalanche; vectorized, stable across platforms."""
    x = x.astype(np.uint64)
    x ^= x >> np.uint64(16)
    x = (x * np.uint64(0x7FEB352D)) & np.uint64(0xFFFFFFFF)
    x ^= x >> np.uint64(15)
    x = (x * np.uint64(0x846CA68B)) & np.uint64(0xFFFFFFFF)
    x ^= x >> np.uint64(16)
    return x.astype(np.uint32)


@dataclasses.dataclass(frozen=True)
class SyntheticCorpus:
    """Markov-flavoured synthetic token stream with vocabulary ``vocab``.

    ``sample(idx, seq_len)`` is deterministic in ``idx``; consecutive tokens
    are correlated (t_{i+1} depends on t_i and position) so a model can
    learn structure and the loss curve is meaningful in examples/tests.
    """

    vocab: int
    seed: int = 0

    def sample(self, idx: int, seq_len: int) -> np.ndarray:
        base = _hash_u32(np.arange(seq_len, dtype=np.uint64) + np.uint64(idx * 1_000_003 + self.seed))
        toks = base % np.uint32(self.vocab)
        # inject learnable bigram structure: half the positions repeat a
        # shifted copy of the previous token
        mask = (base >> np.uint32(8)) % np.uint32(2) == 0
        shifted = np.roll((toks * 31 + 7) % np.uint32(self.vocab), 1)
        toks = np.where(mask, shifted, toks)
        return toks.astype(np.int32)

    def batch(self, start_idx: int, batch: int, seq_len: int) -> Dict[str, np.ndarray]:
        toks = np.stack([self.sample(start_idx + i, seq_len + 1) for i in range(batch)])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ShardedLoader:
    """Per-data-rank loader over the global sample-index space.

    Rank r of R draws indices ``step * global_batch + r::R`` — so the global
    batch at a step is identical regardless of R (elastic reshaping keeps
    the data order), and a failed rank's shard is regenerable anywhere.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        global_batch: int,
        seq_len: int,
        data_rank: int = 0,
        data_ranks: int = 1,
        seed: int = 0,
    ):
        assert global_batch % data_ranks == 0
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg.vocab if cfg.vocab > 1 else 32, seed)
        self.global_batch = global_batch
        self.local_batch = global_batch // data_ranks
        self.seq_len = seq_len
        self.data_rank = data_rank
        self.data_ranks = data_ranks

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        base = step * self.global_batch
        idxs = [base + self.data_rank + i * self.data_ranks for i in range(self.local_batch)]
        toks = np.stack([self.corpus.sample(i, self.seq_len + 1) for i in idxs])
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        return self._modality(out)

    def _modality(self, out: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        if cfg.family == "vlm":
            s_img = cfg.frontend_seq
            rng = np.random.default_rng(int(out["tokens"][:, 0].sum()) & 0x7FFFFFFF)
            out["patches"] = rng.standard_normal(
                (out["tokens"].shape[0], s_img, cfg.d_model), dtype=np.float32
            ) * 0.02
        elif cfg.family == "encoder":
            B, S = out["tokens"].shape
            rng = np.random.default_rng(int(out["tokens"][:, 0].sum()) & 0x7FFFFFFF)
            out["frames"] = rng.standard_normal((B, S, cfg.d_model), dtype=np.float32) * 0.02
            out.pop("tokens")
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int):
    """(shape, dtype, logical_axes) per batch leaf — shared with input_specs."""
    specs = {}
    if cfg.family == "encoder":
        specs["frames"] = ((global_batch, seq_len, cfg.d_model), "bfloat16",
                           ("batch", None, None))
        specs["labels"] = ((global_batch, seq_len), "int32", ("batch", None))
    elif cfg.family == "vlm":
        s_img = cfg.frontend_seq
        s_txt = seq_len - s_img
        specs["tokens"] = ((global_batch, s_txt), "int32", ("batch", None))
        specs["labels"] = ((global_batch, s_txt), "int32", ("batch", None))
        specs["patches"] = ((global_batch, s_img, cfg.d_model), "bfloat16",
                            ("batch", None, None))
    else:
        specs["tokens"] = ((global_batch, seq_len), "int32", ("batch", None))
        specs["labels"] = ((global_batch, seq_len), "int32", ("batch", None))
    return specs
