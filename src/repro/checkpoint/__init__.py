from .store import CheckpointStore, latest_step
