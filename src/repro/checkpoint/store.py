"""Sharded, atomic, elastic checkpointing.

Layout::

    <dir>/step_000123/
        manifest.json      # flat-key -> {shape, dtype, logical_axes}
        <flat.key>.npy     # one file per leaf (host-gathered)
        COMMIT             # written LAST; a step dir without it is ignored

Properties the trainer relies on:

* **Atomicity** — the COMMIT marker is written after every leaf has been
  fsync'd to its final name; a crash mid-save leaves a garbage dir that
  restore skips (``latest_step`` only considers committed steps).
* **Async** — ``save_async`` snapshots leaves to host memory synchronously
  (cheap) and writes files on a background thread, so the train loop only
  stalls for the device->host copy.
* **Elastic reshape** — the manifest stores *logical* axes, not device
  layouts.  ``restore(mesh=...)`` re-resolves them against the new mesh's
  :class:`ShardingRules`, so a checkpoint written on (4 data, 2 model)
  restores bit-identically onto (2, 4), (8, 1), or a different pod count.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..distributed.sharding import ShardingRules

PyTree = Any
_SEP = "/"


def _flatten_with_paths(tree: PyTree, is_leaf=None):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
            os.path.join(directory, name, "COMMIT")
        ):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree, logical_axes: Optional[PyTree] = None) -> None:
        host, manifest = self._snapshot(tree, logical_axes)
        self._write(step, host, manifest)

    def save_async(self, step: int, tree: PyTree, logical_axes: Optional[PyTree] = None) -> None:
        """Device->host copy now; file IO on a background thread."""
        self.wait()  # one outstanding save at a time
        host, manifest = self._snapshot(tree, logical_axes)

        def work():
            try:
                self._write(step, host, manifest)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _snapshot(self, tree: PyTree, logical_axes: Optional[PyTree]):
        leaves, _ = _flatten_with_paths(tree)
        axes_leaves = {}
        if logical_axes is not None:
            axes_leaves, _ = _flatten_with_paths(
                logical_axes, is_leaf=lambda x: isinstance(x, tuple)
            )
        host: Dict[str, np.ndarray] = {}
        manifest: Dict[str, Any] = {}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            manifest[key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "logical_axes": list(axes_leaves.get(key, ())) or None,
            }
            # custom dtypes (bfloat16 etc.) don't survive np.save: store raw
            host[key] = np.frombuffer(
                np.ascontiguousarray(arr).tobytes(), dtype=np.uint8
            )
        return host, manifest

    def _write(self, step: int, host: Dict[str, np.ndarray], manifest) -> None:
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for key, arr in host.items():
            fname = key.replace(_SEP, ".") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        with open(os.path.join(final, "COMMIT"), "w") as f:
            f.write("ok")
        self._gc()

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.directory, n, "COMMIT"))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def restore(
        self,
        step: int,
        like: PyTree,
        mesh=None,
        logical_axes: Optional[PyTree] = None,
    ) -> PyTree:
        """Restore into the structure of ``like`` (values ignored).

        With ``mesh`` + ``logical_axes``, every leaf is device_put with the
        sharding re-resolved on the *new* mesh — the elastic-reshape path.
        """
        d = os.path.join(self.directory, f"step_{step:09d}")
        if not os.path.exists(os.path.join(d, "COMMIT")):
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        leaves, treedef = _flatten_with_paths(like)
        axes_leaves = {}
        if logical_axes is not None:
            axes_leaves, _ = _flatten_with_paths(
                logical_axes, is_leaf=lambda x: isinstance(x, tuple)
            )
        rules = ShardingRules(mesh) if mesh is not None else None

        out = {}
        for key in leaves:
            fname = key.replace(_SEP, ".") + ".npy"
            raw = np.load(os.path.join(d, fname))
            meta = manifest[key]
            import jax.numpy as jnp

            dtype = jnp.dtype(meta["dtype"])
            arr = raw.view(dtype).reshape(meta["shape"])
            if rules is not None:
                axes = axes_leaves.get(key) or meta.get("logical_axes") or [None] * arr.ndim
                arr = jax.device_put(arr, rules.named(list(axes), arr.shape))
            out[key] = arr
        ordered = [out[k] for k in leaves]
        return jax.tree.unflatten(treedef, ordered)
