from .sharding import DEFAULT_RULES, ShardingRules, abstract, logical_sharding
