"""Logical-axis sharding rules with divisibility-aware fallback.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"d_ff", ...).  A :class:`ShardingRules` maps logical names to mesh axes and
drops any mapping whose dimension does not divide the mesh axis (JAX/GSPMD
requires even partitions for program inputs) — e.g. smollm-360m's 15 heads on
a 16-way model axis fall back to replicated heads, and the attention layer
then switches to sequence (context) parallelism instead.

The rules double as the elastic-reshape vocabulary: checkpoints store logical
specs, restore re-resolves them against whatever mesh the job restarts on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical->mesh mapping for the production meshes
# (pod, data, model) or (data, model).
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),      # pure DP over pod+data (training default)
    "batch_data": ("data",),       # batch over data only (serving)
    "seq": (),                     # unsharded by default
    "seq_model": ("model",),       # context/sequence parallelism
    "vocab": ("model",),
    "embed": (),                   # d_model replicated (Megatron-style TP)
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "d_ff": ("model",),
    "experts": ("model",),
    "expert_ff": (),
    "layers": (),
    "kv_seq": ("model",),          # decode-time KV cache sequence sharding
    "ssm_inner": ("model",),
    "ssm_state": (),
    "ssm_heads": ("model",),
    "conv": (),
    "stages": ("pod",),            # pipeline / disagg stage axis
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    rules: Optional[Dict[str, Tuple[str, ...]]] = None

    def _mesh_axes(self) -> Dict[str, int]:
        return dict(self.mesh.shape)

    def resolve(self, logical: Sequence[Optional[str]], dims: Sequence[int]) -> P:
        """Resolve logical axis names to a PartitionSpec for shape ``dims``.

        Any logical axis whose mapped mesh axes do not evenly divide the
        dimension is replicated instead (recorded via :meth:`fallbacks`).
        A mesh axis may be consumed by at most one tensor dimension.
        """
        assert len(logical) == len(dims), (logical, dims)
        rules = self.rules if self.rules is not None else DEFAULT_RULES
        avail = self._mesh_axes()
        used: set = set()
        out = []
        for name, dim in zip(logical, dims):
            if name is None:
                out.append(None)
                continue
            mapped = tuple(
                ax for ax in rules.get(name, ()) if ax in avail and ax not in used
            )
            if not mapped:
                out.append(None)
                continue
            total = 1
            for ax in mapped:
                total *= avail[ax]
            if dim % total != 0:
                # divisibility fallback: try progressively shorter prefixes
                ok = ()
                for k in range(len(mapped) - 1, 0, -1):
                    t = 1
                    for ax in mapped[:k]:
                        t *= avail[ax]
                    if dim % t == 0:
                        ok = mapped[:k]
                        break
                mapped = ok
            if not mapped:
                out.append(None)
                continue
            used.update(mapped)
            out.append(mapped if len(mapped) > 1 else mapped[0])
        return P(*out)

    def named(self, logical: Sequence[Optional[str]], dims: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(logical, dims))

    def zero1_resolve(self, logical: Sequence[Optional[str]], dims: Sequence[int]) -> P:
        """ZeRO-1 layout: the parameter's model-parallel spec PLUS the data
        (and pod) axes on the first still-replicated, evenly-divisible dim.
        Optimizer moments (and the f32 update math) then live 1/DP-sharded;
        GSPMD turns the gradient all-reduce into reduce-scatter + the param
        write-back into an all-gather."""
        base = list(self.resolve(logical, dims))
        avail = self._mesh_axes()
        used = set()
        for entry in base:
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                if ax is not None:
                    used.add(ax)
        dp_axes = tuple(a for a in ("pod", "data") if a in avail and a not in used)
        if not dp_axes:
            return P(*base)
        total = 1
        for a in dp_axes:
            total *= avail[a]
        for i, (entry, dim) in enumerate(zip(base, dims)):
            if entry is None and dim % total == 0:
                base[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                break
        return P(*base)

    def zero1_named(self, logical: Sequence[Optional[str]], dims: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.zero1_resolve(logical, dims))

    def shards_evenly(self, name: str, dim: int) -> bool:
        """True iff logical axis ``name`` actually shards a dim of size ``dim``."""
        spec = self.resolve([name], [dim])
        return spec[0] is not None


# FSDP-style variant (§Perf hillclimb): parameters' d_model dim is sharded
# over the data axis on top of the model-axis TP.  GSPMD then all-gathers
# each layer's weights just-in-time (bytes/layer = params, not activations)
# and reduce-scatters their grads — the right trade when per-device token
# count is large (train_4k: 65k tokens/device makes activation psums 10-30x
# the per-layer weight traffic).
FSDP_RULES: Dict[str, Tuple[str, ...]] = dict(DEFAULT_RULES, embed=("data",))


def rules_for(cfg, mesh: Mesh) -> "ShardingRules":
    """The sharding rules a model config selects (fsdp_params knob)."""
    if getattr(cfg, "fsdp_params", False):
        return ShardingRules(mesh, FSDP_RULES)
    return ShardingRules(mesh)


def logical_sharding(
    mesh: Mesh,
    logical: Sequence[Optional[str]],
    dims: Sequence[int],
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
) -> NamedSharding:
    return ShardingRules(mesh, rules).named(logical, dims)


def abstract(
    shape: Tuple[int, ...],
    dtype,
    mesh: Optional[Mesh],
    logical: Sequence[Optional[str]],
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
) -> jax.ShapeDtypeStruct:
    """ShapeDtypeStruct with resolved sharding (dry-run stand-in)."""
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=ShardingRules(mesh, rules).named(logical, shape)
    )
