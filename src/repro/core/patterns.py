"""The paper's communication patterns as TPU-native mesh collectives.

Paper §4.2.1/§7.1 defines four inter-function patterns: producer-consumer
(1-1), scatter (map), gather (reduce), and broadcast.  On a TPU mesh the XDT
principle — *the consumer pulls exactly its bytes directly from the producer
after placement is decided* — maps onto point-to-point ``collective-permute``
(``lax.ppermute``) and, for the regular fused scatter+gather (MoE dispatch),
onto ``lax.all_to_all``.  The anti-pattern XDT replaces (staging through an
intermediary) corresponds to bouncing via host / replicating via all-gather
when only one consumer needs the bytes.

All ``*_shard`` functions are *per-shard* programs: call them inside
``jax.shard_map``.  ``build_pattern_fn`` wraps one into a jitted host-level
callable for tests and benchmarks; see each pattern for its global layout
convention.

Traffic accounting (used by the roofline): with object size ``s`` and fan
``n`` on one axis —

==============  =========================  ===========================
pattern         XDT-native lowering        bytes on the wire
==============  =========================  ===========================
1-1 / p2p       1 collective-permute       s
scatter         n-1 collective-permutes    s*(n-1)/n (one slice each)
gather-to-one   n-1 collective-permutes    (n-1)*s  (focused on dst)
gather-to-all   ring all-gather            (n-1)*s  per link
broadcast       masked psum (all-reduce)   ~2s      (ring all-reduce)
moe dispatch    all-to-all                 s*(n-1)/n per link
==============  =========================  ===========================
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


# --------------------------------------------------------------------------
# Per-shard collective programs (call inside shard_map)
# --------------------------------------------------------------------------


def p2p_shard(x: jax.Array, axis: str, src: int, dst: int) -> jax.Array:
    """1-1: move ``x`` from rank ``src`` to rank ``dst`` along ``axis``.

    Every rank participates (SPMD); ranks not addressed by the permute
    receive zeros (``ppermute`` semantics).  Lowers to a single
    collective-permute: the direct producer->consumer pull.
    """
    if src == dst:
        return x
    return lax.ppermute(x, axis, [(src, dst)])


def scatter_shard(x_stacked: jax.Array, axis: str, src: int, n: int) -> jax.Array:
    """Scatter: rank ``src`` holds rows ``(n, ...)``; rank j receives row j.

    Lowered as n-1 point-to-point permutes (total wire bytes = (n-1)/n of the
    object, each slice moving once, directly) rather than a masked
    all-to-all (which would move n x the bytes).  This is the XDT scatter:
    each consumer pulls only its slice.  Non-``src`` ranks' input blocks are
    ignored (pass zeros).
    """
    idx = lax.axis_index(axis)
    out = x_stacked[src]  # rank ``src`` keeps its own row, no wire transfer
    for j in range(n):
        if j == src:
            continue
        piece = lax.ppermute(x_stacked[j], axis, [(src, j)])
        out = jnp.where(idx == j, piece, out)
    return out


def gather_shard(x: jax.Array, axis: str, dst: int, n: int) -> jax.Array:
    """Gather-to-one: rank ``dst`` receives the stack of every rank's shard.

    n-1 point-to-point permutes focused on ``dst`` — XDT's gather, where the
    single consumer pulls each producer's buffer.  Ranks other than ``dst``
    hold zeros in the foreign rows (only the consumer's copy is meaningful).
    """
    rows = []
    idx = lax.axis_index(axis)
    for j in range(n):
        recv = x if j == dst else lax.ppermute(x, axis, [(j, dst)])
        # row j is x's own shard only at rank dst position j == dst
        rows.append(jnp.where(idx == dst, recv, jnp.where(j == idx, x, jnp.zeros_like(x))))
    return jnp.stack(rows, axis=0)


def gather_all_shard(x: jax.Array, axis: str) -> jax.Array:
    """Gather-to-all: ring all-gather (when every rank consumes the result)."""
    return lax.all_gather(x, axis)


def broadcast_shard(x: jax.Array, axis: str, src: int) -> jax.Array:
    """Broadcast: rank ``src``'s object delivered to every rank.

    Masked psum lowers to one all-reduce, which XLA schedules as a
    bandwidth-optimal ring on ICI.
    """
    idx = lax.axis_index(axis)
    return lax.psum(jnp.where(idx == src, x, jnp.zeros_like(x)), axis)


def all_to_all_shard(x: jax.Array, axis: str) -> jax.Array:
    """All-to-all: the fused scatter+gather pattern used by MoE routing.

    Per-shard ``x`` has leading dim == axis size; row j goes to rank j.
    """
    return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)


# --------------------------------------------------------------------------
# Host-level wrappers
# --------------------------------------------------------------------------
#
# Global-layout conventions (n = mesh.shape[axis], C = payload shape):
#   1-1        in (n, *C) sharded P(axis): row r is rank r's buffer.
#              out (n, *C): row dst == in row src, others zero.
#   scatter    in (n, n, *C) sharded P(axis): block src holds the stacked
#              object; other blocks ignored.  out (n, *C): row j == slice j.
#   gather     in (n, *C) sharded P(axis).  out (n, n, *C): block dst holds
#              the full stack.
#   gather_all in (n, *C) sharded P(axis).  out (n, n, *C): every block holds
#              the full stack.
#   broadcast  in (n, *C) sharded P(axis).  out (n, *C): every row == row src.
#   all_to_all in (n*n, *C) sharded P(axis): rank r's block row j is r's
#              message to j.  out: rank r's block row j is j's message to r.


def build_pattern_fn(
    mesh: Mesh,
    axis: str,
    pattern: str,
    *,
    src: int = 0,
    dst: int = 0,
) -> Callable[[jax.Array], jax.Array]:
    """Build a jitted shard_map callable running one pattern along ``axis``."""
    n = mesh.shape[axis]
    spec1 = P(axis)

    if pattern == "1-1":
        def fn(x):  # x: (1, *C)
            return p2p_shard(x[0], axis, src, dst)[None]
    elif pattern == "scatter":
        def fn(x):  # x: (1, n, *C)
            return scatter_shard(x[0], axis, src, n)[None]
    elif pattern == "gather":
        def fn(x):  # x: (1, *C)
            return gather_shard(x[0], axis, dst, n)[None]
    elif pattern == "gather_all":
        def fn(x):  # x: (1, *C)
            return gather_all_shard(x[0], axis)[None]
    elif pattern == "broadcast":
        def fn(x):  # x: (1, *C)
            return broadcast_shard(x[0], axis, src)[None]
    elif pattern == "all_to_all":
        def fn(x):  # x: (n, *C) — the per-rank message stack
            return all_to_all_shard(x, axis)
    else:
        raise ValueError(pattern)

    mapped = shard_map(fn, mesh=mesh, in_specs=spec1, out_specs=spec1)
    return jax.jit(mapped)


def pattern_wire_bytes(pattern: str, nbytes: int, fan: int) -> float:
    """Analytic wire-traffic model (per the table in the module docstring)."""
    if pattern == "1-1":
        return float(nbytes)
    if pattern == "scatter":
        return nbytes * (fan - 1) / max(1, fan)
    if pattern in ("gather", "gather_all"):
        return float((fan - 1) * nbytes)
    if pattern == "broadcast":
        return 2.0 * nbytes * (fan - 1) / max(1, fan)
    if pattern == "all_to_all":
        return nbytes * (fan - 1) / max(1, fan)
    raise ValueError(pattern)
