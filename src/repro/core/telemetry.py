"""Shared telemetry substrate for the adaptive control plane.

Both halves of the control plane's observe->decide loop read from this
module: autoscaler policies (:mod:`repro.core.scheduler`) consume
per-deployment arrival/concurrency/cold-start signals, and feedback routing
policies (:class:`repro.core.dag.AdaptiveRoute`) consume per-medium
latency/cost/bytes observations.  Everything is sampled on the injected
:mod:`repro.core.clock` clock, so the same estimators behave identically
under ``MonotonicClock`` (real deployments) and ``VirtualClock``
(discrete-event sweeps) — a rate window that decays over 2 *virtual*
seconds is exactly assertable in tests and fast-forwardable in load sweeps.

Estimators (all O(1) per observation — these sit on the steer()/get() hot
paths):

:class:`DecayRate`
    Exponentially-decayed event counter: ``record(t)`` bumps a count that
    decays with time-constant ``tau_s``; ``rate(t)`` is the smoothed
    events/sec.  Warmup-corrected: before one full ``tau_s`` has elapsed the
    effective window is the observed span, so a fresh deployment sees its
    true arrival rate within a few samples instead of ``tau``-lagged.

:class:`DecayGauge`
    Time-decayed average of a sampled value (e.g. in-flight concurrency).

:class:`DecayedLinear`
    Sample-decayed least-squares fit ``y ~ a + b*x`` with non-negative
    coefficients — the per-medium latency and fee models (``x`` in GB), so
    one estimator serves both per-op-dominated media (S3: intercept) and
    per-byte-dominated media (ElastiCache capacity, stream time: slope).

Aggregates:

:class:`DeploymentTelemetry`
    Per-deployment windows: arrival rate + trend (fast/slow ``DecayRate``
    pair; the spread between them is the rate's slope, which
    :class:`~repro.core.scheduler.PredictivePolicy` extrapolates over the
    cold-start horizon), concurrency gauge, a cold-start window, and a
    keep-alive **reap window** (instances scaled down after idling past
    keep-alive).  The reap window is what
    :class:`~repro.core.dagopt.PredictiveSpill` reads to predict whether a
    producer's instances will outlive their consumers' pulls — a high reap
    rate means staged objects on instance-resident media are at risk and
    should spill to durable storage ahead of the eviction.

:class:`MediumTelemetry`
    Per-transfer-medium observations: latency model + bounded p99 window,
    fee model, op/byte totals.  Fed by
    :meth:`TelemetryHub.record_transfer` — the
    :class:`~repro.core.transfer.TransferEngine` feeds it on every ``get``
    and the cluster lowering feeds it per resolved edge object.

:class:`TelemetryHub`
    The shared registry handed to consumers: ``hub.deployment(name)`` /
    ``hub.medium(name)`` create-on-first-use, so the scheduler and the
    router observe one substrate instead of keeping private counters.

Custom autoscaler policies (see :class:`~repro.core.scheduler.AutoscalerPolicy`)
subclass the policy base, set a class-level ``name``, and are registered
with :func:`repro.core.scheduler.register_autoscaler`; policies that set
``needs_telemetry = True`` get a :class:`DeploymentTelemetry` maintained on
their deployment automatically and read it in ``desired_instances``.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from .clock import ensure_clock


class DecayRate:
    """Exponentially-decayed event rate with warmup correction.

    ``record(t)`` adds one event; the running count decays as
    ``exp(-dt/tau)``.  For a constant rate ``r`` observed over ``span``
    seconds the expected count is ``r * tau * (1 - exp(-span/tau))``, so
    dividing by that normalization (floored at ``warmup_floor_s``) gives an
    asymptotically unbiased rate at *every* span — including the first
    milliseconds of a load ramp, where a plain ``count/tau`` EWMA
    underestimates by ``span/tau``.
    """

    __slots__ = ("tau_s", "warmup_floor_s", "_n", "_last", "_first")

    def __init__(self, tau_s: float = 2.0, warmup_floor_s: float = 0.05):
        self.tau_s = tau_s
        self.warmup_floor_s = warmup_floor_s
        self._n = 0.0
        self._last = 0.0
        self._first: Optional[float] = None

    def record(self, t: float) -> None:
        if self._first is None:
            self._first = self._last = t
        dt = t - self._last
        if dt > 0.0:
            self._n *= math.exp(-dt / self.tau_s)
            self._last = t
        self._n += 1.0

    def record_n(self, t: float, n: int) -> None:
        """``n`` simultaneous events (a batched-arrival bucket): one decay
        step, one add — identical to ``n`` ``record(t)`` calls at equal
        ``t``, without the per-event loop."""
        if self._first is None:
            self._first = self._last = t
        dt = t - self._last
        if dt > 0.0:
            self._n *= math.exp(-dt / self.tau_s)
            self._last = t
        self._n += float(n)

    def rate(self, t: float) -> float:
        if self._first is None:
            return 0.0
        n = self._n
        dt = t - self._last
        if dt > 0.0:
            n *= math.exp(-dt / self.tau_s)
        span = t - self._first
        norm = (
            self.tau_s * (1.0 - math.exp(-span / self.tau_s))
            if span > 0.0 else 0.0
        )
        return n / max(norm, self.warmup_floor_s)


class DecayGauge:
    """Time-decayed average of a sampled value (holds its level when idle)."""

    __slots__ = ("tau_s", "_value", "_last", "_seen")

    def __init__(self, tau_s: float = 2.0):
        self.tau_s = tau_s
        self._value = 0.0
        self._last = 0.0
        self._seen = False

    def sample(self, t: float, value: float) -> None:
        if not self._seen:
            self._value, self._last, self._seen = float(value), t, True
            return
        dt = max(0.0, t - self._last)
        alpha = 1.0 - math.exp(-dt / self.tau_s) if dt > 0.0 else 0.5
        self._value += (value - self._value) * alpha
        self._last = t

    def value(self) -> float:
        return self._value


class DecayedLinear:
    """Sample-decayed non-negative least squares ``y ~ a + b*x``.

    Old observations fade geometrically (``gamma`` per sample), so the fit
    tracks drifting behaviour; with a single observed ``x`` the slope
    collapses to 0 and the intercept to the decayed mean — exactly the
    right prediction for homogeneous edges.
    """

    __slots__ = ("gamma", "sw", "sx", "sy", "sxx", "sxy")

    def __init__(self, gamma: float = 0.98):
        self.gamma = gamma
        self.sw = self.sx = self.sy = self.sxx = self.sxy = 0.0

    def add(self, x: float, y: float) -> None:
        g = self.gamma
        self.sw = self.sw * g + 1.0
        self.sx = self.sx * g + x
        self.sy = self.sy * g + y
        self.sxx = self.sxx * g + x * x
        self.sxy = self.sxy * g + x * y

    def predict(self, x: float) -> float:
        if self.sw <= 0.0:
            return 0.0
        mean_y = self.sy / self.sw
        denom = self.sw * self.sxx - self.sx * self.sx
        if denom <= 1e-18 * max(1.0, self.sxx * self.sw):
            return mean_y
        b = (self.sw * self.sxy - self.sx * self.sy) / denom
        b = max(0.0, b)
        a = max(0.0, (self.sy - b * self.sx) / self.sw)
        return a + b * x


class DeploymentTelemetry:
    """Arrival, concurrency, cold-start, and reap windows for one deployment."""

    __slots__ = ("clock", "fast", "slow", "concurrency", "cold_starts",
                 "reaps", "n_arrivals", "n_reaps")

    def __init__(
        self,
        clock: Optional[Callable[[], float]] = None,
        fast_tau_s: float = 0.5,
        slow_tau_s: float = 2.0,
    ):
        self.clock = ensure_clock(clock)
        self.fast = DecayRate(fast_tau_s)
        self.slow = DecayRate(slow_tau_s)
        self.concurrency = DecayGauge(slow_tau_s)
        self.cold_starts = DecayRate(slow_tau_s)
        # keep-alive reaping is much rarer than arrivals: a longer window so
        # a few reaps carry signal for the spill predictor
        self.reaps = DecayRate(slow_tau_s * 8)
        self.n_arrivals = 0
        self.n_reaps = 0

    def record_arrival(self, t: float, in_flight: int) -> None:
        self.n_arrivals += 1
        self.fast.record(t)
        self.slow.record(t)
        self.concurrency.sample(t, float(in_flight))

    def record_arrivals(self, t: float, n: int, in_flight: int = 0) -> None:
        """One quantized same-timestamp bucket of ``n`` arrivals (the trace
        replay driver's unit of work): equivalent to ``n`` single records
        at ``t``, amortized to one decay step per window."""
        self.n_arrivals += n
        self.fast.record_n(t, n)
        self.slow.record_n(t, n)
        self.concurrency.sample(t, float(in_flight))

    def record_cold_start(self, t: float) -> None:
        self.cold_starts.record(t)

    def record_reap(self, t: float) -> None:
        """One idle instance scaled down past keep-alive (the scheduler's
        expiry reaper calls this on telemetry-backed deployments)."""
        self.n_reaps += 1
        self.reaps.record(t)

    def reap_rate(self, t: float) -> float:
        """Smoothed instance reaps/sec over the (long) reap window."""
        return self.reaps.rate(t)

    def expected_instance_lifetime_s(self, t: float) -> float:
        """Predicted survival of an idle instance, from the reap window.

        With no reaps observed the prediction is unbounded (``inf``) — the
        keep-alive policy floor is the caller's to apply.  With an observed
        reap rate ``r`` the mean inter-reap gap ``1/r`` is used as a
        *conservative* per-instance lifetime: it under-estimates survival on
        multi-instance fleets (whose per-instance lifetime is ~n/r), so a
        spill predictor reading it errs toward durable media, never toward
        losing an object with its producer."""
        r = self.reaps.rate(t)
        return math.inf if r <= 0.0 else 1.0 / r

    def arrival_rate(self, t: float) -> float:
        """Smoothed arrivals/sec (the fast, responsive estimate)."""
        return self.fast.rate(t)

    def arrival_trend(self, t: float) -> tuple:
        """(rate, slope_per_s): the fast estimate and its drift.

        The fast EWMA lags the true rate by ~``fast.tau_s`` and the slow one
        by ~``slow.tau_s``; their spread divided by the lag difference is a
        cheap O(1) slope estimate (positive while load ramps up)."""
        rf = self.fast.rate(t)
        rs = self.slow.rate(t)
        lag = self.slow.tau_s - self.fast.tau_s
        slope = (rf - rs) / lag if lag > 0.0 else 0.0
        return rf, slope

    def snapshot(self, t: Optional[float] = None) -> Dict[str, float]:
        t = self.clock() if t is None else t
        rate, slope = self.arrival_trend(t)
        return {
            "n_arrivals": float(self.n_arrivals),
            "arrival_rps": rate,
            "arrival_slope_rps_per_s": slope,
            "concurrency": self.concurrency.value(),
            "cold_start_rate": self.cold_starts.rate(t),
            "reap_rate": self.reaps.rate(t),
            "n_reaps": float(self.n_reaps),
        }


class MediumTelemetry:
    """Observed behaviour of one transfer medium: latency, cost, volume."""

    __slots__ = ("n", "bytes_total", "fee_usd_total", "latency_model",
                 "fee_model", "_latencies", "_p99", "_p99_dirty")

    #: recent-latency window backing the p99 estimate
    WINDOW = 256
    #: samples before the latency/fee-vs-size models are trusted over an
    #: analytic prior (chunk-size auto-tuning reads this via
    #: :meth:`TelemetryHub.medium_model`)
    MIN_MODEL_SAMPLES = 8
    #: the window is re-sorted at most once per REFRESH records, so a
    #: record/query interleave (every routed pull records, every resolve
    #: queries) amortizes the O(W log W) quantile to O(W log W / REFRESH)
    REFRESH = 16

    def __init__(self):
        self.n = 0
        self.bytes_total = 0
        self.fee_usd_total = 0.0
        self.latency_model = DecayedLinear()
        self.fee_model = DecayedLinear()
        self._latencies: deque = deque(maxlen=self.WINDOW)
        self._p99 = 0.0
        self._p99_dirty = False

    def record(self, nbytes: int, seconds: float, fee_usd: float) -> None:
        self.n += 1
        self.bytes_total += nbytes
        self.fee_usd_total += fee_usd
        gb = nbytes / 1e9
        self.latency_model.add(gb, seconds)
        self.fee_model.add(gb, fee_usd)
        self._latencies.append(seconds)
        # always fresh while the window is small (the sort is trivial),
        # amortized to every REFRESH-th record once it has filled out
        if self.n <= self.REFRESH or self.n % self.REFRESH == 0:
            self._p99_dirty = True

    def model_ready(self) -> bool:
        """Whether the size-conditioned models have enough samples to beat
        an analytic prior."""
        return self.n >= self.MIN_MODEL_SAMPLES

    def predict_seconds(self, nbytes: int) -> float:
        return self.latency_model.predict(nbytes / 1e9)

    def predict_fee_usd(self, nbytes: int) -> float:
        return self.fee_model.predict(nbytes / 1e9)

    def usd_per_gb(self) -> float:
        gb = self.bytes_total / 1e9
        return self.fee_usd_total / gb if gb > 0.0 else 0.0

    def p99_s(self) -> float:
        if self._p99_dirty:
            lat = sorted(self._latencies)
            self._p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))]
            self._p99_dirty = False
        return self._p99

    def snapshot(self) -> Dict[str, float]:
        return {
            "n": float(self.n),
            "bytes": float(self.bytes_total),
            "fee_usd": self.fee_usd_total,
            "usd_per_gb": self.usd_per_gb(),
            "p99_s": self.p99_s() if self.n else 0.0,
        }


class TelemetryHub:
    """One shared registry of deployment + medium telemetry.

    Create-on-first-use accessors keep wiring trivial: the scheduler asks
    for ``hub.deployment(name)``, the transfer engine calls
    ``hub.record_transfer(...)`` per pull, and a routing policy reads
    ``hub.media`` — all against one object whose clock is the substrate's
    injected clock.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = ensure_clock(clock)
        self.media: Dict[str, MediumTelemetry] = {}
        self.deployments: Dict[str, DeploymentTelemetry] = {}
        #: per-tenant arrival windows, fed by the trace replay driver —
        #: kept apart from ``deployments`` so a tenant named like a
        #: function never aliases an autoscaler's window
        self.tenants: Dict[str, DeploymentTelemetry] = {}
        #: injected-fault timeline, fed by :class:`repro.core.faults`'s
        #: injectors as each armed :class:`~repro.core.faults.FaultEvent`
        #: fires/ends — ``(virtual time, kind, detail)`` — so resilience
        #: reports and SLO guards can correlate tail-latency excursions with
        #: the adversity that caused them.  Empty (and never touched) when
        #: no fault plan is installed.
        self.faults: List[Tuple[float, str, str]] = []

    def medium(self, name: str) -> MediumTelemetry:
        tel = self.media.get(name)
        if tel is None:
            tel = self.media[name] = MediumTelemetry()
        return tel

    def medium_model(self, name: str) -> Optional[MediumTelemetry]:
        """The medium's telemetry iff its size-conditioned models are ready
        (:attr:`MediumTelemetry.MIN_MODEL_SAMPLES` observations), else
        ``None`` — the chunk-size auto-tuner's trust gate: too few samples
        and the caller keeps its analytic prior."""
        tel = self.media.get(name)
        if tel is not None and tel.model_ready():
            return tel
        return None

    def deployment(self, name: str, **kw) -> DeploymentTelemetry:
        tel = self.deployments.get(name)
        if tel is None:
            tel = self.deployments[name] = DeploymentTelemetry(self.clock, **kw)
        return tel

    def tenant(self, name: str, **kw) -> DeploymentTelemetry:
        tel = self.tenants.get(name)
        if tel is None:
            tel = self.tenants[name] = DeploymentTelemetry(self.clock, **kw)
        return tel

    def tenants_snapshot(self) -> Dict[str, Dict[str, float]]:
        t = self.clock()
        return {name: tel.snapshot(t) for name, tel in self.tenants.items()}

    def record_transfer(
        self, medium: str, nbytes: int, seconds: float, fee_usd: float = 0.0
    ) -> None:
        self.medium(medium).record(nbytes, seconds, fee_usd)

    def record_fault(self, kind: str, detail: str = "") -> None:
        """One injected-fault timeline entry at the current virtual time."""
        self.faults.append((self.clock(), kind, detail))

    def has_media_samples(self) -> bool:
        return any(m.n for m in self.media.values())

    def media_snapshot(self) -> Dict[str, Dict[str, float]]:
        return {name: m.snapshot() for name, m in self.media.items()}


__all__ = [
    "DecayGauge",
    "DecayRate",
    "DecayedLinear",
    "DeploymentTelemetry",
    "MediumTelemetry",
    "TelemetryHub",
]
