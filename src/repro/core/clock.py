"""Injected time source for the whole execution substrate.

Every component that makes time-dependent decisions — the autoscaler's
keep-alive reaper, cold-start gates, transfer accounting's GB-second
integrals, the workflow engine's latency records — reads time through a
``Clock`` instead of calling ``time.monotonic()`` directly.  Two
implementations:

:class:`MonotonicClock`
    Real wall time (``time.monotonic``).  The default everywhere, so
    interactive use behaves exactly as before.

:class:`VirtualClock`
    Bound to a discrete-event :class:`~repro.core.cluster.Simulator`; returns
    ``sim.now``.  Under this clock a 60-second keep-alive expiry is one heap
    pop, which makes autoscaling decisions exactly assertable in tests and
    lets the load-generator sweep minutes of offered load in milliseconds.

A clock is just a zero-argument callable returning seconds as ``float``, so
every existing ``clock: Callable[[], float]`` parameter accepts one.
"""
from __future__ import annotations

import time
from typing import Callable, Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """Zero-arg callable returning the current time in seconds."""

    def __call__(self) -> float: ...


class MonotonicClock:
    """Real time: delegates to ``time.monotonic``."""

    def __call__(self) -> float:
        return time.monotonic()

    def __repr__(self) -> str:
        return "MonotonicClock()"


class VirtualClock:
    """Simulator-driven time: reads ``sim.now``; advances only via events."""

    def __init__(self, sim):
        self.sim = sim

    def __call__(self) -> float:
        return self.sim.now          # always a float; no conversion on the hot path

    def __repr__(self) -> str:
        return f"VirtualClock(now={self.sim.now:.6f})"


def ensure_clock(clock: Callable[[], float] | None) -> Callable[[], float]:
    """``None`` -> a fresh MonotonicClock; anything else passes through."""
    return MonotonicClock() if clock is None else clock
