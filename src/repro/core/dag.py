"""Declarative workflow DAGs with per-edge transfer routing.

The paper's central observation is that the *communication medium of each
producer->consumer edge* — not the functions — decides a serverless
workflow's latency and bill.  This module makes that edge-level decision a
first-class, declarative object:

* :class:`Stage` — one named function of the workflow (fan = number of
  parallel instances, intrinsic compute seconds, orchestration style).
* :class:`Edge` — one producer->consumer data dependency carrying its own
  transfer policy: a fixed backend name (``"s3"``) or a :class:`RoutePolicy`
  resolved **per object at send time** (e.g. :class:`SizeRoute`: inline under
  a cutoff, XDT otherwise, S3 when the producer is marked evictable;
  :class:`AdaptiveRoute`: cheapest observed medium whose p99 fits the edge's
  latency budget, fed by the shared telemetry substrate).
* :class:`WorkflowDAG` — the validated graph.

Two lowerings share the one description:

``execute_on_cluster``
    Interprets the DAG on the calibrated discrete-event
    :class:`~repro.core.cluster.ServerlessCluster` — the Fig. 7 / Table 2
    measurement path.  For a fixed single backend this reproduces the
    legacy hand-rolled workload generators *bit-for-bit* (same op order,
    same rng draw order, same billing spans); ``tests/test_dag.py`` guards
    the equivalence differentially.

``WorkflowDAG.bind``
    Compiles the DAG onto the event-driven
    :class:`~repro.core.workflow.WorkflowEngine` via the existing
    generator-handler protocol: ``submit()``/``drain()``, at-most-once ids,
    producer-death retries, and virtual-time accounting are reused
    unchanged.  Real (scaled) arrays move through the
    :class:`~repro.core.transfer.TransferEngine`; every edge's objects are
    ``put`` on the medium its policy resolves, and per-edge bytes/latency
    plus per-medium op counts accumulate so
    :func:`repro.core.cost.routed_workflow_cost` prices the mixed run.

Cost attribution: per-edge storage fees are exact for request-fee media
(S3: the edge's own PUT/GET counts) and proportional for capacity-billed
media (ElastiCache: the edge's share of bytes staged, since capacity is
provisioned for the run-level peak, which no single edge owns).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Generator, List, Optional, Sequence, Tuple, Union

import numpy as np

from .clock import VirtualClock
from .cluster import DEFAULT_NET, NetConstants, ServerlessCluster
from .cost import (
    S3_GET_USD,
    S3_PUT_USD,
    StorageOps,
    WorkflowCostInputs,
    egress_fee_usd,
    elasticache_storage_cost,
    marginal_pull_fee_usd,
    routed_workflow_cost,
    transfer_fee_usd,
)
from .scheduler import ControlPlane, ScalingPolicy
from .telemetry import TelemetryHub
from .topology import Topology
from .transfer import modeled_transfer_seconds

#: media whose transfers go through a storage service in the cluster model
_STORAGE_MEDIA = ("s3", "elasticache")
#: media a cluster-interpreted edge may resolve to
_CLUSTER_MEDIA = ("s3", "elasticache", "xdt", "inline")

#: engine-lowering kill-switch for the streaming fast path: when True,
#: same-instant same-(object, medium) chunk runs publish and drain through
#: the span kernels (one dispatch, one billed request, columnar refs); when
#: False every chunk is an individual put/pull — the pre-coalescing
#: behavior, kept reachable so benchmarks can measure the speedup and users
#: can bisect a suspected fast-path divergence.  Virtual-time results are
#: bit-identical either way.
STREAM_COALESCE = True


# ---------------------------------------------------------------------------
# Routing policies
# ---------------------------------------------------------------------------


class RoutePolicy:
    """Decides the transfer medium of one object at send time.

    ``resolve`` sees the edge, the object's size, and whether the producer
    stage is marked evictable (its instance may be reclaimed before the
    last retrieval, so instance-resident media would lose the object)."""

    def resolve(self, edge: "Edge", nbytes: int, evictable: bool) -> str:
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


class FixedRoute(RoutePolicy):
    """Always the same medium (equivalent to a plain backend-name string)."""

    def __init__(self, backend: str):
        self.backend = backend

    def resolve(self, edge, nbytes, evictable):
        return self.backend

    def describe(self):
        return self.backend


class SizeRoute(RoutePolicy):
    """Size/handoff-aware routing: inline under a cutoff, XDT otherwise,
    durable storage when the producer is marked evictable.

    This is the paper-motivated hybrid: small objects on *sync* handoffs
    ride the invocation message itself (no storage bill, no extra hop —
    inline only exists where an invoke accompanies the payload; on staged
    fan-in/fan-out edges the consumers fetch without an invoke, so inlining
    would add a control-plane round-trip and lose), bulk objects move over
    the producer NIC via XDT, and only objects that must outlive their
    producer pay a through-storage service.
    """

    def __init__(
        self,
        inline_under: int = 1 << 10,
        default: str = "xdt",
        durable: str = "s3",
    ):
        self.inline_under = inline_under
        self.default = default
        self.durable = durable

    def resolve(self, edge, nbytes, evictable):
        if edge.handoff == "external":
            # original input predates the workflow: only a durable service
            # can serve it (inlining or instance-resident media are
            # impossible, not merely slow)
            return self.durable
        if evictable:
            return self.durable
        if (
            edge.handoff == "sync" and nbytes < self.inline_under
            and not edge.streaming
        ):
            return "inline"
        return self.default

    def describe(self):
        return (
            f"inline<{self.inline_under}B sync, else {self.default}, "
            f"{self.durable} if evictable"
        )


class AdaptiveRoute(RoutePolicy):
    """Feedback routing: pick the medium from *observed* telemetry.

    Reads the shared :class:`~repro.core.telemetry.TelemetryHub` — observed
    per-medium $/GB (fee model) and p99 pull latency — and picks, per object
    at send time, the **cheapest medium whose observed p99 fits the edge's
    ``latency_budget_s``** (no budget: cheapest overall, latency as the
    tie-break).  Media the feed has not observed yet are scored with
    calibrated priors — the price sheet
    (:func:`repro.core.cost.transfer_fee_usd`) for fees and the latency
    model (:func:`repro.core.transfer.modeled_transfer_seconds`) for p99 —
    so cheap or fast untried media keep getting explored instead of the
    router locking onto its first observed choice.

    Hard constraints always dominate the scores: evictable producers only
    route to durable media, external (original-input) edges only to
    through-storage, and inlining only exists on sync handoffs under the
    activator payload cap.

    Until the hub has *any* samples the policy defers entirely to its
    ``static`` fallback (default: the paper-motivated :class:`SizeRoute`) —
    cold-start routing is never guessed from an empty feed.  Both lowerings
    bind an unbound hub automatically: ``dag.bind`` wires the workflow
    engine's ``TransferEngine.telemetry`` (real per-pull observations),
    ``execute_on_cluster`` feeds a run-local hub per resolved edge object.

    **Decaying exploration.**  A purely-observed score can lock a medium out
    forever: one freak sample (transient congestion, a mispriced first pull)
    makes it look bad, it never gets traffic again, so its model never
    recovers even after the medium turns cheap.  The policy therefore routes
    an occasional *probe* to the least-observed candidate: after
    ``explore_every`` budget-free resolves where every candidate has samples,
    one object is steered to the thinnest feed, and the interval until the
    next probe grows by ``explore_growth ** n`` in that candidate's sample
    count — an exploration bonus that decays exponentially as evidence
    accumulates, so a converged router spends a vanishing fraction of
    traffic re-checking its losers.  Probes never fire on edges with a
    latency budget (learning must not risk an SLO) and never override the
    hard constraints.  ``explore_every=0`` disables probing.

    **Time-decayed re-probe (blacklist recovery).**  Sample-count probing
    cannot recover a medium a *fault window* poisoned: with
    ``explore_every=0`` (or on budgeted edges, where count probes never
    fire) a candidate whose windowed p99 was inflated by penalty samples
    is filtered out of the feasible set on every resolve, gets no
    traffic, and its latency window never refills with healthy samples —
    the blackout outlives the fault.  ``reprobe_after_s > 0`` adds a
    wall-clock escape hatch: a candidate the router has not picked for at
    least that long is routed one probe object regardless of its score,
    and the interval until its next probe grows by ``reprobe_growth`` per
    consecutive timed probe (reset whenever the medium wins on merit
    again).  Unlike the count probe this *deliberately* fires on budgeted
    edges — a poisoned p99 keeps the medium infeasible forever otherwise,
    so the timed probe is the only path back into the feasible set.
    ``reprobe_after_s=0`` (default) disables it.

    **Uncertainty bonus (``explore_bonus``).**  Orthogonal to both probes:
    an *explicit* optimism-under-uncertainty discount on observed scores.
    Each observed candidate's (fee, p99) is scaled by
    ``1 / (1 + explore_bonus / (1 + n_samples))`` — a thinly-observed medium
    looks a little better than its evidence, so a medium condemned by a few
    drifted samples keeps winning occasional merit traffic and its model can
    recover; the bonus vanishes as evidence accumulates.  ``explore_bonus=0``
    (default) scores exactly the raw observations.

    **Topology-derived priors.**  Under an edge-cloud topology the lowerings
    install a prior hook (see :meth:`auto_bind`): unobserved media are scored
    with the flat price-sheet/latency priors *plus* the cross-tier egress fee
    and tier RTT/bandwidth seconds of the edge's actual producer/consumer
    zones — so the router never has to burn real objects to learn that an
    edge-crossing medium is expensive.  Observed media need no hook: the
    lowerings feed tier-inclusive fees and latencies into the hub.
    """

    #: media a durable (producer-death-surviving) decision may pick
    DURABLE = ("s3", "elasticache")

    def __init__(
        self,
        telemetry: Optional[TelemetryHub] = None,
        static: Optional[RoutePolicy] = None,
        inline_under: Optional[int] = None,
        net: NetConstants = DEFAULT_NET,
        explore_every: int = 256,
        explore_growth: float = 4.0,
        reprobe_after_s: float = 0.0,
        reprobe_growth: float = 2.0,
        explore_bonus: float = 0.0,
    ):
        self.telemetry = telemetry
        self.explore_every = explore_every
        self.explore_growth = explore_growth
        self.explore_bonus = explore_bonus
        #: lowering-installed topology prior: (edge, medium, nbytes) ->
        #: (extra_fee_usd, extra_seconds) added to unobserved-media priors
        self._prior_extra = None
        self._probe_countdown = explore_every
        self.reprobe_after_s = reprobe_after_s
        self.reprobe_growth = reprobe_growth
        #: medium -> clock time it was last routed an object (merit or probe)
        self._last_pick: Dict[str, float] = {}
        #: medium -> consecutive timed probes since its last merit win
        self._reprobe_n: Dict[str, int] = {}
        #: True when a lowering (not the user) supplied the hub: the next
        #: bind/execute re-binds to ITS hub, so one route instance reused
        #: across runs never keeps feeding off a previous run's dead feed
        self._auto_bound = False
        self.net = net
        self.inline_under = (
            net.inline_limit if inline_under is None else inline_under
        )
        self.static = static or SizeRoute(inline_under=self.inline_under)

    def auto_bind(
        self, hub: Optional[TelemetryHub], prior_extra=None
    ) -> Optional[TelemetryHub]:
        """Bind a lowering-supplied hub and return the effective one.

        A user-pinned hub (passed to the constructor) is kept; a hub a
        previous lowering auto-bound is replaced, so one route instance
        reused across runs never keeps feeding off a dead run's feed.  Both
        lowerings route every bind through here — the rebind rule lives
        only on the policy.  ``prior_extra`` installs (or, when None, clears)
        the run's topology prior hook — it is per-run state like the
        auto-bound hub, never carried across lowerings."""
        self._prior_extra = prior_extra
        if self.telemetry is None or self._auto_bound:
            self.telemetry = hub
            self._auto_bound = True
        return self.telemetry

    def _candidates(self, edge: "Edge", nbytes: int, evictable: bool):
        if edge.handoff == "external":
            return list(_STORAGE_MEDIA)
        if evictable:
            return list(self.DURABLE)
        cands = ["xdt", "s3", "elasticache"]
        if (
            edge.handoff == "sync" and nbytes < self.inline_under
            and not edge.streaming
        ):
            cands.insert(0, "inline")
        return cands

    def _maybe_probe(self, cands, hub) -> Optional[str]:
        """The decaying-exploration probe: every ``explore_every`` eligible
        resolves, steer one object to the least-observed candidate, then
        back off exponentially in its sample count.  Only fires when every
        candidate has samples (unobserved media already explore via priors)
        and an observation skew actually exists."""
        counts = []
        for m in cands:
            stats = hub.media.get(m)
            if stats is None or not stats.n:
                return None              # priors handle the unobserved one
            counts.append((stats.n, m))
        self._probe_countdown -= 1
        if self._probe_countdown > 0:
            return None
        n_min, m_min = min(counts)
        self._probe_countdown = max(
            1, int(self.explore_every * self.explore_growth ** n_min)
        )
        return m_min if n_min < max(counts)[0] else None

    def _timed_reprobe(self, cands, hub, now: float) -> Optional[str]:
        """The wall-clock blacklist-recovery probe: the first OBSERVED
        candidate the router has not routed to for ``reprobe_after_s``
        (backed off by ``reprobe_growth`` per consecutive probe).  Only
        media with samples qualify — an unobserved candidate is scored by
        calibrated priors and therefore already explorable, so probing it
        would spend real objects to learn nothing (and make the adaptive
        cell strictly worse than static in fault-free runs).  A candidate
        observed but never timed just starts its timer.  Fires on budgeted
        edges too — a p99 poisoned by fault-penalty samples keeps a medium
        out of the feasible set forever, so this is its only way back in."""
        for m in cands:
            stats = hub.media.get(m)
            if stats is None or not stats.n:
                continue
            last = self._last_pick.get(m)
            if last is None:
                self._last_pick[m] = now
                continue
            n = self._reprobe_n.get(m, 0)
            if now - last >= self.reprobe_after_s * self.reprobe_growth ** n:
                self._last_pick[m] = now
                self._reprobe_n[m] = n + 1
                return m
        return None

    def resolve(self, edge, nbytes, evictable):
        hub = self.telemetry
        if hub is None or not hub.has_media_samples():
            return self.static.resolve(edge, nbytes, evictable)
        budget = edge.latency_budget_s
        cands = self._candidates(edge, nbytes, evictable)
        now = hub.clock() if self.reprobe_after_s > 0.0 else 0.0
        if self.reprobe_after_s > 0.0:
            probe = self._timed_reprobe(cands, hub, now)
            if probe is not None:
                return probe
        if self.explore_every and budget <= 0.0:
            probe = self._maybe_probe(cands, hub)
            if probe is not None:
                return probe
        scored = []                      # (medium, fee, p99-or-prior)
        for m in cands:
            stats = hub.media.get(m)
            if stats is not None and stats.n:
                fee, lat = stats.predict_fee_usd(nbytes), stats.p99_s()
                if self.explore_bonus:
                    # optimism under uncertainty: thin evidence scores a
                    # little better than it reads, decaying in sample count
                    w = 1.0 / (1.0 + self.explore_bonus / (1.0 + stats.n))
                    fee *= w
                    lat *= w
                scored.append((m, fee, lat))
            else:
                # unobserved medium: calibrated priors keep it explorable
                # (fee-tied media would otherwise never be tried)
                fee = transfer_fee_usd(m, nbytes)
                lat = modeled_transfer_seconds(m, nbytes, self.net)
                if self._prior_extra is not None:
                    extra_fee, extra_s = self._prior_extra(edge, m, nbytes)
                    fee += extra_fee
                    lat += extra_s
                scored.append((m, fee, lat))
        if budget > 0.0:
            feasible = [s for s in scored if s[2] <= budget]
            if feasible:
                scored = feasible
            else:                        # nothing fits the budget: fastest
                chosen = min(scored, key=lambda s: s[2])[0]
                if self.reprobe_after_s > 0.0:
                    self._last_pick[chosen] = now
                    self._reprobe_n[chosen] = 0
                return chosen
        chosen = min(scored, key=lambda s: (s[1], s[2]))[0]
        if self.reprobe_after_s > 0.0:
            # a merit win resets the medium's probe backoff and timer
            self._last_pick[chosen] = now
            self._reprobe_n[chosen] = 0
        return chosen

    def describe(self):
        return f"adaptive(telemetry, fallback: {self.static.describe()})"


Route = Union[str, RoutePolicy]


# ---------------------------------------------------------------------------
# The declarative graph
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stage:
    """One workflow function.

    ``blocking=True`` (vSwarm semantics) means the stage is invoked by the
    producer of its in-edge, which stalls — and keeps billing — until the
    stage's whole subtree completes.  ``blocking=False`` stages are
    orchestrated (Step-Functions style): the entry stage spawns them in
    dependency waves and its wait is *not* billed.

    ``gather_compute_s`` is entry-only epilogue compute (e.g. SET's model
    reconciliation) billed in a second ``<entry>_gather`` span together with
    the gather edges.  ``evictable`` marks the stage's instances as
    reclaimable before their objects' last retrieval — durable routing
    policies send such edges through storage.
    """

    name: str
    fan: int = 1
    compute_s: float = 0.0
    gather_compute_s: float = 0.0
    blocking: bool = True
    evictable: bool = False


@dataclasses.dataclass(frozen=True)
class Edge:
    """One producer->consumer data dependency with its transfer policy.

    * ``src=None`` marks ORIGINAL input living in S3 (the paper never
      optimizes it); ``handoff`` must be ``"external"`` and the route a
      through-storage medium.
    * ``handoff="sync"`` — the blocking-invoke handoff: the producer's
      buffer is published at invoke time and the consumer's billed span
      covers publish + control hop + retrieval (vSwarm 1-1/scatter edges).
    * ``handoff="staged"`` — the producer stages objects in its own billed
      span; consumers fetch later with no control hop (datasets, shuffles,
      gathers).
    * ``fanout="broadcast"`` — the producer stages ``n_objects`` once and
      EVERY consumer instance fetches all of them; ``"partition"`` — each
      (producer, consumer) pair exchanges ``n_objects`` private objects.
    * ``concurrency`` bounds one consumer's parallel fetches (0 =
      unbounded; 1 = the sync-SDK sequential loop of the paper's baselines).
    * ``latency_budget_s`` is the edge's per-object transfer latency budget
      (0 = none): :class:`AdaptiveRoute` picks the cheapest medium whose
      observed p99 fits it.
    * ``streaming=True`` chunks every object into ``chunk_bytes`` pieces the
      producer publishes *while still computing* and the consumer pulls as
      they land (DataFlower-style overlap).  Route policies resolve **per
      chunk**, so one logical object may split across media; ``inline`` is
      refused outright — chunks outlive the sync handoff message, exactly
      like staged/external objects outlive an invoke.
    * ``chunk_bytes="auto"`` defers the chunk size to the telemetry-tuned
      resolver (:func:`resolve_auto_chunk_bytes`): scored per (edge, medium)
      at stream start from the TelemetryHub latency-vs-size models with the
      analytic streamed-pull recurrence as the prior, and re-scored
      mid-stream whenever the per-chunk route decision lands on a new
      medium.
    * ``max_inflight_chunks`` (streaming only) is the producer's credit
      window: at most that many instance-resident chunks may be published
      but not yet fully pulled.  Exhausted credits block the producer's
      ``put_chunk`` on the virtual clock (engine lowering) or stretch the
      overlap recurrence (cluster lowering); persistent zero-credit triggers
      ``OnlineSpill``'s spill-on-pressure, diverting the remaining stream
      durable.  ``0`` = unbounded (a slow consumer buffers the stream).
    """

    src: Optional[str]
    dst: str
    nbytes: int
    label: str = ""
    route: Route = "default"
    handoff: str = "sync"            # sync | staged | external
    fanout: str = "partition"        # partition | broadcast
    n_objects: int = 1
    concurrency: int = 0
    latency_budget_s: float = 0.0
    streaming: bool = False
    chunk_bytes: Any = 0             # int bytes, or "auto" (telemetry-tuned)
    max_inflight_chunks: int = 0

    def __post_init__(self):
        if not self.label:
            object.__setattr__(
                self, "label", f"{self.src or 's3-input'}->{self.dst}"
            )
        if self.handoff not in ("sync", "staged", "external"):
            raise ValueError(f"unknown handoff {self.handoff!r}")
        if self.fanout not in ("partition", "broadcast"):
            raise ValueError(f"unknown fanout {self.fanout!r}")
        if self.src is None and self.handoff != "external":
            raise ValueError("src=None (original input) requires handoff='external'")
        if self.handoff == "external" and self.src is not None:
            raise ValueError("external edges have src=None")
        if self.streaming:
            if self.chunk_bytes != "auto" and (
                not isinstance(self.chunk_bytes, int) or self.chunk_bytes <= 0
            ):
                raise ValueError(
                    f"streaming edge {self.label!r} needs chunk_bytes > 0 "
                    "(or 'auto')"
                )
            if self.max_inflight_chunks < 0:
                raise ValueError(
                    f"streaming edge {self.label!r}: max_inflight_chunks "
                    "must be >= 0 (0 = unbounded)"
                )
            if self.handoff == "external":
                raise ValueError(
                    f"streaming edge {self.label!r}: original (external) "
                    "input predates the workflow, there is no producer to "
                    "stream from"
                )
            if self.route == "inline":
                # mirrors the staged/external refusal: a chunk outlives the
                # sync handoff message it would have to ride
                raise ValueError(
                    f"streaming edge {self.label!r} cannot route 'inline': "
                    "chunks outlive the sync handoff message"
                )
        elif self.chunk_bytes:
            raise ValueError(
                f"edge {self.label!r}: chunk_bytes requires streaming=True"
            )
        elif self.max_inflight_chunks:
            raise ValueError(
                f"edge {self.label!r}: max_inflight_chunks requires "
                "streaming=True"
            )

    def chunk_sizes(self, chunk_bytes: Optional[int] = None) -> Tuple[int, ...]:
        """Per-chunk byte sizes of ONE logical object of this edge: full
        ``chunk_bytes`` pieces plus the remainder tail (never empty).

        ``chunk_bytes`` overrides the declared size — how a resolved
        ``"auto"`` size (per medium, from :func:`resolve_auto_chunk_bytes`)
        is applied without mutating the frozen edge."""
        cb = self.chunk_bytes if chunk_bytes is None else chunk_bytes
        if cb == "auto":
            raise ValueError(
                f"streaming edge {self.label!r}: chunk_bytes='auto' must be "
                "resolved against a medium first (resolve_auto_chunk_bytes)"
            )
        if not self.streaming or self.nbytes <= cb:
            return (self.nbytes,)
        n_full, tail = divmod(self.nbytes, cb)
        sizes = [cb] * n_full
        if tail:
            sizes.append(tail)
        return tuple(sizes)


class WorkflowDAG:
    """A validated workflow graph; ``stages[0]`` is the entry stage."""

    def __init__(self, name: str, stages: Sequence[Stage], edges: Sequence[Edge]):
        self.name = name
        self.stages: Tuple[Stage, ...] = tuple(stages)
        self.edges: Tuple[Edge, ...] = tuple(edges)
        if not self.stages:
            raise ValueError("a DAG needs at least one stage")
        self.by_name: Dict[str, Stage] = {}
        for s in self.stages:
            if s.name in self.by_name:
                raise ValueError(f"duplicate stage {s.name!r}")
            self.by_name[s.name] = s
        self.entry = self.stages[0]
        labels = set()
        for e in self.edges:
            if e.src is not None and e.src not in self.by_name:
                raise ValueError(f"edge {e.label!r}: unknown src {e.src!r}")
            if e.dst not in self.by_name:
                raise ValueError(f"edge {e.label!r}: unknown dst {e.dst!r}")
            if e.label in labels:
                raise ValueError(f"duplicate edge label {e.label!r}")
            labels.add(e.label)
        self._validate()

    # -- structure ---------------------------------------------------------
    def gather_edges(self) -> List[Edge]:
        """Back-edges into the entry (fan-in results), fetched in the
        entry's ``_gather`` epilogue span."""
        return [e for e in self.edges if e.dst == self.entry.name]

    def in_edges(self, stage: Stage) -> List[Edge]:
        if stage.name == self.entry.name:
            return []
        return [e for e in self.edges if e.dst == stage.name]

    def out_edges(self, stage: Stage) -> List[Edge]:
        return [e for e in self.edges if e.src == stage.name]

    def blocking_children(self, stage: Stage) -> List[Stage]:
        seen, out = set(), []
        for e in self.out_edges(stage):
            child = self.by_name[e.dst]
            if child.blocking and child.name != self.entry.name and child.name not in seen:
                seen.add(child.name)
                out.append(child)
        return out

    def orchestrated_waves(self) -> List[List[Stage]]:
        """Non-blocking stages grouped into dependency waves: a stage runs
        once every non-entry producer of its in-edges has run."""
        pending = [s for s in self.stages if not s.blocking and s is not self.entry]
        done = {self.entry.name}
        waves: List[List[Stage]] = []
        while pending:
            wave = [
                s for s in pending
                if all(
                    e.src is None or e.src in done for e in self.in_edges(s)
                )
            ]
            if not wave:
                raise ValueError(f"cycle among orchestrated stages: "
                                 f"{[s.name for s in pending]}")
            for s in wave:
                done.add(s.name)
            pending = [s for s in pending if s.name not in done]
            waves.append(wave)
        return waves

    def _validate(self) -> None:
        entry = self.entry
        if entry.fan != 1:
            raise ValueError("entry stage must have fan=1")
        blocking = [
            s for s in self.stages if s.blocking and s is not entry
        ]
        orchestrated = [
            s for s in self.stages if not s.blocking and s is not entry
        ]
        if blocking and orchestrated:
            raise ValueError(
                "mixed blocking and orchestrated stages are not supported "
                "in one DAG (pick vSwarm chains OR Step-Functions style)"
            )
        if blocking and (self.gather_edges() or entry.gather_compute_s > 0):
            # a blocking chain's results return via the call tree; staged
            # gather edges would be PUT (and billed) but never fetched
            raise ValueError(
                "gather edges into the entry (and gather_compute_s) require "
                "orchestrated stages (blocking=False)"
            )
        for s in blocking:
            ins = self.in_edges(s)
            if len(ins) != 1 or ins[0].handoff != "sync" or ins[0].src is None:
                raise ValueError(
                    f"blocking stage {s.name!r} needs exactly one sync in-edge"
                )
            if self.by_name[ins[0].src].fan != 1:
                raise ValueError(
                    f"blocking stage {s.name!r}: producer fan must be 1"
                )
        for e in self.edges:
            if e.fanout == "broadcast" and e.src is not None:
                if self.by_name[e.src].fan != 1:
                    raise ValueError(
                        f"broadcast edge {e.label!r}: producer fan must be 1"
                    )
            if e.handoff == "external" and isinstance(e.route, str) and (
                e.route not in _STORAGE_MEDIA
            ):
                raise ValueError(
                    f"external edge {e.label!r} must route to storage "
                    f"({_STORAGE_MEDIA}), got {e.route!r}"
                )
        self.orchestrated_waves()       # raises on cycles

    # -- routing -----------------------------------------------------------
    def route_resolver(self, default: Route) -> Callable[[Edge, int], str]:
        """(edge, nbytes) -> medium, applying the run default to
        ``route="default"`` edges and policies per object at send time.

        Every resolution must name a concrete medium in ``_CLUSTER_MEDIA``:
        aggregate backends like ``"hybrid"`` (two-tier cache+object storage)
        cannot be attributed per edge, so they are rejected here — on both
        lowerings.  External (original-input) edges must additionally land
        on a through-storage medium: string routes are rejected at
        construction, policy routes here — instance-resident media can't
        serve data that predates the workflow, and pricing the input GETs
        as free would silently violate the paper's 'original data is never
        optimized' invariant."""

        def resolve(edge: Edge, nbytes: int) -> str:
            route = edge.route
            if route == "default":
                route = default
            if isinstance(route, RoutePolicy):
                evictable = (
                    edge.src is not None and self.by_name[edge.src].evictable
                )
                medium = route.resolve(edge, nbytes, evictable)
            else:
                medium = route
            if medium not in _CLUSTER_MEDIA:
                raise ValueError(
                    f"edge {edge.label!r} routed to {medium!r}; per-edge "
                    f"routable media are {_CLUSTER_MEDIA}"
                )
            if edge.handoff == "external" and medium not in _STORAGE_MEDIA:
                raise ValueError(
                    f"external edge {edge.label!r} must resolve to storage "
                    f"({_STORAGE_MEDIA}), got {medium!r}"
                )
            if edge.streaming and medium == "inline":
                raise ValueError(
                    f"streaming edge {edge.label!r} resolved to 'inline': "
                    "chunks outlive the sync handoff message (route policies "
                    "must skip inline when edge.streaming)"
                )
            return medium

        return resolve

    # -- optimization ------------------------------------------------------
    def optimize(
        self,
        passes: Optional[Sequence[Any]] = None,
        telemetry: Optional[TelemetryHub] = None,
        scaling: Optional[Callable[[Stage], ScalingPolicy]] = None,
        fault_plan: Any = None,
        topology: Optional[Topology] = None,
        backend: Any = None,
    ) -> Tuple["WorkflowDAG", Any]:
        """Run the graph optimizer; returns (optimized DAG, PlacementPlan).

        Composable passes (see :mod:`repro.core.dagopt`): ``"fuse"`` merges
        1:1 sync chains so the handoff never leaves the instance,
        ``"coplace"`` emits producer->consumer affinity hints the
        scheduler's steering honors, ``"spill"`` rewrites staged edges onto
        durable media when the telemetry feed predicts the producer's
        keep-alive expiry beats the consumer's pull.  Hand the returned
        plan to ``compile(..., plan=plan)``; this DAG itself is never
        mutated.

        ``fault_plan`` makes the spill pass fault-aware: a plan that
        *schedules* evictions needs no telemetry prediction — staged
        instance-resident edges are rewritten durable outright.

        ``topology`` makes the co-placement pass tier-aware: each stage is
        greedily assigned the zone minimizing (egress fees, tier seconds)
        against its already-placed neighbors (workload pins honored), the
        chosen zones land in ``plan.zones``, and cross-zone affinity hints
        are refused.  ``backend`` is the run's intended default route — a
        hint the zone cost model uses to price service-homed vs
        instance-resident transfers correctly.
        """
        from .dagopt import DEFAULT_PASSES, optimize as _optimize

        return _optimize(
            self,
            passes=DEFAULT_PASSES if passes is None else passes,
            telemetry=telemetry,
            scaling=scaling,
            fault_plan=fault_plan,
            topology=topology,
            backend=backend,
        )

    # -- compilation (the one run API) -------------------------------------
    def compile(
        self,
        target: str = "cluster",
        backend: Any = None,
        engine: Any = None,
        net: NetConstants = DEFAULT_NET,
        plan: Any = None,
        faults: Any = None,
        telemetry: Optional[TelemetryHub] = None,
        topology: Optional[Topology] = None,
        autoscaler: Any = None,
        scaling: Optional[Callable[[Stage], ScalingPolicy]] = None,
        online_spill: Any = None,
        bytes_scale: float = 1.0,
        policy: Optional[Callable[[Stage], Any]] = None,
        handlers: Optional[Dict[str, Callable]] = None,
    ) -> "Runnable":
        """Compile this DAG for one of the two lowerings; returns a
        :class:`Runnable`.

        ``target="cluster"`` (default) compiles onto the calibrated
        discrete-event cluster: ``backend`` (required) is the default route
        applied to ``route="default"`` edges, and ``run(seed=...,
        deterministic=...)`` on the returned :class:`ClusterRunnable`
        executes one seeded run, returning a :class:`ClusterDagRun`.

        ``target="engine"`` compiles onto a real
        :class:`~repro.core.workflow.WorkflowEngine` (``engine`` required):
        the returned :class:`DagBinding` registers one handler per stage;
        ``backend`` doubles as the binding's default route (``None`` means
        the engine's transfer backend).  ``bytes_scale`` / ``policy`` /
        ``handlers`` are engine-only knobs (see :class:`DagBinding`).

        Cross-cutting options mean the same thing on both targets:
        ``plan`` is the :class:`~repro.core.dagopt.PlacementPlan` from
        :meth:`optimize`; ``faults`` is a
        :class:`~repro.core.faults.FaultPlan` (armed via the cluster's
        fault interpreter or a :class:`~repro.core.faults.FaultInjector`
        installed on the engine); ``telemetry`` pins the hub adaptive
        routes feed on; ``topology`` places the run on an edge-cloud
        continuum (:class:`~repro.core.topology.Topology`).
        """
        if target == "cluster":
            if engine is not None:
                raise ValueError(
                    "compile(target='cluster') takes no engine; pass "
                    "target='engine' to lower onto a WorkflowEngine"
                )
            if backend is None:
                raise ValueError(
                    "compile(target='cluster') requires a backend (the "
                    "default route for route='default' edges)"
                )
            for arg, name in ((policy, "policy"), (handlers, "handlers")):
                if arg is not None:
                    raise ValueError(
                        f"compile(target='cluster') does not take {name!r} "
                        "(engine-only option)"
                    )
            return ClusterRunnable(
                self, backend=backend, net=net, plan=plan, faults=faults,
                telemetry=telemetry, topology=topology,
                autoscaler=autoscaler, scaling=scaling,
                online_spill=online_spill,
            )
        if target == "engine":
            if engine is None:
                raise ValueError(
                    "compile(target='engine') requires an engine "
                    "(a repro.core.workflow.WorkflowEngine)"
                )
            binding = DagBinding(
                self, engine, backend, bytes_scale, policy,
                handlers=handlers, autoscaler=autoscaler, plan=plan,
                online_spill=online_spill, topology=topology,
            )
            if telemetry is not None:
                # pin the engine's transfer hub so adaptive routes (and the
                # caller) observe this run's pulls on the supplied hub
                engine.transfer.telemetry = telemetry
                for e in (self.edges):
                    r = e.route
                    if isinstance(r, AdaptiveRoute):
                        r.auto_bind(telemetry)
                if isinstance(binding.default_route, AdaptiveRoute):
                    binding.default_route.auto_bind(telemetry)
            if faults is not None:
                from .faults import FaultInjector

                binding.fault_injector = FaultInjector(engine, faults).install()
            return binding
        raise ValueError(
            f"unknown compile target {target!r}; expected 'cluster' or "
            "'engine'"
        )

    # -- engine lowering (deprecated spelling) ------------------------------
    def bind(
        self,
        engine,
        default_route: Optional[Route] = None,
        bytes_scale: float = 1.0,
        policy: Optional[Callable[[Stage], Any]] = None,
        handlers: Optional[Dict[str, Callable]] = None,
        autoscaler: Any = None,
        plan: Any = None,
        online_spill: Any = None,
    ) -> "DagBinding":
        """Deprecated: use ``compile(target="engine", engine=...,
        backend=...)``.  Kept as a thin shim — same semantics, same bits."""
        warnings.warn(
            "WorkflowDAG.bind() is deprecated; use "
            "dag.compile(target='engine', engine=..., backend=...).",
            DeprecationWarning,
            stacklevel=2,
        )
        return DagBinding(
            self, engine, default_route, bytes_scale, policy,
            handlers=handlers, autoscaler=autoscaler, plan=plan,
            online_spill=online_spill,
        )


# ---------------------------------------------------------------------------
# Per-edge usage accounting (shared by both lowerings)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EdgeUsage:
    """What one edge actually did: objects/bytes per medium, ops, time.

    An edge's objects are homogeneous (one declared size, one evictability),
    so the shipped policies resolve every object of an edge identically; a
    stateful policy may still split one edge across media, which the
    per-medium tallies keep exact for the capacity share.  The storage-op
    counters (``n_puts``/``n_gets``) are edge totals: request fees are
    attributed wholly to the edge that performed them."""

    media: Dict[str, int] = dataclasses.field(default_factory=dict)
    media_bytes: Dict[str, int] = dataclasses.field(default_factory=dict)
    bytes_moved: int = 0
    n_puts: int = 0
    n_gets: int = 0
    n_local: int = 0                 # pulls that took the co-placed memcpy path
    put_s: float = 0.0               # producer-side staging time (summed)
    fetch_s: float = 0.0             # consumer-side retrieval time (summed)
    modeled_s: float = 0.0           # engine lowering: modeled pull seconds
    peak_inflight_chunk_bytes: float = 0.0  # max unconsumed streamed bytes

    def count(self, medium: str, nbytes: int) -> None:
        self.media[medium] = self.media.get(medium, 0) + 1
        self.media_bytes[medium] = self.media_bytes.get(medium, 0) + nbytes
        self.bytes_moved += nbytes

    def storage_fee_usd(self, ec_capacity_usd_per_byte: float = 0.0) -> float:
        """This edge's attributed storage bill: exact request fees for S3,
        a bytes-proportional share of provisioned capacity for ElastiCache
        (the run-level peak is not separable per edge; only the bytes this
        edge actually staged there count), zero for XDT/inline.
        """
        fee = 0.0
        if self.media.get("s3"):
            fee += self.n_puts * S3_PUT_USD + self.n_gets * S3_GET_USD
        ec_bytes = self.media_bytes.get("elasticache", 0)
        if ec_bytes:
            fee += ec_bytes * ec_capacity_usd_per_byte
        return fee


def _media_ops(accts, now: float) -> Dict[str, StorageOps]:
    """Per-medium :class:`StorageOps` from ``(medium, TransferAccounting)``
    pairs, GB-second integration touched to ``now``.  Media that performed
    no storage ops are omitted.  Shared by both lowerings' reporting."""
    out: Dict[str, StorageOps] = {}
    for medium, acct in accts:
        acct.touch(now)
        if acct.n_storage_puts or acct.n_storage_gets:
            out[medium] = StorageOps(
                n_puts=acct.n_storage_puts,
                n_gets=acct.n_storage_gets,
                gb_seconds=acct.storage_gb_seconds,
                peak_resident_gb=acct.peak_resident_gb,
            )
    return out


def _edge_fee_rows(
    edge_usage: Dict[str, EdgeUsage],
    media: Dict[str, StorageOps],
    extra: Callable[[EdgeUsage], Dict[str, Any]],
) -> Dict[str, Dict[str, Any]]:
    """Per-edge attribution table (medium, objects/bytes, ops, $ share).

    One implementation for both lowerings so the attribution formula —
    exact request fees for S3 edges, bytes-proportional share of the
    provisioned-capacity bill for ElastiCache edges — can never diverge
    between the cluster and engine bills.  ``extra`` supplies the
    lowering-specific timing columns."""
    ec = media.get("elasticache")
    ec_bytes = sum(
        u.media_bytes.get("elasticache", 0) for u in edge_usage.values()
    )
    ec_per_byte = (
        elasticache_storage_cost(ec.peak_resident_gb) / ec_bytes
        if ec is not None and ec_bytes else 0.0
    )
    return {
        label: {
            "media": dict(u.media),
            "bytes": u.bytes_moved,
            "n_puts": u.n_puts,
            "n_gets": u.n_gets,
            "n_local": u.n_local,
            **extra(u),
            "storage_uUSD": u.storage_fee_usd(ec_per_byte) * 1e6,
        }
        for label, u in edge_usage.items()
    }


# ---------------------------------------------------------------------------
# Streaming-edge analytics (shared by the cluster lowering and fig13's bound)
# ---------------------------------------------------------------------------


def _chunk_ready_offsets(compute_s: float, sizes: Sequence[int]) -> List[float]:
    """Byte-proportional production offsets of one object's chunks relative
    to the producer's compute start: chunk ``k`` is published once the first
    ``sum(sizes[:k+1]) / sum(sizes)`` fraction of the compute has run (the
    last chunk lands exactly at compute end)."""
    total = sum(sizes)
    if total <= 0 or compute_s <= 0.0:
        return [0.0] * len(sizes)
    acc, out = 0, []
    for s in sizes:
        acc += s
        out.append(compute_s * acc / total)
    return out


def _staged_get_seconds(m: str, nbytes: int, net: NetConstants) -> float:
    """Consumer-side pull time of one staged object/chunk already resident
    on medium ``m`` — the get half only (the producer's put happened in its
    own span), mirroring ``ServerlessCluster.storage_get`` / ``xdt_pull``
    contention-free."""
    if m == "s3":
        return net.s3_op_latency + nbytes / min(net.s3_stream_bw, net.nic_bw)
    if m == "elasticache":
        return net.ec_op_latency + nbytes / min(net.ec_stream_bw, net.nic_bw)
    if m == "xdt":
        return net.xdt_pull_rtt + nbytes / min(
            net.xdt_stream_bw, net.nic_bw * net.xdt_stream_eff
        )
    # inline is refused for streaming edges; anything else is a config error
    raise ValueError(f"no staged-get model for medium {m!r}")


def _streamed_finish(
    start: float,
    ready: Sequence[float],
    sizes: Sequence[int],
    media: Sequence[str],
    span_of: Callable[[str, int], float],
) -> float:
    """Absolute completion time of a single-threaded consumer pulling chunks
    as they land: beginning at ``start``, every chunk already published is
    coalesced into one batch (one request per distinct medium — ranged GET /
    multipart semantics), the batch transfer runs, and the puller then waits
    for the next publication.  ``span_of(medium, nbytes)`` models one
    batch-request's transfer seconds on a medium."""
    order = sorted(range(len(sizes)), key=lambda k: ready[k])
    t, i, n = start, 0, len(order)
    while i < n:
        if ready[order[i]] > t:
            t = ready[order[i]]
        batch: Dict[str, int] = {}
        while i < n and ready[order[i]] <= t:
            k = order[i]
            batch[media[k]] = batch.get(media[k], 0) + sizes[k]
            i += 1
        for m, b in batch.items():
            t += span_of(m, b)
    return t


#: candidate chunk sizes the auto-tuner scores — a superset of fig13's
#: committed sweep sizes, so ``chunk_bytes="auto"`` can always at least tie
#: the best fixed cell
AUTO_CHUNK_CANDIDATES = (
    256 << 10, 512 << 10, 1 << 20, 2 << 20, 4 << 20, 8 << 20,
)


def resolve_auto_chunk_bytes(
    edge: Edge,
    medium: str,
    net: NetConstants = DEFAULT_NET,
    telemetry: Optional[TelemetryHub] = None,
    compute_s: float = 0.0,
    nbytes: Optional[int] = None,
    staged: Optional[bool] = None,
) -> int:
    """Telemetry-tuned chunk size for one (edge, medium) stream.

    Scores every :data:`AUTO_CHUNK_CANDIDATES` size with the streamed-pull
    recurrence (:func:`_streamed_finish`, clamped by the store-then-fetch
    span exactly like the execution path) — the Fig. 13 analytic bound is
    the *prior* — swapping in the medium's observed latency-vs-size model
    once the :class:`TelemetryHub` has enough samples for it.  Near-ties go
    to the larger candidate: same finish, fewer chunk events and fewer
    request-overhead sheds.  ``nbytes`` overrides the edge's declared size
    (mid-stream re-scoring passes the remaining bytes)."""
    nb = int(edge.nbytes if nbytes is None else nbytes)
    if nb <= 0:
        return AUTO_CHUNK_CANDIDATES[0]
    if staged is None:
        staged = edge.handoff == "staged"
    # the hub's trust gate: the observed latency-vs-size model substitutes
    # for the prior only once it has MIN_MODEL_SAMPLES observations
    mt = telemetry.medium_model(medium) if telemetry is not None else None

    def span_of(m: str, b: int) -> float:
        if mt is not None:
            s = mt.predict_seconds(b)
            if s > 0.0:
                return s
        if staged:
            return _staged_get_seconds(m, b, net)
        return modeled_transfer_seconds(m, b, net)

    best = AUTO_CHUNK_CANDIDATES[-1]
    best_fin = float("inf")
    clamp = compute_s + span_of(medium, nb)    # store-then-fetch span
    for cand in reversed(AUTO_CHUNK_CANDIDATES):
        if cand >= nb:
            sizes: Sequence[int] = (nb,)
        else:
            n_full, tail = divmod(nb, cand)
            sizes = [cand] * n_full
            if tail:
                sizes.append(tail)
        ready = _chunk_ready_offsets(compute_s, sizes)
        start = ready[0] + net.ctrl_plane_latency
        fin = _streamed_finish(
            start, ready, sizes, [medium] * len(sizes), span_of
        )
        if clamp < fin:
            fin = clamp
        if fin < best_fin - 1e-15:
            best, best_fin = cand, fin
    return best


def _chunk_event_timeline(
    start: float,
    ready: Sequence[float],
    sizes: Sequence[int],
    media: Sequence[str],
    span_of: Callable[[str, int], float],
    max_inflight: int = 0,
    on_pressure: Optional[Callable[[str, float], Optional[str]]] = None,
    pressure_patience: int = 2,
) -> Tuple[float, List[float], List[str], float, int]:
    """Forward-simulate the chunk events of ONE streamed object.

    The same single-threaded coalescing puller as :func:`_streamed_finish`
    (with ``max_inflight=0`` the finish time is bit-identical), generalized
    three ways so the cluster lowering can *simulate* chunk events instead
    of clamping to an analytic overlap model:

    * **batch completion times** come back in ``batch_ends`` — the cluster
      fetch paths emit one real simulator event per pull batch;
    * **credit stretching**: with ``max_inflight=k``, an instance-resident
      chunk cannot publish until the resident chunk ``k`` places back has
      been fully pulled (its batch completed), so its publication is
      ``max(ready, freeing completion)`` — the producer blocks on zero
      credits;
    * **spill-on-pressure**: after ``pressure_patience`` consecutive
      credit-delayed publications, ``on_pressure(medium, now)`` is
      consulted; a returned durable medium rewrites the REMAINING chunks'
      media — durable puts free the producer's buffer, so those chunks stop
      occupying credits and publish at their ready offsets.

    Returns ``(finish, batch_ends, media_out, peak_inflight_bytes,
    n_pressure_spilled)`` where ``peak_inflight_bytes`` is the high-water
    mark of resident published-but-unpulled chunk bytes (what the credit
    window provably bounds: <= max_inflight * max(sizes))."""
    n = len(sizes)
    order = sorted(range(n), key=lambda k: ready[k])
    media_out = list(media)
    resident = [m not in _STORAGE_MEDIA for m in media_out]
    window = int(max_inflight)
    res_comp: List[float] = []      # completions of resident chunks, FIFO
    res_assigned = 0                # resident chunks published so far
    spans: List[Tuple[float, float, int]] = []   # (pub, completion) spans
    batch_ends: List[float] = []
    streak = 0
    n_spilled = 0
    t, i = start, 0
    while i < n:
        # ---- open a batch at the next publishable chunk
        k = order[i]
        p = ready[k]
        if window > 0 and resident[k] and res_assigned >= window:
            gate = res_comp[res_assigned - window]
            if gate > p:
                p = gate
        if p > t:
            t = p
        batch: Dict[str, int] = {}
        members: List[int] = []
        while i < n:
            k = order[i]
            p = ready[k]
            if window > 0 and resident[k]:
                need = res_assigned - window
                if need >= 0:
                    if need >= len(res_comp):
                        break        # freeing chunk still in this batch
                    gate = res_comp[need]
                    if gate > t:
                        break        # credits exhausted past this instant
                    if gate > p:
                        p = gate
                        streak += 1
                        if (
                            on_pressure is not None
                            and streak >= pressure_patience
                        ):
                            durable = on_pressure(media_out[k], p)
                            if durable is not None:
                                # remaining stream goes durable: those puts
                                # free the sender buffer at publish time
                                for j in order[i:]:
                                    media_out[j] = durable
                                    resident[j] = False
                                n_spilled += n - i
                                streak = 0
                                p = ready[k]
                    else:
                        streak = 0
            if p > t:
                break
            if resident[k]:
                res_assigned += 1
                spans.append((p, 0.0, sizes[k]))
            batch[media_out[k]] = batch.get(media_out[k], 0) + sizes[k]
            members.append(k)
            i += 1
        for m, b in batch.items():
            t += span_of(m, b)
        batch_ends.append(t)
        for k in members:
            if resident[k]:
                idx = len(res_comp)
                res_comp.append(t)
                pub, _, sz = spans[idx]
                spans[idx] = (pub, t, sz)
    # peak resident inflight bytes: sweep the (pub, completion) spans
    peak = 0.0
    if spans:
        events: List[Tuple[float, float]] = []
        for pub, comp, sz in spans:
            events.append((pub, float(sz)))
            events.append((comp, -float(sz)))
        events.sort(key=lambda ev: (ev[0], ev[1]))
        cur = 0.0
        for _, delta in events:
            cur += delta
            if cur > peak:
                peak = cur
    return t, batch_ends, media_out, peak, n_spilled


def critical_path_lower_bound(
    dag: WorkflowDAG,
    backend: Route = "xdt",
    net: NetConstants = DEFAULT_NET,
) -> float:
    """Makespan lower bound of ``dag`` with *perfect* streaming overlap.

    Models the best any chunking can do: every edge's transfer is pipelined
    with its producer's compute, so a consumer can start no earlier than

    ``start(producer) + max(compute(producer), marginal_transfer) + overhead``

    — the data must both be produced (compute) and moved (marginal per-byte
    time, whichever is slower bounds the pipeline) plus one request's fixed
    overhead for the tail chunk.  Staged edges charge the consumer-side get
    only (the producer's put overlaps its compute); sync edges charge the
    full publish+retrieve model.  External original inputs are fetched at
    consumer start.  Orchestration round-trips, cold starts, and FIFO
    contention are excluded — that is what makes it a *bound*; fig13
    measures how close streaming gets.
    """
    resolve = dag.route_resolver(backend)

    def edge_rates(e: Edge) -> Tuple[str, float, float]:
        """(medium, marginal seconds for this consumer's bytes, overhead)."""
        m = resolve(e, e.nbytes)
        if e.handoff == "sync":
            per_consumer = e.nbytes * e.n_objects
            ovh = modeled_transfer_seconds(m, 0, net)
            marg = modeled_transfer_seconds(m, per_consumer, net) - ovh
            return m, marg, ovh
        if e.handoff == "external":
            per_consumer = e.nbytes * e.n_objects
        elif e.fanout == "broadcast":
            per_consumer = e.nbytes * e.n_objects
        else:
            per_consumer = e.nbytes * e.n_objects * dag.by_name[e.src].fan
        ovh = _staged_get_seconds(m, 0, net)
        marg = _staged_get_seconds(m, per_consumer, net) - ovh
        return m, marg, ovh

    cstart: Dict[str, float] = {}        # compute start (after ext fetches)
    finish: Dict[str, float] = {}

    def avail_via(e: Edge) -> float:
        """Earliest the consumer of ``e`` has its data: the producer's
        compute start, plus whichever of production or pipelined transfer
        is slower, plus one request overhead for the tail."""
        _, marg, ovh = edge_rates(e)
        return cstart[e.src] + max(dag.by_name[e.src].compute_s, marg) + ovh

    def visit(name: str) -> None:
        if name in finish:
            return
        s = dag.by_name[name]
        t = 0.0
        ext = 0.0
        for e in dag.in_edges(s):
            if e.src is None:
                _, marg, ovh = edge_rates(e)
                ext += ovh + marg            # fetched at consumer start
                continue
            visit(e.src)
            t = max(t, avail_via(e))
        cstart[name] = t + ext
        finish[name] = t + ext + s.compute_s

    cstart[dag.entry.name] = 0.0
    finish[dag.entry.name] = dag.entry.compute_s
    for s in dag.stages:
        if s.name != dag.entry.name:
            visit(s.name)
    bound = max(finish.values())
    gathers = dag.gather_edges()
    if gathers or dag.entry.gather_compute_s > 0:
        g = max((avail_via(e) for e in gathers), default=0.0)
        bound = max(bound, g) + dag.entry.gather_compute_s
    return bound


# ---------------------------------------------------------------------------
# Lowering 1: the calibrated cluster simulator (Fig 7 / Table 2 path)
# ---------------------------------------------------------------------------


class Billing:
    """Tracks per-invocation billed spans (blocking-chain semantics)."""

    def __init__(self, sim):
        self.sim = sim
        self.spans: List[Tuple[str, float, float]] = []
        self._open: Dict[int, Tuple[str, float]] = {}
        self._next = 0

    def start(self, name: str) -> int:
        self._next += 1
        self._open[self._next] = (name, self.sim.now)
        return self._next

    def stop(self, token: int) -> None:
        name, t0 = self._open.pop(token)
        self.spans.append((name, t0, self.sim.now))

    @property
    def n_invocations(self) -> int:
        return len(self.spans) + len(self._open)

    @property
    def billed_s(self) -> float:
        return sum(t1 - t0 for _, t0, t1 in self.spans)


@dataclasses.dataclass
class ClusterDagRun:
    """Everything a workload wrapper needs to assemble a result."""

    dag: WorkflowDAG
    cluster: ServerlessCluster
    bill: Billing
    marks: Dict[str, float]
    edge_usage: Dict[str, EdgeUsage]
    edge_media: Dict[str, str]           # label -> media summary string
    #: per-stage autoscaled fleets (set when execute_on_cluster ran with an
    #: autoscaler/scaling selection; None models the pre-provisioned fleet)
    control: Optional[ControlPlane] = None
    #: fault-injection bookkeeping (set when execute_on_cluster ran with a
    #: non-empty fault_plan): retries / re-routes / injected refusals
    faults: Optional[Any] = None

    @property
    def latency_s(self) -> float:
        return self.cluster.sim.now

    def media_storage_ops(self) -> Dict[str, StorageOps]:
        """Per-medium storage accounting of the whole run (exact: read from
        the cluster's per-backend accounting, touched to 'now')."""
        return _media_ops(self.cluster.acct.items(), self.cluster.sim.now)

    def cost_inputs(self) -> WorkflowCostInputs:
        media = self.media_storage_ops()
        return WorkflowCostInputs(
            n_function_invocations=self.bill.n_invocations,
            billed_duration_s=self.bill.billed_s,
            n_storage_puts=sum(m.n_puts for m in media.values()),
            n_storage_gets=sum(m.n_gets for m in media.values()),
            storage_gb_seconds=sum(m.gb_seconds for m in media.values()),
            peak_resident_gb=max(
                (m.peak_resident_gb for m in media.values()), default=0.0
            ),
        )

    def cost(self):
        return routed_workflow_cost(
            self.cost_inputs(), self.media_storage_ops(),
            egress_usd=self.cluster.egress_usd,
        )

    def edge_cost_rows(self) -> Dict[str, Dict[str, Any]]:
        """Per-edge attribution table: medium, objects, bytes, seconds, $."""
        return _edge_fee_rows(
            self.edge_usage, self.media_storage_ops(),
            lambda u: {"put_s": u.put_s, "fetch_s": u.fetch_s},
        )


class Runnable:
    """A DAG compiled for one lowering — what :meth:`WorkflowDAG.compile`
    returns.  ``run(...)`` executes it; concrete subclasses are
    :class:`ClusterRunnable` (calibrated event simulation) and
    :class:`DagBinding` (real :class:`~repro.core.workflow.WorkflowEngine`).
    """

    dag: "WorkflowDAG"

    def run(self, *args: Any, **kwargs: Any) -> Any:
        raise NotImplementedError

    def describe(self) -> str:
        return f"{type(self).__name__}({self.dag.name})"


class ClusterRunnable(Runnable):
    """``compile(target="cluster")`` product: the DAG plus every run-invariant
    option, so one compiled object yields many seeded runs.

    ``run(seed=0, deterministic=False)`` executes one run on a fresh
    :class:`~repro.core.cluster.ServerlessCluster` and returns the
    :class:`ClusterDagRun`.  Compilation itself is cheap (the cluster
    lowering interprets the graph), so this object is pure configuration —
    which is exactly what makes its runs reproducible.
    """

    def __init__(
        self,
        dag: "WorkflowDAG",
        backend: Route,
        net: NetConstants = DEFAULT_NET,
        plan: Any = None,
        faults: Any = None,
        telemetry: Optional[TelemetryHub] = None,
        topology: Optional[Topology] = None,
        autoscaler: Any = None,
        scaling: Optional[Callable[["Stage"], ScalingPolicy]] = None,
        online_spill: Any = None,
    ):
        self.dag = dag
        self.backend = backend
        self.net = net
        self.plan = plan
        self.faults = faults
        self.telemetry = telemetry
        self.topology = topology
        self.autoscaler = autoscaler
        self.scaling = scaling
        self.online_spill = online_spill

    def run(self, seed: int = 0, deterministic: bool = False) -> ClusterDagRun:
        return _execute_on_cluster(
            self.dag, self.backend, net=self.net, seed=seed,
            deterministic=deterministic, autoscaler=self.autoscaler,
            scaling=self.scaling, plan=self.plan, fault_plan=self.faults,
            online_spill=self.online_spill, topology=self.topology,
            telemetry=self.telemetry,
        )


def _execute_on_cluster(
    dag: WorkflowDAG,
    backend: Route,
    net: NetConstants = DEFAULT_NET,
    seed: int = 0,
    deterministic: bool = False,
    autoscaler: Any = None,
    scaling: Optional[Callable[[Stage], ScalingPolicy]] = None,
    plan: Any = None,
    fault_plan: Any = None,
    online_spill: Any = None,
    topology: Optional[Topology] = None,
    telemetry: Optional[TelemetryHub] = None,
) -> ClusterDagRun:
    """Interpret ``dag`` on the calibrated discrete-event cluster.

    ``backend`` is the run default applied to ``route="default"`` edges: a
    fixed medium name reproduces the legacy single-backend workloads
    bit-for-bit; a :class:`RoutePolicy` yields a per-edge-routed (hybrid)
    run priced per medium.  :class:`AdaptiveRoute` policies are fed a
    run-local telemetry hub (each resolved object's modeled latency and
    marginal fee), closing the observe->decide loop on this lowering too.

    ``autoscaler`` / ``scaling`` optionally put every stage's instances
    behind a :class:`~repro.core.scheduler.Deployment` on the run's virtual
    clock — per-stage fleets then pay cold starts and queue exactly as the
    selected :class:`~repro.core.scheduler.AutoscalerPolicy` decides.  Both
    default to off, which models the paper's pre-provisioned measurement
    fleet (and keeps the legacy runs bit-for-bit).

    ``plan`` is the :class:`~repro.core.dagopt.PlacementPlan` produced by
    ``dag.optimize()``: each co-placement affinity entry maps that
    consumer's instances onto its producer's nodes, and their XDT pulls
    take the shared-memory path (:meth:`ServerlessCluster.local_pull`)
    instead of the producer NIC.  Without a plan nothing changes.

    ``fault_plan`` is a :class:`~repro.core.faults.FaultPlan`: evictions
    mark nodes dead (a staged instance-resident fetch from a dead node pays
    a billed producer re-run that re-stages durable), degradation windows
    inject seeded per-get refusals (bounded re-attempts, then a durable
    re-route) and stretch pulls by the bandwidth-cut multiplier.  An empty
    or ``None`` plan changes nothing — the run stays bit-identical.

    **Streaming edges** (``Edge(streaming=True, chunk_bytes=...)``) are
    modeled analytically: the producer publishes chunks byte-proportionally
    across its compute (the data-plane push rides the background, so a
    streaming producer pays no staging tail), the consumer is data-triggered
    — steered on the first chunk's publication, one control hop, then pulls
    chunks as they land (:func:`_streamed_finish`) — and only the tail that
    outlives the producer's compute is waited on the virtual clock.  Route
    policies resolve **per chunk**, so one logical object may split across
    media; ``online_spill`` (an :class:`~repro.core.dagopt.OnlineSpill`) is
    consulted per chunk and may redirect the remaining chunks of a stream to
    a durable medium mid-flight as the producer's predicted reap closes in.
    The modeled finish is clamped to never exceed the store-then-fetch
    equivalent (all chunks moved as one batch at producer completion), so
    streaming can only help.  Under an active ``fault_plan`` the streamed
    paths apply each medium's degradation slowdown to every batch but skip
    per-get refusal draws and eviction recovery (those remain exercised by
    the engine lowering's real chunk protocol); billing stays exact — one
    logical PUT/GET per distinct storage medium per object (multipart
    upload / ranged-GET semantics) with residency integrated on the clock.

    ``topology`` places the run on an edge-cloud continuum
    (:class:`~repro.core.topology.Topology`): each stage's nodes land in a
    zone — workload pins first, then the plan's optimizer-chosen zones,
    then a naive round-robin spread (the topology-oblivious baseline) —
    and every tier-crossing transfer rides a shared per-zone-pair FIFO,
    pays the tier RTT, and accrues cross-tier egress fees into the bill.
    Storage services are homed in the topology's service zone.  A
    single-zone topology (or None) is bit-identical to the flat cluster.
    ``telemetry`` pins the hub adaptive routes are fed with (default: a
    fresh run-local hub on the run's virtual clock).
    """
    n_nodes = sum(s.fan for s in dag.stages)

    nodes: Dict[str, List[int]] = {}
    base = 0
    for s in dag.stages:
        nodes[s.name] = list(range(base, base + s.fan))
        base += s.fan

    # edge-cloud continuum: stage -> zones (pins > plan > naive spread),
    # then node -> zone.  Flat/absent topologies skip the whole layer.
    node_zones: Optional[List[int]] = None
    stage_zones: Optional[Dict[str, Tuple[int, ...]]] = None
    if topology is not None and not topology.is_flat:
        stage_zones = topology.assign_stage_zones(
            [s.name for s in dag.stages],
            plan_zones=getattr(plan, "zones", None),
        )
        node_zones = [0] * n_nodes
        for s in dag.stages:
            zs = stage_zones[s.name]
            for k, nd in enumerate(nodes[s.name]):
                node_zones[nd] = zs[k % len(zs)]

    cluster = ServerlessCluster(
        n_nodes, net, seed=seed, deterministic=deterministic,
        topology=topology if node_zones is not None else None,
        node_zones=node_zones,
    )
    sim = cluster.sim
    faults = None
    if fault_plan is not None and fault_plan:
        from .faults import _ClusterFaults

        faults = _ClusterFaults(fault_plan, sim, list(range(n_nodes)))
    bill = Billing(sim)
    marks: Dict[str, float] = {}
    usage: Dict[str, EdgeUsage] = {e.label: EdgeUsage() for e in dag.edges}
    media_seen: Dict[str, set] = {e.label: set() for e in dag.edges}

    # -- cross-tier pricing/pacing helpers (all zero when node_zones is
    # None, so the flat cluster's floats and rng stream are untouched) ----
    def _tier_seconds(level: int, nbytes: int) -> float:
        if level <= 1:
            return 0.0
        return net.tier_rtt(level) + nbytes / net.tier_bw(level)

    def _node_level(a: Optional[int], b: Optional[int]) -> int:
        """Crossing level between two nodes; service-homed side when None."""
        svc = cluster._svc_zone
        za = svc if a is None else node_zones[a]
        zb = svc if b is None else node_zones[b]
        if za == zb:
            return 1
        return topology.crossing(za, zb)

    def _pull_extras(
        m: str, nbytes: int, retrievals: int,
        src_node: Optional[int], dst_node: Optional[int],
    ) -> Tuple[float, float]:
        """(extra fee USD, extra seconds) one pull pays for tier crossings:
        service media cross producer->service-home on the put (amortized
        over the object's retrievals) and service-home->consumer on the
        get; instance-resident media cross producer->consumer directly."""
        if node_zones is None:
            return 0.0, 0.0
        if m in _STORAGE_MEDIA:
            lp = _node_level(src_node, None)
            lg = _node_level(None, dst_node)
            fee = egress_fee_usd(lg, nbytes) + (
                egress_fee_usd(lp, nbytes) / max(1, retrievals)
            )
            return fee, _tier_seconds(lg, nbytes)
        level = _node_level(src_node, dst_node)
        return egress_fee_usd(level, nbytes), _tier_seconds(level, nbytes)

    def _stage_zone(name: Optional[str]) -> Optional[int]:
        """Representative zone of a stage (its first instance's)."""
        if name is None or stage_zones is None:
            return None
        return stage_zones[name][0]

    def _edge_tier_extras(
        edge: Edge, m: str, nbytes: int, retrievals: int = 1
    ) -> Tuple[float, float]:
        """Stage-level (fee, seconds) tier extras of one pull on ``edge`` —
        the representative-zone form used where per-node identity is not in
        scope (adaptive priors, streamed batches)."""
        if node_zones is None:
            return 0.0, 0.0
        src = nodes[edge.src][0] if edge.src is not None else None
        dst = nodes[edge.dst][0]
        return _pull_extras(m, nbytes, retrievals, src, dst)

    # adaptive routes: ensure every AdaptiveRoute has a hub and feed each
    # distinct hub with this run's observations (modeled seconds + fee)
    hubs: List[TelemetryHub] = []
    adaptive = [
        r for r in (backend, *(e.route for e in dag.edges))
        if isinstance(r, AdaptiveRoute)
    ]
    if adaptive:
        # fresh run-local hub (auto_bind replaces a previous run's feed, so
        # reused route instances start clean; user-pinned hubs are kept);
        # under a topology the routes also score unobserved media with
        # tier-aware priors (egress + tier seconds of this edge's zones)
        shared_hub = telemetry if telemetry is not None else TelemetryHub(
            VirtualClock(sim)
        )
        prior_extra = (
            _edge_tier_extras if node_zones is not None else None
        )
        for r in adaptive:
            hub = r.auto_bind(shared_hub, prior_extra)
            if hub is not None and hub not in hubs:
                hubs.append(hub)
    resolve = dag.route_resolver(backend)

    control: Optional[ControlPlane] = None
    if autoscaler is not None or scaling is not None:
        control = ControlPlane(clock=VirtualClock(sim))
        make_policy = scaling or (lambda s: ScalingPolicy(
            max_instances=s.fan, target_concurrency=1, autoscaler=autoscaler,
        ))
        for s in dag.stages:
            control.register(s.name, make_policy(s))

    # co-placement: consumer node -> producer node it shares (the optimizer
    # bounded the packing, so every affined consumer instance maps onto its
    # producer's node round-robin).  Pulls between co-resident pairs over
    # instance-resident media go through shared memory below.
    colocal: Dict[int, int] = {}
    if plan is not None and getattr(plan, "affinity", None):
        for cname, pname in plan.affinity.items():
            if cname not in dag.by_name or pname not in dag.by_name:
                raise ValueError(
                    f"placement plan affines unknown stage {cname!r} -> "
                    f"{pname!r}; was the plan produced by optimize() on "
                    "this DAG?"
                )
            pn = nodes[pname]
            for j, dn in enumerate(nodes[cname]):
                colocal[dn] = pn[j % len(pn)]
    if node_zones is not None and colocal:
        # a hand-written plan may affine stages the topology separated;
        # cross-zone pairs cannot share a node, so the hint is dropped
        colocal = {
            d: s for d, s in colocal.items()
            if node_zones[d] == node_zones[s]
        }
    # contention-aware co-placement: at pull time, compare the shared-memory
    # FIFO backlog against the producer-NIC alternative and route around a
    # saturated memory channel (splitting a hot broadcast across paths)
    contention_aware = bool(plan is not None and getattr(plan, "contention_aware", False))

    def _mark_max(key: str) -> None:
        t = sim.now
        if t > marks.get(key, -1.0):
            marks[key] = t

    def _observe(
        m: str, nbytes: int, retrievals: int = 1, external: bool = False,
        src_node: Optional[int] = None, dst_node: Optional[int] = None,
    ) -> None:
        """Feed the adaptive hubs once per PULL with that pull's marginal
        fee (:func:`repro.core.cost.marginal_pull_fee_usd`), so the
        router's observed $/object matches what routed_workflow_cost will
        bill.  Under a topology the fee and seconds include the pull's
        tier-crossing extras, so the router's observations are
        topology-aware too."""
        if not hubs:
            return
        fee = marginal_pull_fee_usd(m, nbytes, retrievals, external)
        secs = modeled_transfer_seconds(m, nbytes, net)
        if node_zones is not None:
            extra_fee, extra_s = _pull_extras(
                m, nbytes, retrievals, src_node, dst_node
            )
            fee += extra_fee
            secs += extra_s
        if faults is not None:
            # degraded media are observed degraded, so AdaptiveRoute's
            # window sees the throttle and can route around it
            secs *= faults.slowdown_at(m)
        for hub in hubs:
            hub.record_transfer(m, nbytes, secs, fee)

    def _medium(
        edge: Edge, nbytes: int,
        retrievals: int = 1, record: bool = True, external: bool = False,
        src_node: Optional[int] = None, dst_node: Optional[int] = None,
    ) -> str:
        m = resolve(edge, nbytes)       # validates against _CLUSTER_MEDIA
        media_seen[edge.label].add(m)
        if record:
            _observe(m, nbytes, retrievals, external, src_node, dst_node)
        return m

    # staged edges: the medium is decided ONCE per object, at stage (put)
    # time, and the consumer's fetch reuses that decision — a stateful
    # policy whose answer drifts between the producer's put and the
    # consumer's get must not split one object across media (a GET from a
    # service the object was never PUT to is physically impossible and
    # would corrupt the per-edge bill).  label -> src_node -> media in put
    # order (partition puts are consumer-major: all of consumer 0's
    # objects, then consumer 1's, ...).
    staged_media: Dict[str, Dict[int, List[str]]] = {
        e.label: {} for e in dag.edges if e.handoff == "staged"
    }
    # streaming staged edges: label -> src_node -> per-object chunk tuples
    # (ready_abs, nbytes, medium) in the same consumer-major put order as
    # staged_media, recorded at the producer's compute end and replayed by
    # the consumer's merged pull recurrence.
    streamed_staged: Dict[str, Dict[int, List[List[Tuple[float, int, str]]]]] = {
        e.label: {}
        for e in dag.edges if e.handoff == "staged" and e.streaming
    }

    def chunk_media(
        edge: Edge, sizes: Sequence[int], ready: Sequence[float],
        compute_end: float,
    ) -> List[str]:
        """Per-chunk route resolution of one streamed object, with the
        online spill re-check: as the predicted producer-reap window closes
        in, the remaining chunks of the stream divert to a durable medium
        mid-flight."""
        media = []
        for b, r in zip(sizes, ready):
            m = _medium(edge, b, record=False)
            if online_spill is not None and m not in _STORAGE_MEDIA:
                # remaining production plus the modeled pull of this chunk:
                # the horizon the producer's instance must survive
                eta = (compute_end - r) + _staged_get_seconds(m, b, net)
                m2 = online_spill.medium_for(dag, edge, m, r, eta)
                if m2 != m:
                    media_seen[edge.label].add(m2)
                    m = m2
            media.append(m)
        return media

    auto_hub = hubs[0] if hubs else None

    def auto_object_chunks(
        edge: Edge, staged: bool, acc: int, total: int,
        compute_s: float, t_end: float,
    ) -> Tuple[List[int], List[float], List[str]]:
        """(sizes, ready, media) of ONE object of a ``chunk_bytes="auto"``
        edge.  The chunk size resolves per (edge, medium) from telemetry
        with the analytic recurrence as prior, and RE-resolves at every
        route-decision point: when the per-chunk route (policy or online
        spill) lands on a new medium mid-stream, the remaining bytes
        re-chunk at that medium's best size.  ``acc``/``total`` share the
        byte-proportional production clock across a staged producer's
        objects, exactly like the fixed-size path."""
        nb = edge.nbytes
        m_cur = resolve(edge, nb)
        cb = resolve_auto_chunk_bytes(
            edge, m_cur, net, auto_hub, compute_s, staged=staged
        )
        sizes: List[int] = []
        ready: List[float] = []
        media: List[str] = []
        done = 0
        while done < nb:
            b = min(cb, nb - done)
            off = compute_s * (acc + done + b) / total if total else 0.0
            r = t_end - compute_s + off
            m = _medium(edge, b, record=False)
            if online_spill is not None and m not in _STORAGE_MEDIA:
                eta = (t_end - r) + _staged_get_seconds(m, b, net)
                m2 = online_spill.medium_for(dag, edge, m, r, eta)
                if m2 != m:
                    media_seen[edge.label].add(m2)
                    m = m2
            sizes.append(b)
            ready.append(r)
            media.append(m)
            done += b
            if m != m_cur and done < nb:
                # route-decision point: re-score the remaining stream
                m_cur = m
                rem = nb - done
                cb = resolve_auto_chunk_bytes(
                    edge, m, net, auto_hub,
                    compute_s * rem / nb if nb else 0.0,
                    nbytes=rem, staged=staged,
                )
        return sizes, ready, media

    def pressure_for(edge: Edge):
        """Spill-on-pressure hook for the chunk-event timeline: persistent
        zero-credit hands the remaining stream to OnlineSpill's durable
        medium (None = no online spill installed, pressure only stretches)."""
        if online_spill is None:
            return None

        def cb(medium: str, now: float) -> Optional[str]:
            m2 = online_spill.on_pressure(dag, edge, medium, now)
            if m2 is not None:
                media_seen[edge.label].add(m2)
            return m2

        return cb

    def streamed_spans(m: str, b: int, staged: bool, edge: Optional[Edge] = None) -> float:
        """One batch-request's modeled seconds on ``m`` (get side only for
        staged chunks — the producer's push overlapped its compute),
        stretched by any active degradation window.  Under a topology the
        batch additionally pays the edge's tier-crossing seconds."""
        dt = (
            _staged_get_seconds(m, b, net) if staged
            else modeled_transfer_seconds(m, b, net)
        )
        if edge is not None and node_zones is not None:
            dt += _edge_tier_extras(edge, m, b)[1]
        if faults is not None:
            dt *= faults.slowdown_at(m)
        return dt

    def xdt_pull_ev(u: EdgeUsage, src_node: int, dst_node: int, nbytes: int):
        """One xdt pull's data-plane event, honoring co-placement: the
        shared-memory path when consumer and producer share a node.  The
        contention-aware plan variant reads the shared-memory FIFO's
        occupancy first and falls back to the producer NIC when the memory
        channel's backlog would make it the slower path — a hot broadcast
        splits across both instead of serializing behind one channel."""
        if colocal.get(dst_node) == src_node:
            if contention_aware:
                mem_eta = (
                    cluster.mem_backlog_s(src_node)
                    + nbytes / net.local_bw + net.local_rtt
                )
                nic_eta = (
                    cluster.nic_backlog_s(src_node)
                    + max(
                        nbytes / (net.nic_bw * net.xdt_stream_eff),
                        nbytes / net.xdt_stream_bw,
                    )
                    + net.xdt_pull_rtt
                )
                if mem_eta > nic_eta:
                    return cluster.xdt_pull(src_node, nbytes, consumer=dst_node)
            u.n_local += 1
            return cluster.local_pull(src_node, nbytes)
        return cluster.xdt_pull(src_node, nbytes, consumer=dst_node)

    def faulted_staged_fetch(
        edge: Edge, u: EdgeUsage, m: str, src_node: int, dst_node: int,
        n_pulls: int,
    ) -> Generator:
        """One staged object's fetch under an active fault plan: eviction
        recovery (billed producer re-run -> durable re-stage), bounded
        refusal draws inside degradation windows (then a durable re-route),
        and bandwidth-cut stretch on the winning pull."""
        nbytes = edge.nbytes
        if m not in _STORAGE_MEDIA and faults.node_dead(src_node):
            # correlated eviction took the producer's node: the staged
            # instance-resident object died with it.  At-least-once (paper
            # §4.2.2): a billed producer re-run regenerates the object,
            # re-staged durable this time so the next pull cannot die too.
            m = faults.durable_for(m)
            faults.retries += 1
            faults.rerouted += 1
            media_seen[edge.label].add(m)
            tok = bill.start(f"{edge.src}:retry")
            cs = dag.by_name[edge.src].compute_s
            if cs > 0:
                yield sim.timeout(cs)
            u.n_puts += 1
            yield cluster.storage_put(m, src_node, nbytes)
            bill.stop(tok)
        attempts = 0
        while attempts < faults.max_attempts and faults.error_draw(m):
            # refused inside a degradation window: the failed round trip
            # still costs a control-plane hop, then the consumer retries
            attempts += 1
            faults.retries += 1
            faults.errors_injected += 1
            yield cluster.invoke_ctrl()
        if attempts >= faults.max_attempts:
            # retry budget spent on this medium: durable escape hatch
            m = faults.durable_for(m)
            faults.rerouted += 1
            media_seen[edge.label].add(m)
            u.n_puts += 1
            yield cluster.storage_put(m, src_node, nbytes)
        _observe(m, nbytes, retrievals=n_pulls,
                 src_node=src_node, dst_node=dst_node)
        u.count(m, nbytes)
        if m in _STORAGE_MEDIA:
            u.n_gets += 1
            yield cluster.storage_get(m, dst_node, nbytes)
        elif m == "xdt":
            yield xdt_pull_ev(u, src_node, dst_node, nbytes)
        else:
            yield cluster.inline_send(src_node, nbytes, dst=dst_node)
        extra = faults.extra_seconds(
            m, modeled_transfer_seconds(m, nbytes, net)
        )
        if extra > 0.0:
            yield sim.timeout(extra)

    def fetch_objects(edge: Edge) -> List[Optional[int]]:
        """Source node per object one consumer instance retrieves, in the
        legacy fetch order (chunk-major for broadcast, producer-major for
        partition)."""
        if edge.handoff == "external":
            return [None] * edge.n_objects
        if edge.fanout == "broadcast":
            src = nodes[edge.src][0]
            return [src] * edge.n_objects
        return [
            nodes[edge.src][p]
            for p in range(dag.by_name[edge.src].fan)
            for _ in range(edge.n_objects)
        ]

    def streamed_sync_fetch(edge: Edge, u: EdgeUsage) -> Generator:
        """Streamed sync edge, consumer side: the producer published chunks
        byte-proportionally across the compute that just ended (data-plane
        push), the consumer was steered on the first chunk (one control
        hop) and pulled as chunks landed; only the tail outliving the
        producer's compute is waited here."""
        compute_s = dag.by_name[edge.src].compute_s
        t_end = sim.now                  # producer compute just ended
        if edge.chunk_bytes == "auto":
            sizes, ready, media = auto_object_chunks(
                edge, False, 0, edge.nbytes, compute_s, t_end
            )
        else:
            sizes = list(edge.chunk_sizes())
            offsets = _chunk_ready_offsets(compute_s, sizes)
            ready = [t_end - compute_s + off for off in offsets]
            media = chunk_media(edge, sizes, ready, t_end)
        # data-triggered activation: steered on the first chunk's
        # publication event instead of the post-compute invoke round-trip
        start = ready[0] + net.ctrl_plane_latency
        window = edge.max_inflight_chunks
        finish, batch_ends, media, peak, _ = _chunk_event_timeline(
            start, ready, sizes, media,
            lambda m, b: streamed_spans(m, b, False, edge),
            max_inflight=window,
            on_pressure=pressure_for(edge) if window else None,
        )
        if peak > u.peak_inflight_chunk_bytes:
            u.peak_inflight_chunk_bytes = peak
        per_m: Dict[str, int] = {}
        for m, b in zip(media, sizes):
            per_m[m] = per_m.get(m, 0) + b
        if window == 0:
            # clamp: one store-then-fetch batch at producer completion —
            # unbounded chunking's per-batch request overhead can only ever
            # help.  A credit window is exempt: bounded sender memory may
            # legitimately cost latency, that is the trade it buys.
            un = t_end + sum(
                streamed_spans(m, b, False, edge) for m, b in per_m.items()
            )
            if un < finish:
                finish = un
        for m, b in per_m.items():
            u.count(m, b)
            _observe(m, b, src_node=nodes[edge.src][0],
                     dst_node=nodes[edge.dst][0])
            if node_zones is not None:
                # streamed batches never touch the cluster's transfer
                # primitives (pure modeled timers), so their cross-tier
                # egress is accrued here instead
                cluster.egress_usd += _edge_tier_extras(edge, m, b)[0]
            if m in _STORAGE_MEDIA:
                acct = cluster.accounting(m)
                acct.n_storage_puts += 1
                acct.store(sim.now, b / 1e9)
                u.n_puts += 1
        # simulated chunk events: one timer per coalesced pull batch (the
        # same virtual events the engine lowering runs), capped at the
        # clamped finish — absolute timers, so batches land on the
        # timeline's precomputed boundaries exactly
        for end in batch_ends:
            tgt = end if end < finish else finish
            if tgt > sim.now:
                yield sim.timeout_abs(tgt)
            if tgt >= finish:
                break
        if finish > sim.now:
            yield sim.timeout_abs(finish)
        for m, b in per_m.items():
            if m in _STORAGE_MEDIA:
                acct = cluster.accounting(m)
                acct.n_storage_gets += 1
                acct.free(sim.now, b / 1e9)
                u.n_gets += 1

    def streamed_staged_fetch(edge: Edge, u: EdgeUsage, dst_node: int) -> Generator:
        """Streamed staged edge, consumer side: every chunk of this
        consumer's objects (media decided at publish time) merges into one
        pull recurrence — a single-threaded data-plane puller draining
        chunks in publication order."""
        srcs = fetch_objects(edge)
        n_pulls = (
            dag.by_name[edge.dst].fan if edge.fanout == "broadcast" else 1
        )
        j = dst_node - nodes[edge.dst][0]
        cursor: Dict[int, int] = {}
        ready: List[float] = []
        sizes: List[int] = []
        media: List[str] = []
        per_obj: List[Dict[str, int]] = []
        for src_node in srcs:
            i = cursor.get(src_node, 0)
            cursor[src_node] = i + 1
            objs = streamed_staged[edge.label][src_node]
            chunks = objs[i if edge.fanout == "broadcast"
                          else j * edge.n_objects + i]
            om: Dict[str, int] = {}
            for r, b, m in chunks:
                ready.append(r)
                sizes.append(b)
                media.append(m)
                om[m] = om.get(m, 0) + b
            per_obj.append(om)
        start = min(ready) + net.ctrl_plane_latency   # data-triggered steer
        window = edge.max_inflight_chunks
        # staged chunks were billed per medium at publish time, so the
        # timeline must not rewrite media here: credits only STRETCH the
        # producer's publications (no consumer-side pressure spill)
        finish, batch_ends, _, peak, _ = _chunk_event_timeline(
            start, ready, sizes, media,
            lambda m, b: streamed_spans(m, b, True, edge),
            max_inflight=window,
        )
        if peak > u.peak_inflight_chunk_bytes:
            u.peak_inflight_chunk_bytes = peak
        if window == 0:
            # clamp: the store-then-fetch consumer pulls each object whole
            # once everything was staged (the sequential sync-SDK loop);
            # credit windows are exempt — bounded memory may cost latency
            un = max(ready) + sum(
                streamed_spans(m, b, True, edge)
                for om in per_obj for m, b in om.items()
            )
            if un < finish:
                finish = un
        for om in per_obj:
            for m, b in om.items():
                u.count(m, b)
                _observe(m, b, retrievals=n_pulls,
                         src_node=nodes[edge.src][0], dst_node=dst_node)
                if node_zones is not None:
                    cluster.egress_usd += _pull_extras(
                        m, b, n_pulls, nodes[edge.src][0], dst_node
                    )[0]
        # simulated chunk events: one timer per coalesced pull batch
        for end in batch_ends:
            tgt = end if end < finish else finish
            if tgt > sim.now:
                yield sim.timeout_abs(tgt)
            if tgt >= finish:
                break
        if finish > sim.now:
            yield sim.timeout_abs(finish)
        for om in per_obj:
            for m, b in om.items():
                if m in _STORAGE_MEDIA:
                    acct = cluster.accounting(m)
                    acct.n_storage_gets += 1
                    acct.free(sim.now, b / 1e9)
                    u.n_gets += 1

    def consumer_fetch(edge: Edge, dst_node: int) -> Generator:
        """Consumer-side ops of one edge for one consumer instance."""
        u = usage[edge.label]
        t0 = sim.now
        nbytes = edge.nbytes
        if edge.handoff == "sync":
            src_node = nodes[edge.src][0]
            if edge.streaming:
                yield from streamed_sync_fetch(edge, u)
            else:
                m = _medium(edge, nbytes,
                            src_node=src_node, dst_node=dst_node)
                u.count(m, nbytes)
                if m in _STORAGE_MEDIA:
                    u.n_puts += 1
                    u.n_gets += 1
                    yield cluster.storage_put(m, src_node, nbytes)
                    yield cluster.invoke_ctrl()
                    yield cluster.storage_get(m, dst_node, nbytes)
                elif m == "xdt":
                    yield cluster.invoke_ctrl()
                    yield xdt_pull_ev(u, src_node, dst_node, nbytes)
                else:                   # inline: payload rides the response
                    yield cluster.inline_send(src_node, nbytes, dst=dst_node)
        elif edge.streaming:
            yield from streamed_staged_fetch(edge, u, dst_node)
        else:
            srcs = fetch_objects(edge)
            # broadcast: every consumer instance pulls the one staged copy
            n_pulls = (
                dag.by_name[edge.dst].fan if edge.fanout == "broadcast" else 1
            )
            # this consumer's index and per-producer object cursor, to look
            # up the medium each object was staged on
            j = dst_node - nodes[edge.dst][0]
            cursor: Dict[int, int] = {}
            per_wave = edge.concurrency if edge.concurrency > 0 else len(srcs)
            for k in range(0, len(srcs), max(1, per_wave)):
                evs = []
                for src_node in srcs[k:k + per_wave]:
                    if src_node is None:             # external original input
                        m = _medium(edge, nbytes, external=True,
                                    dst_node=dst_node)
                        u.count(m, nbytes)
                        u.n_gets += 1
                        evs.append(cluster.storage_get(m, dst_node, nbytes))
                        continue
                    i = cursor.get(src_node, 0)
                    cursor[src_node] = i + 1
                    puts = staged_media[edge.label][src_node]
                    m = puts[i if edge.fanout == "broadcast"
                             else j * edge.n_objects + i]
                    if faults is not None:
                        evs.append(sim.spawn(faulted_staged_fetch(
                            edge, u, m, src_node, dst_node, n_pulls
                        )).done)
                        continue
                    _observe(m, nbytes, retrievals=n_pulls,
                             src_node=src_node, dst_node=dst_node)
                    u.count(m, nbytes)
                    if m in _STORAGE_MEDIA:
                        u.n_gets += 1
                        evs.append(cluster.storage_get(m, dst_node, nbytes))
                    elif m == "xdt":
                        evs.append(xdt_pull_ev(u, src_node, dst_node, nbytes))
                    else:
                        evs.append(cluster.inline_send(
                            src_node, nbytes, dst=dst_node
                        ))
                if evs:
                    yield sim.all_of(evs)
        _mark_max(f"edge:{edge.label}")
        u.fetch_s += sim.now - t0

    def producer_stage_puts(edge: Edge, src_node: int) -> Generator:
        """Producer-side staged puts of one edge for one producer instance
        (sequential — the sync-SDK loop of the paper's baselines).
        Instance-resident media (xdt/inline) stage nothing."""
        u = usage[edge.label]
        t0 = sim.now
        n = (
            edge.n_objects if edge.fanout == "broadcast"
            else dag.by_name[edge.dst].fan * edge.n_objects
        )
        if edge.streaming:
            # Chunks were published byte-proportionally across the compute
            # that just ended; the data-plane push rides the background, so
            # a streaming producer pays NO staging tail — only the logical
            # PUT bills (one per distinct storage medium per object,
            # multipart-upload semantics) land here.
            compute_s = dag.by_name[edge.src].compute_s
            auto = edge.chunk_bytes == "auto"
            sizes = None if auto else list(edge.chunk_sizes())
            objs = streamed_staged[edge.label].setdefault(src_node, [])
            total = n * edge.nbytes
            acc = 0
            for _ in range(n):
                if auto:
                    sizes_o, ready, media = auto_object_chunks(
                        edge, True, acc, total, compute_s, sim.now
                    )
                    acc += edge.nbytes
                else:
                    sizes_o = sizes
                    ready = []
                    for b in sizes_o:
                        acc += b
                        off = compute_s * acc / total if total else 0.0
                        ready.append(sim.now - compute_s + off)
                    media = chunk_media(edge, sizes_o, ready, sim.now)
                objs.append(list(zip(ready, sizes_o, media)))
                per_m: Dict[str, int] = {}
                for m, b in zip(media, sizes_o):
                    per_m[m] = per_m.get(m, 0) + b
                for m, b in per_m.items():
                    if m in _STORAGE_MEDIA:
                        acct = cluster.accounting(m)
                        acct.n_storage_puts += 1
                        acct.store(sim.now, b / 1e9)
                        u.n_puts += 1
            _mark_max(f"staged:{edge.label}")
            u.put_s += sim.now - t0
            return
        puts = staged_media[edge.label].setdefault(src_node, [])
        for _ in range(n):
            # the object's medium is decided HERE; consumers reuse it (the
            # consumer-side pull records the telemetry observation, with
            # this put's fee share folded in)
            m = _medium(edge, edge.nbytes, record=False)
            puts.append(m)
            if m in _STORAGE_MEDIA:
                u.n_puts += 1
                yield cluster.storage_put(m, src_node, edge.nbytes)
        _mark_max(f"staged:{edge.label}")
        u.put_s += sim.now - t0

    def stage_proc(stage: Stage, i: int) -> Generator:
        inst = None
        if control is not None:
            # placement first: the activator steers this stage instance and
            # buffers it across any cold start the autoscaler incurs
            inst, wait = control.steer(stage.name)
            if wait > 0:
                yield sim.timeout(wait)
        tok = bill.start(stage.name)
        dst_node = nodes[stage.name][i]
        for edge in dag.in_edges(stage):
            yield from consumer_fetch(edge, dst_node)
        if stage.compute_s > 0:
            yield sim.timeout(stage.compute_s)
        _mark_max(f"compute:{stage.name}")
        for edge in dag.out_edges(stage):
            if edge.handoff == "staged":   # incl. gather edges into the entry
                yield from producer_stage_puts(edge, dst_node)
        children = dag.blocking_children(stage)
        if children:
            done = [
                sim.spawn(stage_proc(c, j)).done
                for c in children
                for j in range(c.fan)
            ]
            yield sim.all_of(done)
        bill.stop(tok)
        if control is not None:
            control.release(stage.name, inst.instance_id)

    def entry_proc() -> Generator:
        entry = dag.entry
        entry_node = nodes[entry.name][0]
        entry_inst = None
        if control is not None:
            entry_inst, wait = control.steer(entry.name)
            if wait > 0:
                yield sim.timeout(wait)
        tok = bill.start(entry.name)
        if entry.compute_s > 0:
            yield sim.timeout(entry.compute_s)
        _mark_max(f"compute:{entry.name}")
        for edge in dag.out_edges(entry):
            if edge.handoff == "staged":
                yield from producer_stage_puts(edge, entry_node)
        children = dag.blocking_children(entry)
        if children:
            # vSwarm blocking chain: the entry's billed span covers the
            # whole subtree (slow transfers inflate the compute bill).
            done = [
                sim.spawn(stage_proc(c, j)).done
                for c in children
                for j in range(c.fan)
            ]
            yield sim.all_of(done)
            bill.stop(tok)
            if control is not None:
                control.release(entry.name, entry_inst.instance_id)
            return
        # Orchestrated: the entry's wait on children is NOT billed.
        bill.stop(tok)
        for wave in dag.orchestrated_waves():
            done = [
                sim.spawn(stage_proc(s, i)).done
                for s in wave
                for i in range(s.fan)
            ]
            yield sim.all_of(done)
        gathers = dag.gather_edges()
        if gathers or entry.gather_compute_s > 0:
            tok2 = bill.start(f"{entry.name}_gather")
            marks["gather_start"] = sim.now
            for edge in gathers:
                yield from consumer_fetch(edge, entry_node)
            marks["gather_done"] = sim.now
            if entry.gather_compute_s > 0:
                yield sim.timeout(entry.gather_compute_s)
            bill.stop(tok2)
        if control is not None:
            control.release(entry.name, entry_inst.instance_id)

    root = sim.spawn(entry_proc())
    sim.run()
    assert root.done.fired, f"DAG {dag.name!r} deadlocked"
    edge_media = {
        label: "+".join(sorted(ms)) if ms else "unused"
        for label, ms in media_seen.items()
    }
    return ClusterDagRun(
        dag=dag, cluster=cluster, bill=bill, marks=marks,
        edge_usage=usage, edge_media=edge_media, control=control,
        faults=faults,
    )


def execute_on_cluster(
    dag: WorkflowDAG,
    backend: Route,
    net: NetConstants = DEFAULT_NET,
    seed: int = 0,
    deterministic: bool = False,
    autoscaler: Any = None,
    scaling: Optional[Callable[[Stage], ScalingPolicy]] = None,
    plan: Any = None,
    fault_plan: Any = None,
    online_spill: Any = None,
    topology: Optional[Topology] = None,
    telemetry: Optional[TelemetryHub] = None,
) -> ClusterDagRun:
    """Deprecated: use ``dag.compile(target="cluster", backend=...,
    ...).run(seed=..., deterministic=...)``.  Kept as a thin shim — same
    parameters, same bits."""
    warnings.warn(
        "execute_on_cluster() is deprecated; use "
        "dag.compile(target='cluster', backend=...).run(seed=...).",
        DeprecationWarning,
        stacklevel=2,
    )
    return _execute_on_cluster(
        dag, backend, net=net, seed=seed, deterministic=deterministic,
        autoscaler=autoscaler, scaling=scaling, plan=plan,
        fault_plan=fault_plan, online_spill=online_spill,
        topology=topology, telemetry=telemetry,
    )


# ---------------------------------------------------------------------------
# Lowering 2: the event-driven WorkflowEngine (sweep / loadgen path)
# ---------------------------------------------------------------------------


class DagBinding(Runnable):
    """A DAG compiled onto a :class:`~repro.core.workflow.WorkflowEngine`.

    Registers one generator handler per stage (named ``<dag>.<stage>``) that
    speaks the engine's existing protocol — ``ctx.put``/``ctx.get`` move
    real (optionally down-scaled) arrays, ``ctx.call`` + ``yield`` fan out
    — so at-most-once ids, producer-death retries, autoscaling, and
    virtual-time records are reused unchanged.  Each edge's objects are put
    on the medium its route resolves (per object, at send time); per-edge
    usage lands in :attr:`edge_usage` and per-medium storage ops in the
    transfer engine's ``media_acct`` for mixed-backend pricing.

    Use with the load generator::

        binding = dag.bind(engine, default_route=SizeRoute())
        rep = LoadGenerator(engine, binding).run_open(rate_rps=50, duration_s=20)
    """

    #: reserved inbox key carrying the caller's coords on affined spawns —
    #: never a valid edge label (labels come from stage names / user strings)
    _SRC_KEY = "#src"
    #: reserved inbox key handing a wave producer its consumers' pre-created
    #: ChunkStreams (the entry orchestrates streams; producers only push)
    _STREAMS_KEY = "#streams"

    def __init__(
        self,
        dag: WorkflowDAG,
        engine,
        default_route: Optional[Route] = None,
        bytes_scale: float = 1.0,
        policy: Optional[Callable[[Stage], Any]] = None,
        handlers: Optional[Dict[str, Callable]] = None,
        autoscaler: Any = None,
        plan: Any = None,
        online_spill: Any = None,
        topology: Optional[Topology] = None,
    ):
        self.dag = dag
        self.engine = engine
        self.plan = plan
        #: optional :class:`~repro.core.dagopt.OnlineSpill` — consulted per
        #: chunk so remaining chunks of a streamed edge divert to durable
        #: media when the producer's live reap window closes in
        self.online_spill = online_spill
        #: :class:`~repro.core.faults.FaultInjector` armed by
        #: ``compile(target="engine", faults=...)``; None otherwise
        self.fault_injector: Any = None
        # edge-cloud continuum: each stage's instances are placed by a
        # zone-carrying placer (coords embed the zone index, so cross-zone
        # instances never compare coords-equal), and every cross-tier
        # transfer pays tier RTT + bandwidth as ctx.sleep debt plus egress
        # fees into ``egress_usd``.  Flat/absent topologies skip it all —
        # placers, debt, and fees — keeping the engine bit-identical.
        self.topology: Optional[Topology] = (
            topology if topology is not None and not topology.is_flat
            else None
        )
        self.egress_usd = 0.0
        self._stage_zones: Dict[str, Tuple[int, ...]] = {}
        if self.topology is not None:
            self._stage_zones = self.topology.assign_stage_zones(
                [s.name for s in dag.stages],
                plan_zones=getattr(plan, "zones", None),
            )
        # co-placement hints: the spawner forwards the affinity producer's
        # instance coords to the callee's steer (blocking children are
        # spawned by their producer; wave stages by the entry, which learns
        # each fan-1 wave producer's coords from its result).  A consumer
        # that lands co-resident with those coords models the edge's pulls
        # at shared-memory speed — mirroring the cluster lowering, which
        # honors every plan entry.
        self._affinity: Dict[str, str] = {}
        if plan is not None and getattr(plan, "affinity", None):
            for cname, pname in plan.affinity.items():
                if cname not in dag.by_name or pname not in dag.by_name:
                    raise ValueError(
                        f"placement plan affines unknown stage {cname!r} -> "
                        f"{pname!r}; was the plan produced by optimize() on "
                        "this DAG?"
                    )
                self._affinity[cname] = pname
            if self._SRC_KEY in {e.label for e in dag.edges}:
                raise ValueError(
                    f"edge label {self._SRC_KEY!r} collides with the "
                    "binding's reserved co-placement key"
                )
            if self.topology is not None:
                # a hand-written plan may affine stages the topology
                # separated; cross-zone pairs cannot share a node
                self._affinity = {
                    c: p for c, p in self._affinity.items()
                    if self._stage_zones[c][0] == self._stage_zones[p][0]
                }
        self.default_route: Route = (
            engine.transfer.backend if default_route is None else default_route
        )
        self.bytes_scale = bytes_scale
        # adaptive routes observe the engine's transfer telemetry, so
        # routing decisions feed on THIS engine's real pulls; the feed is
        # off by default (hot-path cost) and switched on here on demand
        adaptive = [
            r for r in (self.default_route, *(e.route for e in dag.edges))
            if isinstance(r, AdaptiveRoute)
        ]
        if adaptive:
            if engine.transfer.telemetry is None:
                engine.transfer.telemetry = TelemetryHub(engine.transfer.clock)
            for r in adaptive:
                r.auto_bind(engine.transfer.telemetry)
        self._resolve = dag.route_resolver(self.default_route)
        # the graph is immutable: derive per-stage edge lists, blocking
        # children, waves, and gathers ONCE at bind time — handlers run per
        # request on the sweep hot path and must not rescan the edge list
        self._in_edges: Dict[str, List[Edge]] = {
            s.name: dag.in_edges(s) for s in dag.stages
        }
        self._out_edges: Dict[str, List[Edge]] = {
            s.name: dag.out_edges(s) for s in dag.stages
        }
        self._children: Dict[str, List[Stage]] = {
            s.name: dag.blocking_children(s) for s in dag.stages
        }
        self._waves: List[List[Stage]] = dag.orchestrated_waves()
        self._gathers: List[Edge] = dag.gather_edges()
        self._streaming: List[Edge] = [e for e in dag.edges if e.streaming]
        if self._waves:
            for e in self._streaming:
                if e.max_inflight_chunks and e.dst == dag.entry.name:
                    raise ValueError(
                        f"streaming gather edge {e.label!r} cannot use "
                        "max_inflight_chunks on the engine lowering: the "
                        "entry drains gathers only after the producer wave "
                        "returns, so a blocked producer would deadlock"
                    )
        if self._streaming and self._STREAMS_KEY in {e.label for e in dag.edges}:
            raise ValueError(
                f"edge label {self._STREAMS_KEY!r} collides with the "
                "binding's reserved stream-handoff key"
            )
        self.edge_usage: Dict[str, EdgeUsage] = {
            e.label: EdgeUsage() for e in dag.edges
        }
        # external (original-input) reads never pass through the transfer
        # engine — the consumer synthesizes the object locally and pays the
        # modeled read — so their per-medium request fees are tracked here
        # and merged into media_storage_ops(); the cluster lowering bills
        # the same GETs through the cluster's per-backend accounting.
        self._external_gets: Dict[str, int] = {}
        self.entry = self._fn(dag.entry.name)

        default_policy = policy or (
            lambda s: ScalingPolicy(
                max_instances=max(16, 4 * s.fan), target_concurrency=1,
                autoscaler=autoscaler,
            )
        )
        handlers = handlers or {}
        unknown = set(handlers) - set(dag.by_name)
        if unknown:
            raise ValueError(f"handlers for unknown stages: {sorted(unknown)}")
        for stage in dag.stages:
            svc = stage.compute_s
            if any(e.streaming for e in self._out_edges[stage.name]):
                if stage.name in handlers:
                    raise ValueError(
                        f"stage {stage.name!r} has streaming out-edges: its "
                        "handler must pace chunk publication across the "
                        "compute window, so a custom handler cannot be bound"
                    )
                # the streaming handler self-paces compute as numeric yields
                # interleaved with chunk publication; registering the compute
                # as service_time on top would double-charge it
                svc = 0.0
            placer = None
            if self.topology is not None:
                # coords embed (zone index, instance id): same-zone stages
                # with matching ids still model co-residency (as the flat
                # placer's (i,) did), cross-zone stages never do, and the
                # Coord's tier path drives the zone-affine steer fallback
                zs = self._stage_zones[stage.name]
                placer = (
                    lambda i, zs=zs: self.topology.coord(
                        (zs[i % len(zs)], i), zs[i % len(zs)]
                    )
                )
            engine.register(
                self._fn(stage.name),
                handlers.get(stage.name) or self._make_handler(stage),
                policy=default_policy(stage),
                service_time=svc,
                placer=placer,
            )

    def _fn(self, stage_name: str) -> str:
        return f"{self.dag.name}.{stage_name}"

    # -- cross-tier debt (topology runs only) ------------------------------
    def _ctx_zone(self, ctx) -> Optional[int]:
        """Zone index of the acting instance (its Coord's tier path)."""
        zone = getattr(ctx.instance.coords, "zone", None)
        if zone is None:
            return None
        return self.topology.zone_index.get(zone)

    def _tier_level(self, za: Optional[int], zb: Optional[int]) -> int:
        """Crossing level between two zones; ``None`` means the topology's
        service zone (where S3/ElastiCache front-ends are homed)."""
        svc = self.topology.service_zone
        za = svc if za is None else za
        zb = svc if zb is None else zb
        if za == zb:
            return 1
        return self.topology.crossing(za, zb)

    def _pay_tier(self, ctx, level: int, nbytes: int) -> None:
        """One transfer's tier-crossing debt: tier RTT + tier-bandwidth
        seconds as ctx.sleep (virtual time, billed like any handler wait)
        and cross-tier egress fees into :attr:`egress_usd`."""
        if level <= 1:
            return
        net = self.engine.transfer.net
        ctx.sleep(net.tier_rtt(level) + nbytes / net.tier_bw(level))
        self.egress_usd += egress_fee_usd(level, nbytes)

    def _ref_medium(self, ref) -> str:
        """The medium a staged object actually landed on (the put-time
        routing decision rides inside the ref's envelope)."""
        tr = self.engine.transfer
        payload = getattr(ref, "_payload", None)
        if payload is None:
            payload = tr.minter.open(ref)
        return payload.medium or tr.backend

    # -- data movement (tracked) ------------------------------------------
    def _elems(self, edge: Edge) -> int:
        return max(1, int(edge.nbytes * self.bytes_scale) // 4)

    def _put(self, ctx, edge: Edge, fill: float, n_retrievals: int):
        # Route on the DECLARED edge size (the workload's object), not the
        # down-scaled sweep array — routing must match the modeled workload.
        medium = self._resolve(edge, edge.nbytes)
        arr = np.full((self._elems(edge),), fill, np.float32)
        ref = ctx.put(arr, n_retrievals=n_retrievals, backend=medium)
        u = self.edge_usage[edge.label]
        u.count(medium, arr.nbytes)
        u.n_puts += 1
        if self.topology is not None and medium in _STORAGE_MEDIA:
            # service put: producer zone -> service home (resident media
            # stage in place; their crossing is paid by the consumer's get)
            self._pay_tier(
                ctx, self._tier_level(self._ctx_zone(ctx), None), arr.nbytes
            )
        return ref

    def _get(self, ctx, edge: Edge, ref, local: bool = False):
        stats = self.engine.transfer.stats
        before = stats.modeled_seconds
        before_local = stats.local_pulls
        val = ctx.get(ref, local=local)
        u = self.edge_usage[edge.label]
        u.n_gets += 1
        u.n_local += stats.local_pulls - before_local
        u.modeled_s += stats.modeled_seconds - before
        if self.topology is not None and not local:
            medium = self._ref_medium(ref)
            nbytes = getattr(val, "nbytes", edge.nbytes)
            if medium in _STORAGE_MEDIA:
                # service get: service home -> consumer zone
                level = self._tier_level(None, self._ctx_zone(ctx))
            else:
                # resident pull: producer stage zone -> consumer zone
                src = (
                    self._stage_zones[edge.src][0]
                    if edge.src is not None else None
                )
                level = self._tier_level(src, self._ctx_zone(ctx))
            self._pay_tier(ctx, level, nbytes)
        return val

    def _put_for_consumers(self, ctx, edge: Edge, fill: float) -> List[List[Any]]:
        """Produce one edge's objects; returns refs per consumer instance."""
        fd = 1 if edge.dst == self.dag.entry.name else self.dag.by_name[edge.dst].fan
        if edge.fanout == "broadcast":
            refs = [
                self._put(ctx, edge, fill, n_retrievals=fd)
                for _ in range(edge.n_objects)
            ]
            return [list(refs) for _ in range(fd)]
        return [
            [self._put(ctx, edge, fill, n_retrievals=1)
             for _ in range(edge.n_objects)]
            for _ in range(fd)
        ]

    def _consume_external(self, ctx, edge: Edge, fill: float) -> List[Any]:
        """Original input: synthesize locally, charge the modeled read."""
        from .transfer import modeled_transfer_seconds

        medium = self._resolve(edge, edge.nbytes)
        net = self.engine.transfer.net
        hub = self.engine.transfer.telemetry
        out = []
        u = self.edge_usage[edge.label]
        for _ in range(edge.n_objects):
            arr = np.full((self._elems(edge),), fill, np.float32)
            modeled = modeled_transfer_seconds(medium, arr.nbytes, net)
            ctx.sleep(modeled)
            u.count(medium, arr.nbytes)
            u.n_gets += 1
            u.modeled_s += modeled
            if self.topology is not None:
                # original inputs live with the storage service: every read
                # crosses service home -> consumer zone
                self._pay_tier(
                    ctx, self._tier_level(None, self._ctx_zone(ctx)),
                    arr.nbytes,
                )
            self._external_gets[medium] = self._external_gets.get(medium, 0) + 1
            if hub is not None:
                # reads bypass the transfer engine, so feed the observe side
                # here (external: the input was never put by us)
                hub.record_transfer(
                    medium, arr.nbytes, modeled,
                    marginal_pull_fee_usd(medium, arr.nbytes, external=True),
                )
            out.append(arr)
        return out

    # -- streaming edges (chunk protocol) ----------------------------------
    def _chunk_medium(self, edge: Edge, nbytes: int, remaining_s: float) -> str:
        """Route one chunk; consult the online spill so chunks published
        late in the producer's reap window divert to durable media."""
        medium = self._resolve(edge, nbytes)
        if self.online_spill is not None and medium not in _STORAGE_MEDIA:
            eta = remaining_s + modeled_transfer_seconds(
                medium, nbytes, self.engine.transfer.net
            )
            medium = self.online_spill.medium_for(
                self.dag, edge, medium, self.engine.sim.now, eta
            )
        return medium

    def _produce_streams(self, ctx, stage: Stage, edges: List[Edge], streams, fill):
        """Publish every streaming out-edge's chunks, pacing the stage's
        compute as numeric yields so each chunk lands at its byte-
        proportional offset — the cluster lowering's production model.
        Objects/consumers follow ``_put_for_consumers``'s order; routing is
        per chunk-span (one logical object may split across media) and
        service-backend request fees bill once per (object, medium) —
        multipart upload semantics.  Streams seal in a ``finally`` so parked
        consumers always resume, even when production dies mid-flight.

        Three dynamic behaviors layer on the static schedule:

        * **coalescing** (:data:`STREAM_COALESCE`): a run of same-instant
          chunks of one object publishes through ``put_chunk_span`` — one
          shared payload, columnar refs, one billed PUT — and wakes parked
          consumers once per span via ``push_span``;
        * **credit backpressure** (``Edge(max_inflight_chunks=w)``): at most
          ``w`` instance-resident chunks may be published-but-undrained, the
          producer parking on the gate's credit event when the window fills
          (spans truncate to the available credits); after
          ``OnlineSpill.pressure_patience`` consecutive credit-delayed
          publications the remaining stream spills durable — bounded sender
          memory without stalling forever behind a structurally slow
          consumer;
        * **auto chunk sizing** (``chunk_bytes="auto"``): sizes resolve per
          (edge, medium) from :func:`resolve_auto_chunk_bytes` at production
          start, and an object's remaining bytes re-split whenever its route
          lands on a different medium mid-stream — the route-decision points
          double as re-scoring points.
        """
        dag = self.dag
        sim = self.engine.sim
        transfer = self.engine.transfer
        compute_s = stage.compute_s
        sched: List[Tuple[float, int, Edge, Optional[int], Any, int]] = []
        fan_dst: Dict[str, int] = {}
        total_of: Dict[str, float] = {}
        scored_medium: Dict[str, str] = {}
        n = 0
        for edge in edges:
            fd = 1 if edge.dst == dag.entry.name else dag.by_name[edge.dst].fan
            fan_dst[edge.label] = fd
            if edge.chunk_bytes == "auto":
                m0 = self._resolve(edge, edge.nbytes)
                scored_medium[edge.label] = m0
                cb = resolve_auto_chunk_bytes(
                    edge, m0, net=transfer.net, telemetry=transfer.telemetry,
                    compute_s=compute_s,
                )
                sizes = edge.chunk_sizes(cb)
            else:
                sizes = edge.chunk_sizes()
            rows = 1 if edge.fanout == "broadcast" else fd
            total = float(edge.nbytes * edge.n_objects * rows)
            total_of[edge.label] = total
            acc = 0
            for row in range(rows):
                for _ in range(edge.n_objects):
                    tok = object()
                    for b in sizes:
                        acc += b
                        off = compute_s * (acc / total) if total else 0.0
                        j = None if edge.fanout == "broadcast" else row
                        sched.append((off, n, edge, j, tok, b))
                        n += 1
        sched.sort(key=lambda item: (item[0], item[1]))
        gates: Dict[str, Any] = {}
        for edge in edges:
            if edge.max_inflight_chunks > 0:
                from .workflow import CreditGate

                g = CreditGate(sim, edge.max_inflight_chunks)
                gates[edge.label] = g
                for s in streams[edge.label]:
                    prev = s.gate
                    s.gate = (g,) if prev is None else tuple(prev) + (g,)
        seen: Dict[Any, set] = {}
        auto_m: Dict[Any, str] = {}       # tok -> medium its split was scored for
        streak: Dict[str, int] = {}       # consecutive credit-delayed publishes
        forced: Dict[str, str] = {}       # post-pressure-spill durable target
        spill = self.online_spill
        try:
            t = 0.0
            t0 = sim.now
            idx = 0
            while idx < len(sched):
                off, _, edge, j, tok, b = sched[idx]
                if off > t:
                    yield off - t
                    t = off
                label = edge.label
                run = 1
                if STREAM_COALESCE:
                    end = len(sched)
                    while idx + run < end:
                        o2, _, e2, j2, tok2, b2 = sched[idx + run]
                        if (o2 != off or e2 is not edge or j2 != j
                                or tok2 is not tok or b2 != b):
                            break
                        run += 1
                rem_s = compute_s - t
                if rem_s < 0.0:          # credit waits can outlast compute
                    rem_s = 0.0
                medium = forced.get(label)
                if medium is None:
                    medium = self._chunk_medium(edge, b, rem_s)
                gate = gates.get(label)
                if gate is not None and medium not in _STORAGE_MEDIA:
                    if gate.full:
                        hits = streak.get(label, 0) + 1
                        streak[label] = hits
                        if spill is not None and hits >= spill.pressure_patience:
                            # persistent zero-credit: the consumer is
                            # structurally slower — remaining stream durable
                            medium = forced[label] = spill.on_pressure(
                                dag, edge, medium, sim.now
                            )
                            gate = None
                        else:
                            while gate.full:
                                yield gate.wait()
                            t = sim.now - t0
                    else:
                        streak[label] = 0
                    if gate is not None:
                        avail = gate.window - gate.outstanding
                        if run > avail:
                            run = avail
                else:
                    gate = None
                nr = fan_dst[label] if j is None else 1
                media = seen.setdefault(tok, set())
                bill = medium not in media
                media.add(medium)
                u = self.edge_usage[label]
                if run > 1:
                    arr = np.full(
                        (max(1, int(b * self.bytes_scale) // 4),),
                        fill, np.float32,
                    )
                    refs = ctx.put_chunk_span(
                        arr, run, n_retrievals=nr, backend=medium,
                        bill_put=bill,
                    )
                    anb = arr.nbytes
                    u.media[medium] = u.media.get(medium, 0) + run
                    u.media_bytes[medium] = (
                        u.media_bytes.get(medium, 0) + anb * run
                    )
                    u.bytes_moved += anb * run
                    if bill:
                        u.n_puts += 1
                    if gate is not None:
                        for r in refs:
                            gate.publish(r, nr)
                    if j is None:    # broadcast: every consumer sees the refs
                        for s in streams[label]:
                            s.push_span(refs, medium, tok)
                    else:
                        streams[label][j].push_span(refs, medium, tok)
                else:
                    arr = np.full(
                        (max(1, int(b * self.bytes_scale) // 4),),
                        fill, np.float32,
                    )
                    ref = ctx.put_chunk(
                        arr, n_retrievals=nr, backend=medium, bill_put=bill
                    )
                    u.count(medium, arr.nbytes)
                    if bill:
                        u.n_puts += 1
                    if gate is not None:
                        gate.publish(ref, nr)
                    if j is None:
                        for s in streams[label]:
                            s.push(ref, medium, tok)
                    else:
                        streams[label][j].push(ref, medium, tok)
                idx += run
                # mid-stream re-score: an auto object's remaining bytes
                # re-split for the medium the route actually landed on
                if label in scored_medium:
                    if medium != auto_m.setdefault(
                        tok, scored_medium[label]
                    ):
                        auto_m[tok] = medium
                        idx = self._rescore_auto_tail(
                            sched, idx, edge, tok, medium, compute_s,
                            total_of[label],
                        )
            if compute_s > t:
                yield compute_s - t
        finally:
            for edge in edges:
                for s in streams[edge.label]:
                    s.seal()

    def _rescore_auto_tail(
        self, sched, idx, edge: Edge, tok, medium: str, compute_s: float,
        total: float,
    ) -> int:
        """Re-split ``tok``'s unpublished chunks for ``medium``.

        Called from :meth:`_produce_streams` when an ``"auto"`` edge's route
        resolves a chunk onto a medium different from the one the current
        split was scored against.  The remaining byte range keeps its start
        and end offsets (byte-proportional pacing is unchanged — only the
        chunk boundaries inside it move), so other edges' interleaved
        entries keep their relative order.  Returns ``idx`` (the schedule is
        rewritten in place from ``idx`` on)."""
        transfer = self.engine.transfer
        rest = [s for s in sched[idx:] if s[4] is tok]
        if not rest:
            return idx
        rem_b = sum(s[5] for s in rest)
        off_hi = rest[-1][0]
        off_lo = sched[idx - 1][0]
        window = compute_s * (rem_b / total) if total else 0.0
        cb = resolve_auto_chunk_bytes(
            edge, medium, net=transfer.net, telemetry=transfer.telemetry,
            compute_s=window, nbytes=rem_b,
        )
        q, r = divmod(rem_b, cb)
        new_sizes = [cb] * q + ([r] if r else [])
        j = next(s[3] for s in rest)
        new_entries = []
        done = 0
        n = sched[-1][1] + 1 if sched else 0
        for b in new_sizes:
            done += b
            o = off_lo + (off_hi - off_lo) * (done / rem_b)
            new_entries.append((o, n, edge, j, tok, b))
            n += 1
        tail = [s for s in sched[idx:] if s[4] is not tok] + new_entries
        tail.sort(key=lambda item: (item[0], item[1]))
        sched[idx:] = tail
        return idx

    def _drain_stream(self, ctx, edge: Edge, stream, local: bool = False):
        """Pull a stream's chunks as they publish, parking on the stream's
        ``more`` event between publications — the data-triggered consumer's
        wait-for-data, in virtual time.  Request fees bill once per
        (object, medium): a ranged multi-GET of each object's chunk run.
        Runs of already-published same-(object, medium) chunks drain through
        ``get_chunk_span`` — one dispatch for the whole backlog run instead
        of one per chunk event — and every drained chunk is reported to the
        stream's credit gates so a parked producer's window can release."""
        stats = self.engine.transfer.stats
        u = self.edge_usage[edge.label]
        vals: List[Any] = []
        seen: set = set()
        i = 0
        while True:
            avail = len(stream.refs)
            while i < avail:
                medium = stream.media[i]
                obj = stream.objs[i]
                j = i + 1
                if STREAM_COALESCE:
                    while (j < avail and stream.media[j] == medium
                           and stream.objs[j] is obj):
                        j += 1
                key = (obj, medium)
                bill = key not in seen
                seen.add(key)
                before = stats.modeled_seconds
                before_local = stats.local_pulls
                before_n = len(vals)
                if j - i > 1:
                    vals.extend(ctx.get_chunk_span(
                        stream.refs[i:j], local=local, bill_first=bill
                    ))
                else:
                    vals.append(ctx.get_chunk(
                        stream.refs[i], local=local, bill_get=bill
                    ))
                if bill:
                    u.n_gets += 1
                u.n_local += stats.local_pulls - before_local
                u.modeled_s += stats.modeled_seconds - before
                if self.topology is not None and not local:
                    span_bytes = sum(
                        getattr(v, "nbytes", 0) for v in vals[before_n:]
                    )
                    if medium in _STORAGE_MEDIA:
                        level = self._tier_level(None, self._ctx_zone(ctx))
                    else:
                        src = (
                            self._stage_zones[edge.src][0]
                            if edge.src is not None else None
                        )
                        level = self._tier_level(src, self._ctx_zone(ctx))
                    self._pay_tier(ctx, level, span_bytes)
                gates = stream.gate
                if gates is not None:
                    for k in range(i, j):
                        r = stream.refs[k]
                        for g in gates:
                            g.on_pull(r)
                i = j
            if stream.sealed:
                return vals
            yield stream.more

    # -- handlers ----------------------------------------------------------
    def _make_handler(self, stage: Stage):
        dag = self.dag
        if stage is dag.entry:
            return self._make_entry_handler(stage)
        in_edges = self._in_edges[stage.name]
        out_edges = self._out_edges[stage.name]
        if any(e.streaming for e in in_edges) or any(
            e.streaming for e in out_edges
        ):
            return self._make_streaming_handler(stage)
        children = self._children[stage.name]
        aff_producer = self._affinity.get(stage.name)
        src_key = self._SRC_KEY

        def handler(ctx, payload):
            fill, inbox = payload
            # the spawner stamped the producer's coords when the plan
            # affines this stage to it.  Locality is CO-RESIDENCY of
            # placement coords — the shared node space the default placer
            # models — which the steering hint biases toward (and a fresh
            # spawn may land on outright); only then do that producer's
            # pulls go shared-memory.
            src_coords = inbox.get(src_key)
            co_located = (
                src_coords is not None and ctx.instance is not None
                and ctx.instance.coords == src_coords
            )
            values: Dict[str, List[Any]] = {}
            for edge in in_edges:
                if edge.handoff == "external":
                    values[edge.label] = self._consume_external(ctx, edge, fill)
                else:
                    local = co_located and edge.src == aff_producer
                    values[edge.label] = [
                        self._get(ctx, edge, r, local=local)
                        for r in inbox[edge.label]
                    ]
            out: Dict[str, List[List[Any]]] = {}
            for edge in out_edges:
                out[edge.label] = self._put_for_consumers(ctx, edge, fill)
            for child in children:
                edge = self._in_edges[child.name][0]
                affine = (
                    self._affinity.get(child.name) == stage.name
                    and ctx.instance is not None
                )
                handles = []
                for j in range(child.fan):
                    box = {edge.label: out[edge.label][j]}
                    if affine:
                        box[src_key] = ctx.instance.coords
                    handles.append(ctx.call(
                        self._fn(child.name), (fill, box),
                        affinity=ctx.instance.coords if affine else None,
                    ))
                yield handles
            checksum = float(
                sum(float(np.sum(v)) for vs in values.values() for v in vs)
            )
            # coords travel with the result so the entry can forward
            # affinity hints for edges whose producer is a wave stage (the
            # entry spawns the consumers, not the producer itself)
            coords = ctx.instance.coords if ctx.instance is not None else None
            return {"out": out, "sum": checksum, "coords": coords}

        return handler

    def _make_streaming_handler(self, stage: Stage):
        """Stage-handler variant for stages touched by streaming edges.

        Streaming inputs drain from :class:`~repro.core.workflow.ChunkStream`
        mailboxes (parking between publications); streaming outputs publish
        paced chunks; and blocking children fed by a streaming edge spawn
        BEFORE production — data-triggered activation: the child is steered
        and pulling on the first chunk's arrival event instead of after this
        handler's orchestration round-trip.  Wave producers find their
        consumers' streams pre-created by the entry under ``#streams``.
        Stages no streaming edge touches keep the stock handler, so
        ``streaming=False`` runs are bit-identical."""
        from .workflow import ChunkStream

        dag = self.dag
        in_edges = self._in_edges[stage.name]
        out_edges = self._out_edges[stage.name]
        children = self._children[stage.name]
        aff_producer = self._affinity.get(stage.name)
        src_key = self._SRC_KEY
        streams_key = self._STREAMS_KEY
        stream_out = [e for e in out_edges if e.streaming]
        sim = self.engine.sim

        def handler(ctx, payload):
            fill, inbox = payload
            src_coords = inbox.get(src_key)
            co_located = (
                src_coords is not None and ctx.instance is not None
                and ctx.instance.coords == src_coords
            )
            values: Dict[str, List[Any]] = {}
            for edge in in_edges:
                if edge.handoff == "external":
                    values[edge.label] = self._consume_external(ctx, edge, fill)
                    continue
                local = co_located and edge.src == aff_producer
                if edge.streaming:
                    values[edge.label] = yield from self._drain_stream(
                        ctx, edge, inbox[edge.label], local=local
                    )
                else:
                    values[edge.label] = [
                        self._get(ctx, edge, r, local=local)
                        for r in inbox[edge.label]
                    ]
            out: Dict[str, List[List[Any]]] = {}
            for edge in out_edges:
                if not edge.streaming:
                    out[edge.label] = self._put_for_consumers(ctx, edge, fill)
            # streaming outputs: wave producers got their consumers' streams
            # from the entry; streams to blocking children are minted here,
            # and those children spawn NOW — before production
            streams = dict(inbox.get(streams_key) or {})
            spawned: Dict[str, List[Any]] = {}
            for child in children:
                edge = self._in_edges[child.name][0]
                if not edge.streaming:
                    continue
                streams[edge.label] = [ChunkStream(sim) for _ in range(child.fan)]
                affine = (
                    self._affinity.get(child.name) == stage.name
                    and ctx.instance is not None
                )
                handles = []
                for j in range(child.fan):
                    box = {edge.label: streams[edge.label][j]}
                    if affine:
                        box[src_key] = ctx.instance.coords
                    handles.append(ctx.call(
                        self._fn(child.name), (fill, box),
                        affinity=ctx.instance.coords if affine else None,
                    ))
                spawned[child.name] = handles
            missing = [e.label for e in stream_out if e.label not in streams]
            if missing:
                raise RuntimeError(
                    f"no ChunkStreams for streaming out-edges {missing}: a "
                    "streaming consumer must be a blocking child or an "
                    "orchestrated wave stage"
                )
            if stream_out:
                yield from self._produce_streams(
                    ctx, stage, stream_out, streams, fill
                )
            for child in children:
                handles = spawned.get(child.name)
                if handles is None:
                    edge = self._in_edges[child.name][0]
                    affine = (
                        self._affinity.get(child.name) == stage.name
                        and ctx.instance is not None
                    )
                    handles = []
                    for j in range(child.fan):
                        box = {edge.label: out[edge.label][j]}
                        if affine:
                            box[src_key] = ctx.instance.coords
                        handles.append(ctx.call(
                            self._fn(child.name), (fill, box),
                            affinity=ctx.instance.coords if affine else None,
                        ))
                yield handles
            checksum = float(
                sum(float(np.sum(v)) for vs in values.values() for v in vs)
            )
            coords = ctx.instance.coords if ctx.instance is not None else None
            return {"out": out, "sum": checksum, "coords": coords}

        return handler

    def _make_entry_handler(self, entry: Stage):
        if self._streaming:
            return self._make_streaming_entry_handler(entry)
        out_edges = self._out_edges[entry.name]
        children = self._children[entry.name]
        waves = self._waves
        gathers = self._gathers
        in_edges = self._in_edges

        def handler(ctx, fill):
            fill = float(fill) if np.isscalar(fill) else 1.0
            out: Dict[str, List[List[Any]]] = {}
            for edge in out_edges:
                out[edge.label] = self._put_for_consumers(ctx, edge, fill)
            total = 0.0
            if children:
                for child in children:
                    edge = in_edges[child.name][0]
                    affine = (
                        self._affinity.get(child.name) == entry.name
                        and ctx.instance is not None
                    )
                    handles = []
                    for j in range(child.fan):
                        box = {edge.label: out[edge.label][j]}
                        if affine:
                            box[self._SRC_KEY] = ctx.instance.coords
                        handles.append(ctx.call(
                            self._fn(child.name), (fill, box),
                            affinity=ctx.instance.coords if affine else None,
                        ))
                    results = yield handles
                    total += sum(r["sum"] for r in results)
                return total
            # orchestrated waves: pools[label][consumer_idx] -> refs
            pools: Dict[str, List[List[Any]]] = dict(out)
            # affinity producers' instance coords, for hints whose producer
            # is an earlier wave's stage (the plan only affines fan-1
            # producers, so one coords per stage suffices)
            stage_coords: Dict[str, Any] = {}
            if ctx.instance is not None:
                stage_coords[entry.name] = ctx.instance.coords
            for wave in waves:
                handles, owners = [], []
                for s in wave:
                    prod_coords = stage_coords.get(self._affinity.get(s.name))
                    for j in range(s.fan):
                        inbox = {
                            e.label: pools[e.label][j]
                            for e in in_edges[s.name]
                            if e.handoff != "external"
                        }
                        if prod_coords is not None:
                            inbox[self._SRC_KEY] = prod_coords
                        handles.append(ctx.call(
                            self._fn(s.name), (fill, inbox),
                            affinity=prod_coords,
                        ))
                        owners.append(s)
                results = yield handles
                # merge returned out-pools: consumer j's refs concatenate
                # across all producer instances of the wave
                for s, res in zip(owners, results):
                    if s.fan == 1:
                        stage_coords[s.name] = res.get("coords")
                    for label, per_consumer in res["out"].items():
                        pool = pools.setdefault(
                            label, [[] for _ in per_consumer]
                        )
                        for j, refs in enumerate(per_consumer):
                            pool[j].extend(refs)
            for edge in gathers:
                for r in pools.get(edge.label, [[]])[0]:
                    total += float(np.sum(self._get(ctx, edge, r)))
            if entry.gather_compute_s > 0:
                ctx.sleep(entry.gather_compute_s)
            return total

        return handler

    def _make_streaming_entry_handler(self, entry: Stage):
        """Entry-handler variant used whenever the DAG has streaming edges.

        Blocking-children mode mirrors the stage handler: children fed by a
        streaming edge spawn before production.  Orchestrated-wave mode is
        where data-triggered activation pays off: the entry pre-creates one
        ChunkStream per (edge, consumer instance), hands each wave producer
        its consumers' streams via the reserved ``#streams`` inbox key, and
        arms the consumer spawn on each stream's first-chunk event — so
        while the entry is parked on the producer wave's fan-in barrier,
        consumers whose inputs all stream are steered and pulling the moment
        data lands, not after the producer wave returns."""
        from .workflow import ChunkStream

        dag = self.dag
        out_edges = self._out_edges[entry.name]
        children = self._children[entry.name]
        waves = self._waves
        gathers = self._gathers
        in_edges = self._in_edges
        out_edges_of = self._out_edges
        streaming = self._streaming
        src_key = self._SRC_KEY
        streams_key = self._STREAMS_KEY
        sim = self.engine.sim
        entry_stream_out = [e for e in out_edges if e.streaming]

        def handler(ctx, fill):
            fill = float(fill) if np.isscalar(fill) else 1.0
            out: Dict[str, List[List[Any]]] = {}
            for edge in out_edges:
                if not edge.streaming:
                    out[edge.label] = self._put_for_consumers(ctx, edge, fill)
            if children:
                streams: Dict[str, List[Any]] = {}
                spawned: Dict[str, List[Any]] = {}
                for child in children:
                    edge = in_edges[child.name][0]
                    if not edge.streaming:
                        continue
                    streams[edge.label] = [
                        ChunkStream(sim) for _ in range(child.fan)
                    ]
                    affine = (
                        self._affinity.get(child.name) == entry.name
                        and ctx.instance is not None
                    )
                    handles = []
                    for j in range(child.fan):
                        box = {edge.label: streams[edge.label][j]}
                        if affine:
                            box[src_key] = ctx.instance.coords
                        handles.append(ctx.call(
                            self._fn(child.name), (fill, box),
                            affinity=ctx.instance.coords if affine else None,
                        ))
                    spawned[child.name] = handles
                missing = [
                    e.label for e in entry_stream_out if e.label not in streams
                ]
                if missing:
                    raise RuntimeError(
                        f"no ChunkStreams for streaming out-edges {missing}: "
                        "a streaming consumer must be a blocking child or an "
                        "orchestrated wave stage"
                    )
                if entry_stream_out:
                    yield from self._produce_streams(
                        ctx, entry, entry_stream_out, streams, fill
                    )
                total = 0.0
                for child in children:
                    handles = spawned.get(child.name)
                    if handles is None:
                        edge = in_edges[child.name][0]
                        affine = (
                            self._affinity.get(child.name) == entry.name
                            and ctx.instance is not None
                        )
                        handles = []
                        for j in range(child.fan):
                            box = {edge.label: out[edge.label][j]}
                            if affine:
                                box[src_key] = ctx.instance.coords
                            handles.append(ctx.call(
                                self._fn(child.name), (fill, box),
                                affinity=(
                                    ctx.instance.coords if affine else None
                                ),
                            ))
                    results = yield handles
                    total += sum(r["sum"] for r in results)
                return total
            # orchestrated waves: every streaming edge's per-consumer
            # streams exist before any producer runs
            pools: Dict[str, List[List[Any]]] = dict(out)
            streams = {}
            for e in streaming:
                fd = 1 if e.dst == entry.name else dag.by_name[e.dst].fan
                fs = 1 if e.src == entry.name else dag.by_name[e.src].fan
                streams[e.label] = [
                    ChunkStream(sim, n_producers=fs) for _ in range(fd)
                ]

            def out_streams(s: Stage) -> Dict[str, List[Any]]:
                return {
                    e.label: streams[e.label]
                    for e in out_edges_of[s.name] if e.streaming
                }

            # arm data-triggered spawns: a wave stage whose every
            # (non-external) input streams spawns instance j on the first
            # chunk event of any of j's streams
            early: Dict[str, List[Any]] = {}
            pending: Dict[str, set] = {}

            def arm(s: Stage) -> None:
                sedges = [
                    e for e in in_edges[s.name] if e.handoff != "external"
                ]
                outs = out_streams(s)
                hs: List[Any] = [None] * s.fan
                todo = set(range(s.fan))

                def mk(j: int):
                    def trigger():
                        if j not in todo:
                            return
                        todo.discard(j)
                        box = {e.label: streams[e.label][j] for e in sedges}
                        if outs:
                            box[streams_key] = dict(outs)
                        hs[j] = ctx.call(self._fn(s.name), (fill, box))
                    return trigger

                for j in range(s.fan):
                    for e in sedges:
                        streams[e.label][j].first.add_waiter(mk(j))
                early[s.name] = hs
                pending[s.name] = todo

            for wave in waves:
                for s in wave:
                    sedges = [
                        e for e in in_edges[s.name] if e.handoff != "external"
                    ]
                    if sedges and all(e.streaming for e in sedges):
                        arm(s)
            stage_coords: Dict[str, Any] = {}
            if ctx.instance is not None:
                stage_coords[entry.name] = ctx.instance.coords
            if entry_stream_out:
                yield from self._produce_streams(
                    ctx, entry, entry_stream_out, streams, fill
                )
            total = 0.0
            for wave in waves:
                handles, owners = [], []
                for s in wave:
                    if s.name in early:
                        # producers sealed their streams, so the first-chunk
                        # triggers have fired; spawn any stragglers (defense)
                        hs = early[s.name]
                        for j in sorted(pending[s.name]):
                            box = {
                                e.label: streams[e.label][j]
                                for e in in_edges[s.name]
                                if e.handoff != "external"
                            }
                            outs = out_streams(s)
                            if outs:
                                box[streams_key] = outs
                            hs[j] = ctx.call(self._fn(s.name), (fill, box))
                        pending[s.name].clear()
                        handles.extend(hs)
                        owners.extend(s for _ in hs)
                        continue
                    prod_coords = stage_coords.get(self._affinity.get(s.name))
                    outs = out_streams(s)
                    for j in range(s.fan):
                        inbox = {
                            e.label: (
                                streams[e.label][j] if e.streaming
                                else pools[e.label][j]
                            )
                            for e in in_edges[s.name]
                            if e.handoff != "external"
                        }
                        if outs:
                            inbox[streams_key] = dict(outs)
                        if prod_coords is not None:
                            inbox[src_key] = prod_coords
                        handles.append(ctx.call(
                            self._fn(s.name), (fill, inbox),
                            affinity=prod_coords,
                        ))
                        owners.append(s)
                results = yield handles
                for s, res in zip(owners, results):
                    if s.fan == 1:
                        stage_coords[s.name] = res.get("coords")
                    for label, per_consumer in res["out"].items():
                        pool = pools.setdefault(
                            label, [[] for _ in per_consumer]
                        )
                        for j, refs in enumerate(per_consumer):
                            pool[j].extend(refs)
            for edge in gathers:
                if edge.streaming:
                    vals = yield from self._drain_stream(
                        ctx, edge, streams[edge.label][0]
                    )
                    total += sum(float(np.sum(v)) for v in vals)
                else:
                    for r in pools.get(edge.label, [[]])[0]:
                        total += float(np.sum(self._get(ctx, edge, r)))
            if entry.gather_compute_s > 0:
                ctx.sleep(entry.gather_compute_s)
            return total

        return handler

    # -- reporting ---------------------------------------------------------
    def media_storage_ops(self) -> Dict[str, StorageOps]:
        """Per-medium storage ops of the engine's run so far: the transfer
        engine's per-medium accounting plus the external original-input GETs
        (which bypass the transfer engine but are real request fees — the
        cluster lowering bills them too)."""
        out = _media_ops(
            self.engine.transfer.media_acct.items(), self.engine.sim.now
        )
        for medium, n in self._external_gets.items():
            base = out.get(medium, StorageOps())
            out[medium] = dataclasses.replace(base, n_gets=base.n_gets + n)
        return out

    def cost(self):
        """Price the engine's whole run so far with per-medium fees."""
        eng = self.engine
        inputs = WorkflowCostInputs(
            n_function_invocations=len(eng.records),
            billed_duration_s=eng.billed_virtual_seconds(),
        )
        return routed_workflow_cost(
            inputs, self.media_storage_ops(), egress_usd=self.egress_usd
        )

    def edge_report(self) -> Dict[str, Dict[str, Any]]:
        return _edge_fee_rows(
            self.edge_usage, self.media_storage_ops(),
            lambda u: {"modeled_s": u.modeled_s},
        )


__all__ = [
    "AdaptiveRoute",
    "Billing",
    "ClusterDagRun",
    "ClusterRunnable",
    "DagBinding",
    "Edge",
    "EdgeUsage",
    "FixedRoute",
    "Route",
    "RoutePolicy",
    "Runnable",
    "SizeRoute",
    "Stage",
    "WorkflowDAG",
    "critical_path_lower_bound",
    "execute_on_cluster",
]
