"""Load generators for the event-driven workflow engine.

Two standard driving modes, both on the engine's virtual clock (minutes of
offered load run in milliseconds of wall time):

* **Closed loop** — ``n_clients`` clients, each submitting one workflow
  request, waiting for its completion, thinking for ``think_time_s``, and
  repeating.  Offered load adapts to service capacity (the classic
  benchmark-harness loop; concurrency is the controlled variable).
* **Open loop** — Poisson arrivals at ``rate_rps`` for ``duration_s``,
  independent of completions.  This is the regime where queueing, cold
  starts, and autoscaler lag actually show up in the tail (the paper's
  concurrent-workflow claims live here).

Both return a :class:`LoadReport` with per-request latencies, percentile
summaries, achieved throughput, and the cost-model inputs needed to price the
run ($ per 1k requests via :func:`repro.core.cost.cost_per_1k_requests`).
Driving a compiled :class:`~repro.core.dag.DagBinding` instead of a function
name prices the run per transfer medium (mixed-backend routing).

Scale: open-loop arrival trains are drawn **vectorized** from the simulator's
seeded rng (one numpy call per block instead of one Python-level exponential
per request) and driven by a single self-rescheduling dispatcher — no
per-arrival closures or up-front heap flooding.  Against an engine in
``records="columnar"`` mode, reports are computed from the engine's columnar
request log and no per-request objects are retained, so million-request
sweeps are memory-bounded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, List, Optional, Union

import numpy as np

from .cost import (
    StorageOps,
    WorkflowCostInputs,
    cost_per_1k_requests,
    routed_cost_per_1k_requests,
)
from .workflow import WorkflowEngine, WorkflowRequest


@dataclasses.dataclass
class LoadReport:
    """Summary of one load-generation run (all times virtual seconds)."""

    mode: str                          # "closed" | "open"
    backend: str
    offered_rps: float                 # open: arrival rate; closed: achieved
    achieved_rps: float
    n_requests: int
    n_ok: int
    duration_s: float
    p50_s: float
    p99_s: float
    mean_s: float
    latencies_s: List[float]
    cost_inputs: WorkflowCostInputs = None  # type: ignore[assignment]
    usd_per_1k_requests: float = 0.0
    #: which AutoscalerPolicy the engine's deployments ran under (names of
    #: the distinct policies, "+"-joined)
    autoscaler: str = ""
    #: control-plane activity during THIS run (deltas across deployments):
    #: cold instance boots, proactive pre-warm spawns, requests buffered
    #: across a cold start, requests queued at the max_instances cap
    n_cold_starts: int = 0
    n_prewarmed: int = 0
    n_buffered: int = 0
    n_queued: int = 0

    def as_row(self) -> Dict[str, Any]:
        return {
            "mode": self.mode,
            "backend": self.backend,
            "offered_rps": self.offered_rps,
            "achieved_rps": self.achieved_rps,
            "n_requests": self.n_requests,
            "n_ok": self.n_ok,
            "p50_s": self.p50_s,
            "p99_s": self.p99_s,
            "mean_s": self.mean_s,
            "usd_per_1k_requests": self.usd_per_1k_requests,
            "autoscaler": self.autoscaler,
            "n_cold_starts": self.n_cold_starts,
            "n_prewarmed": self.n_prewarmed,
            "n_buffered": self.n_buffered,
            "n_queued": self.n_queued,
        }


def poisson_arrival_times(
    rng: np.random.Generator, rate_rps: float, duration_s: float,
    t_start: float = 0.0, block: int = 4096,
) -> np.ndarray:
    """Absolute Poisson arrival timestamps in ``[t_start, t_start + duration)``.

    Vectorized: inter-arrival gaps are drawn in blocks and cumulative-summed
    (numpy's cumsum accumulates sequentially, so the resulting times match
    the legacy one-exponential-per-arrival loop bit-for-bit when ``t_start``
    is 0 — the fixed-seed reproducibility anchor of the benchmarks).
    """
    scale = 1.0 / rate_rps
    chunks: List[np.ndarray] = []
    carry: Optional[float] = None
    while True:
        gaps = rng.exponential(scale, size=block)
        if carry is None:
            offsets = np.cumsum(gaps)
        else:
            # continue the sequential accumulation across the block boundary
            # (seeding cumsum with the previous running sum keeps every
            # partial sum identical to a single uninterrupted loop)
            buf = np.empty(block + 1)
            buf[0] = carry
            buf[1:] = gaps
            offsets = np.cumsum(buf)[1:]
        cut = int(np.searchsorted(offsets, duration_s, side="left"))
        if cut < block:
            chunks.append(offsets[:cut])
            break
        chunks.append(offsets)
        carry = float(offsets[-1])
    times = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
    if t_start != 0.0:
        times = times + t_start
    return times


class _OpenLoopDispatcher:
    """One callable that walks the precomputed arrival train, submitting a
    request per arrival and rescheduling itself at the next absolute time —
    O(1) live heap entries and zero per-arrival closures."""

    __slots__ = ("gen", "times", "idx")

    def __init__(self, gen: "LoadGenerator", times: np.ndarray):
        self.gen = gen
        self.times = times.tolist()   # plain floats: no per-arrival unboxing
        self.idx = 0

    def start(self) -> None:
        if self.times:
            self.gen.engine.sim.schedule_abs(self.times[0], self)

    def __call__(self) -> None:
        gen = self.gen
        idx = self.idx
        req = gen.engine.submit(gen.entry, gen.payload_fn(idx))
        if gen._collect_objects:
            gen._requests.append(req)
        self.idx = idx = idx + 1
        if idx < len(self.times):
            gen.engine.sim.schedule_abs(self.times[idx], self)


class LoadGenerator:
    """Drives a :class:`WorkflowEngine` with synthetic request arrivals.

    ``entry`` is a registered function name, or a compiled
    :class:`~repro.core.dag.DagBinding` — then requests enter at the DAG's
    entry stage and the run is priced per transfer medium
    (:func:`~repro.core.cost.routed_cost_per_1k_requests`), so sweeps over
    per-edge-routed (hybrid) workflows report the mixed-backend bill.
    """

    def __init__(
        self,
        engine: WorkflowEngine,
        entry: Union[str, Any],
        payload_fn: Optional[Callable[[int], Any]] = None,
    ):
        self.engine = engine
        # a DagBinding (anything exposing .entry + .media_storage_ops) routes
        # per edge; its per-medium ops price the run
        self.binding = None if isinstance(entry, str) else entry
        self.entry: str = entry if isinstance(entry, str) else entry.entry
        self.payload_fn = payload_fn or (lambda i: i)
        self._requests: List[WorkflowRequest] = []
        # columnar engines report from the engine's request log; object-mode
        # engines from the retained WorkflowRequest list (legacy behaviour)
        self._collect_objects = engine.request_log is None

    #: Deployment.stats keys surfaced (as run-deltas) on the LoadReport
    _CONTROL_KEYS = ("cold_starts", "prewarmed", "buffered", "queued")

    def _control_stats(self) -> Dict[str, int]:
        """Control-plane counters summed across the engine's deployments."""
        tot = dict.fromkeys(self._CONTROL_KEYS, 0)
        for dep in self.engine.control.deployments.values():
            for k in self._CONTROL_KEYS:
                tot[k] += dep.stats.get(k, 0)
        return tot

    def _baseline(self) -> Dict[str, float]:
        """Snapshot cumulative engine counters so repeated runs on one
        engine report only their own invocations/storage ops."""
        eng = self.engine
        acct = eng.transfer.acct
        acct.touch(eng.sim.now)
        base = {
            "n_records": len(eng.records),
            "billed_s": eng.billed_virtual_seconds(),
            "puts": acct.n_storage_puts,
            "gets": acct.n_storage_gets,
            "gb_seconds": acct.storage_gb_seconds,
            "n_req_log": 0 if eng.request_log is None else len(eng.request_log),
            "control": self._control_stats(),
        }
        if self.binding is not None:
            base["media"] = self.binding.media_storage_ops()
        return base

    # -- closed loop ---------------------------------------------------------
    def run_closed(
        self,
        n_clients: int,
        requests_per_client: int,
        think_time_s: float = 0.0,
    ) -> LoadReport:
        sim = self.engine.sim
        t_start = sim.now
        base = self._baseline()

        def client(cid: int) -> Generator:
            for k in range(requests_per_client):
                req = self.engine.submit(
                    self.entry, self.payload_fn(cid * requests_per_client + k)
                )
                if self._collect_objects:
                    self._requests.append(req)
                yield req.done
                if think_time_s > 0:
                    yield think_time_s

        procs = [sim.spawn(client(c)).done for c in range(n_clients)]
        fin = sim.all_of(procs)
        sim.run()
        if not fin.fired:
            raise RuntimeError("closed-loop clients deadlocked")
        return self._report("closed", t_start, base, offered_rps=None)

    # -- open loop -------------------------------------------------------------
    def run_open(self, rate_rps: float, duration_s: float) -> LoadReport:
        sim = self.engine.sim
        t_start = sim.now
        base = self._baseline()
        # Poisson arrivals from the simulator's seeded rng: deterministic.
        times = poisson_arrival_times(sim.rng, rate_rps, duration_s, t_start)
        _OpenLoopDispatcher(self, times).start()
        sim.run()
        return self._report("open", t_start, base, offered_rps=rate_rps)

    def schedule_open(self, rate_rps: float, duration_s: float) -> int:
        """Schedule an open-loop arrival train WITHOUT running the simulator.

        The sharded-simulation entry point: a cell's drive callable
        schedules its offered load here and the
        :class:`~repro.core.shard.ShardRunner` owns the clock, advancing
        every cell on epoch barriers.  Returns the number of arrivals
        scheduled."""
        sim = self.engine.sim
        times = poisson_arrival_times(sim.rng, rate_rps, duration_s, sim.now)
        _OpenLoopDispatcher(self, times).start()
        return len(times)

    # -- summary ---------------------------------------------------------------
    def _latencies(self, base: Dict[str, float]):
        """(latencies, n_ok) for the requests completed since ``base``."""
        if self._collect_objects:
            reqs = self._requests
            self._requests = []
            done = [r for r in reqs if r.status in ("ok", "error", "failed")]
            lat = [r.latency_s for r in done]
            n_ok = sum(1 for r in done if r.status == "ok")
            return lat, n_ok
        log = self.engine.request_log
        n0 = int(base["n_req_log"])
        # the log appends in completion order; report in submission order
        # (request ids are issued at submit) to match the legacy object path
        order = np.argsort(np.asarray(log.request_ids[n0:]), kind="stable")
        lat = list(np.asarray(log.latencies_s[n0:])[order])
        n_ok = int(sum(log.ok_flags[n0:]))
        return lat, n_ok

    def _report(
        self,
        mode: str,
        t_start: float,
        base: Dict[str, float],
        offered_rps: Optional[float],
    ) -> LoadReport:
        eng = self.engine
        lat, n_ok = self._latencies(base)
        duration = max(eng.sim.now - t_start, 1e-12)
        achieved = len(lat) / duration
        acct = eng.transfer.acct
        acct.touch(eng.sim.now)
        inputs = WorkflowCostInputs(
            n_function_invocations=len(eng.records) - int(base["n_records"]),
            billed_duration_s=eng.billed_virtual_seconds() - base["billed_s"],
            n_storage_puts=acct.n_storage_puts - int(base["puts"]),
            n_storage_gets=acct.n_storage_gets - int(base["gets"]),
            storage_gb_seconds=acct.storage_gb_seconds - base["gb_seconds"],
            peak_resident_gb=acct.peak_resident_gb,
        )
        if self.binding is None:
            backend = eng.transfer.backend
            usd_per_1k = cost_per_1k_requests(inputs, backend, max(1, len(lat)))
        else:
            # routed run: price this window's per-medium op deltas by each
            # medium's own fee structure
            route = self.binding.default_route
            backend = route if isinstance(route, str) else route.describe()
            media = _media_delta(base.get("media", {}),
                                 self.binding.media_storage_ops())
            usd_per_1k = routed_cost_per_1k_requests(
                inputs, media, max(1, len(lat))
            )
        ctrl = self._control_stats()
        ctrl_base = base["control"]
        scalers = sorted({
            d.autoscaler.name for d in eng.control.deployments.values()
        })
        return LoadReport(
            mode=mode,
            backend=backend,
            offered_rps=achieved if offered_rps is None else offered_rps,
            achieved_rps=achieved,
            n_requests=len(lat),
            n_ok=n_ok,
            duration_s=duration,
            p50_s=float(np.percentile(lat, 50)) if lat else 0.0,
            p99_s=float(np.percentile(lat, 99)) if lat else 0.0,
            mean_s=float(np.mean(lat)) if lat else 0.0,
            latencies_s=lat,
            cost_inputs=inputs,
            usd_per_1k_requests=usd_per_1k,
            autoscaler="+".join(scalers),
            n_cold_starts=ctrl["cold_starts"] - ctrl_base["cold_starts"],
            n_prewarmed=ctrl["prewarmed"] - ctrl_base["prewarmed"],
            n_buffered=ctrl["buffered"] - ctrl_base["buffered"],
            n_queued=ctrl["queued"] - ctrl_base["queued"],
        )


# ---------------------------------------------------------------------------
# Trace-driven multi-tenant frontend
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Synthetic Azure-Functions-shaped arrival trace for ONE tenant.

    The generator composes the production-trace stylized facts the
    Azure Functions studies report: a per-tenant diurnal base rate, short
    Poisson-arriving bursts multiplying it, and heavy-tailed (lognormal)
    payload sizes.  Arrival timestamps are quantized onto a ``bucket_s``
    grid — the trace's unit of replay is a same-timestamp *bucket*, which
    the driver submits through :meth:`WorkflowEngine.submit_batch` so one
    steer pass and one simulator span serve the whole cohort (the batched
    event kernel's payoff case).
    """

    duration_s: float = 60.0
    base_rps: float = 2.0
    shape: str = "diurnal"              # "steady" | "diurnal" | "bursty"
    diurnal_period_s: float = 60.0
    diurnal_amplitude: float = 0.6      # rate swings base*(1 +/- amplitude)
    burst_every_s: float = 15.0         # mean gap between burst onsets
    burst_duration_s: float = 2.0
    burst_multiplier: float = 6.0
    payload_log_mu: float = 9.7         # ~exp(9.7) = 16 KiB median
    payload_log_sigma: float = 1.2      # heavy tail: p99 ~ 25x the median
    bucket_s: float = 0.05              # timestamp quantization grid

    SHAPES = ("steady", "diurnal", "bursty")

    def __post_init__(self):
        if self.shape not in self.SHAPES:
            raise ValueError(f"shape must be one of {self.SHAPES}")


def synthesize_trace(
    rng: np.random.Generator, cfg: TraceConfig, phase: float = 0.0,
) -> List:
    """One tenant's quantized trace: ``[(bucket_time, payload_nbytes), ...]``.

    Inhomogeneous-Poisson arrivals by thinning: a homogeneous train at the
    shape's peak rate is drawn vectorized, then each arrival survives with
    probability ``rate(t) / rate_max``.  ``phase`` de-synchronizes tenants'
    diurnal cycles (tenant populations do not peak in lock-step).
    Timestamps then collapse onto the ``bucket_s`` grid and arrivals
    sharing a bucket merge into one batch.
    """
    if cfg.shape == "steady":
        rate_max = cfg.base_rps
    elif cfg.shape == "diurnal":
        rate_max = cfg.base_rps * (1.0 + cfg.diurnal_amplitude)
    else:
        rate_max = cfg.base_rps * cfg.burst_multiplier
    times = poisson_arrival_times(rng, rate_max, cfg.duration_s)
    if cfg.shape == "diurnal":
        rate = cfg.base_rps * (
            1.0 + cfg.diurnal_amplitude
            * np.sin(2.0 * np.pi * times / cfg.diurnal_period_s + phase)
        )
        times = times[rng.random(len(times)) * rate_max < rate]
    elif cfg.shape == "bursty":
        onsets = poisson_arrival_times(
            rng, 1.0 / cfg.burst_every_s, cfg.duration_s
        )
        idx = np.searchsorted(onsets, times, side="right") - 1
        in_burst = np.zeros(len(times), dtype=bool)
        hit = idx >= 0
        in_burst[hit] = (
            times[hit] - onsets[idx[hit]] < cfg.burst_duration_s
        )
        rate = np.where(
            in_burst, cfg.base_rps * cfg.burst_multiplier, cfg.base_rps
        )
        times = times[rng.random(len(times)) * rate_max < rate]
    sizes = np.maximum(
        64,
        rng.lognormal(
            cfg.payload_log_mu, cfg.payload_log_sigma, size=len(times)
        ).astype(np.int64),
    )
    bucket_ids = np.floor_divide(times, cfg.bucket_s).astype(np.int64)
    out = []
    start = 0
    for bid, count in zip(*np.unique(bucket_ids, return_counts=True)):
        out.append((float(bid) * cfg.bucket_s, sizes[start:start + count]))
        start += count
    return out


class _BucketSubmit:
    """One scheduled trace bucket: submit_batch + span bookkeeping."""

    __slots__ = ("driver", "tenant", "entry", "sizes")

    def __init__(self, driver, tenant, entry, sizes):
        self.driver = driver
        self.tenant = tenant
        self.entry = entry
        self.sizes = sizes

    def __call__(self) -> None:
        driver = self.driver
        eng = driver.engine
        first = eng._request_counter + 1
        payload_fn = driver.payload_fn
        eng.submit_batch(
            self.entry, [payload_fn(int(s)) for s in self.sizes]
        )
        n = len(self.sizes)
        driver._spans.append((first, n, self.tenant))
        hub = driver.telemetry
        if hub is not None:
            hub.tenant(self.tenant).record_arrivals(
                eng.sim.now, n, eng._inflight_requests
            )


class TraceReplayDriver:
    """Replays quantized multi-tenant traces onto one workflow engine.

    Each tenant contributes a trace (from :func:`synthesize_trace` or any
    ``[(t, sizes)]`` list) and a tuple of entry workflows; buckets rotate
    through the entries round-robin, and every bucket lands as ONE
    :meth:`~repro.core.workflow.WorkflowEngine.submit_batch` call at its
    quantized timestamp.  The driver records which request-id span each
    bucket produced — ids are issued contiguously inside ``submit_batch``
    — so per-tenant latency/SLO attribution after the run is a vectorized
    span lookup over the columnar request log, with no per-request
    bookkeeping during the sweep.
    """

    def __init__(
        self,
        engine: WorkflowEngine,
        payload_fn: Optional[Callable[[int], Any]] = None,
        telemetry=None,
    ):
        if engine.request_log is None:
            raise ValueError(
                "TraceReplayDriver needs a records='columnar' engine"
            )
        self.engine = engine
        self.payload_fn = payload_fn or (lambda nbytes: nbytes)
        self.telemetry = telemetry
        self._spans: List = []        # (first_request_id, n, tenant)

    def schedule(self, tenant: str, entries, trace) -> int:
        """Schedule one tenant's buckets; returns the arrival count."""
        if not entries:
            raise ValueError("tenant needs at least one entry workflow")
        sim = self.engine.sim
        n = 0
        for i, (t, sizes) in enumerate(trace):
            entry = entries[i % len(entries)]
            sim.schedule_abs(t, _BucketSubmit(self, tenant, entry, sizes))
            n += len(sizes)
        return n

    # -- per-tenant attribution ---------------------------------------------
    def request_tenants(self) -> Dict[str, np.ndarray]:
        """request-id arrays per tenant, from the recorded bucket spans."""
        out: Dict[str, List[np.ndarray]] = {}
        for first, n, tenant in self._spans:
            out.setdefault(tenant, []).append(np.arange(first, first + n))
        return {
            tenant: np.concatenate(chunks) for tenant, chunks in out.items()
        }

    def per_tenant_latency(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant SLO summary (n, ok, p50/p99/mean seconds) from the
        engine's columnar request log, via one vectorized span lookup."""
        log = self.engine.request_log
        rids = np.asarray(log.request_ids)
        lats = np.asarray(log.latencies_s)
        oks = np.asarray(log.ok_flags)
        spans = sorted(self._spans)
        starts = np.array([s[0] for s in spans], dtype=np.int64)
        ends = np.array([s[0] + s[1] for s in spans], dtype=np.int64)
        tenant_names = sorted({s[2] for s in spans})
        tid_of = {t: i for i, t in enumerate(tenant_names)}
        span_tid = np.array([tid_of[s[2]] for s in spans], dtype=np.int64)
        idx = np.searchsorted(starts, rids, side="right") - 1
        idx_c = np.maximum(idx, 0)
        valid = (idx >= 0) & (rids < ends[idx_c])
        owner = np.where(valid, span_tid[idx_c], -1)
        out: Dict[str, Dict[str, float]] = {}
        for tid, tenant in enumerate(tenant_names):
            mask = owner == tid
            if not mask.any():
                continue
            lat = lats[mask]
            out[tenant] = {
                "n": int(mask.sum()),
                "ok": int(oks[mask].sum()),
                "p50_s": float(np.percentile(lat, 50)),
                "p99_s": float(np.percentile(lat, 99)),
                "mean_s": float(lat.mean()),
            }
        return out


def _media_delta(
    before: Dict[str, StorageOps], after: Dict[str, StorageOps]
) -> Dict[str, StorageOps]:
    """Per-medium storage ops performed between two snapshots.  Peak resident
    GB is a high-watermark, not a counter — the window inherits the run's."""
    out: Dict[str, StorageOps] = {}
    for medium, ops in after.items():
        b = before.get(medium, StorageOps())
        delta = StorageOps(
            n_puts=ops.n_puts - b.n_puts,
            n_gets=ops.n_gets - b.n_gets,
            gb_seconds=ops.gb_seconds - b.gb_seconds,
            peak_resident_gb=ops.peak_resident_gb,
        )
        if delta.n_puts or delta.n_gets or delta.gb_seconds:
            out[medium] = delta
    return out
