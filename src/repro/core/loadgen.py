"""Load generators for the event-driven workflow engine.

Two standard driving modes, both on the engine's virtual clock (minutes of
offered load run in milliseconds of wall time):

* **Closed loop** — ``n_clients`` clients, each submitting one workflow
  request, waiting for its completion, thinking for ``think_time_s``, and
  repeating.  Offered load adapts to service capacity (the classic
  benchmark-harness loop; concurrency is the controlled variable).
* **Open loop** — Poisson arrivals at ``rate_rps`` for ``duration_s``,
  independent of completions.  This is the regime where queueing, cold
  starts, and autoscaler lag actually show up in the tail (the paper's
  concurrent-workflow claims live here).

Both return a :class:`LoadReport` with per-request latencies, percentile
summaries, achieved throughput, and the cost-model inputs needed to price the
run ($ per 1k requests via :func:`repro.core.cost.cost_per_1k_requests`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, List, Optional

import numpy as np

from .cost import WorkflowCostInputs, cost_per_1k_requests
from .workflow import WorkflowEngine, WorkflowRequest


@dataclasses.dataclass
class LoadReport:
    """Summary of one load-generation run (all times virtual seconds)."""

    mode: str                          # "closed" | "open"
    backend: str
    offered_rps: float                 # open: arrival rate; closed: achieved
    achieved_rps: float
    n_requests: int
    n_ok: int
    duration_s: float
    p50_s: float
    p99_s: float
    mean_s: float
    latencies_s: List[float]
    cost_inputs: WorkflowCostInputs = None  # type: ignore[assignment]
    usd_per_1k_requests: float = 0.0

    def as_row(self) -> Dict[str, Any]:
        return {
            "mode": self.mode,
            "backend": self.backend,
            "offered_rps": self.offered_rps,
            "achieved_rps": self.achieved_rps,
            "n_requests": self.n_requests,
            "n_ok": self.n_ok,
            "p50_s": self.p50_s,
            "p99_s": self.p99_s,
            "mean_s": self.mean_s,
            "usd_per_1k_requests": self.usd_per_1k_requests,
        }


class LoadGenerator:
    """Drives a :class:`WorkflowEngine` with synthetic request arrivals."""

    def __init__(
        self,
        engine: WorkflowEngine,
        entry: str,
        payload_fn: Optional[Callable[[int], Any]] = None,
    ):
        self.engine = engine
        self.entry = entry
        self.payload_fn = payload_fn or (lambda i: i)
        self._requests: List[WorkflowRequest] = []

    def _baseline(self) -> Dict[str, float]:
        """Snapshot cumulative engine counters so repeated runs on one
        engine report only their own invocations/storage ops."""
        acct = self.engine.transfer.acct
        acct.touch(self.engine.sim.now)
        records = self.engine.records
        return {
            "n_records": len(records),
            "billed_s": sum(r.t_end - r.t_start for r in records),
            "puts": acct.n_storage_puts,
            "gets": acct.n_storage_gets,
            "gb_seconds": acct.storage_gb_seconds,
        }

    # -- closed loop ---------------------------------------------------------
    def run_closed(
        self,
        n_clients: int,
        requests_per_client: int,
        think_time_s: float = 0.0,
    ) -> LoadReport:
        sim = self.engine.sim
        t_start = sim.now
        base = self._baseline()

        def client(cid: int) -> Generator:
            for k in range(requests_per_client):
                req = self.engine.submit(
                    self.entry, self.payload_fn(cid * requests_per_client + k)
                )
                self._requests.append(req)
                yield req.done
                if think_time_s > 0:
                    yield sim.timeout(think_time_s)

        procs = [sim.spawn(client(c)).done for c in range(n_clients)]
        fin = sim.all_of(procs)
        sim.run()
        if not fin.fired:
            raise RuntimeError("closed-loop clients deadlocked")
        return self._report("closed", t_start, base, offered_rps=None)

    # -- open loop -------------------------------------------------------------
    def run_open(self, rate_rps: float, duration_s: float) -> LoadReport:
        sim = self.engine.sim
        t_start = sim.now
        base = self._baseline()
        # Poisson arrivals from the simulator's seeded rng: deterministic.
        t, i, arrivals = t_start, 0, []
        while True:
            t += float(sim.rng.exponential(1.0 / rate_rps))
            if t - t_start >= duration_s:
                break
            arrivals.append((t, i))
            i += 1

        def arrive(idx: int):
            def fire():
                self._requests.append(
                    self.engine.submit(self.entry, self.payload_fn(idx))
                )
            return fire

        for at, idx in arrivals:
            sim.schedule(at - sim.now, arrive(idx))
        sim.run()
        return self._report("open", t_start, base, offered_rps=rate_rps)

    # -- summary ---------------------------------------------------------------
    def _report(
        self,
        mode: str,
        t_start: float,
        base: Dict[str, float],
        offered_rps: Optional[float],
    ) -> LoadReport:
        reqs = self._requests
        self._requests = []
        done = [r for r in reqs if r.status in ("ok", "error")]
        lat = [r.latency_s for r in done]
        duration = max(self.engine.sim.now - t_start, 1e-12)
        achieved = len(done) / duration
        records = self.engine.records
        acct = self.engine.transfer.acct
        acct.touch(self.engine.sim.now)
        inputs = WorkflowCostInputs(
            n_function_invocations=len(records) - int(base["n_records"]),
            billed_duration_s=(
                sum(r.t_end - r.t_start for r in records) - base["billed_s"]
            ),
            n_storage_puts=acct.n_storage_puts - int(base["puts"]),
            n_storage_gets=acct.n_storage_gets - int(base["gets"]),
            storage_gb_seconds=acct.storage_gb_seconds - base["gb_seconds"],
            peak_resident_gb=acct.peak_resident_gb,
        )
        backend = self.engine.transfer.backend
        return LoadReport(
            mode=mode,
            backend=backend,
            offered_rps=achieved if offered_rps is None else offered_rps,
            achieved_rps=achieved,
            n_requests=len(done),
            n_ok=sum(1 for r in done if r.status == "ok"),
            duration_s=duration,
            p50_s=float(np.percentile(lat, 50)) if lat else 0.0,
            p99_s=float(np.percentile(lat, 99)) if lat else 0.0,
            mean_s=float(np.mean(lat)) if lat else 0.0,
            latencies_s=lat,
            cost_inputs=inputs,
            usd_per_1k_requests=cost_per_1k_requests(
                inputs, backend, max(1, len(done))
            ),
        )
