"""Load generators for the event-driven workflow engine.

Two standard driving modes, both on the engine's virtual clock (minutes of
offered load run in milliseconds of wall time):

* **Closed loop** — ``n_clients`` clients, each submitting one workflow
  request, waiting for its completion, thinking for ``think_time_s``, and
  repeating.  Offered load adapts to service capacity (the classic
  benchmark-harness loop; concurrency is the controlled variable).
* **Open loop** — Poisson arrivals at ``rate_rps`` for ``duration_s``,
  independent of completions.  This is the regime where queueing, cold
  starts, and autoscaler lag actually show up in the tail (the paper's
  concurrent-workflow claims live here).

Both return a :class:`LoadReport` with per-request latencies, percentile
summaries, achieved throughput, and the cost-model inputs needed to price the
run ($ per 1k requests via :func:`repro.core.cost.cost_per_1k_requests`).
Driving a compiled :class:`~repro.core.dag.DagBinding` instead of a function
name prices the run per transfer medium (mixed-backend routing).

Scale: open-loop arrival trains are drawn **vectorized** from the simulator's
seeded rng (one numpy call per block instead of one Python-level exponential
per request) and driven by a single self-rescheduling dispatcher — no
per-arrival closures or up-front heap flooding.  Against an engine in
``records="columnar"`` mode, reports are computed from the engine's columnar
request log and no per-request objects are retained, so million-request
sweeps are memory-bounded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, List, Optional, Union

import numpy as np

from .cost import (
    StorageOps,
    WorkflowCostInputs,
    cost_per_1k_requests,
    routed_cost_per_1k_requests,
)
from .workflow import WorkflowEngine, WorkflowRequest


@dataclasses.dataclass
class LoadReport:
    """Summary of one load-generation run (all times virtual seconds)."""

    mode: str                          # "closed" | "open"
    backend: str
    offered_rps: float                 # open: arrival rate; closed: achieved
    achieved_rps: float
    n_requests: int
    n_ok: int
    duration_s: float
    p50_s: float
    p99_s: float
    mean_s: float
    latencies_s: List[float]
    cost_inputs: WorkflowCostInputs = None  # type: ignore[assignment]
    usd_per_1k_requests: float = 0.0
    #: which AutoscalerPolicy the engine's deployments ran under (names of
    #: the distinct policies, "+"-joined)
    autoscaler: str = ""
    #: control-plane activity during THIS run (deltas across deployments):
    #: cold instance boots, proactive pre-warm spawns, requests buffered
    #: across a cold start, requests queued at the max_instances cap
    n_cold_starts: int = 0
    n_prewarmed: int = 0
    n_buffered: int = 0
    n_queued: int = 0

    def as_row(self) -> Dict[str, Any]:
        return {
            "mode": self.mode,
            "backend": self.backend,
            "offered_rps": self.offered_rps,
            "achieved_rps": self.achieved_rps,
            "n_requests": self.n_requests,
            "n_ok": self.n_ok,
            "p50_s": self.p50_s,
            "p99_s": self.p99_s,
            "mean_s": self.mean_s,
            "usd_per_1k_requests": self.usd_per_1k_requests,
            "autoscaler": self.autoscaler,
            "n_cold_starts": self.n_cold_starts,
            "n_prewarmed": self.n_prewarmed,
            "n_buffered": self.n_buffered,
            "n_queued": self.n_queued,
        }


def poisson_arrival_times(
    rng: np.random.Generator, rate_rps: float, duration_s: float,
    t_start: float = 0.0, block: int = 4096,
) -> np.ndarray:
    """Absolute Poisson arrival timestamps in ``[t_start, t_start + duration)``.

    Vectorized: inter-arrival gaps are drawn in blocks and cumulative-summed
    (numpy's cumsum accumulates sequentially, so the resulting times match
    the legacy one-exponential-per-arrival loop bit-for-bit when ``t_start``
    is 0 — the fixed-seed reproducibility anchor of the benchmarks).
    """
    scale = 1.0 / rate_rps
    chunks: List[np.ndarray] = []
    carry: Optional[float] = None
    while True:
        gaps = rng.exponential(scale, size=block)
        if carry is None:
            offsets = np.cumsum(gaps)
        else:
            # continue the sequential accumulation across the block boundary
            # (seeding cumsum with the previous running sum keeps every
            # partial sum identical to a single uninterrupted loop)
            buf = np.empty(block + 1)
            buf[0] = carry
            buf[1:] = gaps
            offsets = np.cumsum(buf)[1:]
        cut = int(np.searchsorted(offsets, duration_s, side="left"))
        if cut < block:
            chunks.append(offsets[:cut])
            break
        chunks.append(offsets)
        carry = float(offsets[-1])
    times = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
    if t_start != 0.0:
        times = times + t_start
    return times


class _OpenLoopDispatcher:
    """One callable that walks the precomputed arrival train, submitting a
    request per arrival and rescheduling itself at the next absolute time —
    O(1) live heap entries and zero per-arrival closures."""

    __slots__ = ("gen", "times", "idx")

    def __init__(self, gen: "LoadGenerator", times: np.ndarray):
        self.gen = gen
        self.times = times.tolist()   # plain floats: no per-arrival unboxing
        self.idx = 0

    def start(self) -> None:
        if self.times:
            self.gen.engine.sim.schedule_abs(self.times[0], self)

    def __call__(self) -> None:
        gen = self.gen
        idx = self.idx
        req = gen.engine.submit(gen.entry, gen.payload_fn(idx))
        if gen._collect_objects:
            gen._requests.append(req)
        self.idx = idx = idx + 1
        if idx < len(self.times):
            gen.engine.sim.schedule_abs(self.times[idx], self)


class LoadGenerator:
    """Drives a :class:`WorkflowEngine` with synthetic request arrivals.

    ``entry`` is a registered function name, or a compiled
    :class:`~repro.core.dag.DagBinding` — then requests enter at the DAG's
    entry stage and the run is priced per transfer medium
    (:func:`~repro.core.cost.routed_cost_per_1k_requests`), so sweeps over
    per-edge-routed (hybrid) workflows report the mixed-backend bill.
    """

    def __init__(
        self,
        engine: WorkflowEngine,
        entry: Union[str, Any],
        payload_fn: Optional[Callable[[int], Any]] = None,
    ):
        self.engine = engine
        # a DagBinding (anything exposing .entry + .media_storage_ops) routes
        # per edge; its per-medium ops price the run
        self.binding = None if isinstance(entry, str) else entry
        self.entry: str = entry if isinstance(entry, str) else entry.entry
        self.payload_fn = payload_fn or (lambda i: i)
        self._requests: List[WorkflowRequest] = []
        # columnar engines report from the engine's request log; object-mode
        # engines from the retained WorkflowRequest list (legacy behaviour)
        self._collect_objects = engine.request_log is None

    #: Deployment.stats keys surfaced (as run-deltas) on the LoadReport
    _CONTROL_KEYS = ("cold_starts", "prewarmed", "buffered", "queued")

    def _control_stats(self) -> Dict[str, int]:
        """Control-plane counters summed across the engine's deployments."""
        tot = dict.fromkeys(self._CONTROL_KEYS, 0)
        for dep in self.engine.control.deployments.values():
            for k in self._CONTROL_KEYS:
                tot[k] += dep.stats.get(k, 0)
        return tot

    def _baseline(self) -> Dict[str, float]:
        """Snapshot cumulative engine counters so repeated runs on one
        engine report only their own invocations/storage ops."""
        eng = self.engine
        acct = eng.transfer.acct
        acct.touch(eng.sim.now)
        base = {
            "n_records": len(eng.records),
            "billed_s": eng.billed_virtual_seconds(),
            "puts": acct.n_storage_puts,
            "gets": acct.n_storage_gets,
            "gb_seconds": acct.storage_gb_seconds,
            "n_req_log": 0 if eng.request_log is None else len(eng.request_log),
            "control": self._control_stats(),
        }
        if self.binding is not None:
            base["media"] = self.binding.media_storage_ops()
        return base

    # -- closed loop ---------------------------------------------------------
    def run_closed(
        self,
        n_clients: int,
        requests_per_client: int,
        think_time_s: float = 0.0,
    ) -> LoadReport:
        sim = self.engine.sim
        t_start = sim.now
        base = self._baseline()

        def client(cid: int) -> Generator:
            for k in range(requests_per_client):
                req = self.engine.submit(
                    self.entry, self.payload_fn(cid * requests_per_client + k)
                )
                if self._collect_objects:
                    self._requests.append(req)
                yield req.done
                if think_time_s > 0:
                    yield think_time_s

        procs = [sim.spawn(client(c)).done for c in range(n_clients)]
        fin = sim.all_of(procs)
        sim.run()
        if not fin.fired:
            raise RuntimeError("closed-loop clients deadlocked")
        return self._report("closed", t_start, base, offered_rps=None)

    # -- open loop -------------------------------------------------------------
    def run_open(self, rate_rps: float, duration_s: float) -> LoadReport:
        sim = self.engine.sim
        t_start = sim.now
        base = self._baseline()
        # Poisson arrivals from the simulator's seeded rng: deterministic.
        times = poisson_arrival_times(sim.rng, rate_rps, duration_s, t_start)
        _OpenLoopDispatcher(self, times).start()
        sim.run()
        return self._report("open", t_start, base, offered_rps=rate_rps)

    # -- summary ---------------------------------------------------------------
    def _latencies(self, base: Dict[str, float]):
        """(latencies, n_ok) for the requests completed since ``base``."""
        if self._collect_objects:
            reqs = self._requests
            self._requests = []
            done = [r for r in reqs if r.status in ("ok", "error")]
            lat = [r.latency_s for r in done]
            n_ok = sum(1 for r in done if r.status == "ok")
            return lat, n_ok
        log = self.engine.request_log
        n0 = int(base["n_req_log"])
        # the log appends in completion order; report in submission order
        # (request ids are issued at submit) to match the legacy object path
        order = np.argsort(np.asarray(log.request_ids[n0:]), kind="stable")
        lat = list(np.asarray(log.latencies_s[n0:])[order])
        n_ok = int(sum(log.ok_flags[n0:]))
        return lat, n_ok

    def _report(
        self,
        mode: str,
        t_start: float,
        base: Dict[str, float],
        offered_rps: Optional[float],
    ) -> LoadReport:
        eng = self.engine
        lat, n_ok = self._latencies(base)
        duration = max(eng.sim.now - t_start, 1e-12)
        achieved = len(lat) / duration
        acct = eng.transfer.acct
        acct.touch(eng.sim.now)
        inputs = WorkflowCostInputs(
            n_function_invocations=len(eng.records) - int(base["n_records"]),
            billed_duration_s=eng.billed_virtual_seconds() - base["billed_s"],
            n_storage_puts=acct.n_storage_puts - int(base["puts"]),
            n_storage_gets=acct.n_storage_gets - int(base["gets"]),
            storage_gb_seconds=acct.storage_gb_seconds - base["gb_seconds"],
            peak_resident_gb=acct.peak_resident_gb,
        )
        if self.binding is None:
            backend = eng.transfer.backend
            usd_per_1k = cost_per_1k_requests(inputs, backend, max(1, len(lat)))
        else:
            # routed run: price this window's per-medium op deltas by each
            # medium's own fee structure
            route = self.binding.default_route
            backend = route if isinstance(route, str) else route.describe()
            media = _media_delta(base.get("media", {}),
                                 self.binding.media_storage_ops())
            usd_per_1k = routed_cost_per_1k_requests(
                inputs, media, max(1, len(lat))
            )
        ctrl = self._control_stats()
        ctrl_base = base["control"]
        scalers = sorted({
            d.autoscaler.name for d in eng.control.deployments.values()
        })
        return LoadReport(
            mode=mode,
            backend=backend,
            offered_rps=achieved if offered_rps is None else offered_rps,
            achieved_rps=achieved,
            n_requests=len(lat),
            n_ok=n_ok,
            duration_s=duration,
            p50_s=float(np.percentile(lat, 50)) if lat else 0.0,
            p99_s=float(np.percentile(lat, 99)) if lat else 0.0,
            mean_s=float(np.mean(lat)) if lat else 0.0,
            latencies_s=lat,
            cost_inputs=inputs,
            usd_per_1k_requests=usd_per_1k,
            autoscaler="+".join(scalers),
            n_cold_starts=ctrl["cold_starts"] - ctrl_base["cold_starts"],
            n_prewarmed=ctrl["prewarmed"] - ctrl_base["prewarmed"],
            n_buffered=ctrl["buffered"] - ctrl_base["buffered"],
            n_queued=ctrl["queued"] - ctrl_base["queued"],
        )


def _media_delta(
    before: Dict[str, StorageOps], after: Dict[str, StorageOps]
) -> Dict[str, StorageOps]:
    """Per-medium storage ops performed between two snapshots.  Peak resident
    GB is a high-watermark, not a counter — the window inherits the run's."""
    out: Dict[str, StorageOps] = {}
    for medium, ops in after.items():
        b = before.get(medium, StorageOps())
        delta = StorageOps(
            n_puts=ops.n_puts - b.n_puts,
            n_gets=ops.n_gets - b.n_gets,
            gb_seconds=ops.gb_seconds - b.gb_seconds,
            peak_resident_gb=ops.peak_resident_gb,
        )
        if delta.n_puts or delta.n_gets or delta.gb_seconds:
            out[medium] = delta
    return out
