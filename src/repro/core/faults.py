"""Chaos harness: declarative fault injection with SLO guardrails.

Zipline's core bet — buffering payloads in the *sender's* memory instead of
durable storage — makes producer death, medium degradation, and eviction
storms the central correctness risks (paper §5 handles them with staged
fallbacks).  This module makes adversity a first-class scenario axis:

* :class:`FaultPlan` — a declarative list of :class:`FaultEvent`\\ s scheduled
  on the substrate's injected virtual clock.  Three kinds:

  - ``"evict"`` — a correlated spot-instance eviction: a whole *node* dies at
    once (every co-resident instance across every deployment, plus the XDT
    buffers they held), not one producer.
  - ``"degrade"`` — a per-medium degradation window: an S3 throttle (error
    rate + bandwidth cut), an ElastiCache failover blackout
    (``error_rate=1.0``), degraded xdt bandwidth.  Implemented as a
    :class:`DegradedBackend` decorator swapped over the registered strategy,
    so every medium composes unchanged.
  - ``"storm"`` — a cold-start storm: a temporary ``cold_start_s`` multiplier
    plus an instance-cap squeeze on every deployment.

* :class:`FaultInjector` — arms a plan on a
  :class:`~repro.core.workflow.WorkflowEngine` (the ``dag.bind`` /
  loadgen lowering).  An **empty plan installs nothing**: the engine's fused
  fast paths and bit-identical results are untouched (the fig12 golden gate).

* :class:`_ClusterFaults` — the same plan interpreted by the discrete-event
  cluster lowering, via ``dag.compile(target="cluster", faults=plan)``.

* :class:`SLOGuard` — per-run guardrails: bounded-retry completion (failures
  surface as recorded terminal statuses, never crashes), an availability /
  p99 budget, and the dominance check that adaptive policies beat static
  ones under the *same seeded* fault plan.

Determinism: every stochastic choice (which node an eviction takes, each
error-rate draw) comes from ``random.Random(plan.seed)`` consumed in virtual
event order, so a (plan, workload, seed) triple replays bit-identically.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .errors import Evicted, MediumUnavailable, XDTError, XDTProducerGone
from .transfer import TransferBackend, available_backends

__all__ = [
    "FaultEvent",
    "FaultPlan",
    "DegradedBackend",
    "FaultInjector",
    "SLOGuard",
    "SLOReport",
    "SLOViolation",
]


# ---------------------------------------------------------------------------
# The declarative plan
# ---------------------------------------------------------------------------


_KINDS = ("evict", "degrade", "storm")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled adversity on the virtual clock.

    ``kind`` selects which fields matter:

    * ``"evict"`` — at ``at_s``, kill every instance on one node.  ``node``
      pins the victim (an int node index on the cluster lowering, placement
      coords on the engine lowering); ``None`` picks one from the live set
      with the plan's seeded RNG.  Instantaneous — ``duration_s`` unused.
    * ``"degrade"`` — ``[at_s, at_s + duration_s)`` window on ``medium``:
      each get fails with probability ``error_rate`` (a seeded draw raising
      :class:`~repro.core.errors.MediumUnavailable`) and modeled transfer
      seconds are multiplied by ``slowdown`` (the bandwidth cut).
    * ``"storm"`` — ``[at_s, at_s + duration_s)`` cold-start storm: every
      deployment's ``cold_start_s`` is multiplied by
      ``cold_start_multiplier`` and ``max_instances`` clamped to
      ``max_instances_cap`` (when set), then restored.
    """

    kind: str
    at_s: float
    duration_s: float = 0.0
    medium: Optional[str] = None
    node: Any = None
    slowdown: float = 1.0
    error_rate: float = 0.0
    cold_start_multiplier: float = 1.0
    max_instances_cap: Optional[int] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {self.kind!r}")
        if self.at_s < 0.0 or self.duration_s < 0.0:
            raise ValueError("at_s and duration_s must be >= 0")
        if self.kind == "degrade":
            if self.medium is None:
                raise ValueError("degrade events need a medium")
            if self.medium not in available_backends():
                raise ValueError(
                    f"medium must be one of {available_backends()}, "
                    f"got {self.medium!r}"
                )
            if not 0.0 <= self.error_rate <= 1.0:
                raise ValueError("error_rate must be in [0, 1]")
            if self.slowdown < 1.0:
                raise ValueError("slowdown is a multiplier >= 1.0")
            if self.duration_s <= 0.0:
                raise ValueError("degrade windows need duration_s > 0")
        if self.kind == "storm":
            if self.cold_start_multiplier < 1.0:
                raise ValueError("cold_start_multiplier must be >= 1.0")
            if self.duration_s <= 0.0:
                raise ValueError("storm windows need duration_s > 0")

    @property
    def end_s(self) -> float:
        return self.at_s + self.duration_s


class FaultPlan:
    """An ordered, seeded set of :class:`FaultEvent`\\ s.

    Falsy when empty — injectors treat an empty plan as "install nothing",
    which is what keeps no-fault runs bit-identical to a harness-free build.
    """

    def __init__(self, events: Sequence[FaultEvent] = (), seed: int = 0):
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: e.at_s)
        )
        self.seed = seed

    def __bool__(self) -> bool:
        return bool(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def __repr__(self) -> str:
        kinds = ", ".join(e.kind for e in self.events)
        return f"FaultPlan([{kinds}], seed={self.seed})"

    def rng(self) -> random.Random:
        """A fresh seeded RNG — one per run, so replays are bit-identical."""
        return random.Random(self.seed)

    # -- queries ----------------------------------------------------------
    def evictions(self) -> List[FaultEvent]:
        return [e for e in self.events if e.kind == "evict"]

    def has_evictions(self) -> bool:
        return any(e.kind == "evict" for e in self.events)

    def slowdown_at(self, medium: str, t: float) -> float:
        """The worst bandwidth-cut multiplier active on ``medium`` at ``t``."""
        worst = 1.0
        for e in self.events:
            if (
                e.kind == "degrade" and e.medium == medium
                and e.at_s <= t < e.end_s and e.slowdown > worst
            ):
                worst = e.slowdown
        return worst

    def error_rate_at(self, medium: str, t: float) -> float:
        """The worst refusal probability active on ``medium`` at ``t``."""
        worst = 0.0
        for e in self.events:
            if (
                e.kind == "degrade" and e.medium == medium
                and e.at_s <= t < e.end_s and e.error_rate > worst
            ):
                worst = e.error_rate
        return worst

    # -- scenario builders (the fig12 axis) -------------------------------
    @classmethod
    def eviction_storm(
        cls, at_s: float = 0.5, n_evictions: int = 1,
        spacing_s: float = 0.25, seed: int = 0,
    ) -> "FaultPlan":
        """Correlated spot reclamations: ``n_evictions`` whole-node kills,
        ``spacing_s`` apart, victims drawn with the plan RNG."""
        return cls(
            [
                FaultEvent("evict", at_s=at_s + i * spacing_s)
                for i in range(n_evictions)
            ],
            seed=seed,
        )

    @classmethod
    def medium_throttle(
        cls, medium: str = "s3", at_s: float = 0.2, duration_s: float = 30.0,
        slowdown: float = 4.0, error_rate: float = 0.3, seed: int = 0,
    ) -> "FaultPlan":
        """An S3-style throttle window: partial refusals + a bandwidth cut."""
        return cls(
            [FaultEvent(
                "degrade", at_s=at_s, duration_s=duration_s, medium=medium,
                slowdown=slowdown, error_rate=error_rate,
            )],
            seed=seed,
        )

    @classmethod
    def medium_blackout(
        cls, medium: str = "elasticache", at_s: float = 0.2,
        duration_s: float = 30.0, seed: int = 0,
    ) -> "FaultPlan":
        """A failover blackout: every get on ``medium`` refused in-window."""
        return cls(
            [FaultEvent(
                "degrade", at_s=at_s, duration_s=duration_s, medium=medium,
                error_rate=1.0,
            )],
            seed=seed,
        )

    @classmethod
    def cold_start_storm(
        cls, at_s: float = 0.2, duration_s: float = 30.0,
        multiplier: float = 8.0, max_instances_cap: Optional[int] = None,
        seed: int = 0,
    ) -> "FaultPlan":
        """Boot latency spikes + an instance-cap squeeze on every fleet."""
        return cls(
            [FaultEvent(
                "storm", at_s=at_s, duration_s=duration_s,
                cold_start_multiplier=multiplier,
                max_instances_cap=max_instances_cap,
            )],
            seed=seed,
        )


# ---------------------------------------------------------------------------
# The TransferBackend decorator (degradation windows)
# ---------------------------------------------------------------------------


class DegradedBackend(TransferBackend):
    """Decorator over any registered medium strategy for one degradation
    window: gets fail with probability ``error_rate`` (a seeded draw raising
    :class:`~repro.core.errors.MediumUnavailable`); everything else —
    puts, producer-death propagation, the latency model — delegates to the
    wrapped strategy, so new media registered via
    :func:`~repro.core.transfer.register_backend` compose unchanged.

    The bandwidth-cut half of a window lives in
    :meth:`TransferEngine.degrade_medium` (the modeled-seconds multiplier),
    not here: injection failures are per-*operation*, slowdowns are
    per-*model*, and splitting them keeps the engine's modeled cache clean.
    """

    def __init__(
        self,
        inner: TransferBackend,
        error_rate: float = 0.0,
        rng: Optional[random.Random] = None,
    ):
        self.inner = inner
        self.engine = inner.engine
        self.name = inner.name              # shadow the ClassVars: the
        self.durable = inner.durable        # wrapper *is* the medium
        self.error_rate = error_rate
        self._rng = rng if rng is not None else random.Random(0)

    def put(self, obj, n_retrievals, nbytes, block, timeout):
        return self.inner.put(obj, n_retrievals, nbytes, block, timeout)

    def get(self, payload):
        if self.error_rate > 0.0 and self._rng.random() < self.error_rate:
            raise MediumUnavailable(
                f"{self.name}: injected refusal (degradation window, "
                f"error_rate={self.error_rate})"
            )
        return self.inner.get(payload)

    def on_producer_death(self) -> None:
        self.inner.on_producer_death()

    def modeled_seconds(self, nbytes, net):  # instance method shadows the
        return self.inner.modeled_seconds(nbytes, net)  # inner's classmethod


# ---------------------------------------------------------------------------
# Engine-lowering injector (dag.bind / loadgen / raw WorkflowEngine)
# ---------------------------------------------------------------------------


class FaultInjector:
    """Arms a :class:`FaultPlan` on a :class:`~repro.core.workflow.WorkflowEngine`.

    ``install()`` with an **empty plan is a no-op** — no fast path is
    suspended, no hook set, no event scheduled; the engine's results stay
    bit-identical to a run without the harness.  A non-empty plan:

    * suspends the transfer engine's fused fast paths (every get then flows
      through the strategy dispatch, where the degradation decorator and the
      penalty hook live) and sets ``_fault_penalty``;
    * schedules each event's open/close callbacks on the virtual clock via
      ``sim.schedule_abs``;
    * records every injection on the telemetry hub's fault timeline
      (``hub.record_fault``) when the engine has one.

    The penalty hook does double duty: it reclassifies a post-eviction
    :class:`~repro.core.errors.XDTProducerGone` as
    :class:`~repro.core.errors.Evicted` (same retry machinery, attributable
    cause), and it feeds a pessimistic latency sample for the failing medium
    into the telemetry hub so a budget-constrained
    :class:`~repro.core.dag.AdaptiveRoute` leaves the medium within its
    observation window — the route-*around*, not merely survive, behavior
    fig12 gates on.
    """

    #: penalty sample fed per injected failure: the medium's base modeled
    #: seconds times this, plus a floor — far past any sane latency budget
    PENALTY_FACTOR = 8.0
    PENALTY_FLOOR_S = 0.05

    def __init__(self, engine: Any, plan: FaultPlan):
        self.engine = engine
        self.plan = plan
        self.installed = False
        self._rng = plan.rng()
        self._saved_fast: Optional[Tuple[bool, bool]] = None
        self._wrapped: Dict[str, TransferBackend] = {}
        self._saved_policies: Dict[str, Tuple[float, int]] = {}
        self._evicted = False
        self.n_evicted_instances = 0
        self.n_evicted_buffers = 0

    # -- lifecycle --------------------------------------------------------
    def install(self) -> "FaultInjector":
        if not self.plan or self.installed:
            return self
        eng = self.engine
        sim = eng.sim
        self._saved_fast = eng.transfer.suspend_fast_paths()
        eng.transfer._fault_penalty = self._penalty
        for ev in self.plan:
            if ev.kind == "evict":
                sim.schedule_abs(ev.at_s, lambda e=ev: self._evict(e))
            elif ev.kind == "degrade":
                sim.schedule_abs(ev.at_s, lambda e=ev: self._open_window(e))
                sim.schedule_abs(ev.end_s, lambda e=ev: self._close_window(e))
            else:  # storm
                sim.schedule_abs(ev.at_s, lambda e=ev: self._open_storm(e))
                sim.schedule_abs(ev.end_s, lambda e=ev: self._close_storm(e))
        self.installed = True
        return self

    def uninstall(self) -> None:
        """Restore the engine exactly (fast paths, strategies, policies)."""
        if not self.installed:
            return
        eng = self.engine
        for medium, inner in list(self._wrapped.items()):
            eng.transfer.unwrap_medium(medium, inner)
        self._wrapped.clear()
        eng.transfer.clear_degraded()
        if self._saved_policies:
            self._restore_policies()
        eng.transfer._fault_penalty = None
        if self._saved_fast is not None:
            eng.transfer.resume_fast_paths(self._saved_fast)
        self.installed = False

    # -- event callbacks --------------------------------------------------
    def _record(self, kind: str, detail: str) -> None:
        hub = self.engine.transfer.telemetry
        if hub is not None:
            hub.record_fault(kind, detail)

    def _evict(self, ev: FaultEvent) -> None:
        eng = self.engine
        coords = ev.node
        if coords is None:
            live = eng.control.node_coords()
            coords = self._rng.choice(live) if live else None
        killed = eng.control.kill_node(coords) if coords is not None else 0
        # the node's XDT buffers die with it: in the single-shared-registry
        # model every instance-resident object is producer-side state
        buffers = eng.transfer.kill_producer()
        self._evicted = True
        self.n_evicted_instances += killed
        self.n_evicted_buffers += buffers
        self._record(
            "evict", f"node={coords} instances={killed} buffers={buffers}"
        )

    def _open_window(self, ev: FaultEvent) -> None:
        t = self.engine.transfer
        if ev.medium not in self._wrapped:  # overlapping windows: first wins
            self._wrapped[ev.medium] = t.wrap_medium(
                ev.medium,
                lambda inner: DegradedBackend(
                    inner, error_rate=ev.error_rate, rng=self._rng
                ),
            )
        if ev.slowdown > 1.0:
            t.degrade_medium(ev.medium, ev.slowdown)
        self._record(
            "degrade_open",
            f"{ev.medium} error_rate={ev.error_rate} slowdown={ev.slowdown}",
        )

    def _close_window(self, ev: FaultEvent) -> None:
        t = self.engine.transfer
        inner = self._wrapped.pop(ev.medium, None)
        if inner is not None:
            t.unwrap_medium(ev.medium, inner)
        t.clear_degraded(ev.medium)
        self._record("degrade_close", ev.medium)

    def _open_storm(self, ev: FaultEvent) -> None:
        for name, dep in self.engine.control.deployments.items():
            pol = dep.policy
            if name not in self._saved_policies:  # overlap: first wins
                self._saved_policies[name] = (
                    pol.cold_start_s, pol.max_instances
                )
            pol.cold_start_s *= ev.cold_start_multiplier
            if ev.max_instances_cap is not None:
                pol.max_instances = min(
                    pol.max_instances, ev.max_instances_cap
                )
        self._record(
            "storm_open",
            f"x{ev.cold_start_multiplier} cap={ev.max_instances_cap}",
        )

    def _close_storm(self, ev: FaultEvent) -> None:
        self._restore_policies()
        self._record("storm_close", "")

    def _restore_policies(self) -> None:
        for name, (cold, cap) in self._saved_policies.items():
            dep = self.engine.control.deployments.get(name)
            if dep is not None:
                dep.policy.cold_start_s = cold
                dep.policy.max_instances = cap
        self._saved_policies.clear()

    # -- the transfer-engine penalty hook ---------------------------------
    def _penalty(
        self, medium: str, nbytes: int, exc: XDTError
    ) -> Optional[XDTError]:
        hub = self.engine.transfer.telemetry
        if hub is not None:
            t = self.engine.transfer
            base = t._strategy(medium).modeled_seconds(nbytes, t.net)
            hub.record_transfer(
                medium, nbytes,
                base * self.PENALTY_FACTOR + self.PENALTY_FLOOR_S, 0.0,
            )
        if self._evicted and type(exc) is XDTProducerGone:
            return Evicted(str(exc))
        return None


# ---------------------------------------------------------------------------
# Cluster-lowering adapter (compile target="cluster")
# ---------------------------------------------------------------------------


class _ClusterFaults:
    """The same :class:`FaultPlan` interpreted by the discrete-event cluster
    lowering (``dag.compile(target="cluster")``).

    There is no live scheduler there — stages run on pre-assigned node
    indices — so the adapter models the *consequences* directly on staged
    fetches:

    * an evicted node's instance-resident objects are gone: the consumer's
      fetch pays a billed producer re-run (at-least-once, paper §4.2.2) that
      re-stages onto a durable medium, and the retry is counted;
    * inside a degradation window, each get on the medium draws against the
      error rate; after ``max_attempts`` refused draws the fetch re-routes
      to a durable medium (one extra put + the retries counted);
    * bandwidth cuts stretch the pull by the slowdown multiplier and are
      fed into the run-local telemetry hubs so AdaptiveRoute sees them.
    """

    #: refused draws tolerated per fetch before re-routing durable —
    #: mirrors the engine's default ``max_retries``
    max_attempts = 2

    def __init__(self, plan: FaultPlan, sim: Any, all_nodes: Sequence[int]):
        self.plan = plan
        self.sim = sim
        self.retries = 0
        self.rerouted = 0
        self.errors_injected = 0
        self.evicted_nodes: set = set()
        self._rng = plan.rng()
        pickable = list(all_nodes)
        for ev in plan.evictions():
            node = ev.node
            if node is None:
                node = self._rng.choice(pickable) if pickable else None
            if node is not None:
                sim.schedule_abs(
                    ev.at_s, lambda n=node: self.evicted_nodes.add(n)
                )

    def node_dead(self, node: int) -> bool:
        return node in self.evicted_nodes

    def slowdown_at(self, medium: str) -> float:
        return self.plan.slowdown_at(medium, self.sim.now)

    def extra_seconds(self, medium: str, base_s: float) -> float:
        """Added pull latency from any active bandwidth cut."""
        s = self.plan.slowdown_at(medium, self.sim.now)
        return base_s * (s - 1.0) if s > 1.0 else 0.0

    def error_draw(self, medium: str) -> bool:
        rate = self.plan.error_rate_at(medium, self.sim.now)
        return rate > 0.0 and self._rng.random() < rate

    def durable_for(self, medium: str) -> str:
        """The durable escape hatch when ``medium`` is failing."""
        return "elasticache" if medium == "s3" else "s3"

    def summary(self) -> Dict[str, Any]:
        return {
            "retries": self.retries,
            "rerouted": self.rerouted,
            "errors_injected": self.errors_injected,
            "evicted_nodes": sorted(self.evicted_nodes),
        }


# ---------------------------------------------------------------------------
# SLO guardrails
# ---------------------------------------------------------------------------


class SLOViolation(RuntimeError):
    """An SLO guardrail failed (raise, not assert: survives ``python -O``)."""


@dataclasses.dataclass
class SLOReport:
    """One run's guardrail verdict."""

    label: str
    n_requests: int
    n_ok: int
    n_failed: int
    n_error: int
    availability: float
    p99_s: float
    retry_total: int
    retry_max: int
    violations: List[str]

    @property
    def ok(self) -> bool:
        return not self.violations


def _p99(latencies: Sequence[float]) -> float:
    if not latencies:
        return 0.0
    xs = sorted(latencies)
    return xs[min(len(xs) - 1, int(math.ceil(0.99 * len(xs))) - 1)]


class SLOGuard:
    """Per-run SLO guardrails over a :class:`~repro.core.workflow.WorkflowEngine`.

    Asserts (via :meth:`assert_ok`) that:

    * **bounded-retry completion** — no request retried past the engine's
      ``max_retries``, and every submitted request reached a *recorded*
      terminal status (``ok`` / ``error`` / ``failed``) instead of crashing
      the sweep;
    * **availability** — the ok fraction meets ``availability_min``;
    * **p99 latency** — end-to-end p99 stays under ``p99_budget_s``.

    :meth:`require_dominates` is the headline adaptive-beats-static check:
    under the same seeded fault plan, the adaptive cell must be no worse
    than the static cell on every compared metric.
    """

    def __init__(
        self,
        availability_min: float = 1.0,
        p99_budget_s: float = math.inf,
    ):
        if not 0.0 <= availability_min <= 1.0:
            raise ValueError("availability_min must be in [0, 1]")
        self.availability_min = availability_min
        self.p99_budget_s = p99_budget_s

    def check(self, engine: Any, label: str = "run") -> SLOReport:
        if getattr(engine, "_columnar", False):
            log = engine.request_log
            n = len(log)
            n_ok = sum(log.ok_flags)
            latencies = list(log.latencies_s)
        else:
            done = [
                r for r in engine.requests
                if r.status in ("ok", "error", "failed")
            ]
            n = len(done)
            n_ok = sum(1 for r in done if r.status == "ok")
            latencies = [r.latency_s for r in done]
        n_failed = engine.failed_requests
        n_error = n - n_ok - n_failed
        availability = n_ok / n if n else 1.0
        p99 = _p99(latencies)
        violations: List[str] = []
        if engine.retry_max > engine.max_retries:
            violations.append(
                f"{label}: a request retried {engine.retry_max}x, past "
                f"max_retries={engine.max_retries} (unbounded retry)"
            )
        if engine._inflight_requests:
            violations.append(
                f"{label}: {engine._inflight_requests} request(s) never "
                "reached a terminal status"
            )
        if availability < self.availability_min:
            violations.append(
                f"{label}: availability {availability:.4f} < "
                f"budget {self.availability_min:.4f}"
            )
        if p99 > self.p99_budget_s:
            violations.append(
                f"{label}: p99 {p99:.4f}s > budget {self.p99_budget_s:.4f}s"
            )
        return SLOReport(
            label=label, n_requests=n, n_ok=n_ok, n_failed=n_failed,
            n_error=n_error, availability=availability, p99_s=p99,
            retry_total=engine.retry_total, retry_max=engine.retry_max,
            violations=violations,
        )

    def assert_ok(self, engine: Any, label: str = "run") -> SLOReport:
        report = self.check(engine, label)
        if report.violations:
            raise SLOViolation("; ".join(report.violations))
        return report

    @staticmethod
    def require_dominates(
        adaptive: Dict[str, float],
        static: Dict[str, float],
        keys: Sequence[str] = ("cost_usd", "p99_s"),
        tol: float = 1 + 1e-9,
        label: str = "",
    ) -> None:
        """The headline gate: adaptive must be <= static on every key
        (equality legal — under some faults the best route IS the static
        one; the tolerance only absorbs float noise)."""
        for k in keys:
            a, s = adaptive[k], static[k]
            if a > s * tol:
                raise SLOViolation(
                    f"{label + ': ' if label else ''}adaptive {k}={a:.6g} > "
                    f"static {k}={s:.6g} — adaptive policies must never lose "
                    "under the same seeded fault plan"
                )
