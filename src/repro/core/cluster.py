"""Discrete-event cluster simulator for serverless transfer benchmarks.

The paper evaluates XDT on a real AWS EC2 / Knative cluster; this container is
CPU-only, so the *quantitative* reproduction (Figs 2/5/6, Fig 7, Table 2) runs
on a discrete-event simulator whose constants are calibrated to the paper's
own measured anchors:

* m5.16xlarge NIC: 20 Gb/s (2.5 GB/s).
* Fig 2: inline beats S3 by 8.1x and ElastiCache by 1.3x at 100 KB.
* Fig 5: EC median (tail) 89% (92%) below S3 at 10 KB; 87% (90%) at 10 MB;
  XDT 12%/10% below EC at 10 KB and 45%/34% at 10 MB.
* Fig 6 (fan 32, 10 MB): XDT 16.4 Gb/s (82% of NIC), EC 14.0, S3 5.5.

The simulator is intentionally small: a heap-based event loop, generator
processes, FIFO bandwidth servers for NICs and service-side aggregate caps,
and lognormal service-time jitter for tail behaviour.  The same engine also
drives the real-workload models (VID / SET / MR) and the cost accounting.

This module is *measurement* infrastructure.  The functional XDT data plane —
references, buffers, pull collectives — is real JAX elsewhere in the package.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from heapq import heappush as _heappush
from typing import Callable, Dict, Generator, List, Optional, Tuple

import numpy as np

from .cost import egress_fee_usd
from .errors import InlineTooLarge
from .topology import Topology

# --------------------------------------------------------------------------
# Event-loop core
#
# The loop is allocation-lean by design: a process carries its generator and
# pending send-value *intrusively* (no per-step closures), numeric yields go
# straight onto the heap as slotted (time, seq, process) entries with no
# intermediate Event, and wakeups of already-runnable work go through a FIFO
# run queue instead of synchronous recursion — so arbitrarily long zero-delay
# completion chains execute iteratively (no RecursionError) and every callback
# of one virtual instant runs before the clock advances.
# --------------------------------------------------------------------------


class Event:
    """One-shot level-triggered event.

    ``set()`` never runs waiters synchronously: they are appended to the
    simulator's run queue and execute, in FIFO order, at the same virtual
    instant — before any later-scheduled heap entry.  Waiters may be plain
    callables or :class:`Process` objects (intrusive fast path: the process
    is resumed with ``value`` without allocating a wrapper closure).
    """

    __slots__ = ("_sim", "fired", "value", "_waiters")

    def __init__(self, sim: "Simulator"):
        self._sim = sim
        self.fired = False
        self.value = None
        self._waiters: Optional[list] = []

    def set(self, value=None) -> None:
        if self.fired:
            return
        self.fired = True
        self.value = value
        waiters = self._waiters
        if waiters:
            self._waiters = None
            ready = self._sim._ready
            for w in waiters:
                if w.__class__ is Process:
                    w._send = value
                ready.append(w)
        else:
            self._waiters = None

    def add_waiter(self, fn: Callable[[], None]) -> None:
        """Run ``fn`` once the event fires (deferred via the run queue if it
        already has — immediate wakeups never recurse)."""
        if self.fired:
            self._sim._ready.append(fn)
        else:
            self._waiters.append(fn)


class Process:
    """A generator coroutine on the simulator.

    Intrusive scheduling state: the generator and the value to send on resume
    live on the process itself, so suspending/resuming allocates nothing
    beyond the heap entry.
    """

    __slots__ = ("done", "gen", "_send")

    def __init__(self, sim: "Simulator", gen: Generator):
        self.done = Event(sim)
        self.gen = gen
        self._send = None
        sim._step(self)


class Simulator:
    """Minimal deterministic discrete-event simulator.

    ``events_processed`` counts executed callbacks (heap pops + run-queue
    wakeups) — the denominator-free numerator of the engine benchmark's
    events/sec metric.
    """

    def __init__(self, seed: int = 0):
        self.now = 0.0
        self._heap: List[tuple] = []
        self._ready: deque = deque()
        self._seq = 0
        self.events_processed = 0
        self.rng = np.random.default_rng(seed)

    @property
    def clock(self) -> Callable[[], float]:
        """A :class:`~repro.core.clock.VirtualClock` reading this sim's time."""
        from .clock import VirtualClock

        return VirtualClock(self)

    # -- primitives ---------------------------------------------------------
    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (self.now + max(0.0, delay), self._seq, fn))

    def schedule_abs(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` at absolute virtual time ``t`` (clamped to now).

        Unlike ``schedule(t - now, fn)`` this is exact in floating point —
        open-loop arrival trains land on their precomputed timestamps."""
        self._seq += 1
        heapq.heappush(self._heap, (t if t > self.now else self.now, self._seq, fn))

    def event(self) -> Event:
        return Event(self)

    def timeout(self, delay: float) -> Event:
        """An event that fires ``delay`` seconds from now.

        The Event itself is the heap entry (the run loop calls ``set()`` on
        it) — no bound-method or closure allocation per timeout.
        """
        ev = Event(self)
        self._seq += 1
        heapq.heappush(
            self._heap, (self.now + (delay if delay > 0.0 else 0.0), self._seq, ev)
        )
        return ev

    def timeout_abs(self, t: float) -> Event:
        """An event that fires at absolute virtual time ``t`` (clamped to
        now).  Unlike ``timeout(t - now)`` this is exact in floating point —
        chunk-event timelines land on their precomputed batch boundaries."""
        ev = Event(self)
        self._seq += 1
        heapq.heappush(
            self._heap, (t if t > self.now else self.now, self._seq, ev)
        )
        return ev

    def all_of(self, events: List[Event]) -> Event:
        ev = Event(self)
        pending = sum(1 for e in events if not e.fired)
        if pending == 0:
            ev.set()
            return ev
        box = [pending]

        def dec():
            box[0] -= 1
            if box[0] == 0:
                ev.set()

        for e in events:
            if not e.fired:
                e._waiters.append(dec)
        return ev

    def spawn(self, gen: Generator) -> Process:
        return Process(self, gen)

    # -- process stepping ----------------------------------------------------
    def _step(self, proc: Process) -> None:
        """Trampolined stepper: drives ``proc.gen`` through every yield that
        is immediately satisfiable (already-fired events) in a flat loop."""
        gen = proc.gen
        send = proc._send
        proc._send = None
        while True:
            try:
                yielded = gen.send(send)
            except StopIteration as stop:
                proc.done.set(stop.value)
                return
            cls = yielded.__class__
            if cls is Event or (cls is not float and cls is not int
                                and isinstance(yielded, Event)):
                if yielded.fired:
                    send = yielded.value
                    continue
                yielded._waiters.append(proc)
                return
            if cls is float or cls is int or isinstance(yielded, (int, float)):
                self._seq = seq = self._seq + 1
                _heappush(
                    self._heap,
                    (self.now + (yielded if yielded > 0 else 0.0), seq, proc),
                )
                return
            raise TypeError(f"process yielded {type(yielded)}")

    # legacy alias (pre-optimization name, kept for external callers)
    def _step_process(self, proc: Process, gen: Generator, send=None) -> None:
        proc.gen = gen
        proc._send = send
        self._step(proc)

    def run(self, until: float = math.inf) -> None:
        heap = self._heap
        ready = self._ready
        pop = heapq.heappop
        n = 0
        try:
            while True:
                while ready:
                    item = ready.popleft()
                    n += 1
                    cls = item.__class__
                    if cls is Process:
                        self._step(item)
                    elif cls is Event:
                        item.set()
                    else:
                        item()
                if not heap or heap[0][0] > until:
                    return
                t, _, item = pop(heap)
                if t > self.now:
                    self.now = t
                n += 1
                cls = item.__class__
                if cls is Process:
                    self._step(item)
                elif cls is Event:
                    item.set()
                else:
                    item()
                # Same-instant span kernel: batched arrivals / fan-out put
                # whole cohorts of entries at one timestamp, so drain them
                # without re-testing `until` or advancing the clock (both
                # already settled for this t).  Any wakeup the dispatch
                # enqueued breaks the span — the run queue always drains
                # before the next heap pop, exactly as in the outer loop.
                while not ready and heap and heap[0][0] == t:
                    _, _, item = pop(heap)
                    n += 1
                    cls = item.__class__
                    if cls is Process:
                        self._step(item)
                    elif cls is Event:
                        item.set()
                    else:
                        item()
        finally:
            self.events_processed += n


class FifoLink:
    """FIFO bandwidth server: transfers queue and serialize at ``bw`` B/s."""

    __slots__ = ("sim", "bw", "free_at", "busy_s", "bytes_moved")

    def __init__(self, sim: Simulator, bw_Bps: float):
        self.sim = sim
        self.bw = bw_Bps
        self.free_at = 0.0
        self.busy_s = 0.0
        self.bytes_moved = 0

    def transfer(self, nbytes: float, extra_latency: float = 0.0) -> Event:
        start = max(self.sim.now, self.free_at)
        dur = nbytes / self.bw
        self.free_at = start + dur
        self.busy_s += dur
        self.bytes_moved += nbytes
        return self.sim.timeout((start - self.sim.now) + dur + extra_latency)


# --------------------------------------------------------------------------
# Calibrated service constants
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NetConstants:
    """All times in seconds, bandwidths in bytes/second."""

    nic_bw: float = 2.5e9                 # 20 Gb/s m5.16xlarge
    rtt: float = 200e-6                   # intra-AZ round trip
    ctrl_plane_latency: float = 2.3e-3    # invoke via activator + queue-proxy
    inline_limit: int = 6 * 1024 * 1024   # AWS Lambda sync payload cap

    # S3 (cold object storage).  S3 is a distributed service: the binding
    # throughput cap is PER CLIENT NODE (parallel-GET throughput of one EC2
    # instance talking to S3), not service-wide.  Calibrated so the single
    # consumer of gather@fan32 10MB lands on the paper's 5.5 Gb/s effective.
    s3_op_latency: float = 11.6e-3        # per PUT/GET first-byte
    s3_stream_bw: float = 200e6           # single-stream throughput
    s3_client_bw: float = 0.80e9          # per-node cap -> 5.5 Gb/s @ fan 32
    s3_jitter_sigma: float = 0.55         # lognormal sigma (heavy tail)

    # ElastiCache (one Redis node, cache.m6g.16xlarge, 25 Gb/s NIC): the cap
    # is SERVER-side — independent ingress/egress FIFOs at the one-way
    # aggregate, calibrated to the paper's 14.0 Gb/s effective @ fan 32.
    ec_op_latency: float = 0.30e-3
    ec_stream_bw: float = 1.5e9
    ec_agg_bw: float = 1.76e9             # one-way -> 14 Gb/s eff @ fan 32
    ec_jitter_sigma: float = 0.25

    # XDT (direct pull over producer NIC, Cap'n Proto/TCP)
    xdt_pull_rtt: float = 200e-6
    xdt_stream_bw: float = 1.55e9         # single Cap'n Proto/TCP flow
    xdt_stream_eff: float = 0.82          # aggregate: 16.4 of 20 Gb/s at fan 32
    xdt_jitter_sigma: float = 0.18

    ctrl_jitter_sigma: float = 0.15

    # hybrid (two-tier) backend: objects below the cutoff go to cache,
    # larger ones to object storage (see transfer.HybridBackend)
    hybrid_small_cutoff: int = 1 << 20

    # co-placed (same-node) consumer pulls: when the graph optimizer's
    # CoPlacement pass lands a consumer instance on its producer's node, an
    # XDT pull short-circuits the NIC through shared memory.  Bandwidth is a
    # conservative single-socket memcpy rate; latency a local IPC round trip.
    local_bw: float = 12.5e9
    local_rtt: float = 20e-6

    # per-tier-crossing links (edge-cloud continuum, repro.core.topology).
    # Monotone by construction: same-node (local_bw) >= same-zone (nic_bw)
    # >= cross-zone >= cross-region >= edge<->cloud uplink.  A transfer whose
    # producer and consumer share a zone never touches these (flat-cluster
    # fast path); higher crossings serialize on a shared per-zone-pair FIFO
    # at the tier bandwidth and pay the tier RTT on top of the intra-zone
    # segments.
    cross_zone_bw: float = 1.25e9         # inter-AZ fabric share
    cross_zone_rtt: float = 1.0e-3
    cross_region_bw: float = 0.62e9       # WAN between regions / edge sites
    cross_region_rtt: float = 30e-3
    edge_bw: float = 0.31e9               # edge <-> cloud uplink
    edge_rtt: float = 60e-3

    def tier_bw(self, level: int) -> float:
        """Bandwidth of a tier crossing (level 2..4; >=5 clamps to edge)."""
        if level <= 2:
            return self.cross_zone_bw
        if level == 3:
            return self.cross_region_bw
        return self.edge_bw

    def tier_rtt(self, level: int) -> float:
        """Round-trip latency of a tier crossing (level 2..4)."""
        if level <= 2:
            return self.cross_zone_rtt
        if level == 3:
            return self.cross_region_rtt
        return self.edge_rtt


# The paper's two testbeds, calibrated separately:
# Fig. 2 runs on AWS Lambda against real S3/ElastiCache endpoints; Figs 5-7
# run on the authors' vHive/Knative cluster of m5.16xlarge nodes.  The S3
# first-byte latency they observe differs between the two (Lambda runtime vs
# EC2 + Istio path), hence two presets.
VHIVE_NET = NetConstants()
LAMBDA_NET = dataclasses.replace(
    VHIVE_NET, s3_op_latency=7.85e-3, s3_jitter_sigma=0.6
)
DEFAULT_NET = VHIVE_NET


@dataclasses.dataclass
class TransferAccounting:
    """Inputs to the cost model, accumulated while the sim runs."""

    n_invocations: int = 0
    billed_duration_s: float = 0.0
    n_storage_puts: int = 0
    n_storage_gets: int = 0
    storage_gb_seconds: float = 0.0
    peak_resident_gb: float = 0.0
    _resident_gb: float = 0.0
    _last_t: float = 0.0

    def touch(self, now: float) -> None:
        self.storage_gb_seconds += self._resident_gb * (now - self._last_t)
        self._last_t = now

    def store(self, now: float, gb: float) -> None:
        self.storage_gb_seconds += self._resident_gb * (now - self._last_t)
        self._last_t = now
        resident = self._resident_gb = self._resident_gb + gb
        if resident > self.peak_resident_gb:
            self.peak_resident_gb = resident

    def free(self, now: float, gb: float) -> None:
        self.storage_gb_seconds += self._resident_gb * (now - self._last_t)
        self._last_t = now
        resident = self._resident_gb - gb
        self._resident_gb = resident if resident > 0.0 else 0.0


# --------------------------------------------------------------------------
# Cluster: nodes, services, transfer primitives
# --------------------------------------------------------------------------


class ServerlessCluster:
    """A simulated cluster: per-node NICs + S3/EC services + XDT data plane.

    One node per function instance (the paper pins one function per EC2 node
    so every transfer crosses the network).
    """

    def __init__(
        self,
        n_nodes: int,
        net: NetConstants = DEFAULT_NET,
        seed: int = 0,
        deterministic: bool = False,
        topology: Optional[Topology] = None,
        node_zones: Optional[List[int]] = None,
    ):
        self.sim = Simulator(seed=seed)
        self.net = net
        self.deterministic = deterministic
        self.nics = [FifoLink(self.sim, net.nic_bw) for _ in range(n_nodes)]
        # S3: per-client-node FIFO (distributed service, client-side cap);
        # EC: one cache node with independent ingress/egress FIFO servers.
        self.s3_client = [FifoLink(self.sim, net.s3_client_bw) for _ in range(n_nodes)]
        self.ec_front_in = FifoLink(self.sim, net.ec_agg_bw)
        self.ec_front_out = FifoLink(self.sim, net.ec_agg_bw)
        # per-node shared-memory channels for co-placed pulls, created lazily
        # (runs without a PlacementPlan never touch them)
        self._mem_links: Dict[int, FifoLink] = {}
        self.acct: Dict[str, TransferAccounting] = {}
        # edge-cloud continuum: node -> zone map plus per-directed-zone-pair
        # FIFO links at the tier-crossing bandwidth.  Storage services are
        # homed in the topology's service zone, so a put/get from another
        # zone pays the crossing too.  With no topology (or a single zone)
        # every guard below short-circuits and the float/rng stream is
        # bit-identical to the flat cluster.
        self.topology = topology
        if topology is not None and node_zones is not None:
            if len(node_zones) != n_nodes:
                raise ValueError("node_zones must map every node to a zone")
            self.node_zones: Optional[List[int]] = list(node_zones)
            self._svc_zone = topology.service_zone
        else:
            self.node_zones = None
            self._svc_zone = 0
        self._tier_links: Dict[Tuple[int, int], FifoLink] = {}
        self.egress_usd = 0.0

    # -- helpers -------------------------------------------------------------
    def _jit(self, base: float, sigma: float) -> float:
        if self.deterministic or sigma <= 0:
            return base
        return base * float(self.sim.rng.lognormal(mean=0.0, sigma=sigma))

    def accounting(self, backend: str) -> TransferAccounting:
        if backend not in self.acct:
            self.acct[backend] = TransferAccounting()
        return self.acct[backend]

    # -- edge-cloud continuum ---------------------------------------------
    def crossing(self, a: int, b: int) -> int:
        """Crossing level between two nodes (0 same node .. 4 edge<->cloud)."""
        if a == b:
            return 0
        if self.node_zones is None:
            return 1
        return self.topology.crossing(self.node_zones[a], self.node_zones[b])

    def _tier_extra(self, za: Optional[int], zb: Optional[int], nbytes: int) -> float:
        """Extra seconds (queueing + serialization + tier RTT) and egress
        fee of crossing from zone ``za`` to ``zb``.  Zero — with zero float
        ops — when the transfer stays inside one zone, so flat runs are
        bit-identical.  The tier segment is deterministic on purpose: it
        must not consume rng draws the flat cluster does not."""
        if za is None or zb is None or za == zb:
            return 0.0
        level = self.topology.crossing(za, zb)
        if level <= 1:
            return 0.0
        self.egress_usd += egress_fee_usd(level, nbytes)
        link = self._tier_links.get((za, zb))
        if link is None:
            link = self._tier_links[(za, zb)] = FifoLink(
                self.sim, self.net.tier_bw(level)
            )
        start = max(self.sim.now, link.free_at)
        dur = nbytes / link.bw
        link.free_at = start + dur
        link.busy_s += dur
        link.bytes_moved += nbytes
        return (start - self.sim.now) + dur + self.net.tier_rtt(level)

    def _zone_of(self, node: Optional[int]) -> Optional[int]:
        if node is None or self.node_zones is None:
            return None
        return self.node_zones[node]

    def _svc_zone_of(self, node: Optional[int]) -> Optional[int]:
        """The storage-service home zone, as seen from ``node`` (None when
        the cluster has no topology, so tier handling short-circuits)."""
        if node is None or self.node_zones is None:
            return None
        return self._svc_zone

    def mem_backlog_s(self, node: int) -> float:
        """Current backlog of the node's shared-memory FIFO (seconds until
        free) — what the contention-aware co-placement variant reads before
        committing a pull to the local channel."""
        link = self._mem_links.get(node)
        if link is None:
            return 0.0
        return max(0.0, link.free_at - self.sim.now)

    def nic_backlog_s(self, node: int) -> float:
        """Current backlog of the node's NIC FIFO."""
        return max(0.0, self.nics[node].free_at - self.sim.now)

    # -- control plane --------------------------------------------------------
    def invoke_ctrl(self) -> Event:
        """Control-plane hop: client -> activator -> queue-proxy -> handler."""
        lat = self._jit(self.net.ctrl_plane_latency, self.net.ctrl_jitter_sigma)
        return self.sim.timeout(lat)

    # -- data plane, one object ------------------------------------------------
    def inline_send(self, src: int, nbytes: int, dst: Optional[int] = None) -> Event:
        if nbytes > self.net.inline_limit:
            raise InlineTooLarge(
                f"{nbytes}B exceeds the {self.net.inline_limit}B inline cap"
            )
        lat = self._jit(self.net.ctrl_plane_latency, self.net.ctrl_jitter_sigma)
        extra = self._tier_extra(self._zone_of(src), self._zone_of(dst), nbytes)
        return self.nics[src].transfer(nbytes, extra_latency=lat + extra)

    def storage_put(self, backend: str, src: int, nbytes: int) -> Event:
        net = self.net
        if backend == "s3":
            front, op, stream, sig = (
                self.s3_client[src], net.s3_op_latency, net.s3_stream_bw, net.s3_jitter_sigma,
            )
        else:
            front, op, stream, sig = (
                self.ec_front_in, net.ec_op_latency, net.ec_stream_bw, net.ec_jitter_sigma,
            )
        acct = self.accounting(backend)
        acct.n_storage_puts += 1
        acct.store(self.sim.now, nbytes / 1e9)
        lat = self._jit(op, sig)
        # Producer NIC then service front-end; stream bw is the per-flow cap.
        # Services are homed in the topology's service zone: a put from
        # another zone rides the tier link on top.
        lat += self._tier_extra(self._zone_of(src), self._svc_zone_of(src), nbytes)
        self.nics[src].transfer(nbytes, 0.0)
        return self._service_flow(front, stream, src, nbytes, lat)

    def storage_get(self, backend: str, dst: int, nbytes: int, last: bool = True) -> Event:
        net = self.net
        if backend == "s3":
            front, op, stream, sig = (
                self.s3_client[dst], net.s3_op_latency, net.s3_stream_bw, net.s3_jitter_sigma,
            )
        else:
            front, op, stream, sig = (
                self.ec_front_out, net.ec_op_latency, net.ec_stream_bw, net.ec_jitter_sigma,
            )
        acct = self.accounting(backend)
        acct.n_storage_gets += 1
        if last:
            acct.free(self.sim.now, nbytes / 1e9)
        lat = self._jit(op, sig)
        lat += self._tier_extra(self._svc_zone_of(dst), self._zone_of(dst), nbytes)
        self.nics[dst].transfer(nbytes, 0.0)
        return self._service_flow(front, stream, dst, nbytes, lat)

    def _service_flow(
        self, front: FifoLink, stream_bw: float, node: int, nbytes: int, lat: float
    ) -> Event:
        """A flow capped by min(per-stream bw, service aggregate FIFO)."""
        # Queue the service front-end for the aggregate-capacity share, then
        # pay the per-stream serialization time for the remainder if the
        # stream cap is tighter than the aggregate share.
        per_stream_time = nbytes / min(stream_bw, self.net.nic_bw)
        start = max(self.sim.now, front.free_at)
        agg_time = nbytes / front.bw
        front.free_at = start + agg_time
        front.busy_s += agg_time
        front.bytes_moved += nbytes
        finish = max(start + agg_time, self.sim.now + per_stream_time) + lat
        return self.sim.timeout(finish - self.sim.now)

    def local_pull(self, node: int, nbytes: int) -> Event:
        """Same-node consumer pull: producer -> consumer via shared memory.

        The co-placement locality discount of the graph optimizer: the XDT
        data plane short-circuits the NIC when producer and consumer share a
        node.  Concurrent co-placed pulls serialize on the node's memory
        channel (a FIFO at ``local_bw``), so packing many consumers onto one
        producer node still pays for the contention it creates.  Draws one
        jitter sample, like :meth:`xdt_pull`, so optimized and un-optimized
        runs consume the rng in the same per-pull pattern.
        """
        net = self.net
        lat = self._jit(net.local_rtt, net.xdt_jitter_sigma)
        link = self._mem_links.get(node)
        if link is None:
            link = self._mem_links[node] = FifoLink(self.sim, net.local_bw)
        return link.transfer(nbytes, extra_latency=lat)

    def xdt_pull(self, producer: int, nbytes: int, consumer: Optional[int] = None) -> Event:
        """Consumer pulls directly from the producer's memory over its NIC.

        Concurrent pulls share the producer NIC (FIFO at ``nic_bw *
        xdt_stream_eff`` aggregate); a lone pull is additionally capped by the
        single-TCP-flow rate ``xdt_stream_bw``.  When ``consumer`` is given
        and lives in another zone, the pull additionally rides (and pays
        egress on) the producer->consumer tier link.
        """
        net = self.net
        lat = self._jit(net.xdt_pull_rtt, net.xdt_jitter_sigma)
        lat += self._tier_extra(self._zone_of(producer), self._zone_of(consumer), nbytes)
        front = self.nics[producer]
        agg_bw = net.nic_bw * net.xdt_stream_eff
        start = max(self.sim.now, front.free_at)
        agg_time = nbytes / agg_bw
        front.free_at = start + agg_time
        front.busy_s += agg_time
        front.bytes_moved += nbytes
        per_stream_time = nbytes / net.xdt_stream_bw
        finish = max(start + agg_time, self.sim.now + per_stream_time) + lat
        return self.sim.timeout(finish - self.sim.now)


# --------------------------------------------------------------------------
# Transfer patterns on the simulator (paper §7.1)
# --------------------------------------------------------------------------


def _one_transfer(
    cluster: ServerlessCluster, backend: str, src: int, dst: int, nbytes: int
) -> Generator:
    """producer --(backend)--> consumer; yields until the consumer has data."""
    if backend == "inline":
        yield cluster.inline_send(src, nbytes)
    elif backend in ("s3", "elasticache"):
        yield cluster.storage_put(backend, src, nbytes)
        yield cluster.invoke_ctrl()                      # invoke w/ key
        yield cluster.storage_get(backend, dst, nbytes)
    elif backend == "xdt":
        yield cluster.invoke_ctrl()                      # invoke w/ secure ref
        yield cluster.xdt_pull(src, nbytes)
    else:
        raise ValueError(backend)


def measure_pattern(
    pattern: str,
    backend: str,
    nbytes: int,
    fan: int = 1,
    net: NetConstants = DEFAULT_NET,
    seed: int = 0,
    deterministic: bool = False,
) -> Tuple[float, ServerlessCluster]:
    """End-to-end latency (s) of one collective transfer pattern.

    Patterns (paper §6.4): ``1-1``, ``scatter`` (producer sends a distinct
    1/fan slice to each of ``fan`` consumers), ``gather`` (``fan`` producers
    each send one object to one consumer), ``broadcast`` (one object pulled
    in full by every consumer).
    """
    n_nodes = fan + 1
    cluster = ServerlessCluster(n_nodes, net, seed=seed, deterministic=deterministic)
    sim = cluster.sim
    done: List[Event] = []

    if pattern == "1-1":
        done.append(sim.spawn(_one_transfer(cluster, backend, 0, 1, nbytes)).done)
    elif pattern == "scatter":
        slice_b = max(1, nbytes // fan)
        if backend in ("s3", "elasticache"):
            def flow(i):
                yield cluster.storage_put(backend, 0, slice_b)
                yield cluster.invoke_ctrl()
                yield cluster.storage_get(backend, 1 + i, slice_b)
            done = [sim.spawn(flow(i)).done for i in range(fan)]
        elif backend == "xdt":
            def flow(i):
                yield cluster.invoke_ctrl()
                yield cluster.xdt_pull(0, slice_b)
            done = [sim.spawn(flow(i)).done for i in range(fan)]
        else:
            def flow(i):
                yield cluster.inline_send(0, slice_b)
            done = [sim.spawn(flow(i)).done for i in range(fan)]
    elif pattern == "gather":
        if backend in ("s3", "elasticache"):
            def flow(i):
                yield cluster.storage_put(backend, 1 + i, nbytes)
                yield cluster.invoke_ctrl()
                yield cluster.storage_get(backend, 0, nbytes)
            done = [sim.spawn(flow(i)).done for i in range(fan)]
        elif backend == "xdt":
            def flow(i):
                yield cluster.invoke_ctrl()
                # consumer pulls from each producer; the consumer NIC (node 0)
                # is the shared bottleneck — same FIFO model as xdt_pull
                yield cluster.xdt_pull(0, nbytes)
            done = [sim.spawn(flow(i)).done for i in range(fan)]
        else:
            def flow(i):
                yield cluster.inline_send(1 + i, nbytes)
            done = [sim.spawn(flow(i)).done for i in range(fan)]
    elif pattern == "broadcast":
        if backend in ("s3", "elasticache"):
            def all_flows():
                yield cluster.storage_put(backend, 0, nbytes)  # single put
                evs = []
                for i in range(fan):
                    evs.append(sim.spawn(_bcast_get(cluster, backend, 1 + i, nbytes, i == fan - 1)).done)
                yield sim.all_of(evs)
            done = [sim.spawn(all_flows()).done]
        elif backend == "xdt":
            def flow(i):
                yield cluster.invoke_ctrl()
                yield cluster.xdt_pull(0, nbytes)  # every consumer pulls full obj
            done = [sim.spawn(flow(i)).done for i in range(fan)]
        else:
            def flow(i):
                yield cluster.inline_send(0, nbytes)
            done = [sim.spawn(flow(i)).done for i in range(fan)]
    else:
        raise ValueError(pattern)

    fin = sim.all_of(done)
    sim.run()
    assert fin.fired, "simulation deadlocked"
    return sim.now, cluster


def _bcast_get(cluster, backend, node, nbytes, last):
    yield cluster.invoke_ctrl()
    yield cluster.storage_get(backend, node, nbytes, last=last)


def effective_bandwidth_Bps(
    pattern: str, backend: str, nbytes: int, fan: int, **kw
) -> float:
    """Total payload bytes moved / end-to-end time (paper's 'effective BW')."""
    t, _ = measure_pattern(pattern, backend, nbytes, fan, deterministic=True, **kw)
    if pattern == "scatter":
        total = nbytes  # the object is partitioned
    elif pattern == "1-1":
        total = nbytes
    else:
        total = nbytes * fan
    return total / t
