"""Deployment-sharded simulation: epoch-barrier lanes over independent cells.

Multi-tenant sweeps run hundreds of *deployment groups* (a tenant's
workflows: functions, deployments, private transfer media) that mostly never
interact.  This module partitions them for parallel simulation:

* :class:`GroupSpec` declares one group plus its **interaction points** —
  the shared ServiceStore media it mounts and the cross-group ``ctx.call``
  edges it makes.  Groups joined by either relation must observe one
  virtual clock and one engine, so the planner unions them into a **cell**
  (a connected component of the interaction graph).  Each cell owns a
  private :class:`~repro.core.workflow.WorkflowEngine` seeded from its own
  spec — which makes cell results *partition-invariant by construction*:
  whichever shard executes a cell, its virtual-time trajectory is
  bit-identical.
* :class:`ShardPlan` packs cells into ``n_shards`` execution lanes
  round-robin in canonical cell order (deterministic for a given spec
  list), after the union-find pass.
* :class:`ShardRunner` advances every shard on clock-synced **epoch
  barriers**: all cells reach virtual time ``k * epoch_s`` before any cell
  enters epoch ``k+1``.  Within one process the shards are interleaved
  batch lanes (each epoch visits every cell once — cheap, cache-friendly,
  and observable between epochs via ``on_epoch``); with
  ``workers="process"`` each shard runs in a forked worker and the barrier
  is a pipe round-trip, so independent shards use independent cores.
* :func:`merge_cell_results` folds the per-cell columnar logs back into one
  deterministic global view: RequestLog/InvocationLog columns concatenated
  in canonical cell order with ids namespaced by ``cell_index * id_stride``,
  and per-medium ``media_acct`` totals summed.  A single-shard run and a
  many-shard run of the same plan therefore produce byte-identical merged
  columns — the differential identity test in ``tests/test_shard.py``
  pins exactly that.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
from array import array
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .workflow import InvocationLog, RequestLog, WorkflowEngine

#: request/invocation ids inside a cell are namespaced into the merged view
#: as ``cell_index * ID_STRIDE + local_id`` — far above any realistic
#: per-cell id count, and identical regardless of how cells were sharded
ID_STRIDE = 1 << 40


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """One deployment group and its declared interaction points.

    ``build(engine, spec)`` registers the group's functions/deployments on
    the cell engine it is handed and returns a *drive* callable (schedules
    the group's offered load on ``engine.sim`` — it must not run the
    simulator itself) or ``None`` for passive groups.
    """

    name: str
    build: Callable[[WorkflowEngine, "GroupSpec"], Optional[Callable[[], None]]]
    seed: int = 0
    #: names of shared ServiceStore media this group mounts; two groups
    #: naming the same medium interact through it and must co-simulate
    shared_media: Tuple[str, ...] = ()
    #: names of other groups this group's workflows ``ctx.call`` into
    calls: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class Cell:
    """A connected component of the interaction graph: one engine's worth."""

    index: int
    name: str                     # first member's name (canonical order)
    specs: Tuple[GroupSpec, ...]
    seed: int                     # first member's seed


class ShardPlan:
    """Cells (union-find over interaction edges) packed into shard lanes."""

    def __init__(self, cells: Sequence[Cell], shards: Sequence[Tuple[int, ...]]):
        self.cells = tuple(cells)
        self.shards = tuple(tuple(s) for s in shards)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @classmethod
    def plan(cls, specs: Sequence[GroupSpec], n_shards: int = 1) -> "ShardPlan":
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError("duplicate group names in shard plan")
        index = {n: i for i, n in enumerate(names)}
        parent = list(range(len(specs)))

        def find(i: int) -> int:
            while parent[i] != i:
                parent[i] = i = parent[parent[i]]
            return i

        def union(i: int, j: int) -> None:
            ri, rj = find(i), find(j)
            if ri != rj:
                # anchor on the lower index so canonical order is stable
                parent[max(ri, rj)] = min(ri, rj)

        by_medium: Dict[str, int] = {}
        for i, spec in enumerate(specs):
            for medium in spec.shared_media:
                j = by_medium.setdefault(medium, i)
                union(i, j)
            for callee in spec.calls:
                j = index.get(callee)
                if j is None:
                    raise ValueError(
                        f"group {spec.name!r} calls unknown group {callee!r}"
                    )
                union(i, j)
        members: Dict[int, List[GroupSpec]] = {}
        for i, spec in enumerate(specs):
            members.setdefault(find(i), []).append(spec)
        cells = [
            Cell(index=k, name=group[0].name, specs=tuple(group),
                 seed=group[0].seed)
            for k, (_, group) in enumerate(sorted(members.items()))
        ]
        shards = [
            tuple(range(lane, len(cells), n_shards))
            for lane in range(min(n_shards, max(1, len(cells))))
        ]
        return cls(cells, shards)


def default_engine_factory(cell: Cell) -> WorkflowEngine:
    """Columnar engine seeded from the cell: the partition-invariance anchor."""
    return WorkflowEngine(seed=cell.seed, records="columnar")


@dataclasses.dataclass
class CellResult:
    """One cell's columnar outcome — plain arrays/dicts, so process workers
    ship it through a pipe without custom reducers."""

    name: str
    request_ids: array
    latencies_s: array
    ok_flags: array
    invocation_ids: array
    functions: List[str]
    instance_ids: array
    statuses: array
    error_codes: Dict[int, str]
    t_starts: array
    t_ends: array
    billed_s: float
    media: Dict[str, Dict[str, float]]
    events_processed: int
    t_end: float
    n_deployments: int


def _acct_totals(acct, now: float) -> Dict[str, float]:
    acct.touch(now)
    return {
        "n_puts": acct.n_storage_puts,
        "n_gets": acct.n_storage_gets,
        "gb_seconds": acct.storage_gb_seconds,
        "peak_resident_gb": acct.peak_resident_gb,
    }


def collect_cell_result(name: str, engine: WorkflowEngine) -> CellResult:
    """Snapshot one finished cell engine into its portable columnar result."""
    if engine.request_log is None:
        raise ValueError("sharded cells need records='columnar' engines")
    if engine._inflight_requests:
        raise RuntimeError(
            f"cell {name!r} finished its horizon with "
            f"{engine._inflight_requests} requests still in flight"
        )
    now = engine.sim.now
    log: RequestLog = engine.request_log
    ilog: InvocationLog = engine.records
    media = {
        medium: _acct_totals(acct, now)
        for medium, acct in sorted(engine.transfer.media_acct.items())
    }
    return CellResult(
        name=name,
        request_ids=log.request_ids,
        latencies_s=log.latencies_s,
        ok_flags=log.ok_flags,
        invocation_ids=ilog.invocation_ids,
        functions=ilog.functions,
        instance_ids=ilog.instance_ids,
        statuses=ilog.statuses,
        error_codes=dict(ilog.error_codes),
        t_starts=ilog.t_starts,
        t_ends=ilog.t_ends,
        billed_s=ilog.billed_s,
        media=media,
        events_processed=engine.sim.events_processed,
        t_end=now,
        n_deployments=len(engine.control.deployments),
    )


@dataclasses.dataclass
class MergedRun:
    """Deterministically merged view of every cell in a sharded run."""

    request_log: RequestLog
    invocation_log: Optional[InvocationLog]
    media_totals: Dict[str, Dict[str, float]]
    billed_s: float
    events_processed: int
    t_end: float
    n_deployments: int
    n_cells: int
    n_shards: int
    epochs: int
    per_cell: Dict[str, CellResult]


def merge_cell_results(
    results: Sequence[CellResult],
    n_shards: int = 1,
    epochs: int = 0,
    id_stride: int = ID_STRIDE,
    merge_invocations: bool = True,
) -> MergedRun:
    """Fold per-cell columns into one global view, canonical cell order.

    Ids are namespaced per cell (``cell_index * id_stride + local_id``), so
    the merged columns are a pure function of the plan — independent of how
    many shards (lanes or processes) executed it.
    """
    req = RequestLog()
    ilog = InvocationLog() if merge_invocations else None
    media: Dict[str, Dict[str, float]] = {}
    billed = 0.0
    events = 0
    t_end = 0.0
    n_deps = 0
    for k, cell in enumerate(results):
        base = k * id_stride
        req.request_ids.extend(rid + base for rid in cell.request_ids)
        req.latencies_s.extend(cell.latencies_s)
        req.ok_flags.extend(cell.ok_flags)
        if ilog is not None:
            offset = len(ilog.invocation_ids)
            ilog.invocation_ids.extend(
                iid + base for iid in cell.invocation_ids
            )
            ilog.functions.extend(cell.functions)
            ilog.instance_ids.extend(cell.instance_ids)
            ilog.statuses.extend(cell.statuses)
            for pos, code in cell.error_codes.items():
                ilog.error_codes[offset + pos] = code
            ilog.t_starts.extend(cell.t_starts)
            ilog.t_ends.extend(cell.t_ends)
            ilog.billed_s += cell.billed_s
        for medium, tot in cell.media.items():
            agg = media.setdefault(
                medium,
                {"n_puts": 0, "n_gets": 0, "gb_seconds": 0.0,
                 "peak_resident_gb": 0.0},
            )
            agg["n_puts"] += tot["n_puts"]
            agg["n_gets"] += tot["n_gets"]
            agg["gb_seconds"] += tot["gb_seconds"]
            # cells are co-resident: worst-case provisioning is the sum of
            # their peaks (each cell's peak set must fit simultaneously)
            agg["peak_resident_gb"] += tot["peak_resident_gb"]
        billed += cell.billed_s
        events += cell.events_processed
        t_end = max(t_end, cell.t_end)
        n_deps += cell.n_deployments
    return MergedRun(
        request_log=req,
        invocation_log=ilog,
        media_totals=media,
        billed_s=billed,
        events_processed=events,
        t_end=t_end,
        n_deployments=n_deps,
        n_cells=len(results),
        n_shards=n_shards,
        epochs=epochs,
        per_cell={c.name: c for c in results},
    )


class _CellRuntime:
    """A built cell: its engine plus the drives already scheduled."""

    __slots__ = ("cell", "engine")

    def __init__(self, cell: Cell, engine_factory) -> None:
        self.cell = cell
        engine = engine_factory(cell)
        for spec in cell.specs:
            drive = spec.build(engine, spec)
            if drive is not None:
                drive()
        self.engine = engine

    def advance(self, until: float) -> None:
        self.engine.sim.run(until=until)

    def finish(self) -> CellResult:
        self.engine.sim.run()
        return collect_cell_result(self.cell.name, self.engine)


def _shard_worker(conn, cells, engine_factory) -> None:
    """Forked worker: build this shard's cells, then obey barrier commands.

    Protocol (parent -> worker): a float advances every cell to that virtual
    time and acks with the cells' event total so far; ``None`` runs each
    cell to completion, ships the columnar results back, and exits.
    """
    runtimes = [_CellRuntime(c, engine_factory) for c in cells]
    while True:
        cmd = conn.recv()
        if cmd is None:
            conn.send([rt.finish() for rt in runtimes])
            conn.close()
            return
        for rt in runtimes:
            rt.advance(cmd)
        conn.send(sum(rt.engine.sim.events_processed for rt in runtimes))


class ShardRunner:
    """Drives a :class:`ShardPlan` to a virtual horizon on epoch barriers.

    ``workers="inline"`` (default) interleaves every shard's cells in this
    process — one lane per shard, visited round-robin per epoch.
    ``workers="process"`` forks one worker per shard (requires the ``fork``
    start method; cells are inherited by the fork, only the columnar
    results travel back through a pipe).  ``on_epoch(k, t)`` — if given —
    observes every barrier from the parent, e.g. for progress reporting.
    """

    def __init__(
        self,
        plan: ShardPlan,
        engine_factory: Callable[[Cell], WorkflowEngine] = default_engine_factory,
        epoch_s: float = 1.0,
        workers: str = "inline",
        on_epoch: Optional[Callable[[int, float], None]] = None,
    ):
        if epoch_s <= 0.0:
            raise ValueError("epoch_s must be positive")
        if workers not in ("inline", "process"):
            raise ValueError("workers must be 'inline' or 'process'")
        self.plan = plan
        self.engine_factory = engine_factory
        self.epoch_s = epoch_s
        self.workers = workers
        self.on_epoch = on_epoch

    def run(self, duration_s: float, merge_invocations: bool = True) -> MergedRun:
        epochs = max(1, int(-(-duration_s // self.epoch_s)))
        if self.workers == "process" and len(self.plan.shards) > 1:
            results = self._run_processes(epochs)
        else:
            results = self._run_inline(epochs)
        return merge_cell_results(
            results, n_shards=self.plan.n_shards, epochs=epochs,
            merge_invocations=merge_invocations,
        )

    # -- interleaved batch lanes (one process) ------------------------------
    def _run_inline(self, epochs: int) -> List[CellResult]:
        cells = self.plan.cells
        lanes = [
            [_CellRuntime(cells[i], self.engine_factory) for i in shard]
            for shard in self.plan.shards
        ]
        for k in range(epochs):
            barrier = (k + 1) * self.epoch_s
            for lane in lanes:
                for rt in lane:
                    rt.advance(barrier)
            if self.on_epoch is not None:
                self.on_epoch(k, barrier)
        by_index: Dict[int, CellResult] = {}
        for shard, lane in zip(self.plan.shards, lanes):
            for i, rt in zip(shard, lane):
                by_index[i] = rt.finish()
        return [by_index[i] for i in range(len(cells))]

    # -- forked shard workers ----------------------------------------------
    def _run_processes(self, epochs: int) -> List[CellResult]:
        methods = multiprocessing.get_all_start_methods()
        if "fork" not in methods:
            raise RuntimeError(
                "workers='process' needs the fork start method (cell "
                f"builders are inherited, not pickled); available: {methods}"
            )
        ctx = multiprocessing.get_context("fork")
        cells = self.plan.cells
        pipes, procs = [], []
        for shard in self.plan.shards:
            parent_conn, child_conn = ctx.Pipe()
            proc = ctx.Process(
                target=_shard_worker,
                args=(child_conn, [cells[i] for i in shard],
                      self.engine_factory),
            )
            proc.start()
            child_conn.close()
            pipes.append(parent_conn)
            procs.append(proc)
        try:
            for k in range(epochs):
                barrier = (k + 1) * self.epoch_s
                for conn in pipes:
                    conn.send(barrier)
                for conn in pipes:        # the clock-synced barrier
                    conn.recv()
                if self.on_epoch is not None:
                    self.on_epoch(k, barrier)
            for conn in pipes:
                conn.send(None)
            by_index: Dict[int, CellResult] = {}
            for shard, conn in zip(self.plan.shards, pipes):
                for i, result in zip(shard, conn.recv()):
                    by_index[i] = result
        finally:
            for proc in procs:
                proc.join(timeout=30)
                if proc.is_alive():        # pragma: no cover
                    proc.terminate()
        return [by_index[i] for i in range(len(cells))]


__all__ = [
    "Cell",
    "CellResult",
    "GroupSpec",
    "ID_STRIDE",
    "MergedRun",
    "ShardPlan",
    "ShardRunner",
    "collect_cell_result",
    "default_engine_factory",
    "merge_cell_results",
]
