"""Error taxonomy for the XDT substrate.

The paper (§4.2.2) requires that XDT failures surface to user logic as ordinary
serverless runtime errors so that existing orchestrator-level error handling
(retry / fallback functions) composes with XDT.  Every error below therefore
carries a stable ``code`` string, mirroring how AWS Step Functions matches
errors by name.
"""
from __future__ import annotations


class XDTError(Exception):
    """Base class for all XDT runtime errors."""

    code = "XDT.Error"

    def __init__(self, msg: str = ""):
        super().__init__(msg or self.code)


class XDTRefInvalid(XDTError):
    """Reference failed authentication (forged / tampered / truncated)."""

    code = "XDT.RefInvalid"


class XDTProducerGone(XDTError):
    """Producer instance was shut down before the object was retrieved.

    Paper §4.2.2: "a shutdown of a producer instance leads to immediate
    de-allocation of all the objects, retrievals of which have not completed"
    — the consumer's ``get()`` receives this error and must escalate to the
    orchestrator, which re-invokes the producer (at-least-once on top of
    at-most-once).
    """

    code = "XDT.ProducerGone"


class XDTObjectExhausted(XDTError):
    """All N permitted retrievals of this reference already completed."""

    code = "XDT.ObjectExhausted"


class XDTWouldBlock(XDTError):
    """Non-blocking ``put()`` found no free buffer slot (flow control)."""

    code = "XDT.WouldBlock"


class XDTTimeout(XDTError):
    """Blocking ``put()``/``get()`` exceeded its deadline."""

    code = "XDT.Timeout"


class InlineTooLarge(XDTError):
    """Inline payload exceeds the provider's control-plane cap (6 MB sync)."""

    code = "Provider.InlineTooLarge"


class InvocationReplayed(XDTError):
    """A second execution of the same invocation id was attempted.

    Raised by the workflow engine to enforce at-most-once execution.
    """

    code = "Provider.InvocationReplayed"


class Evicted(XDTProducerGone):
    """A correlated (node-level) eviction killed the producer instance.

    Subclasses :class:`XDTProducerGone` so the orchestrator's bounded-retry
    recovery applies unchanged; the distinct code lets handlers and the
    :class:`~repro.core.faults.SLOGuard` attribute the death to a fault-plan
    eviction rather than an ordinary keep-alive reap.
    """

    code = "Fault.Evicted"


class MediumUnavailable(XDTError):
    """A transfer medium refused the operation inside a degradation window
    (S3 throttle, ElastiCache failover blackout).

    Transient by definition — the orchestrator retries it like a producer
    death (bounded by ``max_retries``); an adaptive route is expected to
    shift traffic off the medium before the budget runs out.
    """

    code = "Fault.MediumUnavailable"


class RetriesExhausted(XDTError):
    """A request spent its whole retry budget on transient errors.

    Terminal: the request lands in the log with status ``"failed"`` (priced
    for the work actually done) instead of crashing the sweep.  ``cause``
    holds the last transient error, so SLO guards can discriminate what
    exhausted the budget.
    """

    code = "Fault.RetriesExhausted"

    def __init__(self, msg: str = "", cause: "XDTError | None" = None):
        super().__init__(msg)
        self.cause = cause
        if cause is not None:
            self.__cause__ = cause
