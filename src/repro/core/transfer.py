"""TransferEngine: the XDT API (`invoke`/`put`/`get`) over real ``jax.Array``s.

This is the host-level data plane used by the serving engine, the data
pipeline, and the workflow engine.  Each transfer medium is a
:class:`TransferBackend` *strategy class* registered by name — adding a new
medium (see :class:`HybridBackend` for a two-tier example) is one subclass
plus :func:`register_backend`, not edits to the engine.  The paper's §2.3
taxonomy maps to:

``xdt``
    The paper's contribution.  ``put`` leaves the array **device-resident in
    its producer sharding** inside the producer's :class:`BufferRegistry`
    (zero copies) and mints an HMAC-signed :class:`XDTRef`.  ``get`` opens the
    ref provider-side and moves the bytes once, directly, to the consumer's
    sharding.  Buffers die with the producer instance (``kill_producer``).

``inline``
    The payload rides the control message.  Enforces the 6 MB cap and pays a
    host staging round-trip (the activator path).  Dies with the producer.

``s3`` / ``elasticache``
    Through-storage: device -> host copy into a :class:`ServiceStore`;
    ``get`` returns the host-resident object and defers the host -> device
    move to the consumer's first jax op (or an explicit ``sharding=``).  The
    service is **durable across producer instance death** (the baseline
    premise of through-storage designs) and can be shared by every engine in
    a cluster so consumers on other instances resolve the same keys.

``hybrid``
    Two-tier through-storage: objects below ``net.hybrid_small_cutoff`` are
    priced/modeled as cache (ElastiCache), larger ones as object storage
    (S3) — the classic cost/latency compromise the paper's taxonomy
    describes.  Functionally identical to the other service backends.

Every backend records *modeled* transfer seconds (what the transfer would
cost on the calibrated cluster) plus the cost-model accounting, so examples
and benchmarks report latency and $ per transfer without real AWS.  All
accounting timestamps go through the injected :class:`~repro.core.clock`
clock, so an engine owned by a virtual-time workflow engine integrates
GB-seconds in simulated time.

Per-object routing: ``put(obj, backend="s3")`` overrides the engine default
for one object (the DAG layer's per-edge policies resolve the medium at send
time); the chosen medium is sealed inside the ref so ``get`` dispatches to
it directly, and per-medium op counts accumulate in ``media_acct`` so
:func:`repro.core.cost.routed_workflow_cost` can price a mixed-backend run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, ClassVar, Dict, Optional, Tuple, Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from .buffers import (
    _E_NBYTES,
    _E_OBJ,
    _E_REMAINING,
    BufferRegistry,
)
from .clock import VirtualClock, ensure_clock
from .cluster import DEFAULT_NET, NetConstants, TransferAccounting
from .cost import marginal_pull_fee_usd
from .errors import (
    InlineTooLarge,
    XDTError,
    XDTObjectExhausted,
    XDTProducerGone,
)
from .registry import Registry
from .refs import (
    _NONCE_LEN,
    ObjectDescriptor,
    RefMinter,
    RefPayload,
    SealedRef,
    XDTRef,
)
from .telemetry import TelemetryHub

Sharding = Any  # jax.sharding.Sharding

_obj_new = object.__new__


def _nbytes(x) -> int:
    """Total bytes of an array or pytree of arrays."""
    nb = getattr(x, "nbytes", None)
    if nb is not None:                    # fast path: a single array
        return int(nb)
    total = 0
    for leaf in jax.tree.leaves(x):
        leaf = jnp.asarray(leaf) if not hasattr(leaf, "nbytes") else leaf
        total += int(leaf.nbytes)
    return total


def _to_host(obj):
    """Host (numpy) view of an array or pytree; zero-copy when already host.

    ``np.asarray`` triggers ``__array__`` — a corrupt object still raises
    here, before any retrieval refcount is consumed."""
    if isinstance(obj, (np.ndarray, jax.Array)):
        return np.asarray(obj)
    return jax.tree.map(np.asarray, obj)


_DTYPE_STR: Dict[Any, str] = {}


def _dtype_str(dt) -> str:
    """Cached ``str(dtype)`` — numpy's dtype name formatting is surprisingly
    expensive and sits on the per-put hot path."""
    s = _DTYPE_STR.get(dt)
    if s is None:
        s = _DTYPE_STR[dt] = str(dt)
    return s


def _describe(obj) -> Tuple[Tuple[int, ...], str]:
    """(shape, dtype-string) for the descriptor; pytrees get a summary."""
    if isinstance(obj, (jax.Array, np.ndarray)):
        return tuple(obj.shape), _dtype_str(obj.dtype)
    return (len(jax.tree.leaves(obj)),), "pytree"


@dataclasses.dataclass
class TransferStats:
    transfers: int = 0
    bytes_moved: int = 0
    modeled_seconds: float = 0.0
    wall_seconds: float = 0.0
    #: pulls that took the co-placement shared-memory path (``get(local=True)``
    #: on an instance-resident medium): modeled at memcpy speed, not the NIC
    local_pulls: int = 0
    #: instance-resident streamed chunk bytes published but not yet fully
    #: retrieved — the sender-side memory a live stream is holding.  Durable
    #: chunks never count (a storage put frees the producer's copy).  The
    #: high-water mark is what credit-based backpressure provably bounds:
    #: with ``Edge(max_inflight_chunks=k)`` it stays <= k * chunk_bytes.
    inflight_chunk_bytes: float = 0.0
    peak_inflight_chunk_bytes: float = 0.0


# ---------------------------------------------------------------------------
# The simulated external storage service (shared per cluster)
# ---------------------------------------------------------------------------


class ServiceStore:
    """Host-resident simulated storage service (the S3/ElastiCache analogue).

    One store per *cluster*, shared by every :class:`TransferEngine` whose
    backend goes through storage: a key minted by the producer's engine
    resolves from any consumer's engine, and — crucially — objects survive
    producer instance death.  Retrieval refcounts free an object after its
    last permitted ``get``; the copy-out happens **before** the refcount is
    decremented so a failed materialization does not leak a retrieval.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = ensure_clock(clock)
        self._objects: Dict[int, Any] = {}
        self._refcount: Dict[int, int] = {}
        self._nbytes: Dict[int, int] = {}
        self._next_key = 0
        # Service-side view of residency/ops (engines keep their own too).
        self.acct = TransferAccounting()

    def put(self, host_obj: Any, n_retrievals: int, nbytes: int) -> int:
        self._next_key += 1
        key = self._next_key
        self._objects[key] = host_obj
        self._refcount[key] = n_retrievals
        self._nbytes[key] = nbytes
        self.acct.n_storage_puts += 1
        self.acct.store(self.clock(), nbytes / 1e9)
        return key

    def fetch(self, key: int) -> Any:
        """Read without consuming a retrieval (consume() after a good copy)."""
        if key not in self._objects:
            raise XDTObjectExhausted(f"service object {key} gone")
        return self._objects[key]

    def consume(self, key: int) -> bool:
        """Burn one retrieval; frees the object on the last one.

        Missing keys raise :class:`XDTObjectExhausted` (never ``KeyError``)
        so cleanup races surface as the documented error.
        """
        if key not in self._refcount:
            raise XDTObjectExhausted(f"service object {key} gone")
        self._refcount[key] -= 1
        self.acct.n_storage_gets += 1
        if self._refcount[key] <= 0:
            nbytes = self._nbytes[key]
            self.acct.free(self.clock(), nbytes / 1e9)
            self._objects.pop(key, None)
            self._refcount.pop(key, None)
            self._nbytes.pop(key, None)
            return True
        return False

    def nbytes_of(self, key: int) -> int:
        return self._nbytes.get(key, 0)

    def __len__(self) -> int:
        return len(self._objects)


# ---------------------------------------------------------------------------
# Backend strategies
# ---------------------------------------------------------------------------


class TransferBackend:
    """One transfer medium: how ``put``/``get`` move bytes, what they model.

    Subclasses implement the storage mechanics; the engine keeps the shared
    concerns (refs, stats, sharding placement, wall timing).  Register new
    media with :func:`register_backend`.
    """

    name: ClassVar[str] = ""
    #: objects survive producer instance death (through-storage services)
    durable: ClassVar[bool] = False

    def __init__(self, engine: "TransferEngine"):
        self.engine = engine

    def put(
        self, obj: Any, n_retrievals: int, nbytes: int,
        block: bool, timeout: Optional[float],
    ) -> Tuple[int, int]:
        """Store ``obj``; return (buffer_id, epoch) for the ref payload."""
        raise NotImplementedError

    def get(self, payload: RefPayload) -> Any:
        """One retrieval; returns the materialized object."""
        raise NotImplementedError

    def on_producer_death(self) -> None:
        """Producer instance died.  Durable backends keep their objects."""

    @classmethod
    def modeled_seconds(cls, nbytes: int, net: NetConstants) -> float:
        """Deterministic producer->consumer latency on the calibrated cluster."""
        raise NotImplementedError


class XDTBackend(TransferBackend):
    """Zero-copy: arrays stay device-resident in the producer's registry."""

    name = "xdt"

    def put(self, obj, n_retrievals, nbytes, block, timeout):
        return self.engine.registry.put(
            obj, n_retrievals, nbytes=nbytes, block=block, timeout=timeout
        )

    def get(self, payload):
        return self.engine.registry.get(payload.buffer_id, payload.epoch)

    @classmethod
    def modeled_seconds(cls, nbytes, net):
        return (
            net.ctrl_plane_latency
            + net.xdt_pull_rtt
            + nbytes / min(net.xdt_stream_bw, net.nic_bw * net.xdt_stream_eff)
        )


class InlineBackend(TransferBackend):
    """Payload rides the control message: 6 MB cap, host staging round-trip."""

    name = "inline"

    def put(self, obj, n_retrievals, nbytes, block, timeout):
        if nbytes > self.engine.inline_limit:
            raise InlineTooLarge(
                f"{nbytes}B exceeds inline cap {self.engine.inline_limit}B"
            )
        return self.engine.registry.put(
            _to_host(obj),                  # staged via control plane (host)
            n_retrievals, nbytes=nbytes, block=block, timeout=timeout,
        )

    def get(self, payload):
        # Host-resident result: device materialization is lazy (the
        # consumer's first jax op — or an explicit ``sharding=`` on
        # ``TransferEngine.get`` — moves the bytes), so the control path
        # never pays a device_put per retrieval.
        return _to_host(self.engine.registry.get(payload.buffer_id, payload.epoch))

    @classmethod
    def modeled_seconds(cls, nbytes, net):
        return net.ctrl_plane_latency + nbytes / net.nic_bw


class _ServiceBackend(TransferBackend):
    """Shared mechanics of through-storage backends: device -> service ->
    consumer (lazy device materialization), durable across producer death,
    exception-safe refcounting."""

    durable = True
    #: this medium's TransferAccounting on the owning engine, bound on first
    #: op (media_acct only lists media that actually performed storage ops)
    _macct: Optional[TransferAccounting] = None

    def put(self, obj, n_retrievals, nbytes, block, timeout):
        # Inlined ServiceStore.put + TransferAccounting.store x3: the
        # through-storage cells of the engine benchmark spend their time in
        # exactly this op pair, so the store/accounting frames are unrolled
        # here (semantics identical to the methods they mirror).
        host = obj if type(obj) is np.ndarray else _to_host(obj)
        eng = self.engine
        svc = eng.service
        svc._next_key = key = svc._next_key + 1
        svc._objects[key] = host
        svc._refcount[key] = n_retrievals
        svc._nbytes[key] = nbytes
        vs = eng._vsim
        now = eng.clock() if vs is None else vs.now
        gb = nbytes / 1e9
        macct = self._macct
        if macct is None:
            macct = self._macct = eng._acct_for(self.name)
        for acct in (svc.acct, eng.acct, macct):
            acct.n_storage_puts += 1
            acct.storage_gb_seconds += acct._resident_gb * (now - acct._last_t)
            acct._last_t = now
            resident = acct._resident_gb = acct._resident_gb + gb
            if resident > acct.peak_resident_gb:
                acct.peak_resident_gb = resident
        return key, 0

    def get(self, payload):
        eng = self.engine
        svc = eng.service
        key = payload.buffer_id
        host = svc._objects.get(key)
        if host is None:
            raise XDTObjectExhausted(f"service object {key} gone")
        # Materialize BEFORE consuming the retrieval: a corrupt service
        # object must not burn one of the N permitted pulls.  The result
        # stays host-resident; the device copy is lazy (the consumer's first
        # jax op, or an explicit ``sharding=`` on ``TransferEngine.get``).
        obj = host if type(host) is np.ndarray else _to_host(host)
        # inlined ServiceStore.consume + accounting (see put)
        remaining = svc._refcount[key] = svc._refcount[key] - 1
        vs = eng._vsim
        now = eng.clock() if vs is None else vs.now
        macct = self._macct
        if macct is None:
            macct = self._macct = eng._acct_for(self.name)
        svc.acct.n_storage_gets += 1
        freed = remaining <= 0
        if freed:
            nbytes = svc._nbytes[key]
            sacct = svc.acct
            sacct.storage_gb_seconds += (
                sacct._resident_gb * (now - sacct._last_t)
            )
            sacct._last_t = now
            resident = sacct._resident_gb - nbytes / 1e9
            sacct._resident_gb = resident if resident > 0.0 else 0.0
            del svc._objects[key]
            del svc._refcount[key]
            del svc._nbytes[key]
        gb = payload.desc.nbytes / 1e9
        for acct in (eng.acct, macct):
            acct.n_storage_gets += 1
            if freed:
                acct.storage_gb_seconds += (
                    acct._resident_gb * (now - acct._last_t)
                )
                acct._last_t = now
                resident = acct._resident_gb - gb
                acct._resident_gb = resident if resident > 0.0 else 0.0
        return obj


class S3Backend(_ServiceBackend):
    name = "s3"

    @classmethod
    def modeled_seconds(cls, nbytes, net):
        return (
            2 * net.s3_op_latency
            + net.ctrl_plane_latency
            + 2 * nbytes / min(net.s3_stream_bw, net.nic_bw)
        )


class ElastiCacheBackend(_ServiceBackend):
    name = "elasticache"

    @classmethod
    def modeled_seconds(cls, nbytes, net):
        return (
            2 * net.ec_op_latency
            + net.ctrl_plane_latency
            + 2 * nbytes / min(net.ec_stream_bw, net.nic_bw)
        )


class HybridBackend(_ServiceBackend):
    """Two-tier through-storage: cache for small objects, S3 for large.

    Demonstrates that a new medium is one strategy class: it reuses the
    service mechanics wholesale and only redefines the latency model (and,
    in :func:`repro.core.cost.workflow_cost`, the pricing) by object size.
    """

    name = "hybrid"

    @classmethod
    def modeled_seconds(cls, nbytes, net):
        if nbytes < net.hybrid_small_cutoff:
            return ElastiCacheBackend.modeled_seconds(nbytes, net)
        return S3Backend.modeled_seconds(nbytes, net)


_BACKEND_REGISTRY = Registry("backend")


def register_backend(cls: Type[TransferBackend]) -> Type[TransferBackend]:
    """Register a strategy class under ``cls.name`` (idempotent overwrite)."""
    return _BACKEND_REGISTRY.register(cls)


for _cls in (XDTBackend, InlineBackend, S3Backend, ElastiCacheBackend, HybridBackend):
    register_backend(_cls)


def available_backends() -> Tuple[str, ...]:
    return tuple(_BACKEND_REGISTRY)


def modeled_transfer_seconds(
    backend: str, nbytes: int, net: NetConstants = DEFAULT_NET
) -> float:
    """Deterministic latency model for one producer->consumer object move."""
    cls = _BACKEND_REGISTRY.get(backend)
    if cls is None:
        raise ValueError(backend)
    return cls.modeled_seconds(nbytes, net)


#: media whose buffers live on the producer instance — the only ones a
#: co-placed consumer can short-circuit through shared memory (a durable
#: service round-trip is the same whichever node the consumer runs on)
INSTANCE_RESIDENT_MEDIA = ("xdt", "inline")


def local_transfer_seconds(nbytes: int, net: NetConstants = DEFAULT_NET) -> float:
    """Same-node pull: producer buffer -> consumer via shared memory.

    The engine-side counterpart of :meth:`ServerlessCluster.local_pull` —
    the modeled latency charged when the graph optimizer co-placed the
    consumer on its producer's node and the object rides an
    instance-resident medium."""
    return net.local_rtt + nbytes / net.local_bw


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class TransferEngine:
    """One producer-side endpoint of the XDT substrate."""

    #: the paper's §2.3 taxonomy; the full set is `available_backends()`
    BACKENDS = ("xdt", "inline", "s3", "elasticache")

    def __init__(
        self,
        backend: str = "xdt",
        *,
        producer_coords: Tuple[int, ...] = (0,),
        registry: Optional[BufferRegistry] = None,
        minter: Optional[RefMinter] = None,
        net: NetConstants = DEFAULT_NET,
        inline_limit: Optional[int] = None,
        service: Optional[ServiceStore] = None,
        clock: Optional[Callable[[], float]] = None,
        telemetry: Union[TelemetryHub, None, bool] = None,
        wall_timing: bool = False,
    ):
        if backend not in _BACKEND_REGISTRY:
            raise ValueError(
                f"backend must be one of {available_backends()}"
            )
        self.backend = backend
        self.producer_coords = producer_coords
        self.clock = ensure_clock(clock)
        self.registry = (
            registry if registry is not None else BufferRegistry(clock=self.clock)
        )
        self.minter = minter if minter is not None else RefMinter()
        self.net = net
        self.inline_limit = (
            net.inline_limit if inline_limit is None else inline_limit
        )
        self.stats = TransferStats()
        #: wall-clock put/get timing is diagnostic-only and costs two
        #: ``perf_counter`` calls per op on the hot path; opt in when needed.
        self._wall_timing = wall_timing
        self.acct = TransferAccounting()
        #: per-medium accounting for through-storage ops, so a mixed-backend
        #: (per-edge routed) run can be priced by each medium's fee structure
        #: (:func:`repro.core.cost.routed_workflow_cost`).  Only media that
        #: actually performed storage ops appear here.
        self.media_acct: Dict[str, TransferAccounting] = {}
        # the simulated external service; pass one in to share it cluster-wide
        self.service = service if service is not None else ServiceStore(self.clock)
        self._backend = _BACKEND_REGISTRY[backend](self)
        # per-engine strategy instances: the default plus any media used via
        # the per-call ``backend=`` override (all share registry/service/acct)
        self._strategies: Dict[str, TransferBackend] = {backend: self._backend}
        # (medium, nbytes) -> modeled seconds and (medium, nbytes,
        # n_retrievals) -> marginal pull fee: net constants and prices are
        # fixed per engine and workloads reuse a handful of object shapes,
        # so the per-get model/fee evaluation collapses to dict hits
        self._modeled_cache: Dict[Tuple[str, int], float] = {}
        self._fee_cache: Dict[Tuple[str, int, int], float] = {}
        # (shape, dtype, nbytes, n_retrievals) -> shared ObjectDescriptor:
        # sweeps reuse a handful of object shapes, so descriptor construction
        # on the fused put path collapses to a dict hit
        self._desc_cache: Dict[tuple, ObjectDescriptor] = {}
        #: fused hot path precondition: the default medium is producer-local
        #: xdt AND the registry is in single-owner mode — then put/get may
        #: inline the registry's unlocked bookkeeping (the registry stays the
        #: owner of the semantics; this is the same code, one frame deep)
        self._fast_single_owner = (
            type(self._backend) is XDTBackend and not self.registry._threadsafe
        )
        #: fused hot path precondition for through-storage media: the default
        #: medium is a service backend that did NOT override the shared
        #: mechanics — put/get may then inline the ServiceStore + accounting
        #: bookkeeping (same ops, no strategy or describe/mint frames)
        cls = type(self._backend)
        self._fast_service = (
            isinstance(self._backend, _ServiceBackend)
            and cls.put is _ServiceBackend.put
            and cls.get is _ServiceBackend.get
        )
        #: under a VirtualClock, "read the clock" is one attribute load off
        #: the simulator — the fused paths skip the ``__call__`` frame
        self._vsim = self.clock.sim if type(self.clock) is VirtualClock else None
        #: per-medium observed latency/cost/bytes feed — the shared substrate
        #: AdaptiveRoute (and anything else) reads; when set, every ``get``
        #: records the pull's modeled seconds and its marginal fee share
        #: (the one-time put/capacity fee apportioned across the object's
        #: permitted retrievals, so an N-consumer broadcast object is not
        #: observed as N puts).  Off by default so the legacy single-backend
        #: hot path pays nothing for the observe side; pass ``True`` (or a
        #: hub to share) to opt in — ``dag.bind`` switches it on
        #: automatically when an :class:`~repro.core.dag.AdaptiveRoute`
        #: needs the feed.
        self.telemetry: Optional[TelemetryHub] = (
            TelemetryHub(self.clock) if telemetry is True
            else telemetry if isinstance(telemetry, TelemetryHub)
            else None
        )
        #: fault-injection hooks (``core.faults``).  Both stay falsy/None
        #: unless a non-empty FaultPlan is installed, so the no-fault paths
        #: below reduce to one dict truthiness test / one ``is None`` test
        #: and results stay bit-identical to a build without the harness.
        #: ``_degraded`` maps medium -> bandwidth-cut slowdown multiplier
        #: (>= 1.0) applied OUTSIDE ``_modeled_cache`` (the cache keeps base
        #: values, so closing a degradation window needs no cache flush).
        self._degraded: Dict[str, float] = {}
        #: called as ``penalty(medium, nbytes, exc)`` when a strategy get
        #: raises; may return a replacement ``XDTError`` (e.g. reclassify
        #: :class:`~repro.core.errors.XDTProducerGone` as ``Evicted`` during
        #: an eviction storm) or ``None`` to re-raise the original.
        self._fault_penalty: Optional[
            Callable[[str, int, XDTError], Optional[XDTError]]
        ] = None

    # ----------------------------------------------------- medium dispatch
    def _acct_for(self, medium: str) -> TransferAccounting:
        acct = self.media_acct.get(medium)
        if acct is None:
            acct = self.media_acct[medium] = TransferAccounting()
        return acct

    def _strategy(self, medium: str) -> TransferBackend:
        strat = self._strategies.get(medium)
        if strat is None:
            cls = _BACKEND_REGISTRY.get(medium)
            if cls is None:
                raise ValueError(
                    f"backend must be one of {available_backends()}, got {medium!r}"
                )
            strat = self._strategies[medium] = cls(self)
        return strat

    # ------------------------------------------------------------------ put
    def put(
        self,
        obj: jax.Array,
        n_retrievals: int = 1,
        *,
        block: bool = True,
        timeout: Optional[float] = None,
        backend: Optional[str] = None,
    ) -> XDTRef:
        """Buffer ``obj`` (array or pytree) and mint a reference permitting
        ``n_retrievals`` pulls.

        ``backend`` overrides the engine's default medium for this one object
        (per-edge routing): the chosen medium is sealed inside the ref, so
        ``get`` dispatches to the same medium with no side-channel state.
        """
        if backend is None and self._fast_single_owner and not self._wall_timing:
            nb = getattr(obj, "nbytes", None)
            if nb is not None and n_retrievals >= 1:
                # fused put: single array -> unlocked registry -> sealed ref,
                # with no strategy/registry/minter frames in between
                nbytes = int(nb)
                reg = self.registry
                if (
                    len(reg._entries) < reg._max_slots
                    and (reg._bytes + nbytes <= reg._max_bytes
                         or not reg._entries)
                ):
                    buffer_id = reg._next_id
                    reg._next_id = buffer_id + 1
                    reg._entries[buffer_id] = [
                        obj, nbytes, n_retrievals, reg._epoch,
                        vs.now if (vs := self._vsim) is not None
                        else reg._clock(),
                    ]
                    b = reg._bytes = reg._bytes + nbytes
                    if b > reg._high_water:
                        reg._high_water = b
                    reg._puts += 1
                else:                  # no room: the raising path stays shared
                    buffer_id, _ = reg._put_unlocked(
                        obj, n_retrievals, nbytes, block
                    )
                dkey = (obj.shape, obj.dtype, nbytes, n_retrievals)
                desc = self._desc_cache.get(dkey)
                if desc is None:
                    desc = self._desc_cache[dkey] = ObjectDescriptor(
                        shape=tuple(obj.shape),
                        dtype=_dtype_str(obj.dtype),
                        nbytes=nbytes,
                        n_retrievals=n_retrievals,
                    )
                m = self.minter
                m._nonce_counter = nonce = m._nonce_counter + 1
                # SealedRef via object.__new__ + direct stores: the same four
                # assignments its __init__ performs, minus the call frame
                ref = _obj_new(SealedRef)
                ref._minter = m
                ref._payload = RefPayload(
                    self.producer_coords, buffer_id, reg._epoch, desc, "xdt",
                )
                ref._nonce = nonce.to_bytes(_NONCE_LEN, "big")
                ref._sealed = None
                return ref
        elif (
            backend is None and self._fast_service and not self._wall_timing
        ):
            nb = getattr(obj, "nbytes", None)
            if nb is not None and n_retrievals >= 1:
                # fused through-storage put: inlined ServiceStore.put +
                # TransferAccounting.store x3 + cached descriptor + sealed
                # ref, with no strategy/describe/mint frames in between
                # (semantics identical to _ServiceBackend.put + mint)
                nbytes = int(nb)
                host = obj if type(obj) is np.ndarray else _to_host(obj)
                svc = self.service
                svc._next_key = bid = svc._next_key + 1
                svc._objects[bid] = host
                svc._refcount[bid] = n_retrievals
                svc._nbytes[bid] = nbytes
                vs = self._vsim
                now = self.clock() if vs is None else vs.now
                gb = nbytes / 1e9
                b = self._backend
                macct = b._macct
                if macct is None:
                    macct = b._macct = self._acct_for(b.name)
                a = svc.acct
                a.n_storage_puts += 1
                a.storage_gb_seconds += a._resident_gb * (now - a._last_t)
                a._last_t = now
                r = a._resident_gb = a._resident_gb + gb
                if r > a.peak_resident_gb:
                    a.peak_resident_gb = r
                a = self.acct
                a.n_storage_puts += 1
                a.storage_gb_seconds += a._resident_gb * (now - a._last_t)
                a._last_t = now
                r = a._resident_gb = a._resident_gb + gb
                if r > a.peak_resident_gb:
                    a.peak_resident_gb = r
                a = macct
                a.n_storage_puts += 1
                a.storage_gb_seconds += a._resident_gb * (now - a._last_t)
                a._last_t = now
                r = a._resident_gb = a._resident_gb + gb
                if r > a.peak_resident_gb:
                    a.peak_resident_gb = r
                dkey = (obj.shape, obj.dtype, nbytes, n_retrievals)
                desc = self._desc_cache.get(dkey)
                if desc is None:
                    desc = self._desc_cache[dkey] = ObjectDescriptor(
                        shape=tuple(obj.shape),
                        dtype=_dtype_str(obj.dtype),
                        nbytes=nbytes,
                        n_retrievals=n_retrievals,
                    )
                m = self.minter
                m._nonce_counter = nonce = m._nonce_counter + 1
                ref = _obj_new(SealedRef)
                ref._minter = m
                ref._payload = RefPayload(
                    self.producer_coords, bid, 0, desc, self.backend,
                )
                ref._nonce = nonce.to_bytes(_NONCE_LEN, "big")
                ref._sealed = None
                return ref
        strat = self._backend if backend is None else self._strategy(backend)
        nbytes = _nbytes(obj)
        if self._wall_timing:
            t0 = time.perf_counter()
            buffer_id, epoch = strat.put(obj, n_retrievals, nbytes, block, timeout)
            self.stats.wall_seconds += time.perf_counter() - t0
        else:
            buffer_id, epoch = strat.put(obj, n_retrievals, nbytes, block, timeout)
        shape, dtype = _describe(obj)
        return self.minter.mint(
            RefPayload(
                producer=self.producer_coords,
                buffer_id=buffer_id,
                epoch=epoch,
                desc=ObjectDescriptor(
                    shape=shape,
                    dtype=dtype,
                    nbytes=nbytes,
                    n_retrievals=n_retrievals,
                ),
                medium=strat.name,
            )
        )

    # ------------------------------------------------------------------ get
    def get(
        self,
        ref: XDTRef,
        sharding: Optional[Sharding] = None,
        local: bool = False,
    ) -> jax.Array:
        """One retrieval.  Moves the object directly to the consumer sharding.

        ``local=True`` declares that this consumer runs on the producer's
        node (the graph optimizer's co-placement hint was honored by the
        scheduler): instance-resident media (xdt/inline) are then modeled at
        shared-memory speed instead of the NIC path.  Durable service media
        ignore the hint — the storage round-trip is node-independent.
        """
        minter = self.minter
        if type(ref) is SealedRef and ref._minter is minter:
            payload = ref._payload     # same-domain fast open (no crypto)
        else:
            payload = minter.open(ref)  # raises XDTRefInvalid on forgery
        nbytes = payload.desc.nbytes
        medium = payload.medium or self.backend
        if (
            medium == "xdt"
            and self._fast_single_owner
            and not local
            and sharding is None
            and not self._wall_timing
        ):
            # fused get: unlocked registry retrieval + cached latency model,
            # no strategy dispatch (mirrors BufferRegistry.get exactly)
            reg = self.registry
            if payload.epoch != reg._epoch:
                raise XDTProducerGone(
                    f"producer epoch {payload.epoch} superseded by {reg._epoch}"
                )
            entry = reg._entries.get(payload.buffer_id)
            if entry is None:
                raise XDTObjectExhausted(
                    f"buffer {payload.buffer_id} not resident"
                )
            obj = entry[_E_OBJ]
            entry[_E_REMAINING] = remaining = entry[_E_REMAINING] - 1
            reg._gets += 1
            if remaining == 0:
                reg._bytes -= entry[_E_NBYTES]
                del reg._entries[payload.buffer_id]
            stats = self.stats
            stats.transfers += 1
            stats.bytes_moved += nbytes
            key = ("xdt", nbytes)
            modeled = self._modeled_cache.get(key)
            if modeled is None:
                modeled = self._modeled_cache[key] = (
                    XDTBackend.modeled_seconds(nbytes, self.net)
                )
            stats.modeled_seconds += modeled
            if self.telemetry is not None:
                n = payload.desc.n_retrievals or 1
                fkey = ("xdt", nbytes, n)
                fee = self._fee_cache.get(fkey)
                if fee is None:
                    fee = self._fee_cache[fkey] = (
                        marginal_pull_fee_usd("xdt", nbytes, n)
                    )
                self.telemetry.record_transfer("xdt", nbytes, modeled, fee)
            return obj
        if (
            self._fast_service
            and medium == self.backend
            and sharding is None
            and not self._wall_timing
        ):
            # fused through-storage get: inlined ServiceStore fetch/consume +
            # accounting + cached latency model — mirrors _ServiceBackend.get
            # exactly (service media ignore the co-placement hint: the
            # storage round-trip is node-independent)
            svc = self.service
            bid = payload.buffer_id
            host = svc._objects.get(bid)
            if host is None:
                raise XDTObjectExhausted(f"service object {bid} gone")
            # materialize BEFORE consuming the retrieval (see backend class)
            obj = host if type(host) is np.ndarray else _to_host(host)
            remaining = svc._refcount[bid] = svc._refcount[bid] - 1
            vs = self._vsim
            now = self.clock() if vs is None else vs.now
            b = self._backend
            macct = b._macct
            if macct is None:
                macct = b._macct = self._acct_for(b.name)
            freed = remaining <= 0
            a = svc.acct
            a.n_storage_gets += 1
            if freed:
                a.storage_gb_seconds += a._resident_gb * (now - a._last_t)
                a._last_t = now
                r = a._resident_gb - svc._nbytes[bid] / 1e9
                a._resident_gb = r if r > 0.0 else 0.0
                del svc._objects[bid]
                del svc._refcount[bid]
                del svc._nbytes[bid]
            gb = nbytes / 1e9
            a = self.acct
            a.n_storage_gets += 1
            if freed:
                a.storage_gb_seconds += a._resident_gb * (now - a._last_t)
                a._last_t = now
                r = a._resident_gb - gb
                a._resident_gb = r if r > 0.0 else 0.0
            a = macct
            a.n_storage_gets += 1
            if freed:
                a.storage_gb_seconds += a._resident_gb * (now - a._last_t)
                a._last_t = now
                r = a._resident_gb - gb
                a._resident_gb = r if r > 0.0 else 0.0
            stats = self.stats
            stats.transfers += 1
            stats.bytes_moved += nbytes
            mkey = (medium, nbytes)
            modeled = self._modeled_cache.get(mkey)
            if modeled is None:
                modeled = self._modeled_cache[mkey] = (
                    b.modeled_seconds(nbytes, self.net)
                )
            stats.modeled_seconds += modeled
            if self.telemetry is not None:
                n = payload.desc.n_retrievals or 1
                fkey = (medium, nbytes, n)
                fee = self._fee_cache.get(fkey)
                if fee is None:
                    fee = self._fee_cache[fkey] = (
                        marginal_pull_fee_usd(medium, nbytes, n)
                    )
                self.telemetry.record_transfer(medium, nbytes, modeled, fee)
            return obj
        strat = (
            self._backend if medium == self.backend else self._strategy(medium)
        )
        local = local and medium in INSTANCE_RESIDENT_MEDIA
        if self._fault_penalty is not None:
            # fault plan installed: give the injector a chance to reclassify
            # the failure (wall timing is diagnostic-only and moot under
            # injected faults, so this branch skips it)
            try:
                obj = strat.get(payload)
            except XDTError as e:
                repl = self._fault_penalty(medium, nbytes, e)
                if repl is not None and repl is not e:
                    raise repl from e
                raise
        elif self._wall_timing:
            t0 = time.perf_counter()
            obj = strat.get(payload)
            self.stats.wall_seconds += time.perf_counter() - t0
        else:
            obj = strat.get(payload)

        if sharding is not None:
            obj = (
                jax.device_put(obj, sharding)
                if isinstance(obj, (jax.Array, np.ndarray))
                else jax.tree.map(lambda v: jax.device_put(v, sharding), obj)
            )

        stats = self.stats
        stats.transfers += 1
        stats.bytes_moved += nbytes
        key = ("local", nbytes) if local else (medium, nbytes)
        modeled = self._modeled_cache.get(key)
        if modeled is None:
            modeled = self._modeled_cache[key] = (
                local_transfer_seconds(nbytes, self.net) if local
                else strat.modeled_seconds(nbytes, self.net)
            )
        if self._degraded and not local:
            # degradation window: bandwidth cut inflates the modeled pull
            # (co-placed shared-memory copies are unaffected by a NIC/medium
            # throttle, hence the ``not local`` guard)
            modeled *= self._degraded.get(medium, 1.0)
        if local:
            stats.local_pulls += 1
        stats.modeled_seconds += modeled
        # co-placed pulls never feed the medium's telemetry: a shared-memory
        # copy says nothing about the medium's cross-node latency, and one
        # memcpy sample in the xdt p99 window would let AdaptiveRoute route
        # NON-co-placed edges against a budget the NIC path cannot meet
        if self.telemetry is not None and not local:
            n = payload.desc.n_retrievals or 1
            fkey = (medium, nbytes, n)
            fee = self._fee_cache.get(fkey)
            if fee is None:
                fee = self._fee_cache[fkey] = (
                    marginal_pull_fee_usd(medium, nbytes, n)
                )
            self.telemetry.record_transfer(medium, nbytes, modeled, fee)
        return obj

    # ------------------------------------------------------- chunk protocol
    def _credit_storage_requests(
        self, medium: str, *, puts: int = 0, gets: int = 0
    ) -> None:
        """Roll back storage *request* counts on the op that just billed
        them (service store + engine + per-medium accounting): chunks of one
        streamed logical object share a single multipart-upload PUT and a
        single ranged GET per medium, so only the first chunk's requests
        stand.  Residency (gb-seconds) and modeled seconds stay per chunk —
        bytes really are stored and moved chunk by chunk."""
        for a in (self.service.acct, self.acct, self._acct_for(medium)):
            a.n_storage_puts -= puts
            a.n_storage_gets -= gets

    def _track_chunk_published(self, nbytes: int) -> None:
        """One instance-resident chunk now held by the producer side."""
        s = self.stats
        s.inflight_chunk_bytes = f = s.inflight_chunk_bytes + nbytes
        if f > s.peak_inflight_chunk_bytes:
            s.peak_inflight_chunk_bytes = f

    def _track_chunk_consumed(self, nbytes: int, n_retrievals: int) -> None:
        """One retrieval of an instance-resident chunk: a broadcast chunk's
        bytes release fractionally, fully freed after its last consumer."""
        s = self.stats
        f = s.inflight_chunk_bytes - nbytes / (n_retrievals or 1)
        s.inflight_chunk_bytes = f if f > 0.0 else 0.0

    def put_chunk(
        self,
        obj: jax.Array,
        n_retrievals: int = 1,
        *,
        backend: Optional[str] = None,
        bill_put: bool = True,
    ) -> XDTRef:
        """Register one chunk of a streamed logical object.

        A chunk is an ordinary ref on ``backend`` — consumers pull it with
        :meth:`get_chunk`, producer death drops un-pulled instance-resident
        chunks exactly like whole objects (:class:`XDTProducerGone` drives
        the engine's retry path).  ``bill_put=False`` marks a continuation
        chunk of an object whose first chunk already billed the storage PUT
        request on this medium (multipart-upload semantics): the request
        count is credited back while residency stays per chunk.

        ``backend="inline"`` is refused: a chunk outlives the sync handoff
        message it would have to ride (the same reason staged/external
        objects can't inline).
        """
        medium = self.backend if backend is None else backend
        if medium == "inline":
            raise ValueError(
                "streaming chunks cannot ride 'inline': a chunk outlives "
                "the sync handoff message"
            )
        ref = self.put(obj, n_retrievals, backend=backend)
        if not bill_put and isinstance(self._strategy(medium), _ServiceBackend):
            self._credit_storage_requests(medium, puts=1)
        if medium in INSTANCE_RESIDENT_MEDIA:
            self._track_chunk_published(
                ref._payload.desc.nbytes
                if type(ref) is SealedRef and ref._minter is self.minter
                else self.minter.open(ref).desc.nbytes
            )
        return ref

    def get_chunk(
        self,
        ref: XDTRef,
        *,
        local: bool = False,
        bill_get: bool = False,
    ) -> jax.Array:
        """One chunk retrieval (see :meth:`put_chunk`).

        ``bill_get=True`` marks the first chunk a consumer pulls from a
        given (object, medium) pair — that one keeps its storage GET
        request; continuation chunks ride the same ranged GET and credit
        the request count back.  Continuation chunks also shed the
        per-request latency overhead from the modeled pull time (the
        connection is already open; only the marginal stream time of the
        extra bytes remains) — mirroring the cluster lowering, which
        coalesces a batch of ready chunks into one request per medium."""
        before = self.stats.modeled_seconds
        if type(ref) is SealedRef and ref._minter is self.minter:
            payload = ref._payload
        else:
            payload = self.minter.open(ref)
        medium = payload.medium or self.backend
        obj = self.get(ref, local=local)
        if not bill_get:
            if isinstance(self._strategy(medium), _ServiceBackend):
                self._credit_storage_requests(medium, gets=1)
            delta = self.stats.modeled_seconds - before
            overhead = modeled_transfer_seconds(medium, 0, self.net)
            if overhead > 0.0 and delta > 0.0:
                self.stats.modeled_seconds -= min(overhead, delta)
        if medium in INSTANCE_RESIDENT_MEDIA:
            self._track_chunk_consumed(
                payload.desc.nbytes, payload.desc.n_retrievals
            )
        return obj

    def put_chunk_span(
        self,
        obj: jax.Array,
        count: int,
        n_retrievals: int = 1,
        *,
        backend: Optional[str] = None,
        bill_put: bool = True,
    ) -> list:
        """Mint ``count`` chunk refs for one same-instant span of a streamed
        object — the producer-side half of the coalesced chunk-event path.

        Every chunk of the span carries the same payload ``obj`` (a span is
        a run of equal-size chunks published at one virtual instant), the
        descriptor is built once and shared columnar across the refs, and
        the storage-request crediting happens once for the whole span
        instead of per chunk.  Accounting, residency, and per-chunk float
        ops are bit-for-bit what ``count`` scalar :meth:`put_chunk` calls
        produce; only the first chunk bills the PUT request (and only when
        ``bill_put=True`` — multipart-upload semantics)."""
        if count <= 0:
            return []
        medium = self.backend if backend is None else backend
        if medium == "inline":
            raise ValueError(
                "streaming chunks cannot ride 'inline': a chunk outlives "
                "the sync handoff message"
            )
        nb = getattr(obj, "nbytes", None)
        if (
            medium == "xdt"
            and self._fast_single_owner
            and not self._wall_timing
            and nb is not None
            and n_retrievals >= 1
        ):
            # fused span put: one descriptor, one nonce counter walk, no
            # strategy/minter frames — mirrors the scalar fused xdt put
            nbytes = int(nb)
            reg = self.registry
            vs = self._vsim
            dkey = (obj.shape, obj.dtype, nbytes, n_retrievals)
            desc = self._desc_cache.get(dkey)
            if desc is None:
                desc = self._desc_cache[dkey] = ObjectDescriptor(
                    shape=tuple(obj.shape),
                    dtype=_dtype_str(obj.dtype),
                    nbytes=nbytes,
                    n_retrievals=n_retrievals,
                )
            m = self.minter
            coords = self.producer_coords
            epoch = reg._epoch
            entries = reg._entries
            refs = []
            for _ in range(count):
                if (
                    len(entries) < reg._max_slots
                    and (reg._bytes + nbytes <= reg._max_bytes
                         or not entries)
                ):
                    buffer_id = reg._next_id
                    reg._next_id = buffer_id + 1
                    entries[buffer_id] = [
                        obj, nbytes, n_retrievals, epoch,
                        vs.now if vs is not None else reg._clock(),
                    ]
                    b = reg._bytes = reg._bytes + nbytes
                    if b > reg._high_water:
                        reg._high_water = b
                    reg._puts += 1
                else:
                    buffer_id, _ = reg._put_unlocked(
                        obj, n_retrievals, nbytes, True
                    )
                m._nonce_counter = nonce = m._nonce_counter + 1
                ref = _obj_new(SealedRef)
                ref._minter = m
                ref._payload = RefPayload(coords, buffer_id, epoch, desc, "xdt")
                ref._nonce = nonce.to_bytes(_NONCE_LEN, "big")
                ref._sealed = None
                refs.append(ref)
                self._track_chunk_published(nbytes)
            return refs
        if (
            medium == self.backend
            and self._fast_service
            and not self._wall_timing
            and nb is not None
            and n_retrievals >= 1
        ):
            # fused through-storage span put: per-chunk residency floats stay
            # in the loop (bit-identical integration), request billing is
            # credited once for the span's continuation chunks
            nbytes = int(nb)
            host = obj if type(obj) is np.ndarray else _to_host(obj)
            svc = self.service
            vs = self._vsim
            now = self.clock() if vs is None else vs.now
            gb = nbytes / 1e9
            b = self._backend
            macct = b._macct
            if macct is None:
                macct = b._macct = self._acct_for(b.name)
            dkey = (obj.shape, obj.dtype, nbytes, n_retrievals)
            desc = self._desc_cache.get(dkey)
            if desc is None:
                desc = self._desc_cache[dkey] = ObjectDescriptor(
                    shape=tuple(obj.shape),
                    dtype=_dtype_str(obj.dtype),
                    nbytes=nbytes,
                    n_retrievals=n_retrievals,
                )
            m = self.minter
            coords = self.producer_coords
            accts = (svc.acct, self.acct, macct)
            refs = []
            for _ in range(count):
                svc._next_key = bid = svc._next_key + 1
                svc._objects[bid] = host
                svc._refcount[bid] = n_retrievals
                svc._nbytes[bid] = nbytes
                for a in accts:
                    a.n_storage_puts += 1
                    a.storage_gb_seconds += a._resident_gb * (now - a._last_t)
                    a._last_t = now
                    r = a._resident_gb = a._resident_gb + gb
                    if r > a.peak_resident_gb:
                        a.peak_resident_gb = r
                m._nonce_counter = nonce = m._nonce_counter + 1
                ref = _obj_new(SealedRef)
                ref._minter = m
                ref._payload = RefPayload(coords, bid, 0, desc, self.backend)
                ref._nonce = nonce.to_bytes(_NONCE_LEN, "big")
                ref._sealed = None
                refs.append(ref)
            credit = count - 1 if bill_put else count
            if credit:
                self._credit_storage_requests(medium, puts=credit)
            return refs
        # generic media (spilled mid-stream, custom backends, wall timing):
        # the scalar path already carries the exact semantics per chunk
        return [
            self.put_chunk(
                obj, n_retrievals, backend=backend,
                bill_put=bill_put and i == 0,
            )
            for i in range(count)
        ]

    def get_chunk_span(
        self,
        refs,
        *,
        local: bool = False,
        bill_first: bool = False,
        marks: Optional[list] = None,
    ) -> list:
        """Drain one run of same-(object, medium) chunks in a single kernel
        call — the consumer-side half of the coalesced chunk-event path.

        Bit-for-bit equivalent to calling :meth:`get_chunk` per ref (same
        accounting, same float-op order on ``stats.modeled_seconds``, same
        billing coalescing) with the per-chunk call frames, medium dispatch,
        and request crediting hoisted out of the loop.  ``bill_first=True``
        keeps the first ref's storage GET request — the ranged GET for this
        (object, medium) range; continuation refs always credit theirs back
        and shed the per-request latency overhead.

        ``marks`` (when given) receives ``stats.modeled_seconds`` after each
        chunk, letting the caller replay per-chunk debt accrual with the
        exact float-op sequence of the scalar path."""
        if not refs:
            return []
        minter = self.minter
        r0 = refs[0]
        stats = self.stats
        if not (type(r0) is SealedRef and r0._minter is minter):
            out = []
            for i, r in enumerate(refs):
                out.append(
                    self.get_chunk(r, local=local,
                                   bill_get=bill_first and i == 0)
                )
                if marks is not None:
                    marks.append(stats.modeled_seconds)
            return out
        medium = r0._payload.medium or self.backend
        net = self.net
        if (
            medium == "xdt"
            and self._fast_single_owner
            and not local
            and not self._wall_timing
        ):
            reg = self.registry
            entries = reg._entries
            cache = self._modeled_cache
            fees = self._fee_cache
            tel = self.telemetry
            overhead = modeled_transfer_seconds("xdt", 0, net)
            epoch = reg._epoch
            billed = bill_first
            out = []
            for ref in refs:
                payload = ref._payload
                nbytes = payload.desc.nbytes
                before = stats.modeled_seconds
                if payload.epoch != epoch:
                    raise XDTProducerGone(
                        f"producer epoch {payload.epoch} superseded by "
                        f"{epoch}"
                    )
                entry = entries.get(payload.buffer_id)
                if entry is None:
                    raise XDTObjectExhausted(
                        f"buffer {payload.buffer_id} not resident"
                    )
                obj = entry[_E_OBJ]
                entry[_E_REMAINING] = remaining = entry[_E_REMAINING] - 1
                reg._gets += 1
                if remaining == 0:
                    reg._bytes -= entry[_E_NBYTES]
                    del entries[payload.buffer_id]
                stats.transfers += 1
                stats.bytes_moved += nbytes
                key = ("xdt", nbytes)
                modeled = cache.get(key)
                if modeled is None:
                    modeled = cache[key] = (
                        XDTBackend.modeled_seconds(nbytes, net)
                    )
                stats.modeled_seconds += modeled
                if tel is not None:
                    n = payload.desc.n_retrievals or 1
                    fkey = ("xdt", nbytes, n)
                    fee = fees.get(fkey)
                    if fee is None:
                        fee = fees[fkey] = (
                            marginal_pull_fee_usd("xdt", nbytes, n)
                        )
                    tel.record_transfer("xdt", nbytes, modeled, fee)
                if not billed:
                    delta = stats.modeled_seconds - before
                    if overhead > 0.0 and delta > 0.0:
                        stats.modeled_seconds -= min(overhead, delta)
                billed = False
                self._track_chunk_consumed(nbytes, payload.desc.n_retrievals)
                if marks is not None:
                    marks.append(stats.modeled_seconds)
                out.append(obj)
            return out
        if (
            self._fast_service
            and medium == self.backend
            and not self._wall_timing
        ):
            svc = self.service
            objects = svc._objects
            refcount = svc._refcount
            vs = self._vsim
            now = self.clock() if vs is None else vs.now
            b = self._backend
            macct = b._macct
            if macct is None:
                macct = b._macct = self._acct_for(b.name)
            accts = (svc.acct, self.acct, macct)
            cache = self._modeled_cache
            fees = self._fee_cache
            tel = self.telemetry
            overhead = modeled_transfer_seconds(medium, 0, net)
            billed = bill_first
            credit = 0
            out = []
            for ref in refs:
                payload = ref._payload
                nbytes = payload.desc.nbytes
                before = stats.modeled_seconds
                bid = payload.buffer_id
                host = objects.get(bid)
                if host is None:
                    raise XDTObjectExhausted(f"service object {bid} gone")
                obj = host if type(host) is np.ndarray else _to_host(host)
                remaining = refcount[bid] = refcount[bid] - 1
                freed = remaining <= 0
                gb = nbytes / 1e9
                a = svc.acct
                a.n_storage_gets += 1
                if freed:
                    a.storage_gb_seconds += (
                        a._resident_gb * (now - a._last_t)
                    )
                    a._last_t = now
                    r = a._resident_gb - svc._nbytes[bid] / 1e9
                    a._resident_gb = r if r > 0.0 else 0.0
                    del objects[bid]
                    del refcount[bid]
                    del svc._nbytes[bid]
                for a in accts[1:]:
                    a.n_storage_gets += 1
                    if freed:
                        a.storage_gb_seconds += (
                            a._resident_gb * (now - a._last_t)
                        )
                        a._last_t = now
                        r = a._resident_gb - gb
                        a._resident_gb = r if r > 0.0 else 0.0
                stats.transfers += 1
                stats.bytes_moved += nbytes
                mkey = (medium, nbytes)
                modeled = cache.get(mkey)
                if modeled is None:
                    modeled = cache[mkey] = b.modeled_seconds(nbytes, net)
                stats.modeled_seconds += modeled
                if tel is not None:
                    n = payload.desc.n_retrievals or 1
                    fkey = (medium, nbytes, n)
                    fee = fees.get(fkey)
                    if fee is None:
                        fee = fees[fkey] = (
                            marginal_pull_fee_usd(medium, nbytes, n)
                        )
                    tel.record_transfer(medium, nbytes, modeled, fee)
                if not billed:
                    credit += 1
                    delta = stats.modeled_seconds - before
                    if overhead > 0.0 and delta > 0.0:
                        stats.modeled_seconds -= min(overhead, delta)
                billed = False
                if marks is not None:
                    marks.append(stats.modeled_seconds)
                out.append(obj)
            if credit:
                self._credit_storage_requests(medium, gets=credit)
            return out
        out = []
        for i, r in enumerate(refs):
            out.append(
                self.get_chunk(r, local=local, bill_get=bill_first and i == 0)
            )
            if marks is not None:
                marks.append(stats.modeled_seconds)
        return out

    # --------------------------------------------------------------- invoke
    def invoke(
        self,
        handler: Callable[[jax.Array], Any],
        obj: jax.Array,
        *,
        consumer_sharding: Optional[Sharding] = None,
    ) -> Any:
        """Blocking 1-1 call: pass ``obj`` by value to ``handler``.

        The SDK splits the call into control (the ref) + data (the pull) and
        re-joins them at the consumer before the handler runs — paper Fig. 4.
        """
        ref = self.put(obj, n_retrievals=1)
        payload = self.get(ref, sharding=consumer_sharding)
        return handler(payload)

    # ------------------------------------------------------------ lifecycle
    def kill_producer(self) -> int:
        """Producer instance death: drops device buffers, invalidates epochs.

        Objects in durable through-storage services (s3/elasticache/hybrid)
        survive by design — only instance-resident XDT/inline buffers die.
        """
        for strat in self._strategies.values():
            strat.on_producer_death()
        return self.registry.kill_instance()

    # ------------------------------------------------- fault-injection hooks
    # Used by core.faults.FaultInjector; all are exact inverses so closing a
    # degradation window restores the engine bit-for-bit.

    def degrade_medium(self, medium: str, slowdown: float) -> None:
        """Open a bandwidth-cut window: modeled pulls on ``medium`` are
        multiplied by ``slowdown`` (>= 1.0) until :meth:`clear_degraded`."""
        if slowdown > 1.0:
            self._degraded[medium] = float(slowdown)
        else:
            self._degraded.pop(medium, None)

    def clear_degraded(self, medium: Optional[str] = None) -> None:
        """Close a degradation window (all windows when ``medium=None``)."""
        if medium is None:
            self._degraded.clear()
        else:
            self._degraded.pop(medium, None)

    def wrap_medium(
        self, medium: str, wrapper: Callable[["TransferBackend"], "TransferBackend"]
    ) -> "TransferBackend":
        """Swap ``medium``'s strategy for ``wrapper(inner)``; returns the
        inner strategy so the caller can :meth:`unwrap_medium` later.

        This is how a decorator like ``faults.DegradedBackend`` composes
        over *any* registered medium without that medium opting in.
        """
        inner = self._strategy(medium)
        wrapped = wrapper(inner)
        self._strategies[medium] = wrapped
        if medium == self.backend:
            self._backend = wrapped
        return inner

    def unwrap_medium(self, medium: str, inner: "TransferBackend") -> None:
        """Undo :meth:`wrap_medium`: reinstall the saved inner strategy."""
        self._strategies[medium] = inner
        if medium == self.backend:
            self._backend = inner

    def suspend_fast_paths(self) -> Tuple[bool, bool]:
        """Force every get through the strategy dispatch (where the fault
        hooks live) for the duration of an installed plan; returns the saved
        flags for :meth:`resume_fast_paths`."""
        saved = (self._fast_single_owner, self._fast_service)
        self._fast_single_owner = False
        self._fast_service = False
        return saved

    def resume_fast_paths(self, saved: Tuple[bool, bool]) -> None:
        self._fast_single_owner, self._fast_service = saved
