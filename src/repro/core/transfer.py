"""TransferEngine: the XDT API (`invoke`/`put`/`get`) over real ``jax.Array``s.

This is the host-level data plane used by the serving engine and the data
pipeline.  Four backends, mirroring the paper's §2.3 taxonomy:

``xdt``
    The paper's contribution.  ``put`` leaves the array **device-resident in
    its producer sharding** inside the producer's :class:`BufferRegistry`
    (zero copies) and mints an HMAC-signed :class:`XDTRef`.  ``get`` opens the
    ref provider-side and moves the bytes once, directly, to the consumer's
    sharding (``jax.device_put`` here; inside a jitted step graph the same
    pull is a ``collective-permute``, see :mod:`repro.core.patterns`).

``inline``
    The payload rides the control message.  Enforces the 6 MB cap and pays a
    host staging round-trip (the activator path).

``s3`` / ``elasticache``
    Through-storage: device -> host copy into the simulated service, then
    host -> device on ``get``.  Functionally real (the copies happen), with
    latency/cost book-keeping from the calibrated constants so framework-level
    reports stay consistent with the cluster simulator.

Every backend records *modeled* transfer seconds (what the transfer would
cost on the calibrated cluster) plus the cost-model accounting, so examples
and benchmarks can report latency and $ per transfer without real AWS.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .buffers import BufferRegistry
from .cluster import DEFAULT_NET, NetConstants, TransferAccounting
from .errors import InlineTooLarge, XDTRefInvalid
from .refs import ObjectDescriptor, RefMinter, RefPayload, XDTRef

Sharding = Any  # jax.sharding.Sharding


def _nbytes(x) -> int:
    """Total bytes of an array or pytree of arrays."""
    total = 0
    for leaf in jax.tree.leaves(x):
        leaf = jnp.asarray(leaf) if not hasattr(leaf, "nbytes") else leaf
        total += int(leaf.nbytes)
    return total


def _describe(obj) -> Tuple[Tuple[int, ...], str]:
    """(shape, dtype-string) for the descriptor; pytrees get a summary."""
    if isinstance(obj, (jax.Array, np.ndarray)):
        return tuple(obj.shape), str(obj.dtype)
    return (len(jax.tree.leaves(obj)),), "pytree"


@dataclasses.dataclass
class TransferStats:
    transfers: int = 0
    bytes_moved: int = 0
    modeled_seconds: float = 0.0
    wall_seconds: float = 0.0


def modeled_transfer_seconds(
    backend: str, nbytes: int, net: NetConstants = DEFAULT_NET
) -> float:
    """Deterministic latency model for one producer->consumer object move."""
    if backend == "inline":
        return net.ctrl_plane_latency + nbytes / net.nic_bw
    if backend == "s3":
        return (
            2 * net.s3_op_latency
            + net.ctrl_plane_latency
            + 2 * nbytes / min(net.s3_stream_bw, net.nic_bw)
        )
    if backend == "elasticache":
        return (
            2 * net.ec_op_latency
            + net.ctrl_plane_latency
            + 2 * nbytes / min(net.ec_stream_bw, net.nic_bw)
        )
    if backend == "xdt":
        return (
            net.ctrl_plane_latency
            + net.xdt_pull_rtt
            + nbytes / min(net.xdt_stream_bw, net.nic_bw * net.xdt_stream_eff)
        )
    raise ValueError(backend)


class TransferEngine:
    """One producer-side endpoint of the XDT substrate."""

    BACKENDS = ("xdt", "inline", "s3", "elasticache")

    def __init__(
        self,
        backend: str = "xdt",
        *,
        producer_coords: Tuple[int, ...] = (0,),
        registry: Optional[BufferRegistry] = None,
        minter: Optional[RefMinter] = None,
        net: NetConstants = DEFAULT_NET,
        inline_limit: Optional[int] = None,
    ):
        if backend not in self.BACKENDS:
            raise ValueError(f"backend must be one of {self.BACKENDS}")
        self.backend = backend
        self.producer_coords = producer_coords
        self.registry = registry if registry is not None else BufferRegistry()
        self.minter = minter if minter is not None else RefMinter()
        self.net = net
        self.inline_limit = (
            net.inline_limit if inline_limit is None else inline_limit
        )
        self.stats = TransferStats()
        self.acct = TransferAccounting()
        # the simulated external service: key -> host-resident bytes
        self._service_store: Dict[int, np.ndarray] = {}
        self._service_refcount: Dict[int, int] = {}
        self._service_key = 0

    # ------------------------------------------------------------------ put
    def put(
        self,
        obj: jax.Array,
        n_retrievals: int = 1,
        *,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> XDTRef:
        """Buffer ``obj`` (array or pytree) and mint a reference permitting
        ``n_retrievals`` pulls."""
        nbytes = _nbytes(obj)
        t0 = time.perf_counter()

        if self.backend == "xdt":
            # Zero-copy: arrays stay device-resident in producer sharding.
            buffer_id, epoch = self.registry.put(
                obj, n_retrievals, nbytes=nbytes, block=block, timeout=timeout
            )
        elif self.backend == "inline":
            if nbytes > self.inline_limit:
                raise InlineTooLarge(
                    f"{nbytes}B exceeds inline cap {self.inline_limit}B"
                )
            buffer_id, epoch = self.registry.put(
                jax.tree.map(np.asarray, obj),  # staged via control plane (host)
                n_retrievals, nbytes=nbytes, block=block, timeout=timeout,
            )
        else:  # s3 / elasticache: device -> host copy into the service
            host = jax.tree.map(np.asarray, obj)
            self._service_key += 1
            self._service_store[self._service_key] = host
            self._service_refcount[self._service_key] = n_retrievals
            buffer_id, epoch = self._service_key, 0
            self.acct.n_storage_puts += 1
            self.acct.store(time.monotonic(), nbytes / 1e9)

        self.stats.wall_seconds += time.perf_counter() - t0
        shape, dtype = _describe(obj)
        desc = ObjectDescriptor(
            shape=shape,
            dtype=dtype,
            nbytes=nbytes,
            n_retrievals=n_retrievals,
        )
        return self.minter.mint(
            RefPayload(
                producer=self.producer_coords,
                buffer_id=buffer_id,
                epoch=epoch,
                desc=desc,
            )
        )

    # ------------------------------------------------------------------ get
    def get(self, ref: XDTRef, sharding: Optional[Sharding] = None) -> jax.Array:
        """One retrieval.  Moves the object directly to the consumer sharding."""
        payload = self.minter.open(ref)  # raises XDTRefInvalid on forgery
        nbytes = payload.desc.nbytes
        t0 = time.perf_counter()

        if self.backend in ("xdt", "inline"):
            obj = self.registry.get(payload.buffer_id, payload.epoch)
            if self.backend == "inline":
                obj = jax.tree.map(jnp.asarray, obj)
        else:
            from .errors import XDTObjectExhausted

            host = self._service_store.get(payload.buffer_id)
            if host is None:
                raise XDTObjectExhausted(f"service object {payload.buffer_id} gone")
            obj = jax.tree.map(jnp.asarray, host)
            self.acct.n_storage_gets += 1
            self._service_refcount[payload.buffer_id] -= 1
            if self._service_refcount[payload.buffer_id] <= 0:
                # last retrieval frees the service-resident copy
                self.acct.free(time.monotonic(), nbytes / 1e9)
                self._service_store.pop(payload.buffer_id, None)
                self._service_refcount.pop(payload.buffer_id, None)

        if sharding is not None:
            obj = (
                jax.device_put(obj, sharding)
                if isinstance(obj, (jax.Array, np.ndarray))
                else jax.tree.map(lambda v: jax.device_put(v, sharding), obj)
            )

        self.stats.transfers += 1
        self.stats.bytes_moved += nbytes
        self.stats.wall_seconds += time.perf_counter() - t0
        self.stats.modeled_seconds += modeled_transfer_seconds(
            self.backend, nbytes, self.net
        )
        return obj

    # --------------------------------------------------------------- invoke
    def invoke(
        self,
        handler: Callable[[jax.Array], Any],
        obj: jax.Array,
        *,
        consumer_sharding: Optional[Sharding] = None,
    ) -> Any:
        """Blocking 1-1 call: pass ``obj`` by value to ``handler``.

        The SDK splits the call into control (the ref) + data (the pull) and
        re-joins them at the consumer before the handler runs — paper Fig. 4.
        """
        ref = self.put(obj, n_retrievals=1)
        payload = self.get(ref, sharding=consumer_sharding)
        return handler(payload)

    # ------------------------------------------------------------ lifecycle
    def kill_producer(self) -> int:
        """Producer instance death: drops buffers, invalidates epochs."""
        self._service_store.clear()
        return self.registry.kill_instance()
