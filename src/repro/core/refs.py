"""XDT references: unforgeable, opaque capability tokens for ephemeral objects.

Paper §4.2.1: "references are just opaque hashes that do not expose any
information regarding the underlying provider infrastructure, and that can be
neither generated nor manipulated by user code."

The prototype in the paper encrypts ``(pod IP, object key)`` into an HTTP
header.  On a TPU cluster there are no IPs; the topology secret is the
producer's *mesh coordinates* (pod index, data-row, model-column) plus the
buffer id and epoch.  We keep the capability property with an
encrypt-then-MAC construction:

  token = nonce || XOR-keystream(payload) || HMAC-SHA256(key, nonce||ct)

The keystream is HMAC-SHA256(key, nonce || counter) blocks — i.e. a standard
PRF-in-counter-mode cipher built only from :mod:`hashlib`/:mod:`hmac` (no
external crypto dependency).  User code holding a token learns nothing about
mesh layout and cannot mint or modify tokens; the provider-side
:class:`RefMinter` (held by queue-proxy analogues, never by user code) is the
only component able to open them.

A reference also carries the object *descriptor* — (shape, dtype, logical
sharding, nbytes, remaining retrievals N) — because the consumer-side pull
program must be able to allocate / lower the receive buffer before any data
moves.  The descriptor is inside the authenticated envelope.
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac
import json
import os
from typing import Any, Mapping, Optional, Tuple

from .errors import XDTRefInvalid

_MAC_LEN = 16  # truncated HMAC-SHA256 tag
_NONCE_LEN = 12


@dataclasses.dataclass(frozen=True)
class ObjectDescriptor:
    """What the consumer needs to know to pull: layout, not location."""

    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    sharding: Optional[Tuple[Any, ...]] = None  # logical PartitionSpec-like tuple
    n_retrievals: int = 1

    def to_json(self) -> Mapping[str, Any]:
        return {
            "shape": list(self.shape),
            "dtype": self.dtype,
            "nbytes": self.nbytes,
            "sharding": list(self.sharding) if self.sharding is not None else None,
            "n": self.n_retrievals,
        }

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "ObjectDescriptor":
        sh = d.get("sharding")
        return ObjectDescriptor(
            shape=tuple(d["shape"]),
            dtype=d["dtype"],
            nbytes=int(d["nbytes"]),
            sharding=tuple(sh) if sh is not None else None,
            n_retrievals=int(d["n"]),
        )


@dataclasses.dataclass(frozen=True)
class RefPayload:
    """Provider-private contents of a reference (never visible to user code)."""

    producer: Tuple[int, ...]  # mesh coordinates of the producer slice (e.g. (pod, row))
    buffer_id: int
    epoch: int  # producer instance generation; stale epoch => producer gone
    desc: ObjectDescriptor

    def to_bytes(self) -> bytes:
        return json.dumps(
            {
                "p": list(self.producer),
                "b": self.buffer_id,
                "e": self.epoch,
                "d": self.desc.to_json(),
            },
            separators=(",", ":"),
            sort_keys=True,
        ).encode()

    @staticmethod
    def from_bytes(raw: bytes) -> "RefPayload":
        d = json.loads(raw.decode())
        return RefPayload(
            producer=tuple(d["p"]),
            buffer_id=int(d["b"]),
            epoch=int(d["e"]),
            desc=ObjectDescriptor.from_json(d["d"]),
        )


@dataclasses.dataclass(frozen=True)
class XDTRef:
    """The opaque token handed to user code.  Hash-able, JSON-able, inert."""

    token: bytes

    def hex(self) -> str:
        return self.token.hex()

    @staticmethod
    def from_hex(s: str) -> "XDTRef":
        return XDTRef(bytes.fromhex(s))

    def __repr__(self) -> str:  # deliberately reveals nothing but length
        return f"XDTRef(<{len(self.token)} opaque bytes>)"


def _keystream(key: bytes, nonce: bytes, n: int) -> bytes:
    out = bytearray()
    counter = 0
    while len(out) < n:
        out.extend(hmac.new(key, nonce + counter.to_bytes(4, "big"), hashlib.sha256).digest())
        counter += 1
    return bytes(out[:n])


class RefMinter:
    """Provider-side authority that mints and opens :class:`XDTRef` tokens.

    One minter (key) per trust domain — in the prototype this lives inside the
    queue-proxy analogue.  ``open()`` authenticates before decrypting; any
    bit-flip, truncation, or forged token raises :class:`XDTRefInvalid`.
    """

    def __init__(self, key: Optional[bytes] = None, rng: Optional["os.urandom.__class__"] = None):
        self._enc_key = hashlib.sha256(b"enc|" + (key or os.urandom(32))).digest()
        self._mac_key = hashlib.sha256(b"mac|" + (key or self._enc_key)).digest()
        self._nonce_counter = 0

    def _next_nonce(self) -> bytes:
        # Deterministic counter nonce: unique per mint, no RNG needed (keeps
        # the substrate reproducible under test).
        self._nonce_counter += 1
        return self._nonce_counter.to_bytes(_NONCE_LEN, "big")

    def mint(self, payload: RefPayload) -> XDTRef:
        pt = payload.to_bytes()
        nonce = self._next_nonce()
        ct = bytes(a ^ b for a, b in zip(pt, _keystream(self._enc_key, nonce, len(pt))))
        tag = hmac.new(self._mac_key, nonce + ct, hashlib.sha256).digest()[:_MAC_LEN]
        return XDTRef(nonce + ct + tag)

    def open(self, ref: XDTRef) -> RefPayload:
        tok = ref.token
        if len(tok) < _NONCE_LEN + _MAC_LEN + 2:
            raise XDTRefInvalid("token too short")
        nonce, ct, tag = (
            tok[:_NONCE_LEN],
            tok[_NONCE_LEN:-_MAC_LEN],
            tok[-_MAC_LEN:],
        )
        want = hmac.new(self._mac_key, nonce + ct, hashlib.sha256).digest()[:_MAC_LEN]
        if not hmac.compare_digest(tag, want):
            raise XDTRefInvalid("authentication failed")
        pt = bytes(a ^ b for a, b in zip(ct, _keystream(self._enc_key, nonce, len(ct))))
        try:
            return RefPayload.from_bytes(pt)
        except Exception as e:  # pragma: no cover - defensive
            raise XDTRefInvalid(f"malformed payload: {e}")
