"""XDT references: unforgeable, opaque capability tokens for ephemeral objects.

Paper §4.2.1: "references are just opaque hashes that do not expose any
information regarding the underlying provider infrastructure, and that can be
neither generated nor manipulated by user code."

The prototype in the paper encrypts ``(pod IP, object key)`` into an HTTP
header.  On a TPU cluster there are no IPs; the topology secret is the
producer's *mesh coordinates* (pod index, data-row, model-column) plus the
buffer id and epoch.  We keep the capability property with an
encrypt-then-MAC construction:

  token = nonce || XOR-keystream(payload) || HMAC-SHA256(key, nonce||ct)

The keystream is a SHAKE-256 squeeze of (key || nonce) — a keyed XOF used as
a PRF stream cipher, built only from :mod:`hashlib`/:mod:`hmac` (no external
crypto dependency) and one C call per mint on the hot path.  User code holding a token learns nothing about
mesh layout and cannot mint or modify tokens; the provider-side
:class:`RefMinter` (held by queue-proxy analogues, never by user code) is the
only component able to open them.

A reference also carries the object *descriptor* — (shape, dtype, logical
sharding, nbytes, remaining retrievals N) — because the consumer-side pull
program must be able to allocate / lower the receive buffer before any data
moves.  The descriptor is inside the authenticated envelope.
"""
from __future__ import annotations

import hashlib
import hmac
import json
import os
import struct
from typing import Any, NamedTuple, Optional, Tuple

from .errors import XDTRefInvalid

_MAC_LEN = 16  # truncated HMAC-SHA256 tag
_NONCE_LEN = 12
_PAYLOAD_VER = 3
_PAYLOAD_HEADER = struct.calcsize("<BqiqiBBBHB")


class ObjectDescriptor(NamedTuple):
    """What the consumer needs to know to pull: layout, not location.

    A NamedTuple (not a frozen dataclass): immutable either way, but C-speed
    construction — one is minted per put and one per open on the hot path.
    """

    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    sharding: Optional[Tuple[Any, ...]] = None  # logical PartitionSpec-like tuple
    n_retrievals: int = 1


class RefPayload(NamedTuple):
    """Provider-private contents of a reference (never visible to user code)."""

    producer: Tuple[int, ...]  # mesh coordinates of the producer slice (e.g. (pod, row))
    buffer_id: int
    epoch: int  # producer instance generation; stale epoch => producer gone
    desc: ObjectDescriptor
    #: transfer medium that stored the object ("" = the engine's default).
    #: Inside the authenticated envelope so a routed engine can dispatch
    #: ``get()`` per object without a side-channel id->backend map.
    medium: str = ""

    def to_bytes(self) -> bytes:
        """Compact binary envelope (struct-packed, version-tagged).

        The old JSON encoding cost two serializer passes per mint/open on
        the transfer hot path; the payload is provider-private and never
        persisted, so the format only needs to round-trip in-process.
        ``sharding`` (arbitrary PartitionSpec-like values, cold path) keeps
        a JSON side-channel."""
        d = self.desc
        prod = self.producer
        shape = d.shape
        dt = d.dtype.encode()
        med = self.medium.encode()
        shard = (
            b"" if d.sharding is None
            else json.dumps(list(d.sharding), separators=(",", ":")).encode()
        )
        return b"".join((
            struct.pack(
                "<BqiqiBBBHB", _PAYLOAD_VER, self.buffer_id, self.epoch,
                d.nbytes, d.n_retrievals, len(prod), len(shape), len(dt),
                len(shard), len(med),
            ),
            struct.pack(f"<{len(prod)}q", *prod),
            struct.pack(f"<{len(shape)}q", *shape),
            dt,
            shard,
            med,
        ))

    @staticmethod
    def from_bytes(raw: bytes) -> "RefPayload":
        (ver, buffer_id, epoch, nbytes, n_ret, n_prod, n_shape, n_dt, n_shard,
         n_med) = struct.unpack_from("<BqiqiBBBHB", raw)
        if ver != _PAYLOAD_VER:
            raise ValueError(f"unknown payload version {ver}")
        off = _PAYLOAD_HEADER
        prod = struct.unpack_from(f"<{n_prod}q", raw, off)
        off += 8 * n_prod
        shape = struct.unpack_from(f"<{n_shape}q", raw, off)
        off += 8 * n_shape
        dtype = raw[off:off + n_dt].decode()
        off += n_dt
        sharding = (
            None if n_shard == 0
            else tuple(json.loads(raw[off:off + n_shard].decode()))
        )
        off += n_shard
        medium = raw[off:off + n_med].decode()
        return RefPayload(
            producer=prod,
            buffer_id=buffer_id,
            epoch=epoch,
            desc=ObjectDescriptor(
                shape=shape, dtype=dtype, nbytes=nbytes,
                sharding=sharding, n_retrievals=n_ret,
            ),
            medium=medium,
        )


class XDTRef:
    """The opaque token handed to user code.  Hash-able, JSON-able, inert."""

    __slots__ = ("token",)

    def __init__(self, token: bytes):
        self.token = token

    def hex(self) -> str:
        return self.token.hex()

    @staticmethod
    def from_hex(s: str) -> "XDTRef":
        return XDTRef(bytes.fromhex(s))

    def __eq__(self, other) -> bool:
        return isinstance(other, XDTRef) and self.token == other.token

    def __hash__(self) -> int:
        return hash(self.token)

    def __repr__(self) -> str:  # deliberately reveals nothing but length
        return f"XDTRef(<{len(self.token)} opaque bytes>)"


class SealedRef(XDTRef):
    """An :class:`XDTRef` whose token is sealed lazily.

    Minted on the hot path when producer and consumer share one trust domain
    (one :class:`RefMinter`): the payload is cached privately on the ref and
    the encrypt-then-MAC envelope is only computed if some holder actually
    reads ``.token`` (serialisation, forgery attempts, cross-domain opens).
    The capability property is unchanged — the payload attributes are
    name-mangled provider state, and ``open()`` only short-circuits when the
    ref object is the very one this minter issued; anything reconstructed
    from bytes takes the full authenticate-then-decrypt path.
    """

    __slots__ = ("_minter", "_payload", "_nonce", "_sealed")

    def __init__(self, minter: "RefMinter", payload: RefPayload, nonce: bytes):
        self._minter = minter
        self._payload = payload
        self._nonce = nonce
        self._sealed = None

    @property
    def token(self) -> bytes:  # type: ignore[override]
        tok = self._sealed
        if tok is None:
            tok = self._sealed = self._minter._seal(self._payload, self._nonce)
        return tok


def _keystream(key: bytes, nonce: bytes, n: int) -> bytes:
    """PRF keystream: one SHAKE-256 squeeze of ``key || nonce``.

    SHAKE-256 as a XOF keyed by prefix is a standard PRF-as-stream-cipher
    construction; one C call replaces the former per-32-byte-block
    HMAC-SHA256 counter loop on the ref-minting hot path."""
    return hashlib.shake_256(key + nonce).digest(n)


def _xor(data: bytes, ks: bytes) -> bytes:
    """Constant-time-ish whole-buffer XOR (C bigint ops, no Python loop)."""
    n = len(data)
    return (
        int.from_bytes(data, "big") ^ int.from_bytes(ks, "big")
    ).to_bytes(n, "big")


class RefMinter:
    """Provider-side authority that mints and opens :class:`XDTRef` tokens.

    One minter (key) per trust domain — in the prototype this lives inside the
    queue-proxy analogue.  ``open()`` authenticates before decrypting; any
    bit-flip, truncation, or forged token raises :class:`XDTRefInvalid`.
    """

    def __init__(self, key: Optional[bytes] = None, rng: Optional["os.urandom.__class__"] = None):
        self._enc_key = hashlib.sha256(b"enc|" + (key or os.urandom(32))).digest()
        self._mac_key = hashlib.sha256(b"mac|" + (key or self._enc_key)).digest()
        self._nonce_counter = 0

    def _next_nonce(self) -> bytes:
        # Deterministic counter nonce: unique per mint, no RNG needed (keeps
        # the substrate reproducible under test).
        self._nonce_counter += 1
        return self._nonce_counter.to_bytes(_NONCE_LEN, "big")

    def _seal(self, payload: RefPayload, nonce: bytes) -> bytes:
        pt = payload.to_bytes()
        ct = _xor(pt, _keystream(self._enc_key, nonce, len(pt)))
        tag = hmac.digest(self._mac_key, nonce + ct, "sha256")[:_MAC_LEN]
        return nonce + ct + tag

    def mint(self, payload: RefPayload) -> XDTRef:
        # The nonce is reserved eagerly (cheap counter bump, keeps nonce
        # assignment deterministic regardless of when/whether the envelope is
        # ever materialised); the crypto itself is deferred to first token use.
        return SealedRef(self, payload, self._next_nonce())

    def mint_eager(self, payload: RefPayload) -> XDTRef:
        """Mint with the envelope sealed immediately (cross-domain handoff)."""
        nonce = self._next_nonce()
        return XDTRef(self._seal(payload, nonce))

    def open(self, ref: XDTRef) -> RefPayload:
        if type(ref) is SealedRef and ref._minter is self:
            return ref._payload
        tok = ref.token
        if len(tok) < _NONCE_LEN + _MAC_LEN + 2:
            raise XDTRefInvalid("token too short")
        nonce, ct, tag = (
            tok[:_NONCE_LEN],
            tok[_NONCE_LEN:-_MAC_LEN],
            tok[-_MAC_LEN:],
        )
        want = hmac.digest(self._mac_key, nonce + ct, "sha256")[:_MAC_LEN]
        if not hmac.compare_digest(tag, want):
            raise XDTRefInvalid("authentication failed")
        pt = _xor(ct, _keystream(self._enc_key, nonce, len(ct)))
        try:
            return RefPayload.from_bytes(pt)
        except Exception as e:  # pragma: no cover - defensive
            raise XDTRefInvalid(f"malformed payload: {e}")
