"""XDT core: the paper's contribution as a composable JAX substrate.

Layers
------
* :mod:`refs`      — unforgeable capability tokens for ephemeral objects.
* :mod:`buffers`   — producer-side refcounted buffer registry + flow control.
* :mod:`clock`     — the injected time source (real or simulator-driven)
                     shared by scheduler, transfer accounting, and workflows.
* :mod:`transfer`  — the XDT API (invoke/put/get) over jax.Arrays; every
                     medium (xdt / inline / s3 / elasticache / hybrid) is a
                     TransferBackend strategy class over one ServiceStore.
* :mod:`patterns`  — 1-1 / scatter / gather / broadcast as mesh collectives.
* :mod:`telemetry` — shared observe-side substrate: per-deployment arrival/
                     concurrency/cold-start windows and per-medium
                     latency/cost/bytes feeds on the injected clock.
* :mod:`scheduler` — activator/autoscaler control plane (placement first,
                     data second — the XDT separation); scale-up strategies
                     are pluggable AutoscalerPolicy classes (concurrency /
                     rps / predictive).
* :mod:`workflow`  — event-driven function-DAG engine: concurrent requests,
                     overlapping fan-out/fan-in, at-most-once semantics,
                     all on the simulator's virtual clock.
* :mod:`dag`       — declarative workflow graphs (Stage/Edge/WorkflowDAG)
                     with per-edge transfer routing; lowered onto the cluster
                     simulator or compiled onto the workflow engine.
* :mod:`dagopt`    — graph optimizer over the declarative DAG: sync-chain
                     fusion, producer/consumer co-placement, predictive
                     spill to durable media; ``dag.optimize()`` returns the
                     rewritten graph plus a PlacementPlan both lowerings
                     honor.
* :mod:`faults`    — chaos harness: declarative FaultPlans (correlated
                     evictions, per-medium degradation windows, cold-start
                     storms) injected on the virtual clock, with SLOGuard
                     guardrails (bounded retries, availability/p99 budgets,
                     adaptive-beats-static dominance checks).
* :mod:`loadgen`   — closed/open-loop request drivers for throughput and
                     tail-latency sweeps under virtual time, plus the
                     trace-driven multi-tenant frontend (synthetic
                     Azure-shaped arrival traces replayed as batched
                     same-timestamp buckets with per-tenant attribution).
* :mod:`shard`     — deployment-sharded simulation: independent deployment
                     cells (connected components of the shared-media /
                     cross-call interaction graph) advanced on clock-synced
                     epoch barriers across in-process lanes or forked
                     workers, with a deterministic columnar merge.
* :mod:`topology`  — the edge-cloud continuum: node -> zone -> region
                     (-> edge-site) hierarchy behind ``compile(topology=)``;
                     tier crossings carry their own bandwidth/RTT and
                     egress fees, a single-zone topology is bit-identical
                     to the flat cluster.
* :mod:`registry`  — the shared name->class Registry behind
                     register_backend / register_pass / register_autoscaler.
* :mod:`cluster`   — calibrated discrete-event simulator for the paper's
                     latency/bandwidth/cost evaluation.
* :mod:`cost`      — AWS cost model (Table 2).
"""
from .buffers import BufferRegistry, RegistryStats
from .clock import Clock, MonotonicClock, VirtualClock
from .cluster import (
    DEFAULT_NET,
    NetConstants,
    ServerlessCluster,
    Simulator,
    TransferAccounting,
    effective_bandwidth_Bps,
    measure_pattern,
)
from .cost import (
    CostBreakdown,
    StorageOps,
    WorkflowCostInputs,
    combine_cost_inputs,
    cost_per_1k_requests,
    elasticache_storage_cost,
    lambda_compute_cost,
    marginal_pull_fee_usd,
    transfer_fee_usd,
    routed_cost_per_1k_requests,
    routed_workflow_cost,
    s3_storage_cost,
    tenant_bills,
    workflow_cost,
)
from .dag import (
    AdaptiveRoute,
    ClusterRunnable,
    DagBinding,
    Edge,
    FixedRoute,
    RoutePolicy,
    Runnable,
    SizeRoute,
    Stage,
    WorkflowDAG,
    execute_on_cluster,
)
from .dagopt import (
    CoPlacement,
    GraphPass,
    PlacementPlan,
    PredictiveSpill,
    SyncChainFusion,
    available_passes,
    register_pass,
)
from .errors import (
    Evicted,
    InlineTooLarge,
    InvocationReplayed,
    MediumUnavailable,
    RetriesExhausted,
    XDTError,
    XDTObjectExhausted,
    XDTProducerGone,
    XDTRefInvalid,
    XDTTimeout,
    XDTWouldBlock,
)
from .faults import (
    DegradedBackend,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    SLOGuard,
    SLOReport,
    SLOViolation,
)
from .patterns import (
    all_to_all_shard,
    broadcast_shard,
    build_pattern_fn,
    gather_all_shard,
    gather_shard,
    p2p_shard,
    pattern_wire_bytes,
    scatter_shard,
)
from .loadgen import (
    LoadGenerator,
    LoadReport,
    TraceConfig,
    TraceReplayDriver,
    synthesize_trace,
)
from .refs import ObjectDescriptor, RefMinter, RefPayload, XDTRef
from .registry import Registry
from .topology import FLAT_TOPOLOGY, Coord, Topology, Zone, as_coord
from .shard import (
    Cell,
    CellResult,
    GroupSpec,
    MergedRun,
    ShardPlan,
    ShardRunner,
    merge_cell_results,
)
from .workloads import (
    DAGS,
    HYBRID_ROUTE,
    ROUTED_BACKENDS,
    TOPO_DAGS,
    TOPO_WORKLOADS,
    TOPOLOGIES,
    WORKLOADS,
    WorkloadResult,
    run_all,
    run_edge,
    run_geo,
    run_mr,
    run_set,
    run_vid,
)
from .scheduler import (
    AutoscalerPolicy,
    ConcurrencyPolicy,
    ControlPlane,
    Deployment,
    Instance,
    PredictivePolicy,
    RpsPolicy,
    ScalingPolicy,
    available_autoscalers,
    make_autoscaler,
    register_autoscaler,
)
from .telemetry import (
    DeploymentTelemetry,
    MediumTelemetry,
    TelemetryHub,
)
from .transfer import (
    ServiceStore,
    TransferBackend,
    TransferEngine,
    TransferStats,
    available_backends,
    modeled_transfer_seconds,
    register_backend,
)
from .workflow import AsyncResult, Context, WorkflowEngine, WorkflowRequest

__all__ = [k for k in dir() if not k.startswith("_")]
