"""AWS cost model for serverless workflows (paper §6.5.1, Table 2).

Pricing as of 1/1/2023 (the paper's stated snapshot):

* AWS Lambda [13]: $0.20 per 1M invocations + $0.0000166667 per GB-second,
  billed on the configured memory footprint (the paper fixes 512 MB for all
  functions) times the *billed duration* — which includes time the function
  spends stalled on transfers, a key reason slow storage also inflates the
  "compute" column.
* AWS S3 [12]: ~$0.023/GB-month storage (negligible for seconds-lived
  ephemeral objects) — the dominant S3 ephemeral cost is the request fee:
  $0.005 per 1k PUT, $0.0004 per 1k GET.
* AWS ElastiCache [11]: ~$0.02 per GB-hour of cache capacity with instance-
  hour granularity: capacity must be provisioned for the peak resident
  ephemeral set and is billed per hour even if the data lives seconds.  No
  per-request fee.
* XDT: no storage service at all — only compute (the producer's keep-alive
  memory already exists; buffering adds no billable resource).

The model reproduces the paper's Table 2 structure: per-invocation cost split
into compute and storage for S3 / ElastiCache / XDT configurations.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


# --- pricing constants (USD, AWS us-west-1-ish, 1/1/2023 snapshot) ----------
LAMBDA_INVOCATION_USD = 0.20 / 1e6          # per request
LAMBDA_GBS_USD = 0.0000166667               # per GB-second
S3_PUT_USD = 0.005 / 1e3                    # per PUT/COPY/POST/LIST
S3_GET_USD = 0.0004 / 1e3                   # per GET
S3_GB_MONTH_USD = 0.023                     # per GB-month (prorated)
EC_GB_HOUR_USD = 0.02                       # per GB-hour, hour granularity
SECONDS_PER_MONTH = 30 * 24 * 3600.0

DEFAULT_FUNCTION_MEM_GB = 0.5               # paper: 512 MB for all functions

# --- cross-tier egress fees (USD/GB, AWS-shaped) -----------------------------
# A pull's price depends on the lowest common tier of producer and consumer
# (crossing levels from :mod:`repro.core.topology`): traffic inside a node or
# a zone is free, inter-AZ traffic pays per GB in each direction, WAN between
# regions pays more, and the edge<->cloud uplink is priciest (metered cellular
# / leased-line shaped).  Indexed by crossing level 0..4.
TIER_EGRESS_USD_PER_GB = (0.0, 0.0, 0.01, 0.02, 0.09)


def egress_fee_usd(level: int, nbytes: int) -> float:
    """Cross-tier egress fee of moving ``nbytes`` across ``level`` — the
    crossing level of producer and consumer (0 same-node .. 4 edge<->cloud).
    Levels beyond the table clamp to the top (edge) rate."""
    if level <= 1:
        return 0.0
    rate = TIER_EGRESS_USD_PER_GB[min(level, len(TIER_EGRESS_USD_PER_GB) - 1)]
    return (nbytes / 1e9) * rate


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Per-invocation cost, USD.

    ``egress`` is the cross-tier transfer column (zero on a flat cluster);
    it is kept separate from ``storage`` so Table-2 style comparisons stay
    comparable with the committed flat-topology numbers.
    """

    compute: float
    storage: float
    egress: float = 0.0

    @property
    def total(self) -> float:
        return self.compute + self.storage + self.egress

    def scaled(self, k: float) -> "CostBreakdown":
        return CostBreakdown(self.compute * k, self.storage * k, self.egress * k)

    def as_micro_usd(self) -> Dict[str, float]:
        out = {
            "compute_uUSD": self.compute * 1e6,
            "storage_uUSD": self.storage * 1e6,
            "total_uUSD": self.total * 1e6,
        }
        if self.egress:
            out["egress_uUSD"] = self.egress * 1e6
        return out


def lambda_compute_cost(
    billed_duration_s: float,
    n_invocations: int,
    mem_gb: float = DEFAULT_FUNCTION_MEM_GB,
) -> float:
    """Compute cost: invocation fee + GB-seconds over *billed* duration."""
    return (
        n_invocations * LAMBDA_INVOCATION_USD
        + billed_duration_s * mem_gb * LAMBDA_GBS_USD
    )


def s3_storage_cost(
    n_puts: int,
    n_gets: int,
    gb_seconds: float = 0.0,
) -> float:
    """S3 ephemeral cost = request fees + (tiny) prorated residency."""
    return (
        n_puts * S3_PUT_USD
        + n_gets * S3_GET_USD
        + (gb_seconds / SECONDS_PER_MONTH) * S3_GB_MONTH_USD
    )


def elasticache_storage_cost(peak_resident_gb: float, hours: float = 1.0) -> float:
    """ElastiCache cost: provisioned capacity for the peak ephemeral set.

    The paper's "minimal possible price" assumption still cannot escape the
    hour-granularity of cache provisioning: capacity for the peak resident
    set is billed for at least one hour, which is what makes EC 17-772x more
    expensive than XDT for bursty ephemeral data.
    """
    import math

    return peak_resident_gb * EC_GB_HOUR_USD * max(1.0, math.ceil(hours))


def xdt_storage_cost() -> float:
    """XDT uses no intermediate service: zero storage cost by construction."""
    return 0.0


@dataclasses.dataclass(frozen=True)
class WorkflowCostInputs:
    """Aggregate accounting for a single end-to-end workflow invocation."""

    n_function_invocations: int
    billed_duration_s: float            # sum across all function instances
    n_storage_puts: int = 0
    n_storage_gets: int = 0
    storage_gb_seconds: float = 0.0     # integral of resident ephemeral GB
    peak_resident_gb: float = 0.0


@dataclasses.dataclass(frozen=True)
class StorageOps:
    """Storage-side accounting for ONE transfer medium of a (possibly
    mixed-backend) run — the per-medium slice of :class:`WorkflowCostInputs`,
    priced by that medium's fee structure in :func:`storage_cost_for`."""

    n_puts: int = 0
    n_gets: int = 0
    gb_seconds: float = 0.0
    peak_resident_gb: float = 0.0


def storage_cost_for(backend: str, ops: StorageOps) -> float:
    """Storage cost of one medium's ops under that medium's fee structure."""
    if backend == "s3":
        return s3_storage_cost(ops.n_puts, ops.n_gets, ops.gb_seconds)
    if backend == "elasticache":
        return elasticache_storage_cost(ops.peak_resident_gb)
    if backend == "hybrid":
        # Two-tier (cache + object storage): the aggregate accounting does
        # not split ops per tier, so price conservatively as the sum of both
        # fee structures — request fees on every op plus provisioned cache
        # capacity for the peak resident set (an upper bound on either tier
        # alone).
        return s3_storage_cost(
            ops.n_puts, ops.n_gets, ops.gb_seconds
        ) + elasticache_storage_cost(ops.peak_resident_gb)
    if backend in ("xdt", "inline"):
        return xdt_storage_cost()
    raise ValueError(f"unknown backend {backend!r}")


#: ElastiCache capacity must exist for ≥ 1 hour: the marginal provisioning
#: cost of putting one more ephemeral object through the cache.
_EC_USD_PER_BYTE = EC_GB_HOUR_USD / 1e9
#: hybrid's cache/object-storage split point mirrors NetConstants — kept as
#: a plain constant to avoid a cluster import from the pricing layer
_HYBRID_SMALL_CUTOFF = 1 << 20


def transfer_fee_usd(medium: str, nbytes: int, n_gets: int = 1) -> float:
    """Estimated *marginal* storage fee of moving one object through a medium.

    This is the price-sheet prior the telemetry substrate feeds per-medium
    $/GB observations with (and :class:`repro.core.dag.AdaptiveRoute` falls
    back to for media it has not observed yet): S3 pays per-request fees,
    ElastiCache pays provisioned capacity for the object's bytes (hour
    granularity), XDT/inline pay nothing.  Aggregate run bills still come
    from :func:`routed_workflow_cost` — this helper never replaces them.
    Media without a published fee structure (custom registered backends)
    are treated as compute-only, like XDT.
    """
    if medium == "s3":
        return S3_PUT_USD + n_gets * S3_GET_USD
    if medium == "elasticache":
        return nbytes * _EC_USD_PER_BYTE
    if medium == "hybrid":
        if nbytes < _HYBRID_SMALL_CUTOFF:
            return nbytes * _EC_USD_PER_BYTE
        return S3_PUT_USD + n_gets * S3_GET_USD
    return 0.0


def marginal_pull_fee_usd(
    medium: str, nbytes: int, retrievals: int = 1, external: bool = False
) -> float:
    """Marginal storage fee of ONE pull of an object permitting
    ``retrievals`` pulls: the pull's own request fee plus its share of the
    object's one-time put/capacity fee.  ``external`` marks original input
    the workflow never put (request fee only).

    This is the single definition of the observed-$/pull the telemetry
    substrate is fed with — every feed site (``TransferEngine.get``, both
    DAG lowerings) must price through here so :class:`AdaptiveRoute` scores
    every medium by one consistent rule.  Request-fee media (S3) attribute
    exactly what :func:`routed_workflow_cost` bills; capacity-billed media
    (ElastiCache) are attributed *conservatively* — each object's full
    bytes, as if every in-flight object were resident at the billing peak —
    because the run-level peak is not separable per pull.  Sequentially
    staged EC objects therefore look somewhat pricier to the router than
    the final bill; the bias is toward under-using the capacity-billed
    tier, never toward surprise bills.
    """
    base = transfer_fee_usd(medium, nbytes, n_gets=0)
    fee = transfer_fee_usd(medium, nbytes, n_gets=1) - base
    if not external:
        fee += base / max(1, retrievals)
    return fee


def combine_cost_inputs(parts) -> WorkflowCostInputs:
    """Sum per-tenant (or per-cell) accounting into one global input.

    Counters and GB-second integrals add; ``peak_resident_gb`` also adds,
    because co-resident tenants' peak sets must be provisioned for
    *simultaneously* — the capacity-billed (ElastiCache) column is priced
    for the worst case where every tenant peaks together.  Under this
    convention every fee structure in :func:`storage_cost_for` is linear in
    the inputs, so per-tenant bills computed by :func:`tenant_bills` sum
    exactly to the bill of the combined inputs — the attribution invariant
    the multi-tenant benchmark gates on.
    """
    n_inv = 0
    billed = 0.0
    puts = gets = 0
    gb_s = peak = 0.0
    for p in parts:
        n_inv += p.n_function_invocations
        billed += p.billed_duration_s
        puts += p.n_storage_puts
        gets += p.n_storage_gets
        gb_s += p.storage_gb_seconds
        peak += p.peak_resident_gb
    return WorkflowCostInputs(
        n_function_invocations=n_inv,
        billed_duration_s=billed,
        n_storage_puts=puts,
        n_storage_gets=gets,
        storage_gb_seconds=gb_s,
        peak_resident_gb=peak,
    )


def tenant_bills(
    parts: Dict[str, WorkflowCostInputs], backend: str
) -> Dict[str, CostBreakdown]:
    """Per-tenant cost attribution from per-tenant accounting.

    Each tenant is billed exactly for its own invocations, billed seconds,
    and storage ops under the shared backend's fee structure; by linearity
    (see :func:`combine_cost_inputs`) the per-tenant totals sum to the
    untenanted global bill."""
    return {
        tenant: workflow_cost(inputs, backend)
        for tenant, inputs in parts.items()
    }


def workflow_cost(inputs: WorkflowCostInputs, backend: str) -> CostBreakdown:
    """Cost of one workflow invocation under a given transfer backend."""
    compute = lambda_compute_cost(
        inputs.billed_duration_s, inputs.n_function_invocations
    )
    storage = storage_cost_for(
        backend,
        StorageOps(
            n_puts=inputs.n_storage_puts,
            n_gets=inputs.n_storage_gets,
            gb_seconds=inputs.storage_gb_seconds,
            peak_resident_gb=inputs.peak_resident_gb,
        ),
    )
    return CostBreakdown(compute=compute, storage=storage)


def routed_workflow_cost(
    inputs: WorkflowCostInputs, media: Dict[str, StorageOps], egress_usd: float = 0.0
) -> CostBreakdown:
    """Cost of one workflow invocation whose edges were routed over MIXED
    media (per-edge backend selection): the compute bill is shared, and each
    medium's ops are priced by its own fee structure — S3 per-request fees on
    the S3-routed edges, provisioned cache capacity for the ElastiCache-
    resident peak, nothing for XDT/inline edges.  ``egress_usd`` is the run's
    accumulated cross-tier egress (see :func:`egress_fee_usd`; zero on a flat
    cluster)."""
    compute = lambda_compute_cost(
        inputs.billed_duration_s, inputs.n_function_invocations
    )
    storage = sum(storage_cost_for(b, ops) for b, ops in media.items())
    return CostBreakdown(compute=compute, storage=storage, egress=egress_usd)


def cost_per_1k_requests(
    inputs: WorkflowCostInputs, backend: str, n_requests: int
) -> float:
    """USD per 1000 workflow requests, given the run's aggregate accounting."""
    if n_requests <= 0:
        raise ValueError("n_requests must be positive")
    return workflow_cost(inputs, backend).total / n_requests * 1000.0


def routed_cost_per_1k_requests(
    inputs: WorkflowCostInputs, media: Dict[str, StorageOps], n_requests: int
) -> float:
    """USD per 1000 workflow requests for a mixed-backend (routed) run."""
    if n_requests <= 0:
        raise ValueError("n_requests must be positive")
    return routed_workflow_cost(inputs, media).total / n_requests * 1000.0
