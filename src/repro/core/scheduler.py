"""Control-plane analogue: activator + autoscaler + per-instance queue-proxy.

Paper §2.2: every invocation traverses the *activator* (load balancer), which
steers it to the least-loaded instance; the *autoscaler* watches per-instance
load (reported by each instance's *queue-proxy*) and scales the deployment;
cold starts buffer the invocation until a new instance is up.

XDT's core compatibility claim is that the control plane is **unchanged** —
placement decisions happen exactly here, before any bulk data moves, and the
data plane then pulls producer->chosen-consumer directly.  The serving engine
(`repro.serving`) uses this scheduler to pick decode slices; the workflow
engine uses it to pick function instances.

All time-dependent decisions (keep-alive reaping, cold-start gates) read the
injected clock (:mod:`repro.core.clock`): real time by default, a
:class:`~repro.core.clock.VirtualClock` under the event-driven workflow
engine — which makes autoscaler dynamics exactly assertable in tests and
fast-forwardable in load sweeps.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Tuple

from .clock import ensure_clock


@dataclasses.dataclass
class ScalingPolicy:
    """Knative-style concurrency autoscaling."""

    target_concurrency: int = 1       # desired in-flight per instance
    min_instances: int = 0
    max_instances: int = 64
    keep_alive_s: float = 60.0        # idle instance lifetime (paper §4.1: >> data lifetime)
    cold_start_s: float = 0.5         # instance boot latency


@dataclasses.dataclass
class Instance:
    instance_id: int
    coords: Tuple[int, ...]           # placement (e.g. pod / mesh slice)
    in_flight: int = 0
    last_used: float = 0.0
    epoch: int = 0                    # bumps when instance is recycled
    ready_at: float = 0.0             # cold-start gate
    alive: bool = True

    @property
    def load(self) -> int:
        return self.in_flight


class Deployment:
    """One function's fleet of instances + its autoscaling state."""

    def __init__(
        self,
        name: str,
        policy: ScalingPolicy,
        placer: Optional[Callable[[int], Tuple[int, ...]]] = None,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.name = name
        self.policy = policy
        self.placer = placer or (lambda i: (i,))
        self.clock = ensure_clock(clock)
        self.instances: Dict[int, Instance] = {}
        self._ids = itertools.count()
        self.stats = {"cold_starts": 0, "scale_downs": 0, "steered": 0, "buffered": 0}
        for _ in range(policy.min_instances):
            self._spawn(cold=False)

    # -- autoscaler ----------------------------------------------------------
    def _spawn(self, cold: bool = True) -> Instance:
        iid = next(self._ids)
        now = self.clock()
        inst = Instance(
            instance_id=iid,
            coords=self.placer(iid),
            last_used=now,
            ready_at=now + (self.policy.cold_start_s if cold else 0.0),
        )
        if cold:
            self.stats["cold_starts"] += 1
        self.instances[iid] = inst
        return inst

    def _reap_idle(self) -> None:
        now = self.clock()
        alive = len(self.instances)
        for iid, inst in list(self.instances.items()):
            if alive <= self.policy.min_instances:
                break
            if inst.in_flight == 0 and now - inst.last_used > self.policy.keep_alive_s:
                inst.alive = False
                del self.instances[iid]
                alive -= 1
                self.stats["scale_downs"] += 1

    # -- activator -----------------------------------------------------------
    def steer(self) -> Tuple[Instance, float]:
        """Pick an instance for one invocation.

        Returns (instance, wait_s) where wait_s > 0 models the activator
        buffering the request across a cold start.
        """
        self._reap_idle()
        now = self.clock()
        ready = [
            i for i in self.instances.values()
            if i.ready_at <= now and i.in_flight < self.policy.target_concurrency
        ]
        if ready:
            inst = min(ready, key=lambda i: (i.load, i.instance_id))
            wait = 0.0
        else:
            # scale up if allowed; otherwise queue on the least-loaded
            if len(self.instances) < self.policy.max_instances:
                inst = self._spawn(cold=True)
                wait = max(0.0, inst.ready_at - now)
                self.stats["buffered"] += 1
            else:
                inst = min(self.instances.values(), key=lambda i: (i.load, i.instance_id))
                wait = 0.0
        inst.in_flight += 1
        inst.last_used = now
        self.stats["steered"] += 1
        return inst, wait

    def release(self, instance_id: int) -> None:
        inst = self.instances.get(instance_id)
        if inst is not None:
            inst.in_flight = max(0, inst.in_flight - 1)
            inst.last_used = self.clock()

    def kill(self, instance_id: int) -> bool:
        """Fault injection: a node dies.  Outstanding XDT buffers die with it."""
        inst = self.instances.pop(instance_id, None)
        if inst is None:
            return False
        inst.alive = False
        return True

    @property
    def n_instances(self) -> int:
        return len(self.instances)


class ControlPlane:
    """The activator/autoscaler pair for a set of deployments."""

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = ensure_clock(clock)
        self.deployments: Dict[str, Deployment] = {}

    def register(
        self,
        name: str,
        policy: Optional[ScalingPolicy] = None,
        placer: Optional[Callable[[int], Tuple[int, ...]]] = None,
    ) -> Deployment:
        dep = Deployment(name, policy or ScalingPolicy(), placer, self.clock)
        self.deployments[name] = dep
        return dep

    def steer(self, name: str) -> Tuple[Instance, float]:
        return self.deployments[name].steer()

    def release(self, name: str, instance_id: int) -> None:
        self.deployments[name].release(instance_id)
