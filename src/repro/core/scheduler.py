"""Control-plane analogue: activator + autoscaler + per-instance queue-proxy.

Paper §2.2: every invocation traverses the *activator* (load balancer), which
steers it to the least-loaded instance; the *autoscaler* watches per-instance
load (reported by each instance's *queue-proxy*) and scales the deployment;
cold starts buffer the invocation until a new instance is up.

XDT's core compatibility claim is that the control plane is **unchanged** —
placement decisions happen exactly here, before any bulk data moves, and the
data plane then pulls producer->chosen-consumer directly.  The serving engine
(`repro.serving`) uses this scheduler to pick decode slices; the workflow
engine uses it to pick function instances.

All time-dependent decisions (keep-alive reaping, cold-start gates) read the
injected clock (:mod:`repro.core.clock`): real time by default, a
:class:`~repro.core.clock.VirtualClock` under the event-driven workflow
engine — which makes autoscaler dynamics exactly assertable in tests and
fast-forwardable in load sweeps.

Scalability: ``steer()`` is O(log n) in fleet size.  Ready instances live in
a lazily-invalidated min-heap keyed ``(load, instance_id)`` (exactly the old
linear scan's ordering), booting instances in a ``ready_at`` heap that
matures them into the ready set, and keep-alive reaping is driven by
scheduled expiry times instead of sweeping every instance on every steer.
Heap entries carry the instance's version counter; any in-flight change bumps
the version, so stale entries are discarded on pop instead of being searched
for and removed — the million-steer path never scans the fleet.

Scale-up strategy is pluggable (:class:`AutoscalerPolicy`): the default
:class:`ConcurrencyPolicy` is the legacy reactive Knative-concurrency
behaviour bit-for-bit; :class:`RpsPolicy` sizes the fleet from the observed
arrival-rate window; :class:`PredictivePolicy` pre-warms from the rate trend.
Rate-driven policies read the deployment's
:class:`~repro.core.telemetry.DeploymentTelemetry` (arrival/concurrency/
cold-start windows on the injected clock).  Select per deployment via
``ScalingPolicy(autoscaler=...)`` — a registered name or a policy instance —
and register custom strategies with :func:`register_autoscaler`.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from collections import deque
from heapq import heapify, heappop, heappush
from typing import Callable, ClassVar, Dict, List, Optional, Tuple, Type, Union

from .clock import VirtualClock, ensure_clock
from .registry import Registry
from .telemetry import DeploymentTelemetry
from .topology import as_coord


# ---------------------------------------------------------------------------
# Autoscaler policies (strategy layer)
# ---------------------------------------------------------------------------


class AutoscalerPolicy:
    """Decides *when a deployment adds instances*; steering stays shared.

    The :class:`Deployment` owns the mechanics (heaps, keep-alive reaping,
    queue-wait modeling) and consults its policy at two points of ``steer``:

    * ``desired_instances(dep, now)`` — a proactive fleet-size floor,
      evaluated per arrival when ``needs_telemetry`` is set; the deployment
      spawns (cold) up to it before picking an instance.
    * ``reactive`` — whether a request that finds no ready instance below
      the ``max_instances`` cap spawns a cold instance on the spot (the
      legacy Knative-concurrency behaviour) or queues on the booting /
      least-loaded fleet the proactive floor provisioned.

    Policies are stateless — all signals live on the deployment (its
    :class:`~repro.core.telemetry.DeploymentTelemetry`, holding-time EWMAs,
    in-flight totals) — so one policy instance can serve many deployments.
    Register new policies with :func:`register_autoscaler`; every
    ``ScalingPolicy(autoscaler=...)`` site (``WorkflowEngine.register``,
    ``dag.compile`` on either lowering, the loadgen-driven benchmarks)
    then selects them by name.
    """

    name: ClassVar[str] = ""
    #: maintain a DeploymentTelemetry (arrival/concurrency/cold-start
    #: windows) on the deployment; False keeps the steer hot path free of
    #: any telemetry work (the legacy policy pays nothing for this layer)
    needs_telemetry: ClassVar[bool] = False
    #: legacy reactive scale-up on a steer miss below the cap
    reactive: ClassVar[bool] = True
    #: proactively retire idle surplus instances when the policy's desired
    #: count falls below the live fleet (instead of waiting out keep-alive).
    #: Policies that opt in may also set ``scale_down_slack`` (a >= 1.0
    #: multiplier on the desired count: a warm buffer against rate-estimate
    #: jitter) and ``scale_down_delay_s`` (how long the surplus must persist
    #: continuously before the trim fires — the anti-flap hysteresis).
    scale_down: ClassVar[bool] = False

    def desired_instances(self, dep: "Deployment", now: float) -> int:
        return 0


class ConcurrencyPolicy(AutoscalerPolicy):
    """The legacy Knative-style concurrency autoscaler, bit-for-bit.

    Scale-up is purely reactive: an arrival that finds every instance at
    ``target_concurrency`` spawns a cold instance (below the cap).  This is
    the default and reproduces the pre-policy-layer ``Deployment`` exactly —
    the fixed-seed latency checksums in ``results/BENCH_engine.json`` and
    the differential-vs-legacy steer test both guard it.
    """

    name = "concurrency"


class RpsPolicy(AutoscalerPolicy):
    """Knative's requests-per-second autoscaling mode.

    Sizes the fleet from the observed arrival rate instead of instantaneous
    concurrency: ``desired = ceil(rate / (rps_per_instance * utilization))``.
    The per-instance capacity defaults to ``target_concurrency /
    holding_time`` using the deployment's observed (or seeded) holding-time
    EWMA, derated by the target ``utilization`` (Knative's
    target-utilization knob: sizing for 100% of capacity queues without
    bound under Poisson arrivals); until a holding estimate exists it
    provisions like the concurrency policy would (one slot per in-flight
    request).  Because scale-up is driven by the rate window rather than
    per-request misses, a load spike provisions the steady-state fleet
    instead of one instance per arrival caught mid cold-start — far fewer
    cold starts at high offered load, at the price of queueing while the
    right-sized fleet boots.
    """

    name = "rps"
    needs_telemetry = True
    reactive = False

    def __init__(
        self,
        target_rps_per_instance: Optional[float] = None,
        utilization: float = 0.7,
    ):
        self.target_rps_per_instance = target_rps_per_instance
        self.utilization = utilization

    def _capacity_rps(self, dep: "Deployment") -> Optional[float]:
        """Sustainable requests/sec of one instance, or None if unknown."""
        if self.target_rps_per_instance is not None:
            return self.target_rps_per_instance * self.utilization
        hold = dep._service_ewma
        if hold <= 0.0:
            return None
        return max(1, dep.policy.target_concurrency) / hold * self.utilization

    def _bootstrap(self, dep: "Deployment") -> int:
        """No holding-time signal yet: provision for observed concurrency."""
        slots = max(1, dep.policy.target_concurrency)
        return -(-(dep.in_flight_total + 1) // slots)

    def desired_instances(self, dep: "Deployment", now: float) -> int:
        per = self._capacity_rps(dep)
        if per is None:
            return self._bootstrap(dep)
        rate = dep.telemetry.arrival_rate(now)
        return max(1, math.ceil(rate / per))


class PredictivePolicy(RpsPolicy):
    """Pre-warms from the arrival-rate *trend* — and decays the prewarm.

    Scale-up: extrapolates the rate over the cold-start horizon (``rate +
    slope * cold_start_s``, never below the current rate) and provisions for
    the forecast with a small headroom — so a ramping load finds instances
    already booting when it arrives instead of paying the boot latency per
    request.  On flat or falling load the forecast degrades to
    :class:`RpsPolicy`.

    Scale-down: with ``scale_down`` (the default) a fleet the forecast no
    longer justifies is trimmed proactively — idle surplus instances beyond
    ``desired * scale_down_slack + scale_down_surge * sqrt(desired)`` are
    retired on arrival instead of idling out the full keep-alive window.
    Three dampers keep the trim from costing rebound cold starts: the slack
    is a warm buffer against rate-estimate jitter, the square-root staffing
    term keeps clump-absorbing capacity on small fleets (Poisson bursts are
    relatively larger there), and ``scale_down_delay_s`` requires the
    surplus to persist continuously before anything is retired (steady-load
    noise crosses back under the threshold and resets the timer; a real
    load drop does not).  ``scale_down=False`` restores the reap-only
    behaviour.
    """

    name = "predictive"

    def __init__(
        self,
        target_rps_per_instance: Optional[float] = None,
        utilization: float = 0.7,
        horizon_s: Optional[float] = None,
        headroom: float = 1.2,
        scale_down: bool = True,
        scale_down_slack: float = 1.25,
        scale_down_delay_s: float = 3.0,
        scale_down_surge: float = 2.0,
    ):
        super().__init__(target_rps_per_instance, utilization)
        self.horizon_s = horizon_s      # None: the deployment's cold_start_s
        self.headroom = headroom
        self.scale_down = scale_down
        if scale_down_slack < 1.0:
            raise ValueError("scale_down_slack must be >= 1.0")
        self.scale_down_slack = scale_down_slack
        if scale_down_delay_s < 0.0:
            raise ValueError("scale_down_delay_s must be >= 0")
        self.scale_down_delay_s = scale_down_delay_s
        if scale_down_surge < 0.0:
            raise ValueError("scale_down_surge must be >= 0")
        self.scale_down_surge = scale_down_surge

    def desired_instances(self, dep: "Deployment", now: float) -> int:
        per = self._capacity_rps(dep)
        if per is None:
            return self._bootstrap(dep)
        rate, slope = dep.telemetry.arrival_trend(now)
        horizon = dep.policy.cold_start_s if self.horizon_s is None else self.horizon_s
        forecast = max(rate, rate + slope * horizon) * self.headroom
        return max(1, math.ceil(forecast / per))


_AUTOSCALER_REGISTRY = Registry("autoscaler")


def register_autoscaler(cls: Type[AutoscalerPolicy]) -> Type[AutoscalerPolicy]:
    """Register a policy class under ``cls.name`` (idempotent overwrite)."""
    return _AUTOSCALER_REGISTRY.register(cls)


for _cls in (ConcurrencyPolicy, RpsPolicy, PredictivePolicy):
    register_autoscaler(_cls)


def available_autoscalers() -> Tuple[str, ...]:
    return tuple(_AUTOSCALER_REGISTRY)


_DEFAULT_AUTOSCALER = ConcurrencyPolicy()


def make_autoscaler(
    spec: Union[None, str, AutoscalerPolicy]
) -> AutoscalerPolicy:
    """Resolve a policy spec: None (legacy default) | name | instance."""
    if spec is None:
        return _DEFAULT_AUTOSCALER
    if isinstance(spec, AutoscalerPolicy):
        return spec
    cls = _AUTOSCALER_REGISTRY.get(spec)
    if cls is None:
        raise ValueError(
            f"autoscaler must be one of {available_autoscalers()}, got {spec!r}"
        )
    return cls()


@dataclasses.dataclass
class ScalingPolicy:
    """Per-deployment scaling knobs + the autoscaler strategy that uses them."""

    target_concurrency: int = 1       # desired in-flight per instance
    min_instances: int = 0
    max_instances: int = 64
    keep_alive_s: float = 60.0        # idle instance lifetime (paper §4.1: >> data lifetime)
    cold_start_s: float = 0.5         # instance boot latency
    #: at the max_instances cap, model the activator's queue delay from the
    #: chosen instance's residual work — the modeled completion time of the
    #: in-flight request whose finish frees this request's concurrency slot
    #: (False restores the legacy wait=0 bug)
    queue_wait_model: bool = True
    #: scale-up strategy: None (legacy concurrency autoscaler), a registered
    #: policy name ("concurrency" | "rps" | "predictive" | custom), or an
    #: AutoscalerPolicy instance
    autoscaler: Union[None, str, AutoscalerPolicy] = None


@dataclasses.dataclass(slots=True)
class Instance:
    instance_id: int
    coords: Tuple[int, ...]           # placement (e.g. pod / mesh slice)
    in_flight: int = 0
    last_used: float = 0.0
    epoch: int = 0                    # bumps when instance is recycled
    ready_at: float = 0.0             # cold-start gate
    alive: bool = True
    #: bumped on every in_flight change / death; heap entries minted against
    #: an older version are stale and discarded on pop
    version: int = 0
    #: occupancy start times of in-flight requests (FIFO; queued requests
    #: carry start = steer time + modeled wait): release() pairs them to
    #: measure holding time, and the cap-path queue model reads them to
    #: estimate this instance's residual work
    starts: deque = dataclasses.field(default_factory=deque)
    #: EWMA of THIS instance's observed request holding times; the cap queue
    #: model prefers it over the deployment-wide estimate (fresh instances
    #: fall back to the fleet's)
    service_ewma: float = 0.0
    #: this instance has a live entry in the deployment's expiry heap.  The
    #: arming discipline keeps the heap O(fleet): without it every
    #: idle-making release pushed a fresh entry, and with keep-alive longer
    #: than the run none ever popped — the heap grew per-request and its
    #: pushes dominated the steer/release path at high offered load.
    expiry_armed: bool = True

    @property
    def load(self) -> int:
        return self.in_flight


class Deployment:
    """One function's fleet of instances + its autoscaling state."""

    def __init__(
        self,
        name: str,
        policy: ScalingPolicy,
        placer: Optional[Callable[[int], Tuple[int, ...]]] = None,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.name = name
        self.policy = policy
        self.autoscaler = make_autoscaler(policy.autoscaler)
        self.placer = placer or (lambda i: (i,))
        self.clock = ensure_clock(clock)
        #: under a VirtualClock, reading time is one attribute load off the
        #: simulator — steer/release skip the ``__call__`` frame per op
        self._vsim = self.clock.sim if type(self.clock) is VirtualClock else None
        #: arrival/concurrency/cold-start windows, maintained only when the
        #: autoscaler asks (the legacy policy keeps steer() telemetry-free)
        self.telemetry: Optional[DeploymentTelemetry] = (
            DeploymentTelemetry(self.clock)
            if self.autoscaler.needs_telemetry else None
        )
        #: total in-flight requests across the fleet (O(1) concurrency read)
        self.in_flight_total = 0
        self.instances: Dict[int, Instance] = {}
        self._ids = itertools.count()
        # (load, iid, version): ready instances with spare concurrency
        self._ready_heap: List[Tuple[int, int, int]] = []
        # (load, iid, version): live instances for the cap-path least-loaded
        # pick.  Maintained lazily: entries are only pushed while the fleet
        # sits at max_instances (the only time the heap is consulted) and the
        # heap is rebuilt from the live fleet when its entries go stale —
        # the un-capped common path pays nothing for it.
        self._all_heap: List[Tuple[int, int, int]] = []
        self._all_dirty = True            # heap missing below-cap mutations
        # (ready_at, iid): booting instances awaiting maturation
        self._warming: List[Tuple[float, int]] = []
        # (expire_at, iid, last_used): scheduled keep-alive expiries
        self._expiry: List[Tuple[float, int, float]] = []
        # fleet-wide EWMA of observed request holding time: the cap queue
        # model's fallback estimate for instances with no history of their own
        self._service_ewma = 0.0
        # coords -> live instance ids at that placement: the affinity lookup
        # behind steer(prefer=...).  Maintained on spawn/reap/kill only, so
        # the hint-free steer path pays nothing for it.
        self._coords_index: Dict[Tuple[int, ...], List[int]] = {}
        # zone name -> live instance ids: the same-zone fallback of
        # steer(prefer=<Coord with a zone>).  Only zone-carrying placers
        # (topology runs) ever populate it.
        self._zone_index: Dict[str, List[int]] = {}
        # scale-down hysteresis: virtual time the fleet first exceeded the
        # autoscaler's keep threshold (None while not in surplus)
        self._surplus_since: Optional[float] = None
        self.stats = {
            "cold_starts": 0, "scale_downs": 0, "steered": 0,
            "buffered": 0, "queued": 0, "prewarmed": 0, "affine_hits": 0,
        }
        for _ in range(policy.min_instances):
            self._spawn(cold=False)

    # -- autoscaler ----------------------------------------------------------
    def _spawn(self, cold: bool = True) -> Instance:
        iid = next(self._ids)
        now = self.clock()
        inst = Instance(
            instance_id=iid,
            coords=self.placer(iid),
            last_used=now,
            ready_at=now + (self.policy.cold_start_s if cold else 0.0),
        )
        if cold:
            self.stats["cold_starts"] += 1
            if self.telemetry is not None:
                self.telemetry.record_cold_start(now)
        self.instances[iid] = inst
        self._coords_index.setdefault(inst.coords, []).append(iid)
        zone = getattr(inst.coords, "zone", None)
        if zone is not None:
            self._zone_index.setdefault(zone, []).append(iid)
        if inst.ready_at <= now:
            heappush(self._ready_heap, (0, iid, 0))
        else:
            heappush(self._warming, (inst.ready_at, iid))
        self._all_dirty = True            # new instance unknown to the cap heap
        heappush(self._expiry, (now + self.policy.keep_alive_s, iid, now))
        return inst

    def _mature_warming(self, now: float) -> None:
        warming = self._warming
        while warming and warming[0][0] <= now:
            _, iid = heappop(warming)
            inst = self.instances.get(iid)
            if (
                inst is not None
                and inst.in_flight < self.policy.target_concurrency
            ):
                heappush(
                    self._ready_heap, (inst.in_flight, iid, inst.version)
                )

    def _reap_expired(self, now: float) -> None:
        """Keep-alive reaping from scheduled expiry times: O(expired), not
        O(fleet), per steer.  Matches the legacy full sweep exactly: reaps
        every idle instance past keep-alive, lowest instance_id first, never
        below ``min_instances``."""
        heap = self._expiry
        expired: List[Tuple[int, float, float]] = []
        seen = set()
        ka = self.policy.keep_alive_s
        while heap and heap[0][0] < now:
            exp_at, iid, lu = heappop(heap)
            inst = self.instances.get(iid)
            if iid in seen or inst is None:   # duplicate / instance gone
                continue
            if inst.in_flight != 0:
                # busy again: disarm, so the release that next idles this
                # instance re-arms it — at most one live entry per instance
                inst.expiry_armed = False
                continue
            if inst.last_used != lu:
                # idle, but re-used since this entry was armed: re-arm at the
                # true expiry of the latest idle period (same reap time the
                # per-release pushes used to provide)
                heappush(heap, (inst.last_used + ka, iid, inst.last_used))
                continue
            seen.add(iid)
            expired.append((iid, exp_at, lu))
        if not expired:
            return
        expired.sort()                        # legacy sweep order: by iid
        alive = len(self.instances)
        floor = self.policy.min_instances
        for iid, exp_at, lu in expired:
            if alive <= floor:
                # Floor binds: leave alive, but re-arm the entry one
                # keep-alive out instead of at its past expiry — re-pushing
                # exp_at < now would make every subsequent steer re-pop and
                # re-sort the floor-bound set forever.  The last_used stale
                # check still governs reaping whenever it does fire.
                heappush(heap, (now + self.policy.keep_alive_s, iid, lu))
                continue
            inst = self.instances.pop(iid)
            inst.alive = False
            inst.version += 1
            self._drop_coords(inst)
            alive -= 1
            self.stats["scale_downs"] += 1
            if self.telemetry is not None:
                # the reap window feeds the spill predictor: a producer
                # deployment whose idle instances keep getting reclaimed is
                # one whose staged objects should ride durable media
                self.telemetry.record_reap(now)

    def _keep_floor(self, want: int) -> int:
        """Fleet size scale-down may never trim below: the desired count
        padded by the policy's slack plus square-root staffing headroom
        (``scale_down_surge * sqrt(want)``).  The slack covers rate-estimate
        jitter; the sqrt term covers Poisson arrival clumping, which needs
        proportionally MORE headroom on small fleets — trimming a 7-instance
        fleet to 9 cold-starts on every clump a 20%% buffer absorbs at 40
        instances."""
        slack = getattr(self.autoscaler, "scale_down_slack", 1.0)
        surge = getattr(self.autoscaler, "scale_down_surge", 0.0)
        return max(
            self.policy.min_instances,
            math.ceil(want * slack + surge * math.sqrt(max(want, 0))),
            1,
        )

    def _maybe_retire(self, now: float, want: int) -> None:
        """Hysteresis gate in front of :meth:`_retire_surplus`: the fleet
        must exceed the keep threshold *continuously* for the policy's
        ``scale_down_delay_s`` before anything is trimmed.  Rate-estimator
        jitter at steady load crosses back over the threshold within the
        delay and resets the timer, so only a sustained surplus — a load
        level that actually fell — ever retires instances (flapping would
        turn every noise dip into cold starts on the rebound)."""
        if len(self.instances) <= self._keep_floor(want):
            self._surplus_since = None
            return
        if self._surplus_since is None:
            self._surplus_since = now
        delay = getattr(self.autoscaler, "scale_down_delay_s", 0.0)
        if now - self._surplus_since < delay:
            return
        self._retire_surplus(now, want)
        self._surplus_since = None

    def _retire_surplus(self, now: float, want: int) -> None:
        """Policy-driven prewarm decay: retire idle instances beyond the
        autoscaler's desired count (plus its slack buffer), newest first.

        The forecast half of scale-down: keep-alive reaping waits out the
        full idle window per instance, while a falling arrival trend already
        proves the surplus will never be used.  Busy instances are never
        touched (retiring them would drop in-flight requests), the
        ``min_instances`` floor always binds, and newest-first victim order
        preserves the longest-lived — warmest — part of the fleet.  Retired
        instances count as ``scale_downs`` and feed the telemetry reap
        window exactly like keep-alive reaps: a policy-trimmed producer is
        just as fatal to its instance-resident staged objects.
        """
        excess = len(self.instances) - self._keep_floor(want)
        if excess <= 0:
            return
        victims = sorted(
            (iid for iid, inst in self.instances.items()
             if inst.in_flight == 0),
            reverse=True,
        )
        tel = self.telemetry
        for iid in victims[:excess]:
            inst = self.instances.pop(iid)
            inst.alive = False
            inst.version += 1           # stale ready/warming entries skip it
            self._drop_coords(inst)
            self.stats["scale_downs"] += 1
            if tel is not None:
                tel.record_reap(now)

    # keep the legacy entry point (tests / external callers)
    def _reap_idle(self) -> None:
        now = self.clock()
        # the legacy sweep reaped at strictly-greater-than keep_alive idle;
        # expiry entries use last_used + keep_alive < now, the same predicate
        self._reap_expired(now)

    def _drop_coords(self, inst: Instance) -> None:
        ids = self._coords_index.get(inst.coords)
        if ids is not None:
            try:
                ids.remove(inst.instance_id)
            except ValueError:
                pass
            if not ids:
                del self._coords_index[inst.coords]
        zone = getattr(inst.coords, "zone", None)
        if zone is not None:
            zids = self._zone_index.get(zone)
            if zids is not None:
                try:
                    zids.remove(inst.instance_id)
                except ValueError:
                    pass
                if not zids:
                    del self._zone_index[zone]

    # -- activator -----------------------------------------------------------
    def _pop_affine(
        self, prefer: Tuple[int, ...], now: float
    ) -> Optional[Instance]:
        """Least-loaded READY instance at the preferred placement, or None.

        The co-placement fast path of ``steer(prefer=...)``: the hint names
        the producer's coords; an instance there with a spare concurrency
        slot is taken directly (its stale ready-heap entry is discarded
        later by the version check).  A cold/booting or saturated match is
        NOT waited for — "prefer when slots allow", never at the price of
        queueing behind the co-located node."""
        ids = self._coords_index.get(prefer)
        if not ids:
            # zone-affine fallback: a Coord hint carrying a zone settles for
            # any ready instance in the producer's zone when the exact node
            # has none — same-zone pulls skip every tier crossing even when
            # they miss shared memory
            zone = getattr(prefer, "zone", None)
            if zone is None:
                return None
            ids = self._zone_index.get(zone)
            if not ids:
                return None
        target = self.policy.target_concurrency
        best: Optional[Instance] = None
        for iid in ids:
            inst = self.instances.get(iid)
            if (
                inst is not None
                and inst.ready_at <= now
                and inst.in_flight < target
                and (best is None or inst.in_flight < best.in_flight)
            ):
                best = inst
        return best

    def _pop_ready(self) -> Optional[Instance]:
        heap = self._ready_heap
        instances = self.instances
        target = self.policy.target_concurrency
        while heap:
            load, iid, version = heap[0]
            inst = instances.get(iid)
            if (
                inst is None
                or inst.version != version
                or inst.in_flight >= target
            ):
                heappop(heap)                 # stale entry
                continue
            heappop(heap)
            return inst
        return None

    def _pop_least_loaded(self) -> Instance:
        if self._all_dirty:
            # below-cap mutations bypassed the heap: rebuild from the fleet
            heap = self._all_heap = [
                (i.in_flight, i.instance_id, i.version)
                for i in self.instances.values()
            ]
            heapify(heap)
            self._all_dirty = False
        heap = self._all_heap
        instances = self.instances
        while True:
            load, iid, version = heap[0]
            inst = instances.get(iid)
            if inst is None or inst.version != version:
                heappop(heap)                 # stale entry
                continue
            heappop(heap)
            return inst

    def steer(
        self, prefer: Optional[Tuple[int, ...]] = None
    ) -> Tuple[Instance, float]:
        """Pick an instance for one invocation — O(log n) in fleet size.

        Returns (instance, wait_s): wait_s > 0 models the activator buffering
        the request across a cold start and, at the ``max_instances`` cap,
        the queue delay implied by the chosen instance's residual work
        (modeled completion times of the in-flight requests ahead of it).

        ``prefer`` is a placement-affinity hint (the graph optimizer's
        co-placement pass emits the producer's coords): a ready instance at
        those coords with a spare slot wins over the least-loaded pick, so
        the consumer lands next to its data when slots allow.  Without the
        hint the legacy steering is bit-for-bit unchanged.  ``prefer``
        accepts a plain tuple or a typed
        :class:`~repro.core.topology.Coord`; a Coord carrying a zone adds
        the same-zone fallback of :meth:`_pop_affine`.
        """
        prefer = as_coord(prefer)
        vs = self._vsim
        now = self.clock() if vs is None else vs.now
        # guard the reap/mature calls with the heaps' own due checks: both
        # are no-ops otherwise, and the empty/not-yet-due case is the common
        # one on the per-invocation path
        exp = self._expiry
        if exp and exp[0][0] < now:
            self._reap_expired(now)
        warm = self._warming
        if warm and warm[0][0] <= now:
            self._mature_warming(now)
        return self._steer_one(now, prefer)

    def steer_batch(
        self, n: int, prefer: Optional[Tuple[int, ...]] = None
    ) -> List[Tuple[Instance, float]]:
        """Steer ``n`` same-instant arrivals — the batched arrival kernel.

        One clock read and one reap/mature pass amortized over the batch,
        then ``n`` per-arrival picks through the exact per-steer body (each
        pick observes the previous picks' in-flight bumps, and rate-driven
        policies still record every arrival), so the decisions are
        bit-identical to ``n`` sequential :meth:`steer` calls at one virtual
        instant — the repeated no-op reap/mature/clock work is what's saved.
        """
        prefer = as_coord(prefer)
        vs = self._vsim
        now = self.clock() if vs is None else vs.now
        exp = self._expiry
        if exp and exp[0][0] < now:
            self._reap_expired(now)
        warm = self._warming
        if warm and warm[0][0] <= now:
            self._mature_warming(now)
        steer_one = self._steer_one
        return [steer_one(now, prefer) for _ in range(n)]

    def _steer_one(
        self, now: float, prefer: Optional[Tuple[int, ...]] = None
    ) -> Tuple[Instance, float]:
        pol = self.policy
        tel = self.telemetry
        if tel is not None:
            # rate-driven policies: observe the arrival, then raise the fleet
            # to the policy's proactive floor before picking an instance
            tel.record_arrival(now, self.in_flight_total)
            want = min(
                self.autoscaler.desired_instances(self, now),
                pol.max_instances,
            )
            n_missing = want - len(self.instances)
            if n_missing > 0:
                for _ in range(n_missing):
                    self._spawn(cold=True)  # ready at once when cold_start_s=0
                self.stats["prewarmed"] += n_missing
                self._surplus_since = None
            elif n_missing < 0 and self.autoscaler.scale_down:
                self._maybe_retire(now, want)
            else:
                self._surplus_since = None
        inst = None
        if prefer is not None:
            inst = self._pop_affine(prefer, now)
            if inst is not None:
                self.stats["affine_hits"] += 1
        if inst is None:
            inst = self._pop_ready()
        if inst is not None:
            wait = 0.0
        elif (
            self.autoscaler.reactive and len(self.instances) < pol.max_instances
        ) or not self.instances:
            inst = self._spawn(cold=True)
            wait = max(0.0, inst.ready_at - now)
            self.stats["buffered"] += 1
        else:
            # cap reached: queue on the least-loaded instance.  The request
            # waits until a concurrency slot frees — modeled per instance
            # from its residual work: each in-flight request's occupancy
            # start (queue wait already folded in at its own steer) plus one
            # estimated holding time is its modeled completion; the new
            # request's slot opens at the k-th earliest of those, where k is
            # its queue position beyond the concurrency target.  Unlike the
            # old deployment-wide excess*EWMA model, elapsed service on the
            # requests ahead shortens the wait.
            inst = self._pop_least_loaded()
            wait = 0.0
            if pol.queue_wait_model:
                wait = max(0.0, inst.ready_at - now)
                # degenerate target_concurrency=0 makes every request excess;
                # clamp the position to the requests actually in flight
                k = min(inst.in_flight - pol.target_concurrency + 1,
                        len(inst.starts))
                if k > 0:
                    hold = inst.service_ewma or self._service_ewma
                    if hold > 0.0:
                        # starts is FIFO with a shared holding estimate, so
                        # the k-th earliest completion is starts[k-1] + hold
                        wait = max(wait, inst.starts[k - 1] + hold - now, 0.0)
                self.stats["queued"] += 1
        inst.in_flight += 1
        self.in_flight_total += 1
        inst.version += 1
        inst.last_used = now
        # occupancy starts once the modeled wait has elapsed: the holding
        # estimate must measure service time, not the queueing it feeds
        inst.starts.append(now + wait)
        iid = inst.instance_id
        if inst.in_flight < pol.target_concurrency and inst.ready_at <= now:
            heappush(self._ready_heap, (inst.in_flight, iid, inst.version))
        if not self._all_dirty:           # keep the cap heap live once built
            heappush(self._all_heap, (inst.in_flight, iid, inst.version))
        self.stats["steered"] += 1
        return inst, wait

    def release(self, instance_id: int) -> None:
        inst = self.instances.get(instance_id)
        if inst is None:
            return
        vs = self._vsim
        now = self.clock() if vs is None else vs.now
        if inst.starts:
            held = now - inst.starts.popleft()
            if held > 0.0:        # inline zero-time invocations carry no signal
                self._service_ewma = (
                    held if self._service_ewma == 0.0
                    else 0.8 * self._service_ewma + 0.2 * held
                )
                inst.service_ewma = (
                    held if inst.service_ewma == 0.0
                    else 0.8 * inst.service_ewma + 0.2 * held
                )
        if inst.in_flight > 0:
            inst.in_flight -= 1
            self.in_flight_total -= 1
        inst.version += 1
        inst.last_used = now
        iid = inst.instance_id
        if inst.in_flight == 0 and not inst.expiry_armed:
            inst.expiry_armed = True
            heappush(
                self._expiry, (now + self.policy.keep_alive_s, iid, now)
            )
        if (
            inst.in_flight < self.policy.target_concurrency
            and inst.ready_at <= now
        ):
            heappush(self._ready_heap, (inst.in_flight, iid, inst.version))
        if not self._all_dirty:           # keep the cap heap live once built
            heappush(self._all_heap, (inst.in_flight, iid, inst.version))

    def kill(self, instance_id: int) -> bool:
        """Fault injection: a node dies.  Outstanding XDT buffers die with it."""
        inst = self.instances.pop(instance_id, None)
        if inst is None:
            return False
        inst.alive = False
        inst.version += 1
        self._drop_coords(inst)
        self.in_flight_total -= inst.in_flight
        return True

    def instances_at(self, coords: Tuple[int, ...]) -> List[int]:
        """Live instance ids placed at ``coords`` (a node, in the default
        placement model).  Accepts tuples, lists, or typed
        :class:`~repro.core.topology.Coord` values."""
        return list(self._coords_index.get(as_coord(coords), ()))

    def kill_node(self, coords: Tuple[int, ...]) -> int:
        """Correlated eviction: every instance at ``coords`` dies at once.

        The spot-market failure mode — reclamation takes the *node*, not one
        instance — so all co-resident instances (and, at the transfer layer,
        every XDT buffer they held) go together.  Returns how many died.
        """
        killed = 0
        for iid in self.instances_at(coords):
            if self.kill(iid):
                killed += 1
        return killed

    def seed_holding_estimate(self, seconds: float) -> None:
        """Seed the holding-time EWMA for rate-driven autoscalers.

        Rate-based fleet sizing needs requests-per-instance capacity before
        the first completions exist; callers that know a function's
        intrinsic service time (``WorkflowEngine.register``) seed it here.
        Only telemetry-backed deployments accept the seed — the legacy
        concurrency policy's cap-path queue model keeps its
        learn-from-observation-only behaviour bit-for-bit.
        """
        if self.telemetry is None or seconds <= 0.0:
            return
        if self._service_ewma == 0.0:
            self._service_ewma = seconds

    @property
    def n_instances(self) -> int:
        return len(self.instances)


class ControlPlane:
    """The activator/autoscaler pair for a set of deployments."""

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = ensure_clock(clock)
        self.deployments: Dict[str, Deployment] = {}

    def register(
        self,
        name: str,
        policy: Optional[ScalingPolicy] = None,
        placer: Optional[Callable[[int], Tuple[int, ...]]] = None,
    ) -> Deployment:
        dep = Deployment(name, policy or ScalingPolicy(), placer, self.clock)
        self.deployments[name] = dep
        return dep

    def steer(
        self, name: str, prefer: Optional[Tuple[int, ...]] = None
    ) -> Tuple[Instance, float]:
        return self.deployments[name].steer(prefer)

    def release(self, name: str, instance_id: int) -> None:
        self.deployments[name].release(instance_id)

    def node_coords(self) -> List[Tuple[int, ...]]:
        """Every node (placement coords) currently hosting a live instance,
        across all deployments, in deterministic order."""
        seen = set()
        for dep in self.deployments.values():
            for inst in dep.instances.values():
                if inst.alive and inst.coords is not None:
                    seen.add(inst.coords)
        return sorted(seen)

    def kill_node(self, coords: Tuple[int, ...]) -> int:
        """Correlated eviction across every deployment sharing ``coords``.

        Spot reclamation is a *machine* event: all instances co-resident on
        the node die together regardless of which deployment owns them.
        Returns the total number of instances killed.
        """
        coords = as_coord(coords)
        killed = 0
        for dep in self.deployments.values():
            killed += dep.kill_node(coords)
        return killed
