"""Workflow engine: chained serverless functions with XDT transfer edges.

A workflow is a DAG of named functions.  Each function is user logic with the
signature ``handler(ctx, payload) -> payload`` where ``ctx`` exposes the XDT
API (paper Table 1): ``ctx.invoke(fn, obj)``, ``ctx.put(obj, n) -> ref``,
``ctx.get(ref) -> obj``.  Placement is delegated to the control plane
(:mod:`repro.core.scheduler`), transfers to a :class:`TransferEngine`.

Semantics (paper §4.2.2):

* **At-most-once per invocation id** — the engine records executed ids and
  refuses replays (:class:`InvocationReplayed`).
* **Producer-death recovery** — if a consumer's ``get()`` raises
  ``XDTProducerGone``, the error propagates to the *orchestrator*, which
  re-invokes the producer sub-workflow with the same arguments under a fresh
  invocation id (at-least-once at workflow level, at-most-once per id).
* Retries are bounded (``max_retries``), after which the error surfaces to
  the caller — identical to Step Functions fallback behaviour.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .errors import XDTError, XDTProducerGone
from .refs import XDTRef
from .scheduler import ControlPlane, ScalingPolicy
from .transfer import TransferEngine


@dataclasses.dataclass
class InvocationRecord:
    invocation_id: int
    function: str
    instance_id: int
    attempt: int
    status: str  # "ok" | "error"
    error_code: Optional[str] = None


class Context:
    """Per-invocation SDK handle given to user handlers."""

    def __init__(self, engine: "WorkflowEngine", function: str, attempt: int):
        self._engine = engine
        self.function = function
        self.attempt = attempt

    # XDT API (paper Table 1)
    def invoke(self, fn_name: str, obj: Any) -> Any:
        return self._engine._invoke(fn_name, obj)

    def put(self, obj: Any, n_retrievals: int = 1) -> XDTRef:
        return self._engine.transfer.put(obj, n_retrievals)

    def get(self, ref: XDTRef) -> Any:
        return self._engine.transfer.get(ref)

    # collective conveniences built from the primitives (paper §7.1)
    def scatter(self, fn_name: str, objs: Sequence[Any]) -> List[Any]:
        return [self._engine._invoke(fn_name, o) for o in objs]

    def broadcast(self, fn_name: str, obj: Any, fan: int) -> List[Any]:
        ref = self.put(obj, n_retrievals=fan)
        return [self._engine._invoke(fn_name, ref) for _ in range(fan)]

    def gather(self, refs: Sequence[XDTRef]) -> List[Any]:
        return [self.get(r) for r in refs]


class WorkflowEngine:
    """Executes function DAGs with at-most-once invocation semantics."""

    def __init__(
        self,
        transfer: Optional[TransferEngine] = None,
        control_plane: Optional[ControlPlane] = None,
        max_retries: int = 2,
    ):
        self.transfer = transfer if transfer is not None else TransferEngine("xdt")
        self.control = control_plane if control_plane is not None else ControlPlane()
        self.functions: Dict[str, Callable[[Context, Any], Any]] = {}
        self.max_retries = max_retries
        self._invocation_ids = itertools.count(1)
        self._executed_ids: set = set()
        self.records: List[InvocationRecord] = []

    # -- registration ----------------------------------------------------------
    def register(
        self,
        name: str,
        handler: Callable[[Context, Any], Any],
        policy: Optional[ScalingPolicy] = None,
    ) -> None:
        self.functions[name] = handler
        self.control.register(name, policy or ScalingPolicy(max_instances=16))

    # -- execution ---------------------------------------------------------------
    def _invoke(self, fn_name: str, payload: Any) -> Any:
        """One control-plane-mediated invocation (no retry at this layer)."""
        if fn_name not in self.functions:
            raise KeyError(f"unknown function {fn_name!r}")
        invocation_id = next(self._invocation_ids)
        if invocation_id in self._executed_ids:  # pragma: no cover - invariant
            from .errors import InvocationReplayed

            raise InvocationReplayed(f"id {invocation_id} already executed")
        self._executed_ids.add(invocation_id)

        instance, _wait = self.control.steer(fn_name)
        ctx = Context(self, fn_name, attempt=0)
        try:
            result = self.functions[fn_name](ctx, payload)
            self.records.append(
                InvocationRecord(invocation_id, fn_name, instance.instance_id, 0, "ok")
            )
            return result
        except XDTError as e:
            self.records.append(
                InvocationRecord(
                    invocation_id, fn_name, instance.instance_id, 0, "error", e.code
                )
            )
            raise
        finally:
            self.control.release(fn_name, instance.instance_id)

    def run(self, entry: str, payload: Any) -> Any:
        """Orchestrator: run the workflow from ``entry``; on XDTProducerGone
        re-invoke the whole sub-workflow with the original arguments."""
        attempt = 0
        while True:
            try:
                return self._invoke(entry, payload)
            except XDTProducerGone:
                attempt += 1
                if attempt > self.max_retries:
                    raise
                # The producer instance is gone; its buffered objects died
                # with it.  Re-invoking from the entry function regenerates
                # them (paper §4.2.2: re-invoke the producer with the same
                # original arguments).
                continue

    # -- introspection -----------------------------------------------------------
    def executed_count(self, fn_name: Optional[str] = None) -> int:
        return sum(
            1 for r in self.records if fn_name is None or r.function == fn_name
        )

    def assert_at_most_once(self) -> None:
        """Invariant: no invocation id appears twice in the records."""
        ids = [r.invocation_id for r in self.records]
        assert len(ids) == len(set(ids)), "invocation id executed more than once"
